"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the CORE correctness signal for the compiled artifacts: the same
pallas_call code paths that aot.py lowers are executed here (interpret
mode) and compared bit-for-bit against ref.py across a hypothesis sweep of
shapes, dtypes-in-range, and adversarial bit patterns.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import digest, recovery, ref

# Hypothesis + XLA: keep deadlines off (first trace compiles).
SETTINGS = dict(deadline=None, max_examples=25)


def rand_u32(rng: np.random.Generator, shape) -> np.ndarray:
    return rng.integers(0, 2**32, size=shape, dtype=np.uint32)


# ---------------------------------------------------------------------------
# digest kernel
# ---------------------------------------------------------------------------


class TestDigestFixed:
    def test_zeros(self):
        d = jnp.zeros((2, 256), jnp.uint32)
        out = digest.digest(d)
        assert out.shape == (2, 2)
        assert (out == 0).all()

    def test_single_word(self):
        # d[0]=1 at position 0 of W=256: A=1, B=(W-0)=256.
        d = np.zeros((1, 256), np.uint32)
        d[0, 0] = 1
        out = np.asarray(digest.digest(jnp.asarray(d)))
        assert out[0, 0] == 1
        assert out[0, 1] == 256

    def test_last_word_weight_is_one(self):
        d = np.zeros((1, 256), np.uint32)
        d[0, 255] = 7
        out = np.asarray(digest.digest(jnp.asarray(d)))
        assert out[0, 0] == 7
        assert out[0, 1] == 7  # weight of last word is 1

    def test_wraparound(self):
        # All-ones rows force many mod-2^32 wraps in both sums.
        d = jnp.full((2, 4096), 0xFFFFFFFF, jnp.uint32)
        assert (digest.digest(d) == ref.digest_ref(d)).all()

    def test_rows_independent(self):
        rng = np.random.default_rng(1)
        d = rand_u32(rng, (4, 1024))
        full = np.asarray(digest.digest(jnp.asarray(d)))
        for i in range(4):
            row = np.asarray(digest.digest(jnp.asarray(d[i : i + 1])))
            assert (row[0] == full[i]).all()

    def test_aot_shape(self):
        # The exact (B, W) the AOT manifest exports.
        from compile import aot

        rng = np.random.default_rng(2)
        d = jnp.asarray(rand_u32(rng, (aot.B, aot.W)))
        assert (digest.digest(d) == ref.digest_ref(d)).all()

    def test_detects_any_single_word_change(self):
        rng = np.random.default_rng(3)
        d = rand_u32(rng, (1, 512))
        base = np.asarray(ref.digest_ref(jnp.asarray(d)))
        for pos in [0, 17, 256, 511]:
            d2 = d.copy()
            d2[0, pos] ^= 0x1
            out = np.asarray(digest.digest(jnp.asarray(d2)))
            assert (out[0] != base[0]).any(), f"flip at {pos} not detected"

    def test_detects_swap_of_equal_words(self):
        # A alone cannot distinguish permutations; B (position-weighted) must.
        d = np.zeros((1, 256), np.uint32)
        d[0, 3], d[0, 200] = 5, 9
        swapped = d.copy()
        swapped[0, 3], swapped[0, 200] = 9, 5
        a = np.asarray(digest.digest(jnp.asarray(d)))
        b = np.asarray(digest.digest(jnp.asarray(swapped)))
        assert a[0, 0] == b[0, 0]  # same multiset -> same A
        assert a[0, 1] != b[0, 1]  # different order -> different B


@given(
    b=st.integers(1, 5),
    logw=st.integers(0, 12),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_digest_matches_ref(b, logw, seed):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rand_u32(rng, (b, 2**logw)))
    assert (digest.digest(d) == ref.digest_ref(d)).all()


@given(
    w=st.sampled_from([96, 160, 1000, 1536, 24 * 1024]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_digest_non_pow2_widths(w, seed):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rand_u32(rng, (2, w)))
    assert (digest.digest(d) == ref.digest_ref(d)).all()


@given(seed=st.integers(0, 2**31 - 1), w_tile=st.sampled_from([64, 256, 1024]))
@settings(**SETTINGS)
def test_digest_tile_size_invariance(seed, w_tile):
    """The digest must not depend on the VMEM tiling chosen."""
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rand_u32(rng, (2, 4096)))
    assert (digest.digest(d, w_tile=w_tile) == ref.digest_ref(d)).all()


# ---------------------------------------------------------------------------
# recovery / popcount kernel
# ---------------------------------------------------------------------------


class TestPopcountFixed:
    def test_zeros_and_ones(self):
        z = jnp.zeros((3, 64), jnp.uint32)
        assert (recovery.popcount(z) == 0).all()
        o = jnp.full((3, 64), 0xFFFFFFFF, jnp.uint32)
        assert (recovery.popcount(o) == 64 * 32).all()

    def test_single_bits(self):
        bm = np.zeros((32, 4), np.uint32)
        for i in range(32):
            bm[i, i % 4] = np.uint32(1) << np.uint32(i)
        out = np.asarray(recovery.popcount(jnp.asarray(bm)))
        assert (out == 1).all()

    def test_aot_shape(self):
        from compile import aot

        rng = np.random.default_rng(4)
        bm = jnp.asarray(rand_u32(rng, (aot.F, aot.WB)))
        assert (recovery.popcount(bm) == ref.popcount_ref(bm)).all()


@given(
    f=st.integers(1, 9),
    w=st.sampled_from([1, 3, 16, 128, 1000]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_popcount_matches_ref(f, w, seed):
    rng = np.random.default_rng(seed)
    bm = jnp.asarray(rand_u32(rng, (f, w)))
    kernel = np.asarray(recovery.popcount(bm))
    oracle = np.asarray(ref.popcount_ref(bm))
    numpy_truth = np.unpackbits(
        np.asarray(bm).view(np.uint8), axis=1
    ).sum(axis=1, dtype=np.uint64)
    assert (kernel == oracle).all()
    assert (kernel.astype(np.uint64) == numpy_truth).all()


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_popcount_tile_invariance(seed):
    rng = np.random.default_rng(seed)
    bm = jnp.asarray(rand_u32(rng, (8, 256)))
    a = recovery.popcount(bm, f_tile=1, w_tile=64)
    b = recovery.popcount(bm, f_tile=8, w_tile=256)
    assert (np.asarray(a) == np.asarray(b)).all()
