"""L2 correctness: model graphs (verify_batch / recovery_summary) vs refs."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(deadline=None, max_examples=25)


def rand_u32(rng, shape):
    return rng.integers(0, 2**32, size=shape, dtype=np.uint32)


@given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 6))
@settings(**SETTINGS)
def test_verify_accepts_correct_digests(seed, b):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rand_u32(rng, (b, 512)))
    digests, ok = model.verify_batch(d, ref.digest_ref(d))
    assert (np.asarray(ok) == 1).all()
    assert (np.asarray(digests) == np.asarray(ref.digest_ref(d))).all()


@given(seed=st.integers(0, 2**31 - 1), row=st.integers(0, 3))
@settings(**SETTINGS)
def test_verify_rejects_corrupted_row(seed, row):
    rng = np.random.default_rng(seed)
    d = rand_u32(rng, (4, 256))
    expected = np.asarray(ref.digest_ref(jnp.asarray(d)))
    d[row, rng.integers(0, 256)] ^= np.uint32(1) << np.uint32(rng.integers(0, 32))
    _, ok = model.verify_batch(jnp.asarray(d), jnp.asarray(expected))
    ok = np.asarray(ok)
    assert ok[row] == 0
    mask = np.ones(4, bool)
    mask[row] = False
    assert (ok[mask] == 1).all()


def test_verify_checks_both_words():
    # A digest that matches on A but not B must be rejected.
    d = jnp.zeros((1, 64), jnp.uint32)
    true_dig = np.asarray(ref.digest_ref(d))  # zeros
    bad = true_dig.copy()
    bad[0, 1] = 123
    _, ok = model.verify_batch(d, jnp.asarray(bad))
    assert np.asarray(ok)[0] == 0


@given(seed=st.integers(0, 2**31 - 1), f=st.integers(1, 8))
@settings(**SETTINGS)
def test_recovery_summary_matches_ref(seed, f):
    rng = np.random.default_rng(seed)
    bm = jnp.asarray(rand_u32(rng, (f, 32)))
    totals = jnp.asarray(rng.integers(0, 32 * 32, size=(f,), dtype=np.uint32))
    c, p = model.recovery_summary(bm, totals)
    cr, pr = ref.recovery_summary_ref(bm, totals)
    assert (np.asarray(c) == np.asarray(cr)).all()
    assert (np.asarray(p) == np.asarray(pr)).all()
    # Invariant: completed + pending == total, completed <= total.
    assert (np.asarray(c) + np.asarray(p) == np.asarray(totals)).all()
    assert (np.asarray(c) <= np.asarray(totals)).all()


def test_recovery_clamps_junk_bits():
    # More bits set than total_blocks (torn write) must clamp, not underflow.
    bm = jnp.full((1, 4), 0xFFFFFFFF, jnp.uint32)  # 128 bits set
    totals = jnp.asarray([100], dtype=jnp.uint32)
    c, p = model.recovery_summary(bm, totals)
    assert int(c[0]) == 100
    assert int(p[0]) == 0
