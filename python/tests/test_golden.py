"""Cross-language golden vectors: ref.py (and therefore the Pallas
kernels) must reproduce tests/golden/digest_vectors.json exactly. The
rust side asserts the same file in rust/tests/golden_vectors.rs, closing
the python<->rust contract.
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax.numpy as jnp

from compile.kernels import digest, recovery, ref

GOLDEN = os.path.join(
    os.path.dirname(__file__), "..", "..", "tests", "golden", "digest_vectors.json"
)


def load():
    with open(GOLDEN) as f:
        return json.load(f)


def test_ref_matches_golden_digests():
    data = load()
    for i, case in enumerate(data["digest"]):
        words = jnp.asarray(np.array(case["words"], dtype=np.uint32)[None, :])
        out = np.asarray(ref.digest_ref(words))[0]
        assert int(out[0]) == case["a"], f"case {i}: A mismatch"
        assert int(out[1]) == case["b"], f"case {i}: B mismatch"


def test_pallas_kernel_matches_golden_digests():
    data = load()
    for i, case in enumerate(data["digest"]):
        words = jnp.asarray(np.array(case["words"], dtype=np.uint32)[None, :])
        out = np.asarray(digest.digest(words))[0]
        assert int(out[0]) == case["a"], f"case {i}: A mismatch (kernel)"
        assert int(out[1]) == case["b"], f"case {i}: B mismatch (kernel)"


def test_popcount_matches_golden():
    data = load()
    for i, case in enumerate(data["popcount"]):
        words = jnp.asarray(np.array(case["words"], dtype=np.uint32)[None, :])
        assert int(np.asarray(ref.popcount_ref(words))[0]) == case["popcount"], i
        assert int(np.asarray(recovery.popcount(words))[0]) == case["popcount"], i
