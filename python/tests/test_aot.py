"""AOT pipeline: lowered HLO text is parseable, entry shapes match manifest,
and the digest math survives the StableHLO -> XlaComputation conversion
(executed via jax on the *lowered* graphs, not the python functions).
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_smoke():
    lowered = jax.jit(lambda x: (x + jnp.uint32(1),)).lower(
        jax.ShapeDtypeStruct((4,), jnp.uint32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "u32[4]" in text


def test_lower_all_produces_three_entries():
    entries = aot.lower_all()
    assert set(entries) == {"digest", "verify", "recovery"}
    for name, (lowered, sig) in entries.items():
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text, name
        # Every declared input shape appears in the entry computation.
        for dtype, dims in sig["inputs"]:
            dims_s = ",".join(str(d) for d in dims)
            assert f"{dtype}[{dims_s}]" in text, (name, dtype, dims)


def test_compiled_digest_executes_like_ref():
    """Execute the jitted (same lowering path) digest at the AOT shape."""
    rng = np.random.default_rng(7)
    d = jnp.asarray(rng.integers(0, 2**32, size=(aot.B, aot.W), dtype=np.uint32))
    (out,) = jax.jit(model.digest_batch)(d)
    assert (np.asarray(out) == np.asarray(ref.digest_ref(d))).all()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestWrittenArtifacts:
    def test_manifest_consistent(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            m = json.load(f)
        assert m["object_words"] == aot.W
        assert m["object_bytes"] == aot.W * 4
        assert m["digest_batch"] == aot.B
        assert set(m["entries"]) == {"digest", "verify", "recovery"}
        for name, e in m["entries"].items():
            path = os.path.join(ARTIFACTS, e["file"])
            assert os.path.exists(path), path
            with open(path) as f:
                text = f.read()
            assert "HloModule" in text

    def test_artifact_text_matches_fresh_lowering_shapes(self):
        with open(os.path.join(ARTIFACTS, "digest.hlo.txt")) as f:
            text = f.read()
        assert f"u32[{aot.B},{aot.W}]" in text
        assert f"u32[{aot.B},2]" in text
