"""AOT entrypoint: lower the L2 graphs to HLO *text* artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client.  HLO text — NOT ``lowered.compile()`` / serialized protos —
because the image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit
instruction ids; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts:
    artifacts/digest.hlo.txt     digest_batch   (B, W) u32 -> ((B,2) u32,)
    artifacts/verify.hlo.txt     verify_batch   (B, W), (B,2) -> ((B,2), (B,))
    artifacts/recovery.hlo.txt   recovery_summary (F, WB), (F,) -> ((F,), (F,))
    artifacts/manifest.json      shapes + entry names for the rust runtime

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Static AOT shapes. The rust runtime pads partial batches to these.
#  - B:  objects per digest/verify batch (one RMA buffer's worth)
#  - W:  uint32 words per object  (65536 words = 256 KiB object / MTU)
#  - F:  files per recovery batch
#  - WB: uint32 bitmap words per file (4096 trackable objects per file)
B = 8
W = 64 * 1024
F = 64
WB = 128


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    u32 = jnp.uint32
    data = jax.ShapeDtypeStruct((B, W), u32)
    expected = jax.ShapeDtypeStruct((B, 2), u32)
    bitmaps = jax.ShapeDtypeStruct((F, WB), u32)
    totals = jax.ShapeDtypeStruct((F,), u32)

    return {
        "digest": (
            jax.jit(model.digest_batch).lower(data),
            {"inputs": [["u32", [B, W]]], "outputs": [["u32", [B, 2]]]},
        ),
        "verify": (
            jax.jit(model.verify_batch).lower(data, expected),
            {
                "inputs": [["u32", [B, W]], ["u32", [B, 2]]],
                "outputs": [["u32", [B, 2]], ["u32", [B]]],
            },
        ),
        "recovery": (
            jax.jit(model.recovery_summary).lower(bitmaps, totals),
            {
                "inputs": [["u32", [F, WB]], ["u32", [F]]],
                "outputs": [["u32", [F]], ["u32", [F]]],
            },
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "object_words": W,
        "object_bytes": W * 4,
        "digest_batch": B,
        "recovery_files": F,
        "bitmap_words": WB,
        "entries": {},
    }
    for name, (lowered, sig) in lower_all().items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {"file": f"{name}.hlo.txt", **sig}
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
