"""L2: the data-integrity compute graphs FT-LADS runs via PJRT.

Two exported computations, both calling the L1 Pallas kernels:

- ``verify_batch(data, expected)`` — sink-side integrity check.  Digests a
  batch of objects (Pallas ``digest`` kernel) and compares against the
  digests carried in the NEW_BLOCK messages.  Returns the recomputed
  digests and a per-object ok flag.  The rust sink runs this over each RMA
  buffer's worth of objects after ``pwrite`` and before emitting
  BLOCK_SYNC — a PFS write error can therefore never go unnoticed (the
  exact failure mode paper §3.2 attributes to stock LADS).

- ``recovery_summary(bitmaps, total_blocks)`` — source-side resume helper.
  Turns a batch of Bit8/Bit64 FT-log bitmaps into per-file completed and
  pending counts (Pallas ``popcount`` kernel).

Shapes are static (AOT); the manifest in artifacts/ records them and the
rust runtime pads the final partial batch.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import digest as digest_kernel
from .kernels import recovery as recovery_kernel


def verify_batch(data: jnp.ndarray, expected: jnp.ndarray):
    """Digest ``(B, W)`` u32 objects and compare with ``(B, 2)`` u32 expected.

    Returns ``(digests (B,2) u32, ok (B,) u32)`` where ``ok[i]`` is 1 iff
    both digest words match.
    """
    digests = digest_kernel.digest_cpu_fullblock(data)
    ok = jnp.all(digests == expected.astype(jnp.uint32), axis=1)
    return digests, ok.astype(jnp.uint32)


def digest_batch(data: jnp.ndarray):
    """Digest-only variant (source-side precompute): ``(B, W)`` → ``(B, 2)``."""
    return (digest_kernel.digest_cpu_fullblock(data),)


def recovery_summary(bitmaps: jnp.ndarray, total_blocks: jnp.ndarray):
    """Per-file completed/pending counts from ``(F, W)`` u32 log bitmaps.

    ``completed`` is clamped to ``total_blocks`` (torn-write safety — see
    ref.recovery_summary_ref).  Returns ``(completed, pending)``, both
    ``(F,)`` uint32.
    """
    f, w = bitmaps.shape
    counts = recovery_kernel.popcount(bitmaps, f_tile=f, w_tile=w)
    total = total_blocks.astype(jnp.uint32)
    completed = jnp.minimum(counts, total)
    pending = total - completed
    return completed, pending
