"""L1 Pallas kernel: blocked dual-sum object digest.

The digest of one object (a row of ``W`` uint32 words) is

    A = sum_i d[i]              (mod 2**32)
    B = sum_i (W - i) * d[i]    (mod 2**32)

Both are reductions, so the kernel tiles the ``W`` axis into ``W_TILE``-wide
VMEM blocks and accumulates the two partial sums across the column grid
dimension.  The weight vector for column tile ``j`` is reconstructed in-kernel
from ``iota`` (``W - (j*W_TILE + i)``), so the only HBM traffic is the data
itself — one stream per object row, exactly the HBM→VMEM schedule a TPU
would want (DESIGN.md §Hardware-Adaptation).

interpret=True everywhere: CPU PJRT cannot execute Mosaic custom-calls, and
the correctness contract is against ``ref.digest_ref`` (also mirrored bit-
for-bit by rust ``integrity::native``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. W_TILE * 4 bytes = 64 KiB per data tile: small enough
# that (tile + weights + accumulators) fits VMEM with double buffering.
# These are the TPU-shaped defaults; the AOT CPU artifact uses full-batch
# tiles (one grid step) because interpret-mode lowering pays a
# while-loop + dynamic-slice tax per grid step (see EXPERIMENTS.md §Perf:
# 25.4 ms -> 1.2 ms for the (8, 64Ki) batch).
B_TILE = 1
W_TILE = 16 * 1024


def _digest_kernel(x_ref, o_ref, *, w_total: int, w_tile: int, b_tile: int):
    """Grid step (b, j): reduce one (b_tile, w_tile) block of objects."""
    j = pl.program_id(1)

    x = x_ref[...].astype(jnp.uint32)  # (b_tile, w_tile)

    # Reconstruct this tile's weights: W - (j*w_tile + i) for local i.
    base = jnp.uint32(w_total) - jnp.uint32(j * w_tile).astype(jnp.uint32)
    local = jax.lax.broadcasted_iota(jnp.uint32, (b_tile, w_tile), 1)
    weights = base - local  # wrapping uint32; exact because j*w_tile < W

    part_a = jnp.sum(x, axis=1, dtype=jnp.uint32)  # (b_tile,)
    part_b = jnp.sum(x * weights, axis=1, dtype=jnp.uint32)  # (b_tile,)
    part = jnp.stack([part_a, part_b], axis=1)  # (b_tile, 2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def digest(
    data: jnp.ndarray, *, w_tile: int = W_TILE, b_tile: int = B_TILE
) -> jnp.ndarray:
    """Digest a ``(B, W)`` uint32 batch → ``(B, 2)`` uint32 ``[A, B]`` rows."""
    b, w = data.shape
    if w % w_tile != 0:
        # Fall back to a tile that divides W (AOT never hits this; tests do).
        w_tile = _largest_divisor_tile(w, w_tile)
    b_tile = min(b_tile, b)
    if b % b_tile != 0:
        b_tile = _largest_divisor_tile(b, b_tile)
    grid = (b // b_tile, w // w_tile)
    kernel = functools.partial(
        _digest_kernel, w_total=w, w_tile=w_tile, b_tile=b_tile
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((b_tile, w_tile), lambda i, j: (i, j))],
        # The output block for row-tile i is revisited for every j: Pallas
        # keeps it resident in VMEM across the inner grid dimension, so the
        # accumulation never round-trips to HBM.
        out_specs=pl.BlockSpec((b_tile, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 2), jnp.uint32),
        interpret=True,
    )(data)


def digest_cpu_fullblock(data: jnp.ndarray) -> jnp.ndarray:
    """The AOT-CPU variant: a single grid step covering the whole batch.

    interpret-mode lowering emits an HLO while-loop with dynamic slices per
    grid step; on CPU-PJRT that costs ~mllisecond-scale overhead per step
    (EXPERIMENTS.md §Perf). One full-batch block lowers to straight-line
    fused HLO. On a real TPU the tiled `digest` with the (B_TILE, W_TILE)
    VMEM blocks is the right shape; both compute identical results (tested).
    """
    b, w = data.shape
    return digest(data, w_tile=w, b_tile=b)


def _largest_divisor_tile(w: int, cap: int) -> int:
    for t in range(min(cap, w), 0, -1):
        if w % t == 0:
            return t
    return 1
