"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must be array_equal against the function of the same name here, for all
shapes and dtypes the AOT manifest exports. The rust `integrity::native`
module implements bit-identical versions of the same math (wrapping u32), so
ref.py is also the cross-language contract.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["digest_ref", "popcount_ref", "recovery_summary_ref"]


def digest_ref(data: jnp.ndarray) -> jnp.ndarray:
    """Order-independent-combinable dual-sum digest of a batch of objects.

    For each row ``d`` of ``data`` (shape ``(B, W)``, dtype uint32) compute

        A = sum_i d[i]                 (mod 2**32)
        B = sum_i (W - i) * d[i]       (mod 2**32)

    and return ``(B, 2)`` uint32 ``[A, B]`` per row.  This is the blocked
    Adler-like digest from DESIGN.md: both sums are plain reductions, so
    the Pallas kernel can tile the W axis and accumulate per grid step.
    """
    data = data.astype(jnp.uint32)
    _, w = data.shape
    idx = jnp.arange(w, dtype=jnp.uint32)
    weights = jnp.uint32(w) - idx  # W, W-1, ..., 1
    a = jnp.sum(data, axis=1, dtype=jnp.uint32)
    bsum = jnp.sum(data * weights[None, :], axis=1, dtype=jnp.uint32)
    return jnp.stack([a, bsum], axis=1)


def popcount_ref(bitmaps: jnp.ndarray) -> jnp.ndarray:
    """Per-row population count of uint32 bitmap words.

    ``bitmaps`` has shape ``(F, W)`` uint32; returns ``(F,)`` uint32 — the
    number of set bits per row, i.e. the number of completed objects recorded
    in a Bit8/Bit64 FT log bitmap (Algorithm 1 in the paper).
    """
    x = bitmaps.astype(jnp.uint32)
    # SWAR popcount, identical to the kernel's math.
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x * jnp.uint32(0x01010101)) >> 24
    return jnp.sum(x, axis=1, dtype=jnp.uint32)


def recovery_summary_ref(bitmaps: jnp.ndarray, total_blocks: jnp.ndarray):
    """Completed / pending object counts per file from FT log bitmaps.

    ``total_blocks`` is ``(F,)`` uint32 (number of objects of each file).
    Returns ``(completed, pending)`` both ``(F,)`` uint32.  ``completed`` is
    clamped to ``total_blocks`` so junk bits beyond a file's last object
    (possible after a torn bitmap write) can never produce a negative
    pending count.
    """
    completed = jnp.minimum(popcount_ref(bitmaps), total_blocks.astype(jnp.uint32))
    pending = total_blocks.astype(jnp.uint32) - completed
    return completed, pending
