"""L1 Pallas kernels + pure-jnp reference oracles."""

from . import digest, recovery, ref  # noqa: F401
