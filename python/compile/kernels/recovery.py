"""L1 Pallas kernel: FT-log bitmap popcount (recovery-set summary).

The Bit8/Bit64 FT logging methods (paper §4.2, Algorithm 1) record one bit
per completed object.  On resume, the source must turn each file's bitmap
into a completed-object count (and, with the total block count, a pending
count).  This kernel computes the per-row popcount of a ``(F, W)`` uint32
bitmap batch with the SWAR reduction, tiled over both axes.

interpret=True: see digest.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F_TILE = 8
W_TILE = 1024


def _popcount_kernel(x_ref, o_ref):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x * jnp.uint32(0x01010101)) >> 24
    part = jnp.sum(x, axis=1, dtype=jnp.uint32)  # (F_TILE,)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def popcount(bitmaps: jnp.ndarray, *, f_tile: int = F_TILE, w_tile: int = W_TILE) -> jnp.ndarray:
    """Per-row popcount of a ``(F, W)`` uint32 batch → ``(F,)`` uint32."""
    f, w = bitmaps.shape
    f_tile = min(f_tile, f)
    if f % f_tile != 0:
        f_tile = _largest_divisor_tile(f, f_tile)
    if w % w_tile != 0:
        w_tile = _largest_divisor_tile(w, w_tile)
    grid = (f // f_tile, w // w_tile)
    return pl.pallas_call(
        functools.partial(_popcount_kernel),
        grid=grid,
        in_specs=[pl.BlockSpec((f_tile, w_tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((f_tile,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((f,), jnp.uint32),
        interpret=True,
    )(bitmaps)


def _largest_divisor_tile(n: int, cap: int) -> int:
    for t in range(min(cap, n), 0, -1):
        if n % t == 0:
            return t
    return 1
