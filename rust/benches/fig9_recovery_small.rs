//! Figure 9: recovery time of the **File logger** at fault points
//! 20/40/60/80 %, **small workload** (file == one MTU).
//!
//! Expected shape (paper §6.4.2): with 1-object files, a file is either
//! fully transferred or not — there are no partially-logged files to
//! parse, so FT recovery is flat/small across fault points. bbcp's
//! *relative* overhead is lower (5–7 % vs FT's 12–14 %) but bbcp's
//! absolute transfer time on many small files is much higher than LADS.
//!
//! Run: `cargo bench --bench fig9_recovery_small`

use ftlads::bench_support::{
    measure_recovery_bbcp, measure_recovery_ftlads, print_table, BenchScale, Case,
};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{Mechanism, Method};
use ftlads::stats::Series;

fn main() {
    let scale = BenchScale::from_env();
    let wl = scale.small();
    println!(
        "Figure 9 — recovery time (s), small workload: {} files x {}",
        wl.file_count(),
        ftlads::util::fmt_bytes(scale.small_file_size)
    );

    let points = FaultPlan::paper_points();
    let mut rows = Vec::new();
    let mut rel_rows = Vec::new();

    let iters = scale.iterations.max(3);
    let avg_ft = |case: Case, p: f64, tag: &str| -> (f64, f64) {
        let mut er = Series::new();
        let mut tt = Series::new();
        for i in 0..iters {
            let r = measure_recovery_ftlads(&scale, &wl, case, p, &format!("{tag}-{i}"));
            er.push(r.estimated_recovery().as_secs_f64());
            tt.push(r.tt.as_secs_f64());
        }
        (er.summary().mean, tt.summary().mean)
    };

    let mut row = vec!["LADS (restart)".to_string()];
    for &p in &points {
        let (er, _) = avg_ft(Case::Lads, p, "fig9-lads");
        row.push(format!("{er:.3}"));
    }
    rows.push(row);

    let mut row = vec!["bbcp".to_string()];
    let mut rel = vec!["bbcp".to_string()];
    for &p in &points {
        let mut er = Series::new();
        let mut tt = Series::new();
        for i in 0..iters {
            let r = measure_recovery_bbcp(&scale, &wl, p, &format!("fig9-bbcp-{i}"));
            er.push(r.estimated_recovery().as_secs_f64());
            tt.push(r.tt.as_secs_f64());
        }
        let (er, tt) = (er.summary().mean, tt.summary().mean);
        row.push(format!("{er:.3}"));
        rel.push(format!("{:.1}%", er / tt.max(1e-9) * 100.0));
    }
    rows.push(row);
    rel_rows.push(rel);

    for m in Method::ALL {
        let mut row = vec![format!("file/{}", m.as_str())];
        let mut rel = vec![format!("file/{}", m.as_str())];
        for &p in &points {
            let (er, tt) = avg_ft(
                Case::Ft(Mechanism::File, m),
                p,
                &format!("fig9-{}", m.as_str()),
            );
            row.push(format!("{er:.3}"));
            rel.push(format!("{:.1}%", er / tt.max(1e-9) * 100.0));
        }
        rows.push(row);
        rel_rows.push(rel);
    }

    print_table(
        "Fig 9: ER_t (s) at fault points, small workload",
        &["case", "20%", "40%", "60%", "80%"],
        &rows,
    );
    print_table(
        "Fig 9 (relative): ER_t / TT — the paper's §6.4.2 percentage comparison",
        &["case", "20%", "40%", "60%", "80%"],
        &rel_rows,
    );
    println!(
        "\nexpected shape: FT rows flat across fault points (file == MTU ⇒ no log \
         parse); LADS-restart grows with fault point"
    );
}
