//! Ablation benches for the design choices DESIGN.md calls out (no
//! direct paper figure — these validate claims made in the paper's text):
//!
//!   A1 txn-size endpoints (§6.1): "if the transaction size is set to 1,
//!      the transaction logger is same as the File logger … if set to
//!      maximum, same as the Universal logger" — compare space + recovery.
//!   A2 sync vs async logging (§5.1): "found no difference between the
//!      two methods".
//!   A3 IO-thread scaling (§6.1 / LADS): transfer time vs IO threads.
//!   A4 RMA pool size: sink back-pressure stalls vs pool slots.
//!   A5 layout-aware scheduling value: transfer time with a congested
//!      OST, LADS scheduler vs sequential baseline (§2.1 motivation).
//!   A6 scheduler-policy axis: every built-in `sched` policy (congestion,
//!      round_robin, fifo_file, straggler) on the same congested-OST
//!      workload — one invocation compares all four (§2.1 / Tavakoli et
//!      al. 2018).
//!   A7 ack-batch axis: `ack_batch` ∈ {1, 2, 8, 32} on the big workload —
//!      wire BLOCK_SYNC messages and group-committed logger writes per
//!      batch size, plus a fault+resume at every size to show recovery
//!      stays paper-correct (a fault mid-window retransmits at most the
//!      un-flushed acks, which block re-write tolerates).
//!   A8 send-window axis: `send_window` ∈ {1, 2, 8, 32} (credit-based
//!      NEW_BLOCK pipelining) on a wire-bound workload — issue-loop slot
//!      stalls, credit waits and transfer time per window, an adaptive-
//!      ack row, and a fault+resume at the widest window to show the
//!      log-based retransmit bound holds with a full window in flight.
//!
//! Run: `cargo bench --bench ablation` (set `FTLADS_BENCH_JSON_DIR` to
//! also emit the tables as a JSON summary — the CI artifact).

use ftlads::bench_support::{print_table, run_sched_case, BenchScale, Case, CONGESTED_OSTS};
use ftlads::sched::SchedPolicy;
use ftlads::config::Config;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{LoggingMode, Mechanism, Method};
use ftlads::net::Side;
use ftlads::pfs::ost::OstId;
use ftlads::pfs::Pfs;
use ftlads::util::fmt_bytes;
use ftlads::workload;

fn main() {
    let scale = BenchScale::from_env();
    a1_txn_size_endpoints(&scale);
    a2_sync_vs_async(&scale);
    a3_io_thread_scaling(&scale);
    a4_rma_pool(&scale);
    a5_layout_aware_value(&scale);
    a6_scheduler_policies(&scale);
    a7_ack_batch(&scale);
    a8_send_window(&scale);
    let _ = ftlads::bench_support::write_json_summary("ablation");
}

/// A1: txn_size=1 ≈ file logger; txn_size=max ≈ universal logger.
fn a1_txn_size_endpoints(scale: &BenchScale) {
    let wl = scale.big();
    let frac = 0.6;
    let mut rows = Vec::new();
    let cases: Vec<(String, Mechanism, usize)> = vec![
        ("file".into(), Mechanism::File, 4),
        ("txn(size=1)".into(), Mechanism::Transaction, 1),
        ("txn(size=4)".into(), Mechanism::Transaction, 4),
        (
            format!("txn(size={})", wl.file_count()),
            Mechanism::Transaction,
            wl.file_count(),
        ),
        ("universal".into(), Mechanism::Universal, 4),
    ];
    for (label, mech, txn) in cases {
        let mut cfg = scale.base_config(&format!("a1-{label}"));
        cfg.mechanism = mech;
        cfg.method = Method::Bit64;
        cfg.txn_size = txn;
        let env = SimEnv::new(cfg, &wl);
        let out = env
            .run(
                &TransferSpec::fresh(env.files.clone())
                    .with_fault(FaultPlan::at_fraction(frac, Side::Source)),
            )
            .unwrap();
        assert!(!out.completed);
        let t0 = std::time::Instant::now();
        let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
        assert!(out2.completed, "{:?}", out2.fault);
        env.verify_sink_complete().unwrap();
        rows.push(vec![
            label,
            fmt_bytes(out.log_space.peak_bytes),
            format!("{}", out.log_space.appends),
            format!("{:.3}", t0.elapsed().as_secs_f64()),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    print_table(
        "A1: transaction-size endpoints (fault at 60%, bit64)",
        &["logger", "peak log bytes", "appends", "resume (s)"],
        &rows,
    );
    println!("claim (§6.1): txn(1) ≈ file granularity, txn(max) ≈ universal");
}

/// A2: sync vs async logging overhead.
fn a2_sync_vs_async(scale: &BenchScale) {
    let wl = scale.big();
    let mut rows = Vec::new();
    for (label, mode) in [("sync", LoggingMode::Sync), ("async", LoggingMode::Async)] {
        let mut times = ftlads::stats::Series::new();
        for i in 0..scale.iterations.max(3) {
            let mut cfg = scale.base_config(&format!("a2-{label}-{i}"));
            cfg.mechanism = Mechanism::Universal;
            cfg.method = Method::Bit64;
            cfg.logging = mode;
            let env = SimEnv::new(cfg, &wl);
            let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
            assert!(out.completed, "{:?}", out.fault);
            env.verify_sink_complete().unwrap();
            times.push(out.elapsed.as_secs_f64());
            let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        }
        let s = times.summary();
        rows.push(vec![label.to_string(), format!("{:.3}±{:.3}", s.mean, s.ci99)]);
    }
    print_table("A2: sync vs async logging (universal/bit64)", &["mode", "time (s)"], &rows);
    println!("claim (§5.1): no difference between the two methods");
}

/// A3: IO-thread scaling.
fn a3_io_thread_scaling(scale: &BenchScale) {
    let wl = scale.big();
    let mut rows = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let mut cfg = scale.base_config(&format!("a3-{threads}"));
        cfg.io_threads = threads;
        cfg.mechanism = Mechanism::Universal;
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed);
        rows.push(vec![
            format!("{threads}"),
            format!("{:.3}", out.elapsed.as_secs_f64()),
            format!("{:.1}", out.throughput_bytes_per_sec() / 1e6),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    print_table(
        "A3: IO-thread scaling (big workload)",
        &["io threads", "time (s)", "MB/s"],
        &rows,
    );
    println!("claim (LADS/§6.1): transfer performance scales with IO threads until storage-bound");
}

/// A4: RMA pool size vs sink stalls.
fn a4_rma_pool(scale: &BenchScale) {
    let wl = scale.big();
    let mut rows = Vec::new();
    for slots in [2usize, 4, 16, 64] {
        let mut cfg = scale.base_config(&format!("a4-{slots}"));
        cfg.rma_bytes = slots * cfg.object_size as usize;
        cfg.mechanism = Mechanism::Universal;
        cfg.time_scale = scale.time_scale.max(0.5);
        let env = SimEnv::new(cfg, &wl);
        // Slow sink: every sink OST 4x loaded, so writes lag reads and the
        // RMA pool is the back-pressure valve.
        for ost in 0..env.cfg.ost_count {
            Pfs::ost_model(&*env.sink).set_external_load(OstId(ost), 4.0);
        }
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed);
        rows.push(vec![
            format!("{slots}"),
            format!("{:.3}", out.elapsed.as_secs_f64()),
            format!("{}", out.rma_stalls_snk.0),
            format!("{:.1}", out.rma_stalls_snk.1 as f64 / 1e6),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    print_table(
        "A4: RMA pool size (slots) vs sink back-pressure",
        &["slots", "time (s)", "stalls", "stall ms"],
        &rows,
    );
}

/// A5: value of layout/congestion-aware scheduling under OST load.
fn a5_layout_aware_value(scale: &BenchScale) {
    use ftlads::baseline::bbcp::{run_bbcp, BbcpConfig};
    let wl = workload::big_workload(22, 4 * scale.small_file_size);
    let mut rows = Vec::new();
    for load in [1.0f64, 4.0, 8.0] {
        // FT-LADS
        let mut cfg = scale.base_config(&format!("a5-l-{load}"));
        cfg.time_scale = scale.time_scale.max(0.5); // needs real service times
        cfg.mechanism = Mechanism::Universal;
        let env = SimEnv::new(cfg, &wl);
        for ost in [1u32, 4, 7] {
            Pfs::ost_model(&*env.source).set_external_load(OstId(ost), load);
        }
        let lads = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(lads.completed);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);

        // bbcp
        let mut cfg2: Config = scale.base_config(&format!("a5-b-{load}"));
        cfg2.time_scale = scale.time_scale.max(0.5);
        let env2 = SimEnv::new(cfg2, &wl);
        for ost in [1u32, 4, 7] {
            Pfs::ost_model(&*env2.source).set_external_load(OstId(ost), load);
        }
        let bcfg = BbcpConfig::paper_defaults(&env2.cfg);
        let bbcp = run_bbcp(
            &env2.cfg,
            &bcfg,
            env2.source.clone(),
            env2.sink.clone(),
            &env2.files,
            FaultPlan::none(),
        )
        .unwrap();
        assert!(bbcp.completed);
        let _ = std::fs::remove_dir_all(&env2.cfg.ft_dir);

        rows.push(vec![
            format!("{load}x"),
            format!("{:.3}", lads.elapsed.as_secs_f64()),
            format!("{:.3}", bbcp.elapsed.as_secs_f64()),
            format!(
                "{:.2}x",
                bbcp.elapsed.as_secs_f64() / lads.elapsed.as_secs_f64()
            ),
        ]);
    }
    print_table(
        "A5: congestion on OSTs {1,4,7} — FT-LADS vs bbcp",
        &["ext load", "ftlads (s)", "bbcp (s)", "speedup"],
        &rows,
    );
    println!("claim (§2.1): layout-aware scheduling routes around congested OSTs");
    let _ = Case::Lads; // (see fig5 for the LADS-vs-FT comparison)
}

/// A6: the scheduler-policy axis — all four built-in policies on one
/// congested-OST workload, one invocation.
fn a6_scheduler_policies(scale: &BenchScale) {
    let wl = workload::big_workload(22, 4 * scale.small_file_size);
    let load = 4.0;
    let mut rows = Vec::new();
    for policy in SchedPolicy::ALL {
        let out = run_sched_case(
            scale,
            &wl,
            policy,
            load,
            &format!("a6-{}", policy.as_str()),
        );
        rows.push(vec![
            policy.as_str().to_string(),
            format!("{:.3}", out.elapsed.as_secs_f64()),
            format!("{:.1}", out.throughput_bytes_per_sec() / 1e6),
            format!("{}", out.rma_stalls_snk.0),
        ]);
    }
    print_table(
        &format!(
            "A6: scheduler policy under {load}x load on OSTs {:?}",
            CONGESTED_OSTS
        ),
        &["policy", "time (s)", "MB/s", "sink stalls"],
        &rows,
    );
    println!("claim (§2.1): congestion-aware dequeue beats order-preserving policies under load");
}

/// A7: the ack-batch axis — per-object vs coalesced BLOCK_SYNC acks and
/// group-committed FT logging, with fault/resume correctness at every
/// batch size.
fn a7_ack_batch(scale: &BenchScale) {
    let wl = scale.big();
    let total = wl.total_objects(scale.small_file_size);
    let mut rows = Vec::new();
    for batch in [1u32, 2, 8, 32] {
        // Clean run: the steady-state message/write counts.
        let mut cfg = scale.base_config(&format!("a7-{batch}"));
        cfg.mechanism = Mechanism::Universal;
        cfg.method = Method::Bit64;
        cfg.ack_batch = batch;
        cfg.ack_flush_us = 20_000;
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed, "a7 batch={batch}: {:?}", out.fault);
        env.verify_sink_complete().unwrap();
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);

        // Fault at 50% + resume: batched acks must stay recoverable.
        let mut cfg2 = scale.base_config(&format!("a7f-{batch}"));
        cfg2.mechanism = Mechanism::Universal;
        cfg2.method = Method::Bit64;
        cfg2.ack_batch = batch;
        cfg2.ack_flush_us = 20_000;
        let env2 = SimEnv::new(cfg2, &wl);
        let faulted = env2
            .run(
                &TransferSpec::fresh(env2.files.clone())
                    .with_fault(FaultPlan::at_fraction(0.5, Side::Source)),
            )
            .unwrap();
        assert!(!faulted.completed, "a7 batch={batch}: fault did not fire");
        let logged: u64 = ftlads::ftlog::recover::recover_all(&env2.cfg.ft())
            .unwrap()
            .values()
            .map(|s| s.count() as u64)
            .sum();
        let resumed = env2.run(&TransferSpec::resuming(env2.files.clone())).unwrap();
        assert!(resumed.completed, "a7 batch={batch}: {:?}", resumed.fault);
        env2.verify_sink_complete().unwrap();
        // Every group-committed object is skipped on resume; only the
        // un-acked tail (at most the in-flight flush windows) re-sends.
        assert!(
            resumed.source.objects_sent <= total - logged,
            "a7 batch={batch}: resume re-sent logged objects"
        );
        let _ = std::fs::remove_dir_all(&env2.cfg.ft_dir);

        rows.push(vec![
            format!("{batch}"),
            format!("{}", out.sink.ack_messages),
            format!("{}", out.source.log_writes),
            format!("{}", resumed.source.objects_sent),
            format!("{:.3}", out.elapsed.as_secs_f64()),
        ]);
    }
    print_table(
        &format!("A7: ack batch size ({total} objects, universal/bit64)"),
        &["ack_batch", "wire acks", "log writes", "resent@resume", "time (s)"],
        &rows,
    );
    println!("claim: batching amortizes the per-object ack/log fixed cost; batch=1 == paper");
}

/// A8: the send-window axis — credit-based NEW_BLOCK pipelining on a
/// wire-bound workload (slow modeled link, free storage, 2 RMA slots, so
/// the lockstep path pins its slots across the wire serialization), plus
/// one adaptive-ack row and a fault+resume at the widest window.
fn a8_send_window(scale: &BenchScale) {
    let wl = scale.big();
    let total = wl.total_objects(scale.small_file_size);
    let wire_bound = |tag: &str| {
        let mut cfg = scale.base_config(tag);
        cfg.mechanism = Mechanism::Universal;
        cfg.method = Method::Bit64;
        cfg.ack_batch = 8;
        // Tight flush bound: at quick scale the per-file batches never
        // fill on count, and a wide window must not serialize behind
        // lazy ack flushes.
        cfg.ack_flush_us = 2_000;
        cfg.io_threads = 4;
        cfg.rma_bytes = 2 * cfg.object_size as usize;
        cfg.time_scale = 1.0;
        cfg.net_bandwidth = 4.0e8;
        cfg.net_latency_us = 5;
        cfg.ost_bandwidth = f64::INFINITY;
        cfg.ost_latency_us = 0;
        cfg
    };
    let mut rows = Vec::new();
    for window in [1u32, 2, 8, 32] {
        let mut cfg = wire_bound(&format!("a8-{window}"));
        cfg.send_window = window;
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed, "a8 window={window}: {:?}", out.fault);
        env.verify_sink_complete().unwrap();
        rows.push(vec![
            format!("{window}"),
            format!("{}", out.source.send_stalls),
            format!("{}", out.source.credit_waits),
            format!("{}", out.ack_batch_effective),
            format!("{:.3}", out.elapsed.as_secs_f64()),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }

    // Adaptive-ack row at the widest window: the effective batch is
    // earned from flush feedback instead of pinned to the cap.
    let mut cfg = wire_bound("a8-adaptive");
    cfg.send_window = 32;
    cfg.ack_adaptive = true;
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "a8 adaptive: {:?}", out.fault);
    env.verify_sink_complete().unwrap();
    rows.push(vec![
        "32+adaptive".into(),
        format!("{}", out.source.send_stalls),
        format!("{}", out.source.credit_waits),
        format!("{}", out.ack_batch_effective),
        format!("{:.3}", out.elapsed.as_secs_f64()),
    ]);
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);

    // Autotuned-window row: the applied window floats in 1..=32 from
    // stall/credit-wait feedback instead of pinning to the cap — on this
    // 2-slot pool the zero-copy pinned buffers should drag it well below
    // the negotiated 32.
    let mut cfg = wire_bound("a8-awin");
    cfg.send_window = 32;
    cfg.send_window_adaptive = true;
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "a8 adaptive window: {:?}", out.fault);
    env.verify_sink_complete().unwrap();
    rows.push(vec![
        format!("32+adaptive-window (eff {})", out.send_window_effective),
        format!("{}", out.source.send_stalls),
        format!("{}", out.source.credit_waits),
        format!("{}", out.ack_batch_effective),
        format!("{:.3}", out.elapsed.as_secs_f64()),
    ]);
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);

    // Fault at 50% with a full 32-wide window in flight, then resume:
    // the log-based retransmit bound must hold.
    let mut cfg = wire_bound("a8f-32");
    cfg.send_window = 32;
    let env = SimEnv::new(cfg, &wl);
    let faulted = env
        .run(
            &TransferSpec::fresh(env.files.clone())
                .with_fault(FaultPlan::at_fraction(0.5, Side::Source)),
        )
        .unwrap();
    assert!(!faulted.completed, "a8 fault did not fire");
    let logged: u64 = ftlads::ftlog::recover::recover_all(&env.cfg.ft())
        .unwrap()
        .values()
        .map(|s| s.count() as u64)
        .sum();
    let resumed = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
    assert!(resumed.completed, "a8 resume: {:?}", resumed.fault);
    env.verify_sink_complete().unwrap();
    assert!(
        resumed.source.objects_sent <= total - logged,
        "a8: resume re-sent logged objects with a full window in flight"
    );
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);

    print_table(
        &format!("A8: send window ({total} objects, wire-bound, 2 RMA slots, ack_batch 8)"),
        &["send_window", "slot stalls", "credit waits", "eff ack batch", "time (s)"],
        &rows,
    );
    println!(
        "claim: windowed issue unpins RMA slots from the wire and removes \
         the send side's per-object stall; window=1 == PR 2 lockstep"
    );
}
