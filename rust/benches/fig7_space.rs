//! Figure 7: FT logger methods space overhead.
//!
//! Peak bytes occupied by logger files (logs + index) during a transfer,
//! for every mechanism × method, on both workloads. Expected shape
//! (paper §6.3): Bit8/Bit64 smallest (1 bit/object); all methods only
//! KB-scale (~60 KB at paper scale); Universal ≤ Transaction ≤ File in
//! structural overhead for the same in-flight set.
//!
//! Run: `cargo bench --bench fig7_space`

use ftlads::bench_support::{print_table, run_case, BenchScale, Case};
use ftlads::ftlog::{Mechanism, Method};
use ftlads::util::fmt_bytes;

fn main() {
    let scale = BenchScale::from_env();
    println!(
        "Figure 7 — logger space overhead (peak bytes on disk during transfer)"
    );

    for (wl_name, wl) in [("big", scale.big()), ("small", scale.small())] {
        let mut rows = Vec::new();
        let mut alloc_rows = Vec::new();
        for mech in Mechanism::ALL_FT {
            let mut row = vec![mech.as_str().to_string()];
            let mut arow = vec![mech.as_str().to_string()];
            for m in Method::ALL {
                let out = run_case(
                    &scale,
                    &wl,
                    Case::Ft(mech, m),
                    &format!("fig7-{wl_name}-{}-{}", mech.as_str(), m.as_str()),
                );
                row.push(fmt_bytes(out.log_space.peak_bytes));
                arow.push(fmt_bytes(out.log_space.peak_alloc_bytes));
            }
            rows.push(row);
            alloc_rows.push(arow);
        }
        print_table(
            &format!(
                "Fig 7 ({wl_name} workload: {} files): peak logger bytes (apparent)",
                wl.file_count()
            ),
            &["mechanism", "char", "int", "enc", "binary", "bit8", "bit64"],
            &rows,
        );
        print_table(
            &format!(
                "Fig 7 ({wl_name}): peak ALLOCATED bytes (4 KiB fs blocks — the                  paper's du-style measure; universal lowest)",
            ),
            &["mechanism", "char", "int", "enc", "binary", "bit8", "bit64"],
            &alloc_rows,
        );
    }
    println!(
        "\nexpected shape: bit8/bit64 columns smallest; every cell KB-scale; \
         universal row lowest structural overhead"
    );
}
