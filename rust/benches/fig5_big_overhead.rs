//! Figure 5: performance comparison of LADS and FT-LADS, **big workload**
//! (paper: 100 × 1 GB; scaled per BenchScale).
//!
//! Three panels: (a) total transfer time, (b) CPU load, (c) memory load —
//! for each FT mechanism × method, with stock LADS as the reference line.
//! Expected shape (paper §6.2): FT overhead on transfer time < 1 %; CPU
//! comparable; memory: File ≈ LADS < Transaction ≈ Universal (in-memory
//! sorted completed-sets).
//!
//! Run: `cargo bench --bench fig5_big_overhead`
//! (set FTLADS_BENCH_SCALE=quick|default|paper).

use ftlads::bench_support::{print_table, run_case, BenchScale, Case};
use ftlads::stats::Series;

fn main() {
    let scale = BenchScale::from_env();
    let wl = scale.big();
    println!(
        "Figure 5 — big workload: {} files x {}, {} iterations",
        wl.file_count(),
        ftlads::util::fmt_bytes(scale.big_file_size),
        scale.iterations
    );

    let mut cases = vec![Case::Lads];
    cases.extend(Case::all_ft());

    let mut rows = Vec::new();
    let mut lads_time = None;
    for case in cases {
        let mut time = Series::new();
        let mut cpu = Series::new();
        let mut mem = Series::new();
        // one discarded warmup run per case (cold caches/thread spin-up
        // dominate the first run and would inflate the error bars)
        let _ = run_case(&scale, &wl, case, &format!("warm-{}", case.label()));
        for i in 0..scale.iterations {
            let out = run_case(&scale, &wl, case, &format!("fig5-{}-{i}", case.label()));
            time.push(out.elapsed.as_secs_f64());
            cpu.push(out.resources.cpu_percent);
            mem.push(out.resources.peak_rss_bytes as f64 / (1 << 20) as f64);
        }
        let t = time.summary();
        let c = cpu.summary();
        let m = mem.summary();
        if case == Case::Lads {
            lads_time = Some(t.mean);
        }
        let overhead = lads_time
            .map(|base| format!("{:+.2}%", (t.mean / base - 1.0) * 100.0))
            .unwrap_or_default();
        rows.push(vec![
            case.label(),
            format!("{:.3}±{:.3}", t.mean, t.ci99),
            overhead,
            format!("{:.1}±{:.1}", c.mean, c.ci99),
            format!("{:.1}±{:.1}", m.mean, m.ci99),
        ]);
    }
    print_table(
        "Fig 5(a,b,c): big workload — transfer time / CPU / memory",
        &["case", "time (s, 99% CI)", "vs LADS", "cpu (%)", "peak rss (MiB)"],
        &rows,
    );
    println!("\nexpected shape: FT time overhead <1% of LADS; memory File ≈ LADS < Txn ≈ Univ");
}
