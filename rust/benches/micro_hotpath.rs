//! Hot-path micro-benchmarks (no paper figure — the §Perf inputs):
//!
//!   log-append      per-object FT logging cost, every mechanism × method
//!   log-batch       group-committed log_blocks vs per-block appends
//!   recovery-parse  log-dir -> CompletedSets throughput
//!   digest          native digest GB/s vs PJRT batched digest GB/s
//!   scheduler       OST queue push/pop throughput
//!   codec           NEW_BLOCK encode/decode round-trip
//!   ack-batch       end-to-end wire-ack / logger-write counts per
//!                   `ack_batch` (the batched BLOCK_SYNC path)
//!   send-window     source issue-loop RMA-slot stalls per
//!                   (`send_window`, pool size) on a wire-bound workload:
//!                   zero-copy pins a payload buffer from pread until the
//!                   sink releases it, so the POOL axis (not the window
//!                   axis) governs slot stalls — provision slots ≥
//!                   in-flight
//!   zero-copy       payload copies per object on the end-to-end data
//!                   path (counter-instrumented; asserts ≤ 1 — the
//!                   unavoidable pread into the RMA slot) and the codec's
//!                   per-message allocation cost (frame-alloc encode vs
//!                   header-scratch + gathered payload)
//!   write-coalesce  sink write submissions + OST service rounds per
//!                   `write_coalesce_bytes` on an 8-block-contiguous
//!                   workload with a slow serial sink: gathered vectored
//!                   pwrites must cut syscalls-per-byte ≥ 2× at 4 MiB
//!                   (the §A10 table)
//!   multi-stream    aggregate goodput per `data_streams` on a wire-bound
//!                   transfer (K OST-sharded data connections, per-stream
//!                   credit windows + RMA pools: ≥ 2× at K = 4) and
//!                   source read syscalls with the preadv gather (≥ 2×
//!                   fewer at a 4 MiB budget) — the §A11 tables
//!   autotune        unified --tune controller started from the pessimal
//!                   knob vector (window 1, batch 1, budgets 0) on a
//!                   wire-bound workload: the best-epoch goodput must
//!                   reach ≥ 0.9× a hand-tuned static run and ≥ 2× the
//!                   pessimal run — the §A12 convergence table
//!   serve           multi-job daemon: J concurrent jobs through one
//!                   in-process `Serve`, weighted fair-share dispatch
//!                   order under a full admission queue, cross-job
//!                   OST steering via the shared congestion registry
//!                   (registry-informed vs blind) — the §A13 tables —
//!                   and the daemon-kill recovery leg: manifest replay
//!                   re-admits every incomplete job under the
//!                   `resent <= total - logged` bound — the §A15 tables
//!   torture         adversarial-network transport: per-profile overhead
//!                   vs a torture-off run for every FT mechanism (wall
//!                   time, duplicates absorbed, retries) and the
//!                   recovery leg — each profile composed with a
//!                   mid-transfer kill, resume honoring the
//!                   `resent <= total - logged` bound — the §A14 tables
//!
//! Plain timing mains (no criterion offline); each reports mean ± 99 % CI
//! over fixed iteration counts with warmup. With `FTLADS_BENCH_JSON_DIR`
//! set, the tables are also written as a JSON summary (CI artifact).


use ftlads::bench_support::print_table;
use ftlads::config::Config;
use ftlads::coordinator::queues::OstQueues;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::ftlog::{self, codec::Method, CompletedSet, FtConfig, Mechanism};
use ftlads::integrity::{DigestEngine, NativeEngine};
use ftlads::net::Message;
use ftlads::pfs::ost::{OstConfig, OstId, OstModel};
use ftlads::stats::bench_seconds;
use ftlads::testutil::Pcg32;
use ftlads::workload;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ftlads-micro-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn bench_log_append() {
    let blocks_per_file = 64u32;
    let files = 32usize;
    let mut rows = Vec::new();
    for mech in Mechanism::ALL_FT {
        for method in Method::ALL {
            let dir = tmp_dir(&format!("append-{}-{}", mech.as_str(), method.as_str()));
            let cfg = FtConfig {
                mechanism: mech,
                method,
                dir: dir.clone(),
                txn_size: 4,
            };
            let mut rng = Pcg32::new(1);
            let s = bench_seconds(1, 3, || {
                let mut logger = ftlog::create_logger(&cfg).unwrap();
                for f in 0..files {
                    let key = logger
                        .register_file(&format!("f{f}"), blocks_per_file)
                        .unwrap();
                    // out-of-order completion order
                    let mut order: Vec<u32> = (0..blocks_per_file).collect();
                    rng.shuffle(&mut order);
                    for b in order {
                        logger.log_block(key, b).unwrap();
                    }
                    logger.complete_file(key).unwrap();
                }
                logger.finish_dataset().unwrap();
            });
            let per_append =
                s.mean / (files as f64 * blocks_per_file as f64) * 1e6;
            rows.push(vec![
                format!("{}/{}", mech.as_str(), method.as_str()),
                format!("{per_append:.2}"),
            ]);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
    print_table("log-append cost (µs/object)", &["mechanism/method", "µs"], &rows);
}

/// Group-commit gain at the logger layer: the same shuffled completion
/// stream written via per-block `log_block` vs `log_blocks` batches.
fn bench_log_batch() {
    let blocks_per_file = 256u32;
    let mut rows = Vec::new();
    for mech in Mechanism::ALL_FT {
        for batch in [1usize, 8, 32] {
            let dir = tmp_dir(&format!("lgb-{}-{batch}", mech.as_str()));
            let cfg = FtConfig {
                mechanism: mech,
                method: Method::Bit64,
                dir: dir.clone(),
                txn_size: 4,
            };
            let mut rng = Pcg32::new(7);
            let mut order: Vec<u32> = (0..blocks_per_file).collect();
            rng.shuffle(&mut order);
            let mut write_ops = 0u64;
            let s = bench_seconds(1, 5, || {
                let mut logger = ftlog::create_logger(&cfg).unwrap();
                let key = logger.register_file("f", blocks_per_file).unwrap();
                for chunk in order.chunks(batch) {
                    logger.log_blocks(key, chunk).unwrap();
                }
                write_ops = logger.space().write_ops;
                logger.finish_dataset().unwrap();
            });
            let per_append = s.mean / blocks_per_file as f64 * 1e6;
            rows.push(vec![
                format!("{}/bit64 x{batch}", mech.as_str()),
                format!("{per_append:.2}"),
                format!("{write_ops}"),
            ]);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
    print_table(
        "group-commit log_blocks (256 objects)",
        &["mechanism x batch", "µs/object", "writes"],
        &rows,
    );
}

/// End-to-end ack batching: wire BLOCK_SYNC messages and source logger
/// writes per `ack_batch`, same 64-object workload. Pins the headline
/// claim: both counts drop ≥ 4× at `ack_batch = 8`.
fn bench_ack_batching() {
    let mut rows = Vec::new();
    let mut baseline: Option<(u64, u64)> = None;
    for batch in [1u32, 4, 8, 16] {
        let mut cfg = Config::for_tests(&format!("micro-ack-{batch}"));
        cfg.mechanism = Mechanism::Universal;
        cfg.method = Method::Bit64;
        cfg.ack_batch = batch;
        // Generous straggler bound so flushes are count-driven, not
        // timer-driven, and the ratio is deterministic even on a loaded
        // machine.
        cfg.ack_flush_us = 200_000;
        let wl = workload::big_workload(4, 16 * cfg.object_size); // 64 objects
        let env = SimEnv::new(cfg, &wl);
        let started = std::time::Instant::now();
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        let elapsed = started.elapsed();
        assert!(out.completed, "ack_batch={batch}: {:?}", out.fault);
        env.verify_sink_complete().unwrap();
        let acks = out.sink.ack_messages;
        let log_writes = out.source.log_writes;
        if batch == 1 {
            assert_eq!(acks, 64, "ack_batch=1 must ack per object");
            assert_eq!(log_writes, 64, "ack_batch=1 must log per object");
            baseline = Some((acks, log_writes));
        }
        if batch == 8 {
            let (a1, l1) = baseline.expect("batch=1 runs first");
            assert!(
                acks * 4 <= a1,
                "wire acks must drop >= 4x at ack_batch=8: {acks} vs {a1}"
            );
            assert!(
                log_writes * 4 <= l1,
                "logger writes must drop >= 4x at ack_batch=8: {log_writes} vs {l1}"
            );
        }
        rows.push(vec![
            format!("{batch}"),
            format!("{acks}"),
            format!("{log_writes}"),
            format!("{:.1}", elapsed.as_secs_f64() * 1e3),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    print_table(
        "ack batching (64 objects, universal/bit64)",
        &["ack_batch", "wire acks", "log writes", "ms"],
        &rows,
    );
}

/// End-to-end send-window × RMA-pool sweep on a workload where the wire
/// (not the storage) is the bottleneck — a slow modeled link and instant
/// OSTs. With the zero-copy path a payload buffer is pinned from its
/// pread until the *sink* releases the last `Bytes` ref (like a real
/// registered RMA region), so slot residency spans the wire
/// serialization in BOTH issue disciplines and the POOL axis is what
/// governs issue-loop stalls: a 2-slot pool stalls the issue loop under
/// any window, an 8-slot pool absorbs the in-flight window and the
/// stalls collapse. Pins that claim: ≥ 2× fewer stalls at
/// (window 8, 8 slots) vs (window 8, 2 slots).
///
/// (Before zero-copy, the windowed path *copied* the payload and
/// released the slot pre-send, so the window axis alone moved the stall
/// count; that copy is exactly what this PR deletes — see the zero-copy
/// table for the copies-per-object pin.)
fn bench_send_window() {
    let mut rows = Vec::new();
    let mut stalls_at: Vec<(u32, usize, u64)> = Vec::new();
    for (window, slots) in [(1u32, 2usize), (8, 2), (8, 8)] {
        let mut cfg = Config::for_tests(&format!("micro-swin-{window}-{slots}"));
        cfg.send_window = window;
        cfg.io_threads = 4;
        // The pool axis: slot occupancy is the contended resource.
        cfg.rma_bytes = slots * cfg.object_size as usize;
        // Wire-bound: ~330 µs to serialize one 64 KiB object...
        cfg.time_scale = 1.0;
        cfg.net_bandwidth = 2.0e8;
        cfg.net_latency_us = 5;
        // ...with free storage on both ends (zero modeled service, so
        // buffers pin for wire serialization + sink release only).
        cfg.ost_bandwidth = f64::INFINITY;
        cfg.ost_latency_us = 0;
        cfg.ost_concurrent = 8;
        let wl = workload::big_workload(6, 16 * cfg.object_size); // 96 objects
        let env = SimEnv::new(cfg, &wl);
        let started = std::time::Instant::now();
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        let elapsed = started.elapsed();
        assert!(out.completed, "send_window={window}/{slots}: {:?}", out.fault);
        assert_eq!(out.send_window, window);
        if window == 1 {
            assert_eq!(
                out.source.credit_waits, 0,
                "lockstep never touches the credit gate"
            );
        }
        env.verify_sink_complete().unwrap();
        stalls_at.push((window, slots, out.source.send_stalls));
        rows.push(vec![
            format!("{window}/{slots}"),
            format!("{}", out.source.send_stalls),
            format!("{}", out.source.credit_waits),
            format!("{:.1}", elapsed.as_secs_f64() * 1e3),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    let find = |w: u32, s: usize| {
        stalls_at.iter().find(|&&(fw, fs, _)| fw == w && fs == s).unwrap().2
    };
    let tight = find(8, 2);
    let roomy = find(8, 8);
    assert!(
        find(1, 2) >= 16,
        "wire-bound issue on a 2-slot pool must stall the issue loop: {}",
        find(1, 2)
    );
    assert!(
        tight >= 2 * roomy.max(1),
        "slot stalls must drop >= 2x when the pool covers the window: \
         {roomy} (8 slots) vs {tight} (2 slots)"
    );
    print_table(
        "send window x RMA pool (96 objects, wire-bound, zero-copy)",
        &["window/slots", "slot stalls", "credit waits", "ms"],
        &rows,
    );
}

/// §A9 headline table: payload memcpys per object on the end-to-end data
/// path, counter-instrumented (`payload_copies`/`bytes_copied`). The
/// zero-copy pipeline performs exactly ONE per object — the `pread` that
/// stages it into the RMA slot; the freeze → wire → sink `pwrite` chain
/// adds zero. Before this change the same transfer cost ≥ 3 (slot →
/// NEW_BLOCK Vec at the source, payload → frame on serializing
/// transports, wire → sink slot), all deleted at once. Asserted hard:
/// copies-per-object ≤ 1 on every swept configuration.
fn bench_zero_copy() {
    let mut rows = Vec::new();
    for (label, window, ack_batch) in
        [("lockstep", 1u32, 1u32), ("window 8", 8, 1), ("window 8 + ack 8", 8, 8)]
    {
        let mut cfg = Config::for_tests(&format!("micro-zc-{window}-{ack_batch}"));
        cfg.send_window = window;
        cfg.ack_batch = ack_batch;
        cfg.ack_flush_us = 200_000;
        let wl = workload::big_workload(4, 16 * cfg.object_size); // 64 objects
        let total_bytes = wl.total_bytes();
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed, "zero-copy {label}: {:?}", out.fault);
        env.verify_sink_complete().unwrap();
        let objects = out.source.objects_sent;
        let copies = out.payload_copies();
        assert!(objects > 0);
        assert!(
            copies <= objects,
            "{label}: {copies} payload copies for {objects} objects — \
             a memcpy crept back onto the data path"
        );
        assert_eq!(
            out.bytes_copied(),
            total_bytes,
            "{label}: copied bytes must equal the staged pread bytes exactly"
        );
        rows.push(vec![
            label.to_string(),
            format!("{objects}"),
            format!("{copies}"),
            format!("{:.2}", copies as f64 / objects as f64),
            format!("{}", out.bytes_copied()),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    print_table(
        "payload copies per object (zero-copy path, 64 objects)",
        &["config", "objects", "copies", "copies/object", "bytes copied"],
        &rows,
    );

    // Codec allocation shape: the old path allocated (and filled) one
    // contiguous frame per message; the new path reuses a header scratch
    // and gathers the payload by reference. Timed on a 256 KiB payload.
    let mut rng = Pcg32::new(6);
    let mut payload = vec![0u8; 256 << 10];
    rng.fill_bytes(&mut payload);
    let msg = Message::NewBlock {
        file_idx: 1,
        block_idx: 2,
        offset: 3 << 18,
        digest: 0xabcd,
        data: payload.into(),
    };
    let s_frame = bench_seconds(3, 30, || {
        // Per-message frame: fresh allocation + full payload memcpy.
        let mut frame = Vec::with_capacity(16 + msg.payload_len());
        frame.extend_from_slice(&0u32.to_le_bytes());
        msg.encode(&mut frame);
        std::hint::black_box(&frame);
    });
    let mut scratch = Vec::with_capacity(64);
    let s_scratch = bench_seconds(3, 30, || {
        // Header scratch reuse: no allocation, payload passed by ref.
        scratch.clear();
        scratch.extend_from_slice(&0u32.to_le_bytes());
        let body = msg.encode_header(&mut scratch);
        std::hint::black_box((&scratch, body.map(|b| b.len())));
    });
    assert!(
        s_scratch.mean < s_frame.mean,
        "header-scratch encode must beat per-message frame allocation: \
         {:.1} µs vs {:.1} µs",
        s_scratch.mean * 1e6,
        s_frame.mean * 1e6
    );
    print_table(
        "NEW_BLOCK send-side encode (256 KiB payload)",
        &["mode", "µs/msg", "allocs/msg", "payload memcpy"],
        &[
            vec![
                "frame alloc (pre-PR)".into(),
                format!("{:.2}", s_frame.mean * 1e6),
                "1".into(),
                "yes".into(),
            ],
            vec![
                "header scratch + gather".into(),
                format!("{:.2}", s_scratch.mean * 1e6),
                "0".into(),
                "no".into(),
            ],
        ],
    );
}

/// §A10 headline table: sink write submissions and OST service rounds
/// per coalesce budget, on a workload built to be byte-contiguous at the
/// sink (8 files × 8 adjacent 64 KiB objects, stripe_count 1 → each file
/// wholly on one OST). The sink's storage is slow and strictly serial
/// per OST while the source/wire are instant, so write queues genuinely
/// back up and runs form; the source floods on a deep window with a pool
/// to match. Asserted hard: ≥ 2× fewer sink write syscalls at 4 MiB
/// coalesce than with coalescing off, with byte-verified content either
/// way and every object still individually acked.
fn bench_write_coalesce() {
    use ftlads::coordinator::TransferJob;
    use ftlads::pfs::sim::SimPfs;
    use std::sync::Arc;
    use std::time::Duration;

    let mut rows = Vec::new();
    let mut syscalls_at: Vec<(u64, u64)> = Vec::new();
    for coalesce in [0u64, 4 << 20, 16 << 20] {
        let mut cfg = Config::for_tests(&format!("micro-coal-{coalesce}"));
        cfg.write_coalesce_bytes = coalesce;
        cfg.send_window = 64;
        cfg.rma_bytes = 64 * cfg.object_size as usize;
        let wl = workload::big_workload(8, 8 * cfg.object_size); // 64 objects
        let source = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), cfg.seed));
        source.populate(&wl.as_tuples());
        let slow = OstConfig {
            bandwidth: 1e12,
            base_latency: Duration::from_millis(1),
            max_concurrent: 1,
            time_scale: 1.0,
        };
        let sink = Arc::new(SimPfs::new(cfg.layout(), slow, cfg.seed));
        let files: Vec<String> = wl.files.iter().map(|f| f.name.clone()).collect();
        let env = SimEnv { cfg, source, sink, files };
        let started = std::time::Instant::now();
        let out = TransferJob::builder(&env.cfg, &TransferSpec::fresh(env.files.clone()))
            .source_pfs(env.source.clone())
            .sink_pfs(env.sink.clone())
            .run()
            .unwrap();
        let elapsed = started.elapsed();
        assert!(out.completed, "coalesce={coalesce}: {:?}", out.fault);
        env.verify_sink_complete().unwrap();
        let objects = out.source.objects_sent;
        assert_eq!(
            out.sink.ack_messages, objects,
            "coalesce={coalesce}: every object must still be individually acked"
        );
        let ost_writes = env.sink.ost_model().total_stats().writes;
        assert_eq!(
            ost_writes, out.sink.write_syscalls,
            "coalesce={coalesce}: one OST service round per write submission"
        );
        if coalesce == 0 {
            assert_eq!(
                out.sink.write_syscalls, objects,
                "coalesce off must pwrite once per object"
            );
            assert_eq!(out.sink.coalesced_runs, 0);
        }
        syscalls_at.push((coalesce, out.sink.write_syscalls));
        let label = if coalesce == 0 {
            "off".to_string()
        } else {
            format!("{} MiB", coalesce >> 20)
        };
        rows.push(vec![
            label,
            format!("{}", out.sink.write_syscalls),
            format!("{ost_writes}"),
            format!("{}", out.sink.coalesced_runs),
            format!("{}", out.sink.coalesce_bytes_max >> 10),
            format!("{:.1}", elapsed.as_secs_f64() * 1e3),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    let find = |c: u64| syscalls_at.iter().find(|&&(fc, _)| fc == c).unwrap().1;
    let (off, four) = (find(0), find(4 << 20));
    assert!(
        four * 2 <= off,
        "4 MiB coalesce must at least halve sink write syscalls: {four} vs {off}"
    );
    print_table(
        "write coalescing (64 contiguous objects, slow serial sink)",
        &["coalesce", "write syscalls", "ost write ops", "runs", "max run KiB", "ms"],
        &rows,
    );
}

/// §A11 headline tables: (a) aggregate goodput vs `data_streams` on a
/// wire-bound transfer — the wire model serializes each connection
/// independently at ~200 MB/s, so K OST-sharded data connections with
/// per-stream credit windows and RMA pools must scale aggregate goodput
/// ≥ 2× at K = 4 vs the fused K = 1 baseline; (b) source read syscalls
/// with the preadv gather on a byte-contiguous workload — one
/// `read_at_vectored` per contiguous run instead of one `read_at` per
/// object must cut read submissions ≥ 2×. `FTLADS_BENCH_SCALE=quick`
/// shrinks the workload for CI smoke runs; the ratios are asserted at
/// either scale.
fn bench_multi_stream() {
    let quick = std::env::var("FTLADS_BENCH_SCALE").as_deref() == Ok("quick");
    // Files sit wholly on one OST each (file ≤ one 1 MiB stripe at 64 KiB
    // objects ×16) and round-robin over the 11 OSTs, so the `ost % K`
    // shard spreads them across every stream.
    let (files, blocks) = if quick { (8usize, 8u64) } else { (12, 16) };
    let wire_cfg = |tag: &str| {
        let mut cfg = Config::for_tests(tag);
        cfg.io_threads = 4;
        // Wire-bound: ~330 µs to serialize one 64 KiB object per
        // connection, free storage on both ends (the send-window bench's
        // §A8 configuration — the wire is the only contended resource).
        cfg.time_scale = 1.0;
        cfg.net_bandwidth = 2.0e8;
        cfg.net_latency_us = 5;
        cfg.ost_bandwidth = f64::INFINITY;
        cfg.ost_latency_us = 0;
        cfg.ost_concurrent = 8;
        cfg
    };

    // (a) stream scaling.
    let mut rows = Vec::new();
    let mut goodput_at: Vec<(u32, f64)> = Vec::new();
    for k in [1u32, 2, 4] {
        let mut cfg = wire_cfg(&format!("micro-mstream-{k}"));
        cfg.data_streams = k;
        // Window and pool are per stream — identical per-stream credit,
        // so added streams are the only variable.
        cfg.send_window = 16;
        cfg.rma_bytes = 16 * cfg.object_size as usize;
        let wl = workload::big_workload(files, blocks * cfg.object_size);
        let total_bytes = wl.total_bytes();
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed, "streams={k}: {:?}", out.fault);
        assert_eq!(out.data_streams, k, "CONNECT must negotiate the asked K");
        env.verify_sink_complete().unwrap();
        let secs = out.elapsed.as_secs_f64();
        let mbps = total_bytes as f64 / secs / 1e6;
        goodput_at.push((k, mbps));
        rows.push(vec![
            format!("{k}"),
            format!("{:.1}", secs * 1e3),
            format!("{mbps:.1}"),
            format!(
                "{:.2}",
                mbps / goodput_at[0].1.max(f64::MIN_POSITIVE)
            ),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    let find = |k: u32| goodput_at.iter().find(|&&(fk, _)| fk == k).unwrap().1;
    assert!(
        find(4) >= 2.0 * find(1),
        "K=4 must at least double aggregate goodput over the fused path: \
         {:.1} MB/s vs {:.1} MB/s",
        find(4),
        find(1)
    );
    print_table(
        &format!(
            "stream scaling ({} objects, wire-bound, window 16/stream)",
            files as u64 * blocks
        ),
        &["data streams", "ms", "MB/s", "speedup"],
        &rows,
    );

    // (b) preadv gather: shallow window (few wire-pinned slots) over a
    // deep pool, so spare slots are available to stage gathered runs.
    let mut rows = Vec::new();
    let mut reads_at: Vec<(u64, u64)> = Vec::new();
    for gather in [0u64, 4 << 20] {
        let mut cfg = wire_cfg(&format!("micro-mgather-{gather}"));
        cfg.read_gather_bytes = gather;
        cfg.send_window = 8;
        cfg.rma_bytes = 64 * cfg.object_size as usize;
        let wl = workload::big_workload(files, blocks * cfg.object_size);
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed, "gather={gather}: {:?}", out.fault);
        env.verify_sink_complete().unwrap();
        let objects = out.source.objects_sent;
        if gather == 0 {
            assert_eq!(
                out.source.read_syscalls, objects,
                "gather off must pread once per object"
            );
            assert_eq!(out.source.gathered_runs, 0);
        } else {
            assert!(
                out.source.gathered_runs > 0,
                "contiguous backlog must form gathered preads"
            );
        }
        reads_at.push((gather, out.source.read_syscalls));
        let label = if gather == 0 {
            "off".to_string()
        } else {
            format!("{} MiB", gather >> 20)
        };
        rows.push(vec![
            label,
            format!("{}", out.source.read_syscalls),
            format!("{}", out.source.gathered_runs),
            format!("{}", out.source.gather_bytes_max >> 10),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    let find = |g: u64| reads_at.iter().find(|&&(fg, _)| fg == g).unwrap().1;
    let (off, four) = (find(0), find(4 << 20));
    assert!(
        four * 2 <= off,
        "4 MiB preadv gather must at least halve source read syscalls: \
         {four} vs {off}"
    );
    print_table(
        &format!(
            "source read gather ({} contiguous objects, preadv)",
            files as u64 * blocks
        ),
        &["gather", "read syscalls", "gathered runs", "max run KiB"],
        &rows,
    );
}

/// §A12 headline table: the unified autotuner walking the whole knob
/// vector mid-transfer. Three runs of the same wire-bound workload:
/// the pessimal static point (window 1, ack batch 1, budgets 0 — the
/// seed defaults), a hand-tuned static point, and --tune started FROM
/// the pessimal point. Asserted hard: the tuner's best-epoch goodput
/// reaches ≥ 0.9× the hand-tuned average and ≥ 2× the pessimal
/// average — the controller must climb essentially the whole gap on
/// its own. `FTLADS_BENCH_SCALE=quick` shrinks the workload for CI.
fn bench_autotune() {
    let quick = std::env::var("FTLADS_BENCH_SCALE").as_deref() == Ok("quick");
    let (files, blocks) = if quick { (16usize, 24u64) } else { (24, 32) };
    let base_cfg = |tag: &str| {
        let mut cfg = Config::for_tests(tag);
        cfg.io_threads = 4;
        // Wire-bound with a fat RTT: ~330 µs to serialize one 64 KiB
        // object per connection at 200 MB/s plus 800 µs propagation each
        // way, free storage on both ends — the knob vector is what
        // stands between lockstep and the wire ceiling (~3.4× headroom
        // over the 2× assertion even before the budgets help).
        cfg.time_scale = 1.0;
        cfg.net_bandwidth = 2.0e8;
        cfg.net_latency_us = 800;
        cfg.ost_bandwidth = f64::INFINITY;
        cfg.ost_latency_us = 0;
        cfg.ost_concurrent = 8;
        // ONE object-sized RMA slot configured: window 1 never arms the
        // credit gate, so the pessimal row is genuinely slot-bound
        // lockstep; the autosizer then grows each pool to whatever
        // window the row actually negotiates, so the tuned row's grown
        // window is never starved by the pool.
        cfg.rma_bytes = cfg.object_size as usize;
        cfg.rma_autosize = true;
        cfg.data_streams = 2;
        cfg.ack_flush_us = 500;
        cfg
    };

    let mut rows = Vec::new();
    let mut avg_at: Vec<(&str, f64)> = Vec::new();
    for (label, window, batch, gather, coalesce) in [
        ("pessimal static", 1u32, 1u32, 0u64, 0u64),
        ("hand-tuned static", 16, 8, 4 << 20, 4 << 20),
    ] {
        let mut cfg = base_cfg(&format!("micro-tune-{window}-{batch}"));
        cfg.send_window = window;
        cfg.ack_batch = batch;
        cfg.read_gather_bytes = gather;
        cfg.write_coalesce_bytes = coalesce;
        let wl = workload::big_workload(files, blocks * cfg.object_size);
        let total_bytes = wl.total_bytes();
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed, "{label}: {:?}", out.fault);
        assert_eq!(out.tune_epochs, 0, "{label}: no tuner may run statically");
        env.verify_sink_complete().unwrap();
        let secs = out.elapsed.as_secs_f64();
        let mbps = total_bytes as f64 / secs / 1e6;
        avg_at.push((label, mbps));
        rows.push(vec![
            label.to_string(),
            format!("{:.1}", secs * 1e3),
            format!("{mbps:.1}"),
            "-".into(),
            "0".into(),
            "-".into(),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }

    // The tuned run: identical pessimal knobs, --tune walks them.
    let mut cfg = base_cfg("micro-tune-on");
    cfg.tune = true;
    cfg.tune_epoch_ms = 10;
    let wl = workload::big_workload(files, blocks * cfg.object_size);
    let total_bytes = wl.total_bytes();
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "tuned: {:?}", out.fault);
    env.verify_sink_complete().unwrap();
    assert!(out.tune_epochs > 0, "tuned run never ticked an epoch");
    let secs = out.elapsed.as_secs_f64();
    let avg_mbps = total_bytes as f64 / secs / 1e6;
    let tuned_final = out.goodput_final / 1e6;
    rows.push(vec![
        "tuned (from pessimal)".to_string(),
        format!("{:.1}", secs * 1e3),
        format!("{avg_mbps:.1}"),
        format!("{tuned_final:.1}"),
        format!("{}", out.tune_epochs),
        format!("{}+ {}- {}r", out.tune_grows, out.tune_shrinks, out.tune_reverts),
    ]);
    let trajectory: Vec<Vec<String>> =
        out.tune_trajectory.iter().take(12).map(|s| vec![s.clone()]).collect();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);

    let find = |l: &str| avg_at.iter().find(|&&(fl, _)| fl == l).unwrap().1;
    let (pessimal, hand) = (find("pessimal static"), find("hand-tuned static"));
    assert!(
        hand >= 2.0 * pessimal,
        "the static gap itself must be ≥ 2× or the walk proves nothing: \
         {hand:.1} vs {pessimal:.1} MB/s"
    );
    assert!(
        tuned_final >= 0.9 * hand,
        "tuner must reach ≥ 0.9× the hand-tuned goodput: \
         best epoch {tuned_final:.1} vs hand-tuned {hand:.1} MB/s"
    );
    assert!(
        tuned_final >= 2.0 * pessimal,
        "tuner must at least double the pessimal goodput: \
         best epoch {tuned_final:.1} vs pessimal {pessimal:.1} MB/s"
    );
    print_table(
        &format!(
            "autotune convergence ({} objects, wire-bound, from pessimal knobs)",
            files as u64 * blocks
        ),
        &["config", "ms", "avg MB/s", "best epoch MB/s", "epochs", "moves"],
        &rows,
    );
    if !trajectory.is_empty() {
        print_table("autotune trajectory (first 12 moves)", &["move"], &trajectory);
    }
}

/// §A13 headline tables: the multi-job `ftlads serve` daemon, all three
/// axes. (a) Job scaling — J identical wire-bound transfers submitted to
/// one in-process [`Serve`] with four admission slots: every job must
/// complete byte-verified and the daemon counters must account for every
/// submission. (b) Weighted fair-share dispatch — a single admission
/// slot with a warmup job holding it while two tenants (weights 2:1)
/// queue alternately: the dispatch order must favour the heavy tenant
/// 2:1, not FIFO. (c) Cross-job OST steering — two concurrent jobs on
/// slow serial storage, shared registry on vs off: registry-informed
/// runs must record foreign-load-aware picks (`shared_picks`) and
/// actual steers away from the other job's hot OSTs (`shared_avoids`);
/// registry-blind runs must record exactly zero of both. (d) The §A15
/// daemon-kill recovery leg — every job killed mid-transfer, a second
/// daemon over the same ft_dir replays the job manifest, re-admits the
/// complement under the `resent <= total - logged` bound, and the
/// recovery wall time is reported against a fault-free full run.
fn bench_serve() {
    use ftlads::coordinator::serve::{JobRequest, Serve};
    use ftlads::fault::FaultPlan;
    use ftlads::net::Side;
    use ftlads::pfs::sim::SimPfs;
    use std::sync::Arc;

    let quick = std::env::var("FTLADS_BENCH_SCALE").as_deref() == Ok("quick");
    let (files, blocks) = if quick { (4usize, 4u64) } else { (6, 8) };

    let wire_cfg = |tag: &str| {
        let mut cfg = Config::for_tests(tag);
        cfg.io_threads = 2;
        // Wire-bound in real time so concurrent jobs genuinely overlap:
        // ~330 µs to serialize one 64 KiB object, free storage.
        cfg.time_scale = 1.0;
        cfg.net_bandwidth = 2.0e8;
        cfg.net_latency_us = 5;
        cfg.ost_bandwidth = f64::INFINITY;
        cfg.ost_latency_us = 0;
        cfg.ost_concurrent = 8;
        cfg.send_window = 8;
        cfg.rma_bytes = 8 * cfg.object_size as usize;
        cfg
    };
    let make_job = |cfg: &Config, seed: u64| {
        let wl = workload::big_workload(files, blocks * cfg.object_size);
        let source = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), seed));
        source.populate(&wl.as_tuples());
        let sink = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), seed));
        let names: Vec<String> = wl.files.iter().map(|f| f.name.clone()).collect();
        let bytes = wl.total_bytes();
        let req = JobRequest {
            spec: TransferSpec::fresh(names.clone()),
            source_pfs: source.clone() as Arc<dyn ftlads::pfs::Pfs>,
            sink_pfs: sink.clone() as Arc<dyn ftlads::pfs::Pfs>,
            runtime: None,
        };
        (req, source, sink, names, bytes)
    };

    // (a) job scaling through one daemon.
    let mut rows = Vec::new();
    for jobs in [1usize, 2, 4] {
        let cfg = {
            let mut c = wire_cfg(&format!("micro-serve-{jobs}"));
            c.serve_max_jobs = 4;
            c
        };
        let serve = Serve::new(cfg.clone());
        let mut handles = Vec::new();
        let mut envs = Vec::new();
        let mut total_bytes = 0u64;
        let started = std::time::Instant::now();
        for j in 0..jobs {
            let (req, source, sink, names, bytes) =
                make_job(&cfg, cfg.seed + j as u64);
            total_bytes += bytes;
            envs.push(SimEnv { cfg: cfg.clone(), source, sink, files: names });
            handles.push(serve.submit("bench", 1, req).unwrap());
        }
        let outs: Vec<_> =
            handles.into_iter().map(|h| h.wait().unwrap()).collect();
        serve.drain();
        let elapsed = started.elapsed();
        for (out, env) in outs.iter().zip(&envs) {
            assert!(out.completed, "serve jobs={jobs}: {:?}", out.fault);
            env.verify_sink_complete().unwrap();
        }
        let stats = serve.stats();
        assert_eq!(stats.jobs_submitted, jobs as u64);
        assert_eq!(stats.jobs_completed, jobs as u64);
        assert_eq!(stats.jobs_faulted, 0);
        let mbps = total_bytes as f64 / elapsed.as_secs_f64() / 1e6;
        rows.push(vec![
            format!("{jobs}"),
            format!("{:.1}", elapsed.as_secs_f64() * 1e3),
            format!("{mbps:.1}"),
            format!("{}", stats.peak_concurrent),
        ]);
        let _ = std::fs::remove_dir_all(&cfg.ft_dir);
    }
    print_table(
        "serve job scaling (concurrent jobs through one daemon)",
        &["jobs", "ms", "aggregate MB/s", "peak concurrent"],
        &rows,
    );

    // (b) weighted fair-share dispatch order. One admission slot; a
    // warmup job holds it while six jobs from two tenants queue
    // (alternating light, heavy — FIFO would alternate right back).
    // Jobs run strictly serially, so completion order IS dispatch
    // order; each run takes milliseconds, dwarfing the recording race.
    let cfg = {
        let mut c = wire_cfg("micro-serve-fair");
        c.serve_max_jobs = 1;
        c.net_latency_us = 100;
        c
    };
    let serve = Serve::new(cfg.clone());
    let (warm_req, _, _, _, _) = make_job(&cfg, cfg.seed + 100);
    let warm = serve.submit("warmup", 1, warm_req).unwrap();
    let (order_tx, order_rx) = std::sync::mpsc::channel();
    let mut waiters = Vec::new();
    for i in 0..6usize {
        let (tenant, weight) =
            if i % 2 == 0 { ("light", 1u32) } else { ("heavy", 2) };
        let (req, _, _, _, _) = make_job(&cfg, cfg.seed + 200 + i as u64);
        let handle = serve.submit(tenant, weight, req).unwrap();
        let tx = order_tx.clone();
        waiters.push(std::thread::spawn(move || {
            let out = handle.wait().unwrap();
            assert!(out.completed, "fair-share {tenant}: {:?}", out.fault);
            let _ = tx.send(tenant);
        }));
    }
    assert!(warm.wait().unwrap().completed);
    let dispatch_order: Vec<&str> = (0..6).map(|_| order_rx.recv().unwrap()).collect();
    for w in waiters {
        w.join().unwrap();
    }
    serve.drain();
    let heavy_first3 =
        dispatch_order.iter().take(3).filter(|t| **t == "heavy").count();
    assert!(
        heavy_first3 >= 2,
        "weight 2 must take >= 2 of the first 3 dispatch slots, got \
         {dispatch_order:?}"
    );
    let rows: Vec<Vec<String>> = dispatch_order
        .iter()
        .enumerate()
        .map(|(i, t)| vec![format!("{}", i + 1), (*t).to_string()])
        .collect();
    print_table(
        "serve fair-share dispatch (2 tenants, weight 2:1, one slot)",
        &["dispatch slot", "tenant"],
        &rows,
    );
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);

    // (c) cross-job OST steering: storage-bound (slow strictly-serial
    // OSTs, near-free wire) so both jobs hold deep in-flight OST queues
    // the whole run — the shared registry is what lets each job's
    // congestion scheduler see the other's.
    let mut rows = Vec::new();
    for informed in [true, false] {
        let cfg = {
            let mut c = wire_cfg(&format!("micro-steer-{informed}"));
            c.serve_max_jobs = 2;
            c.serve_registry = informed;
            c.net_bandwidth = 1e12;
            c.net_latency_us = 0;
            c.ost_bandwidth = 1e12;
            c.ost_latency_us = 200;
            c.ost_concurrent = 1;
            c.send_window = 16;
            c.rma_bytes = 16 * c.object_size as usize;
            c
        };
        let serve = Serve::new(cfg.clone());
        let started = std::time::Instant::now();
        let handles: Vec<_> = (0..2u64)
            .map(|j| {
                let (req, _, _, _, _) = make_job(&cfg, cfg.seed + j);
                serve.submit("steer", 1, req).unwrap()
            })
            .collect();
        let outs: Vec<_> =
            handles.into_iter().map(|h| h.wait().unwrap()).collect();
        serve.drain();
        let elapsed = started.elapsed();
        let mut picks = 0u64;
        let mut avoids = 0u64;
        for out in &outs {
            assert!(out.completed, "steer informed={informed}: {:?}", out.fault);
            picks += out.source_sched.shared_picks + out.sink_sched.shared_picks;
            avoids +=
                out.source_sched.shared_avoids + out.sink_sched.shared_avoids;
        }
        if informed {
            assert!(
                picks > 0,
                "registry-informed overlap must see foreign load at pick time"
            );
            assert!(
                avoids > 0,
                "registry-informed picks must steer around the other job's \
                 hot OSTs at least once ({picks} foreign-load picks)"
            );
        } else {
            assert_eq!(picks, 0, "registry off must never consult foreign load");
            assert_eq!(avoids, 0);
        }
        rows.push(vec![
            if informed { "informed" } else { "blind" }.to_string(),
            format!("{picks}"),
            format!("{avoids}"),
            format!("{:.1}", elapsed.as_secs_f64() * 1e3),
        ]);
        let _ = std::fs::remove_dir_all(&cfg.ft_dir);
    }
    print_table(
        "cross-job OST steering (2 jobs, shared registry vs blind)",
        &["registry", "foreign-load picks", "steered picks", "ms"],
        &rows,
    );

    // (d) daemon-kill recovery: `serve_recover` on, every job killed
    // mid-transfer (the whole daemon dies with them), then a second
    // daemon over the same ft_dir replays the job manifest and
    // re-admits the complement. Reported against a fault-free full run
    // of the same job mix — the paper's claim is that recovery costs
    // ~10 % of the transfer, not a restart from zero.
    let jobs = if quick { 2usize } else { 3 };
    let mk_cfg = |tag: &str| {
        let mut c = wire_cfg(tag);
        c.serve_max_jobs = 4;
        c.serve_recover = true;
        c
    };

    // Fault-free baseline of the same mix (its own ft_dir).
    let cfg_full = mk_cfg("micro-serve-recover-full");
    let serve = Serve::new(cfg_full.clone());
    let mut envs = Vec::new();
    let started = std::time::Instant::now();
    let handles: Vec<_> = (0..jobs)
        .map(|j| {
            let (req, source, sink, names, _) =
                make_job(&cfg_full, cfg_full.seed + 300 + j as u64);
            envs.push(SimEnv { cfg: cfg_full.clone(), source, sink, files: names });
            serve.submit("bench", 1, req).unwrap()
        })
        .collect();
    for h in handles {
        assert!(h.wait().unwrap().completed, "baseline job faulted");
    }
    serve.drain();
    let full_ms = started.elapsed().as_secs_f64() * 1e3;
    let _ = std::fs::remove_dir_all(&cfg_full.ft_dir);

    // Kill run: identical mix, every job dies at 50 % of its bytes.
    let cfg = mk_cfg("micro-serve-recover");
    let serve = Serve::new(cfg.clone());
    let mut envs = Vec::new();
    let mut totals = Vec::new();
    let started = std::time::Instant::now();
    let handles: Vec<_> = (0..jobs)
        .map(|j| {
            let (mut req, source, sink, names, _) =
                make_job(&cfg, cfg.seed + 300 + j as u64);
            req.spec = req
                .spec
                .with_fault(FaultPlan::at_fraction(0.5, Side::Source));
            totals.push(
                (files as u64) * blocks, // big_workload: uniform objects
            );
            envs.push(SimEnv { cfg: cfg.clone(), source, sink, files: names });
            serve.submit("bench", 1, req).unwrap()
        })
        .collect();
    for h in handles {
        assert!(!h.wait().unwrap().completed, "kill did not fire");
    }
    serve.drain();
    let kill_ms = started.elapsed().as_secs_f64() * 1e3;
    drop(serve); // the daemon is gone; only ft_dir + PFS state survive

    let logged: Vec<u64> = (1..=jobs as u64)
        .map(|id| {
            let mut ft = cfg.ft();
            ft.dir = cfg.ft_dir.join(format!("job-{id}"));
            ftlog::recover::recover_all(&ft)
                .unwrap()
                .values()
                .map(|s| s.count() as u64)
                .sum()
        })
        .collect();

    // Restart: manifest replay re-admits every incomplete job, resume
    // forced, only the complement crosses the wire.
    let serve = Serve::new(cfg.clone());
    let started = std::time::Instant::now();
    let handles = serve
        .recover(|r| {
            let env = &envs[(r.id - 1) as usize];
            Some(JobRequest {
                spec: TransferSpec::fresh(env.files.clone()),
                source_pfs: env.source.clone() as Arc<dyn ftlads::pfs::Pfs>,
                sink_pfs: env.sink.clone() as Arc<dyn ftlads::pfs::Pfs>,
                runtime: None,
            })
        })
        .unwrap();
    assert_eq!(handles.len(), jobs, "manifest must re-admit every job");
    let mut rows = Vec::new();
    let outs: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    serve.drain();
    let recover_ms = started.elapsed().as_secs_f64() * 1e3;
    for (i, (out, env)) in outs.iter().zip(&envs).enumerate() {
        assert!(out.completed, "recovered job {}: {:?}", i + 1, out.fault);
        assert!(
            out.source.objects_sent <= totals[i] - logged[i],
            "job {}: resume retransmitted logged objects",
            i + 1
        );
        env.verify_sink_complete().unwrap();
        rows.push(vec![
            format!("{}", i + 1),
            format!("{}", totals[i]),
            format!("{}", logged[i]),
            format!("{}", out.source.objects_skipped_resume),
            format!("{}", out.source.objects_sent),
        ]);
    }
    let stats = serve.stats();
    assert_eq!(stats.jobs_recovered, jobs as u64);
    assert_eq!(stats.jobs_submitted, 0);
    print_table(
        "serve recovery (daemon kill mid-jobs, manifest re-admission)",
        &["job", "total", "logged", "skipped", "resent"],
        &rows,
    );
    print_table(
        "serve recovery cost (manifest replay + resumed complement vs full run)",
        &["jobs", "full ms", "killed-run ms", "recover ms", "recover/full"],
        &[vec![
            format!("{jobs}"),
            format!("{full_ms:.1}"),
            format!("{kill_ms:.1}"),
            format!("{recover_ms:.1}"),
            format!("{:.2}", recover_ms / full_ms),
        ]],
    );
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}

/// §A14: the adversarial-network transport. (a) Overhead — each torture
/// profile against a torture-off baseline for every FT mechanism on a
/// wire-bound transfer: wall time, duplicates the dedup ledgers
/// absorbed, handshake retries. Every run must still complete with a
/// byte-verified sink and exactly-once writes. (b) Recovery — each
/// profile composed with a mid-transfer kill: the resume (adversary
/// still armed) must honor the log-based retransmit bound
/// `resent <= total - logged`.
fn bench_torture() {
    use ftlads::fault::FaultPlan;
    use ftlads::net::Side;

    let quick = std::env::var("FTLADS_BENCH_SCALE").as_deref() == Ok("quick");
    let (files, blocks) = if quick { (3usize, 4u64) } else { (4, 8) };

    let torture_cfg = |tag: &str, profile: &str, mech: Mechanism| {
        let mut cfg = Config::for_tests(tag);
        cfg.mechanism = mech;
        cfg.method = Method::Bit64;
        // Wire-bound in real time so held/duplicated traffic costs
        // something measurable: ~330 µs per 64 KiB object.
        cfg.time_scale = 1.0;
        cfg.net_bandwidth = 2.0e8;
        cfg.net_latency_us = 5;
        cfg.ost_bandwidth = f64::INFINITY;
        cfg.ost_latency_us = 0;
        cfg.send_window = 4;
        cfg.ack_batch = 4;
        cfg.ack_flush_us = 500;
        cfg.data_streams = 2;
        cfg.connect_timeout_ms = 100;
        cfg.connect_retries = 6;
        cfg.torture_profile = profile.into();
        cfg.torture_seed = if profile == "off" { 0 } else { 0xA14 };
        cfg
    };

    // (a) per-profile overhead vs the torture-off baseline.
    let mut rows = Vec::new();
    for mech in Mechanism::ALL_FT {
        let mut off_ms = 0.0f64;
        for profile in ["off", "reorder", "dup", "partition"] {
            let cfg = torture_cfg(
                &format!("micro-torture-{profile}-{}", mech.as_str()),
                profile,
                mech,
            );
            let wl = workload::big_workload(files, blocks * cfg.object_size);
            let total = wl.total_objects(cfg.object_size);
            let env = SimEnv::new(cfg, &wl);
            let started = std::time::Instant::now();
            let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
            let ms = started.elapsed().as_secs_f64() * 1e3;
            assert!(out.completed, "{profile}/{mech:?}: {:?}", out.fault);
            assert_eq!(
                out.sink.write_syscalls, total,
                "{profile}/{mech:?}: duplicate reached a pwrite"
            );
            env.verify_sink_complete().unwrap();
            if profile == "off" {
                off_ms = ms;
            }
            rows.push(vec![
                profile.to_string(),
                mech.as_str().to_string(),
                format!("{ms:.1}"),
                format!("{:.2}", ms / off_ms.max(1e-9)),
                format!("{}", out.sink.dup_blocks_dropped),
                format!("{}", out.source.dup_acks_dropped),
                format!("{}", out.source.retries + out.sink.retries),
            ]);
            let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        }
    }
    print_table(
        "torture overhead (profile vs off, per FT mechanism)",
        &["profile", "mechanism", "ms", "x off", "dup blocks", "dup acks", "retries"],
        &rows,
    );

    // (b) recovery: profile + mid-transfer kill, resume under torture.
    let mut rows = Vec::new();
    for profile in ["reorder", "dup", "partition", "cut-stream"] {
        let cfg = torture_cfg(
            &format!("micro-torture-kill-{profile}"),
            profile,
            Mechanism::Universal,
        );
        let wl = workload::big_workload(files, blocks * cfg.object_size);
        let total = wl.total_objects(cfg.object_size);
        let env = SimEnv::new(cfg, &wl);
        let plan = FaultPlan::at_fraction(0.5, Side::Source);
        let label = plan.label_with(Some(profile));
        let out = env
            .run(&TransferSpec::fresh(env.files.clone()).with_fault(plan))
            .unwrap();
        assert!(!out.completed, "{label}: kill did not fire");
        let logged: u64 = ftlog::recover::recover_all(&env.cfg.ft())
            .unwrap()
            .values()
            .map(|s| s.count() as u64)
            .sum();
        let started = std::time::Instant::now();
        let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
        let resume_ms = started.elapsed().as_secs_f64() * 1e3;
        assert!(out2.completed, "{label}: resume failed: {:?}", out2.fault);
        assert!(
            out2.source.objects_sent <= total - logged,
            "{label}: resume retransmitted logged objects"
        );
        env.verify_sink_complete().unwrap();
        rows.push(vec![
            label,
            format!("{total}"),
            format!("{logged}"),
            format!("{}", out2.source.objects_skipped_resume),
            format!("{}", out2.source.objects_sent),
            format!("{resume_ms:.1}"),
        ]);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    print_table(
        "torture recovery (profile + mid-transfer kill, resume bound)",
        &["kill+profile", "total", "logged", "skipped", "resent", "resume ms"],
        &rows,
    );
}

fn bench_recovery_parse() {
    let blocks_per_file = 256u32;
    let files = 64usize;
    let mut rows = Vec::new();
    for mech in Mechanism::ALL_FT {
        for method in [
            Method::Char,
            Method::Int,
            Method::Enc,
            Method::Binary,
            Method::Bit8,
            Method::Bit64,
        ] {
            let dir = tmp_dir(&format!("rec-{}-{}", mech.as_str(), method.as_str()));
            let cfg = FtConfig {
                mechanism: mech,
                method,
                dir: dir.clone(),
                txn_size: 4,
            };
            // Produce a half-complete dataset (like an 80% fault).
            let mut logger = ftlog::create_logger(&cfg).unwrap();
            let mut rng = Pcg32::new(2);
            for f in 0..files {
                let key = logger
                    .register_file(&format!("f{f}"), blocks_per_file)
                    .unwrap();
                let mut order: Vec<u32> = (0..blocks_per_file).collect();
                rng.shuffle(&mut order);
                for &b in order.iter().take(blocks_per_file as usize / 2) {
                    logger.log_block(key, b).unwrap();
                }
            }
            drop(logger);
            let s = bench_seconds(1, 5, || {
                let rec = ftlog::recover::recover_all(&cfg).unwrap();
                assert_eq!(rec.len(), files);
            });
            let objs_per_sec =
                (files as f64 * blocks_per_file as f64 / 2.0) / s.mean;
            rows.push(vec![
                format!("{}/{}", mech.as_str(), method.as_str()),
                format!("{:.2}", s.mean * 1e3),
                format!("{:.2}M", objs_per_sec / 1e6),
            ]);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
    print_table(
        "recovery parse (64 files x 128 logged objects)",
        &["mechanism/method", "ms/parse", "objs/s"],
        &rows,
    );
}

fn bench_digest() {
    let words = 64 * 1024; // 256 KiB object
    let mut rng = Pcg32::new(3);
    let mut obj = vec![0u8; words * 4];
    rng.fill_bytes(&mut obj);
    let objs: Vec<&[u8]> = vec![&obj; 8];

    let engine = NativeEngine;
    let s = bench_seconds(3, 20, || {
        let d = engine.digest_batch(&objs, words).unwrap();
        std::hint::black_box(d);
    });
    let gbps = (8.0 * obj.len() as f64) / s.mean / 1e9;
    let mut rows = vec![vec![
        "native".to_string(),
        format!("{:.3}", s.mean * 1e3),
        format!("{gbps:.2}"),
    ]];

    // PJRT path if artifacts exist.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let service = ftlads::runtime::RuntimeService::start(&dir).unwrap();
        let engine = ftlads::integrity::PjrtEngine::new(service.handle()).unwrap();
        let s = bench_seconds(3, 20, || {
            let d = engine.digest_batch(&objs, words).unwrap();
            std::hint::black_box(d);
        });
        let gbps = (8.0 * obj.len() as f64) / s.mean / 1e9;
        rows.push(vec![
            "pjrt (batch 8)".to_string(),
            format!("{:.3}", s.mean * 1e3),
            format!("{gbps:.2}"),
        ]);
    }
    print_table(
        "digest throughput (8 x 256 KiB objects)",
        &["engine", "ms/batch", "GB/s"],
        &rows,
    );
}

fn bench_scheduler() {
    let osts = OstModel::new(11, OstConfig { time_scale: 0.0, ..Default::default() });
    let q: OstQueues<u64> = OstQueues::new(11);
    let n = 100_000u64;
    let s = bench_seconds(1, 5, || {
        for i in 0..n {
            q.push(OstId((i % 11) as u32), i);
        }
        for _ in 0..n {
            q.pop_least_congested(&osts).unwrap();
        }
    });
    let ops = 2.0 * n as f64 / s.mean;
    print_table(
        "OST queue scheduler",
        &["op", "Mops/s"],
        &[vec!["push+pop".into(), format!("{:.2}", ops / 1e6)]],
    );
}

fn bench_codec() {
    let mut rng = Pcg32::new(4);
    let mut data = vec![0u8; 256 << 10];
    rng.fill_bytes(&mut data);
    let msg = Message::NewBlock {
        file_idx: 3,
        block_idx: 77,
        offset: 77 << 18,
        digest: 0x1234_5678_9abc_def0,
        data: data.into(),
    };
    let mut buf = Vec::with_capacity(300 << 10);
    let s = bench_seconds(3, 30, || {
        buf.clear();
        msg.encode(&mut buf);
        let back = Message::decode(&buf).unwrap();
        std::hint::black_box(back);
    });
    let gbps = (256 << 10) as f64 / s.mean / 1e9;
    print_table(
        "NEW_BLOCK wire codec (256 KiB payload, encode+decode)",
        &["", "ms/rt", "GB/s"],
        &[vec!["codec".into(), format!("{:.3}", s.mean * 1e3), format!("{gbps:.2}")]],
    );
}

fn bench_completed_set() {
    let total = 4096u32;
    let mut rng = Pcg32::new(5);
    let mut order: Vec<u32> = (0..total).collect();
    rng.shuffle(&mut order);
    let s = bench_seconds(3, 50, || {
        let mut set = CompletedSet::new(total);
        for &b in &order {
            set.insert(b);
        }
        std::hint::black_box(set.pending().len());
    });
    print_table(
        "CompletedSet (4096 inserts + pending scan)",
        &["", "µs"],
        &[vec!["set".into(), format!("{:.1}", s.mean * 1e6)]],
    );
}

fn main() {
    println!("micro_hotpath — §Perf hot-path microbenchmarks");
    bench_digest();
    bench_codec();
    bench_scheduler();
    bench_completed_set();
    bench_log_append();
    bench_log_batch();
    bench_ack_batching();
    bench_send_window();
    bench_zero_copy();
    bench_write_coalesce();
    bench_multi_stream();
    bench_autotune();
    bench_serve();
    bench_torture();
    bench_recovery_parse();
    let _ = ftlads::bench_support::write_json_summary("micro_hotpath");
}
