//! Figure 10: recovery time of **all FT mechanisms × methods** at the
//! 80 % fault point, for (a) big and (b) small workloads.
//!
//! Expected shape (paper §6.4): for big workloads the file logger shows
//! the highest recovery among FT mechanisms (unsorted append parse);
//! Universal lowest; Bit8/Bit64 lowest among methods. For small
//! workloads all mechanisms/methods are similar.
//!
//! Run: `cargo bench --bench fig10_recovery_80`

use ftlads::bench_support::{
    measure_recovery_ftlads, print_table, BenchScale, Case,
};
use ftlads::ftlog::{Mechanism, Method};
use ftlads::stats::Series;

fn main() {
    let scale = BenchScale::from_env();
    println!("Figure 10 — recovery time at the 80% fault point");

    for (panel, wl) in [("(a) big", scale.big()), ("(b) small", scale.small())] {
        let mut rows = Vec::new();
        let iters = scale.iterations.max(3);
        for mech in Mechanism::ALL_FT {
            let mut row = vec![mech.as_str().to_string()];
            for m in Method::ALL {
                let mut s = Series::new();
                for i in 0..iters {
                    let r = measure_recovery_ftlads(
                        &scale,
                        &wl,
                        Case::Ft(mech, m),
                        0.8,
                        &format!("fig10-{panel}-{}-{}-{i}", mech.as_str(), m.as_str()),
                    );
                    s.push(r.estimated_recovery().as_secs_f64());
                }
                row.push(format!("{:.3}", s.summary().mean));
            }
            rows.push(row);
        }
        print_table(
            &format!("Fig 10 {panel} workload: ER_t (s) at 80% fault"),
            &["mechanism", "char", "int", "enc", "binary", "bit8", "bit64"],
            &rows,
        );
    }
    println!(
        "\nexpected shape: big — file row highest, universal lowest, bit8/bit64 \
         columns lowest; small — all cells similar"
    );
}
