//! Figure 6: performance comparison of LADS and FT-LADS, **small
//! workload** (paper: 10 000 × 1 MB files, each exactly one MTU).
//!
//! Same three panels as Fig 5. Expected shape (paper §6.2): overhead
//! still negligible but with visibly higher run-to-run variability (file
//! management overhead dominates with many small files).
//!
//! Run: `cargo bench --bench fig6_small_overhead`

use ftlads::bench_support::{print_table, run_case, BenchScale, Case};
use ftlads::stats::Series;

fn main() {
    let scale = BenchScale::from_env();
    let wl = scale.small();
    println!(
        "Figure 6 — small workload: {} files x {}, {} iterations",
        wl.file_count(),
        ftlads::util::fmt_bytes(scale.small_file_size),
        scale.iterations
    );

    let mut cases = vec![Case::Lads];
    cases.extend(Case::all_ft());

    let mut rows = Vec::new();
    let mut lads_time = None;
    let mut max_rel_ci: f64 = 0.0;
    for case in cases {
        let mut time = Series::new();
        let mut cpu = Series::new();
        let mut mem = Series::new();
        // one discarded warmup run per case (cold caches/thread spin-up
        // dominate the first run and would inflate the error bars)
        let _ = run_case(&scale, &wl, case, &format!("warm-{}", case.label()));
        for i in 0..scale.iterations {
            let out = run_case(&scale, &wl, case, &format!("fig6-{}-{i}", case.label()));
            time.push(out.elapsed.as_secs_f64());
            cpu.push(out.resources.cpu_percent);
            mem.push(out.resources.peak_rss_bytes as f64 / (1 << 20) as f64);
        }
        let t = time.summary();
        let c = cpu.summary();
        let m = mem.summary();
        if t.mean > 0.0 {
            max_rel_ci = max_rel_ci.max(t.ci99 / t.mean);
        }
        if case == Case::Lads {
            lads_time = Some(t.mean);
        }
        let overhead = lads_time
            .map(|base| format!("{:+.2}%", (t.mean / base - 1.0) * 100.0))
            .unwrap_or_default();
        rows.push(vec![
            case.label(),
            format!("{:.3}±{:.3}", t.mean, t.ci99),
            overhead,
            format!("{:.1}±{:.1}", c.mean, c.ci99),
            format!("{:.1}±{:.1}", m.mean, m.ci99),
        ]);
    }
    print_table(
        "Fig 6(a,b,c): small workload — transfer time / CPU / memory",
        &["case", "time (s, 99% CI)", "vs LADS", "cpu (%)", "peak rss (MiB)"],
        &rows,
    );
    println!(
        "\nexpected shape: overhead negligible; higher variability than Fig 5 \
         (max relative CI here: {:.1}%)",
        max_rel_ci * 100.0
    );
}
