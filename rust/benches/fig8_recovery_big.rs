//! Figure 8: recovery time of the **File logger** (all six methods) at
//! fault points 20/40/60/80 %, big workload — vs bbcp and LADS-restart.
//!
//! `ER_t = TBF_t + TAF_t − TT_t` (paper Eq. 1). Expected shape (§6.4.1):
//! file-logger recovery roughly flat across fault points (deleted logs of
//! completed files keep the parse bounded); ≈2× bbcp's offset-checkpoint
//! recovery; far below LADS-restart, which grows with the fault point.
//!
//! Run: `cargo bench --bench fig8_recovery_big`

use ftlads::bench_support::{
    measure_recovery_bbcp, measure_recovery_ftlads, print_table, BenchScale, Case,
};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{Mechanism, Method};
use ftlads::stats::Series;

fn main() {
    let scale = BenchScale::from_env();
    let wl = scale.big();
    println!(
        "Figure 8 — recovery time (s), big workload: {} files x {}",
        wl.file_count(),
        ftlads::util::fmt_bytes(scale.big_file_size)
    );

    let points = FaultPlan::paper_points();
    let mut rows = Vec::new();

    let iters = scale.iterations.max(3);
    let avg_ftlads = |case: Case, p: f64, tag: &str| -> String {
        let mut s = Series::new();
        for i in 0..iters {
            let r = measure_recovery_ftlads(&scale, &wl, case, p, &format!("{tag}-{i}"));
            s.push(r.estimated_recovery().as_secs_f64());
        }
        let sum = s.summary();
        format!("{:.3}", sum.mean)
    };

    // LADS-restart baseline (no FT: retransmit everything).
    let mut row = vec!["LADS (restart)".to_string()];
    for &p in &points {
        row.push(avg_ftlads(Case::Lads, p, "fig8-lads"));
    }
    rows.push(row);

    // bbcp baseline (offset checkpoint).
    let mut row = vec!["bbcp".to_string()];
    for &p in &points {
        let mut s = Series::new();
        for i in 0..iters {
            let r = measure_recovery_bbcp(&scale, &wl, p, &format!("fig8-bbcp-{i}"));
            s.push(r.estimated_recovery().as_secs_f64());
        }
        row.push(format!("{:.3}", s.summary().mean));
    }
    rows.push(row);

    // File logger × every method.
    for m in Method::ALL {
        let mut row = vec![format!("file/{}", m.as_str())];
        for &p in &points {
            row.push(avg_ftlads(
                Case::Ft(Mechanism::File, m),
                p,
                &format!("fig8-{}", m.as_str()),
            ));
        }
        rows.push(row);
    }

    print_table(
        "Fig 8: ER_t = TBF + TAF − TT (s) at fault points, big workload",
        &["case", "20%", "40%", "60%", "80%"],
        &rows,
    );
    println!(
        "\nexpected shape: LADS-restart grows with fault point; file-logger rows \
         ~flat and well below LADS; bbcp lowest (sequential offset checkpoint)"
    );
}
