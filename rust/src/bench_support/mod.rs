//! Shared harness for the figure benches (`rust/benches/fig*.rs`) and
//! the `examples/reproduce_figures.rs` driver.
//!
//! Each paper figure maps to one bench binary; this module holds the
//! common machinery: scaled workload definitions, the per-case runner
//! (fresh SimPfs pair + fresh FT dir per case), the Eq. (1) recovery-time
//! measurement `ER_t = TBF_t + TAF_t − TT_t`, and fixed-width table
//! printing that mirrors the paper's rows/series.
//!
//! Scaling: the paper's datasets (100 × 1 GB, 10 000 × 1 MB) are scaled
//! ~1/64 by default so a full figure regenerates in seconds; set
//! `FTLADS_BENCH_SCALE=paper` for the full sizes (hours) or `=quick` for
//! smoke runs. EXPERIMENTS.md records which scale produced each table.

use std::time::Duration;

use crate::config::Config;
use crate::coordinator::{SimEnv, TransferOutcome, TransferSpec};
use crate::fault::FaultPlan;
use crate::ftlog::{Mechanism, Method};
use crate::net::Side;
use crate::pfs::ost::OstId;
use crate::pfs::Pfs;
use crate::sched::SchedPolicy;
use crate::workload::{big_workload, small_workload, Workload};

/// Workload + iteration scaling for a figure run.
#[derive(Debug, Clone)]
pub struct BenchScale {
    pub big_files: usize,
    pub big_file_size: u64,
    pub small_files: usize,
    pub small_file_size: u64,
    /// Repetitions per case (error bars).
    pub iterations: usize,
    /// OST/wire time scaling (1.0 = modeled service times).
    pub time_scale: f64,
}

impl BenchScale {
    /// Default: ~1/64 of the paper, minutes per figure.
    pub fn default_scale() -> BenchScale {
        BenchScale {
            big_files: 24,
            big_file_size: 4 << 20, // 16 objects @ 256 KiB
            small_files: 192,
            small_file_size: 256 << 10, // file == one MTU (paper property)
            iterations: 3,
            time_scale: 1.0,
        }
    }

    /// Smoke scale for CI: seconds per figure.
    pub fn quick() -> BenchScale {
        BenchScale {
            big_files: 6,
            big_file_size: 1 << 20,
            small_files: 24,
            small_file_size: 256 << 10,
            iterations: 2,
            time_scale: 0.2,
        }
    }

    /// The paper's absolute sizes (needs ~100 GB of patience; the SimPfs
    /// never materializes the data, but service times are modeled).
    pub fn paper() -> BenchScale {
        BenchScale {
            big_files: 100,
            big_file_size: 1 << 30,
            small_files: 10_000,
            small_file_size: 1 << 20,
            iterations: 3,
            time_scale: 1.0,
        }
    }

    /// Resolve from `FTLADS_BENCH_SCALE` (quick|default|paper), with
    /// `FTLADS_BENCH_ITERS` overriding the per-case repetition count.
    pub fn from_env() -> BenchScale {
        let mut s = match std::env::var("FTLADS_BENCH_SCALE").as_deref() {
            Ok("quick") => Self::quick(),
            Ok("paper") => Self::paper(),
            _ => Self::default_scale(),
        };
        if let Ok(n) = std::env::var("FTLADS_BENCH_ITERS") {
            if let Ok(n) = n.parse() {
                s.iterations = n;
            }
        }
        s
    }

    pub fn big(&self) -> Workload {
        big_workload(self.big_files, self.big_file_size)
    }

    pub fn small(&self) -> Workload {
        // Small workload: file size must equal the MTU so that "a file
        // transfer state can be either completed or not" (paper §6.4.2).
        small_workload(self.small_files, self.small_file_size)
    }

    /// Base config for bench runs (object size = small file size = MTU).
    pub fn base_config(&self, tag: &str) -> Config {
        let mut cfg = Config::for_tests(tag);
        cfg.object_size = self.small_file_size;
        cfg.rma_bytes = 64 * self.small_file_size as usize;
        cfg.time_scale = self.time_scale;
        cfg
    }
}

/// One (mechanism, method) cell of Figs 5–7, or a baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Case {
    Lads, // stock LADS (no FT)
    Ft(Mechanism, Method),
}

impl Case {
    pub fn label(&self) -> String {
        match self {
            Case::Lads => "LADS".to_string(),
            Case::Ft(mech, m) => format!("{}/{}", mech.as_str(), m.as_str()),
        }
    }

    pub fn apply(&self, cfg: &mut Config) {
        match self {
            Case::Lads => cfg.mechanism = Mechanism::None,
            Case::Ft(mech, m) => {
                cfg.mechanism = *mech;
                cfg.method = *m;
            }
        }
    }

    /// All 18 FT cells (3 mechanisms × 6 methods).
    pub fn all_ft() -> Vec<Case> {
        let mut v = Vec::new();
        for mech in Mechanism::ALL_FT {
            for m in Method::ALL {
                v.push(Case::Ft(mech, m));
            }
        }
        v
    }
}

/// Run one complete (no-fault) transfer for a case; fresh env per call.
pub fn run_case(scale: &BenchScale, wl: &Workload, case: Case, tag: &str) -> TransferOutcome {
    let mut cfg = scale.base_config(tag);
    case.apply(&mut cfg);
    let env = SimEnv::new(cfg, wl);
    let out = env
        .run(&TransferSpec::fresh(env.files.clone()))
        .expect("bench transfer failed");
    assert!(out.completed, "bench case {} did not complete: {:?}", case.label(), out.fault);
    cleanup(&env);
    out
}

/// The source-side OSTs the scheduler ablation congests.
pub const CONGESTED_OSTS: [u32; 3] = [1, 4, 7];

/// Run one complete transfer under `policy` with OSTs
/// [`CONGESTED_OSTS`] externally loaded `load`× at the source — the
/// congested-OST workload the scheduler-policy axis (`benches/ablation.rs`
/// A6) sweeps across every [`SchedPolicy`].
pub fn run_sched_case(
    scale: &BenchScale,
    wl: &Workload,
    policy: SchedPolicy,
    load: f64,
    tag: &str,
) -> TransferOutcome {
    let mut cfg = scale.base_config(tag);
    cfg.mechanism = Mechanism::Universal;
    cfg.scheduler = policy;
    cfg.time_scale = scale.time_scale.max(0.5); // congestion needs real service times
    let env = SimEnv::new(cfg, wl);
    for ost in CONGESTED_OSTS {
        if ost < env.cfg.ost_count {
            Pfs::ost_model(&*env.source).set_external_load(OstId(ost), load);
        }
    }
    let out = env
        .run(&TransferSpec::fresh(env.files.clone()))
        .expect("sched bench transfer failed");
    assert!(
        out.completed,
        "sched case {} did not complete: {:?}",
        policy.as_str(),
        out.fault
    );
    cleanup(&env);
    out
}

/// Eq. (1) recovery measurement for one case at one fault fraction.
#[derive(Debug, Clone, Copy)]
pub struct Recovery {
    /// Time consumed before the fault.
    pub tbf: Duration,
    /// Time consumed after the fault (the resume run).
    pub taf: Duration,
    /// Fault-free transfer time for the same case.
    pub tt: Duration,
}

impl Recovery {
    /// ER_t = TBF_t + TAF_t − TT_t.
    pub fn estimated_recovery(&self) -> Duration {
        (self.tbf + self.taf).saturating_sub(self.tt)
    }
}

/// Measure recovery for an FT-LADS case: fault at `frac`, resume, and an
/// independent fault-free run for TT.
pub fn measure_recovery_ftlads(
    scale: &BenchScale,
    wl: &Workload,
    case: Case,
    frac: f64,
    tag: &str,
) -> Recovery {
    // TT: fault-free reference.
    let tt = run_case(scale, wl, case, &format!("{tag}-tt")).elapsed;

    // TBF: run to the fault.
    let mut cfg = scale.base_config(&format!("{tag}-f"));
    case.apply(&mut cfg);
    let env = SimEnv::new(cfg, wl);
    let faulted = env
        .run(
            &TransferSpec::fresh(env.files.clone())
                .with_fault(FaultPlan::at_fraction(frac, Side::Source)),
        )
        .expect("faulted run failed");
    assert!(!faulted.completed, "fault at {frac} did not trigger");

    // TAF: resume on the same env. Stock LADS cannot resume — it restarts
    // from scratch (retransmitting everything), which is the paper's
    // baseline for recovery overhead.
    let resume_spec = match case {
        Case::Lads => TransferSpec::fresh(env.files.clone()),
        Case::Ft(..) => TransferSpec::resuming(env.files.clone()),
    };
    let resumed = env.run(&resume_spec).expect("resume run failed");
    assert!(
        resumed.completed,
        "resume did not complete: {:?}",
        resumed.fault
    );
    env.verify_sink_complete().expect("post-resume verification");
    cleanup(&env);

    Recovery { tbf: faulted.elapsed, taf: resumed.elapsed, tt }
}

/// Measure recovery for the bbcp baseline at one fault fraction.
pub fn measure_recovery_bbcp(
    scale: &BenchScale,
    wl: &Workload,
    frac: f64,
    tag: &str,
) -> Recovery {
    use crate::baseline::bbcp::{run_bbcp, BbcpConfig};
    let mk_env = |t: &str| {
        let cfg = scale.base_config(t);
        SimEnv::new(cfg, wl)
    };

    let env_tt = mk_env(&format!("{tag}-tt"));
    let bcfg_tt = BbcpConfig::paper_defaults(&env_tt.cfg);
    let tt = run_bbcp(
        &env_tt.cfg,
        &bcfg_tt,
        env_tt.source.clone(),
        env_tt.sink.clone(),
        &env_tt.files,
        FaultPlan::none(),
    )
    .expect("bbcp tt run")
    .elapsed;
    cleanup(&env_tt);

    let env = mk_env(&format!("{tag}-f"));
    let bcfg = BbcpConfig::paper_defaults(&env.cfg);
    let faulted = run_bbcp(
        &env.cfg,
        &bcfg,
        env.source.clone(),
        env.sink.clone(),
        &env.files,
        FaultPlan::at_fraction(frac, Side::Source),
    )
    .expect("bbcp faulted run");
    assert!(!faulted.completed);
    let resumed = run_bbcp(
        &env.cfg,
        &bcfg,
        env.source.clone(),
        env.sink.clone(),
        &env.files,
        FaultPlan::none(),
    )
    .expect("bbcp resume run");
    assert!(resumed.completed, "bbcp resume failed: {:?}", resumed.fault);
    env.verify_sink_complete().expect("bbcp post-resume verify");
    cleanup(&env);

    Recovery { tbf: faulted.elapsed, taf: resumed.elapsed, tt }
}

/// Remove the per-case FT dir (fresh logger state per case).
pub fn cleanup(env: &SimEnv) {
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

// ---------------------------------------------------------------------------
// table printing + JSON summaries
// ---------------------------------------------------------------------------

/// One table as recorded for the machine-readable bench summary.
#[derive(Debug, Clone)]
struct RecordedTable {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

fn recorded_tables() -> &'static std::sync::Mutex<Vec<RecordedTable>> {
    static TABLES: std::sync::OnceLock<std::sync::Mutex<Vec<RecordedTable>>> =
        std::sync::OnceLock::new();
    TABLES.get_or_init(|| std::sync::Mutex::new(Vec::new()))
}

/// Write every table printed so far to `<dir>/<bench>.json` — the
/// artifact the CI `bench-smoke` job uploads. The shape is
/// `{"bench": ..., "tables": [{"title", "headers", "rows"}]}`.
pub fn write_json_summary_to(
    dir: &std::path::Path,
    bench: &str,
) -> anyhow::Result<std::path::PathBuf> {
    use crate::util::json::Json;
    let tables = recorded_tables()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .clone();
    let json_tables: Vec<Json> = tables
        .iter()
        .map(|t| {
            let mut m = std::collections::BTreeMap::new();
            m.insert("title".into(), Json::Str(t.title.clone()));
            m.insert(
                "headers".into(),
                Json::Arr(t.headers.iter().cloned().map(Json::Str).collect()),
            );
            m.insert(
                "rows".into(),
                Json::Arr(
                    t.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().cloned().map(Json::Str).collect()))
                        .collect(),
                ),
            );
            Json::Obj(m)
        })
        .collect();
    let mut root = std::collections::BTreeMap::new();
    root.insert("bench".into(), Json::Str(bench.to_string()));
    root.insert("tables".into(), Json::Arr(json_tables));
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{bench}.json"));
    std::fs::write(&path, format!("{}\n", Json::Obj(root)))?;
    Ok(path)
}

/// Env-gated summary hook for bench mains: when `FTLADS_BENCH_JSON_DIR`
/// is set, dump the recorded tables there and report the path on stdout.
pub fn write_json_summary(bench: &str) -> Option<std::path::PathBuf> {
    let dir = std::env::var_os("FTLADS_BENCH_JSON_DIR")?;
    match write_json_summary_to(std::path::Path::new(&dir), bench) {
        Ok(path) => {
            println!("\njson summary: {}", path.display());
            Some(path)
        }
        Err(e) => {
            eprintln!("bench summary write failed: {e:#}");
            None
        }
    }
}

/// Print a fixed-width table: `headers` then `rows` (first column left-
/// aligned, the rest right-aligned) — the shape the paper's figures
/// report. Every printed table is also recorded for
/// [`write_json_summary`].
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    recorded_tables()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .push(RecordedTable {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: rows.to_vec(),
        });
    println!("\n### {title}");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut line = String::new();
        for (i, cell) in cells.iter().enumerate() {
            if i == 0 {
                line.push_str(&format!("{:<width$}  ", cell, width = widths[0]));
            } else {
                line.push_str(&format!("{:>width$}  ", cell, width = widths[i]));
            }
        }
        line
    };
    let header_cells: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&header_cells));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

pub fn fmt_secs(d: Duration) -> String {
    format!("{:.3}", d.as_secs_f64())
}

pub fn fmt_secs_ci(mean: f64, ci: f64) -> String {
    format!("{mean:.3}±{ci:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_resolve() {
        let d = BenchScale::default_scale();
        assert_eq!(d.big().file_count(), 24);
        assert_eq!(d.small().total_objects(d.small_file_size), 192);
        let q = BenchScale::quick();
        assert!(q.big_files < d.big_files);
        let p = BenchScale::paper();
        assert_eq!(p.big_files, 100);
        assert_eq!(p.big_file_size, 1 << 30);
    }

    #[test]
    fn case_labels() {
        assert_eq!(Case::Lads.label(), "LADS");
        assert_eq!(
            Case::Ft(Mechanism::Universal, Method::Bit64).label(),
            "universal/bit64"
        );
        assert_eq!(Case::all_ft().len(), 18);
    }

    #[test]
    fn json_summary_captures_printed_tables() {
        print_table(
            "bs-json-test table",
            &["k", "v"],
            &[vec!["a".into(), "1".into()]],
        );
        let dir = std::env::temp_dir().join(format!("ftlads-bsjson-{}", std::process::id()));
        let path = write_json_summary_to(&dir, "bs-json-test").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(parsed.get("bench").as_str(), Some("bs-json-test"));
        let tables = parsed.get("tables").as_arr().unwrap();
        assert!(tables.iter().any(|t| {
            t.get("title").as_str() == Some("bs-json-test table")
                && t.get("rows").as_arr().is_some_and(|r| !r.is_empty())
        }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quick_recovery_roundtrip() {
        // Exercise the Eq. (1) machinery end to end at tiny scale.
        let scale = BenchScale {
            big_files: 3,
            big_file_size: 256 << 10,
            small_files: 4,
            small_file_size: 64 << 10,
            iterations: 1,
            time_scale: 0.0,
        };
        let wl = scale.big();
        let r = measure_recovery_ftlads(
            &scale,
            &wl,
            Case::Ft(Mechanism::File, Method::Bit64),
            0.5,
            "bs-rec",
        );
        // With time_scale 0 everything is fast, but the identity holds.
        assert!(r.estimated_recovery() <= r.tbf + r.taf);
    }
}
