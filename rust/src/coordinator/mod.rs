//! The LADS/FT-LADS coordinator: source and sink nodes, each with the
//! paper's thread structure (one master, one comm, N IO threads over
//! per-OST work queues), the BLOCK_SYNC protocol, FT logging and resume.
//!
//! Which OST queue an IO thread drains next is a pluggable policy
//! ([`crate::sched`]): the source runs `cfg.scheduler`, the sink runs
//! `cfg.sink_scheduler` (defaulting to the same policy), so asymmetric
//! source/sink scheduling experiments need no code changes.
//!
//! Entry point: [`TransferJob`] wires a source and a sink over an
//! in-process channel transport (the Verbs-like path), runs the transfer
//! to completion or injected fault, and reports timing/counters/space —
//! `TransferJob::builder(&cfg, &spec).source_pfs(..).sink_pfs(..).run()`.
//! The `ftlads` CLI's two-process mode uses the same source/sink
//! sessions ([`source::SourceSession`], [`sink::SinkSession`]) over the
//! TCP transport instead, and [`serve`] runs many such jobs concurrently
//! inside one long-lived daemon with a shared cross-job OST congestion
//! registry.

pub mod queues;
pub mod serve;
pub mod shard;
pub mod sink;
pub mod source;

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{Config, TortureSpec};
use crate::fault::FaultPlan;
use crate::ftlog::SpaceStats;
use crate::metrics::{CounterSnapshot, ResourceReport, Sampler};
use crate::net::{channel, Endpoint};
use crate::pfs::registry::JobOstHandle;
use crate::pfs::Pfs;
use crate::runtime::RuntimeHandle;
use crate::sched::SchedSnapshot;

/// What to transfer.
#[derive(Debug, Clone)]
pub struct TransferSpec {
    /// File names (must exist on the source PFS).
    pub files: Vec<String>,
    /// Resume an interrupted transfer (§5.2.2) instead of starting fresh.
    pub resume: bool,
    /// Injected fault plan (§6's simulation environment).
    pub fault: FaultPlan,
}

impl TransferSpec {
    pub fn fresh(files: Vec<String>) -> Self {
        TransferSpec { files, resume: false, fault: FaultPlan::none() }
    }

    pub fn resuming(files: Vec<String>) -> Self {
        TransferSpec { files, resume: true, fault: FaultPlan::none() }
    }

    pub fn with_fault(mut self, fault: FaultPlan) -> Self {
        self.fault = fault;
        self
    }
}

/// How a node obtains its K data connections when the CONNECT handshake
/// negotiates a multi-stream session (`data_streams ≥ 2`).
///
/// The in-process channel transport pre-creates every pair up front and
/// hands each side a [`DataPlane::Ready`] list; the TCP transport cannot
/// dial/accept before the negotiated K is known, so the CLI passes a
/// [`DataPlane::Connector`] closure that brings the connections up on
/// demand (source: dial K times; sink: accept K times and order the
/// connections by their STREAM_HELLO ids). A session that negotiates
/// K = 1 never materializes the plane — the single fused connection is
/// the control endpoint itself.
pub enum DataPlane {
    /// Pre-established endpoints, stream `s` at index `s`. May hold more
    /// than the negotiated K (the excess is dropped) but never fewer.
    Ready(Vec<Arc<dyn Endpoint>>),
    /// Bring up exactly K connections once K is known.
    #[allow(clippy::type_complexity)]
    Connector(Box<dyn FnOnce(u32) -> Result<Vec<Arc<dyn Endpoint>>> + Send>),
}

impl DataPlane {
    /// The plane of a session that can only ever negotiate K = 1 (the
    /// legacy single-connection entry points).
    pub fn none() -> DataPlane {
        DataPlane::Ready(Vec::new())
    }

    /// Produce the K per-stream endpoints. Only called for K ≥ 2.
    pub(crate) fn materialize(self, k: u32) -> Result<Vec<Arc<dyn Endpoint>>> {
        let k = k as usize;
        match self {
            DataPlane::Ready(mut eps) => {
                anyhow::ensure!(
                    eps.len() >= k,
                    "data plane has {} pre-established connections, negotiated {k}",
                    eps.len()
                );
                eps.truncate(k);
                Ok(eps)
            }
            DataPlane::Connector(f) => {
                let eps = f(k as u32)?;
                anyhow::ensure!(
                    eps.len() == k,
                    "data-plane connector produced {} connections, wanted {k}",
                    eps.len()
                );
                Ok(eps)
            }
        }
    }
}

/// Result of one transfer session.
#[derive(Debug, Clone)]
pub struct TransferOutcome {
    /// True iff every file was committed at the sink.
    pub completed: bool,
    /// The fault that ended the session, if any.
    pub fault: Option<String>,
    pub elapsed: Duration,
    pub source: CounterSnapshot,
    pub sink: CounterSnapshot,
    /// FT logger space accounting (Fig 7).
    pub log_space: SpaceStats,
    /// CPU/RSS over the run (Fig 5b/c, 6b/c).
    pub resources: ResourceReport,
    /// Payload bytes that crossed the wire.
    pub payload_bytes: u64,
    /// RMA reservation stalls at the source — (count, total ns) of times
    /// the issue loop found the slot pool dry. With the zero-copy path a
    /// slot buffer stays pinned until the sink releases the payload, so
    /// this is the send side's back-pressure signal.
    pub rma_stalls_src: (u64, u64),
    /// RMA reservation stalls at the sink — (count, total ns); the §3.1
    /// buffer-wait back-pressure signal.
    pub rma_stalls_snk: (u64, u64),
    /// Source read-queue scheduling counters (`cfg.scheduler`).
    pub source_sched: SchedSnapshot,
    /// Sink write-queue scheduling counters (`cfg.sink_scheduler`).
    pub sink_sched: SchedSnapshot,
    /// The NEW_BLOCK send window negotiated at CONNECT (1 = lockstep
    /// issue, the seed/PR 2 path).
    pub send_window: u32,
    /// The source's applied send window at session end — equal to the
    /// negotiated `send_window` in fixed mode, wherever the autotuner's
    /// grow/shrink feedback settled in `send_window_adaptive` mode.
    pub send_window_effective: u32,
    /// The sink's effective ack batch at session end — equal to the
    /// negotiated `ack_batch` in fixed mode, wherever the grow/shrink
    /// feedback settled in `ack_adaptive` mode.
    pub ack_batch_effective: u32,
    /// RMA DRAM registered per side at session end: `slots ×
    /// object_size` — the configured `rma_bytes` rounded down to whole
    /// object-sized slots, unless `rma_autosize` grew the pools toward
    /// `negotiated send_window × object_size` at CONNECT (both sides
    /// apply the same rule — with `data_streams = K ≥ 2` the source
    /// figure sums its K per-stream pools).
    pub rma_bytes_effective: u64,
    /// Parallel data streams negotiated at CONNECT (1 = the fused
    /// single-connection path, byte-identical to the pre-multi-stream
    /// wire; also the legacy-peer fallback).
    pub data_streams: u32,
    /// Unified autotuner (`tune`): epochs observed across both sides'
    /// controllers (0 when `tune` is off or the transfer finished inside
    /// the first epoch).
    pub tune_epochs: u64,
    /// Knob moves the controllers accepted upward / downward.
    pub tune_grows: u64,
    pub tune_shrinks: u64,
    /// Knob moves rolled back on goodput regression.
    pub tune_reverts: u64,
    /// Best single-epoch end-to-end goodput the source controller
    /// measured, bytes/sec (0.0 when `tune` is off) — the §A12
    /// convergence figure.
    pub goodput_final: f64,
    /// Human-readable knob move log, source entries prefixed `src `,
    /// sink entries `snk ` (empty when `tune` is off).
    pub tune_trajectory: Vec<String>,
}

impl TransferOutcome {
    pub fn throughput_bytes_per_sec(&self) -> f64 {
        self.payload_bytes as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Total payload memcpys across both sides. The zero-copy data path
    /// performs exactly one per transferred object (the source `pread`
    /// into the RMA slot); anything above `objects_sent` means a copy
    /// crept back onto the hot path.
    pub fn payload_copies(&self) -> u64 {
        self.source.payload_copies + self.sink.payload_copies
    }

    /// Total bytes moved by those copies.
    pub fn bytes_copied(&self) -> u64 {
        self.source.bytes_copied + self.sink.bytes_copied
    }
}

/// One in-process transfer job, built with [`TransferJob::builder`]:
/// the replacement for the historical five-positional-argument
/// `run_transfer(cfg, source_pfs, sink_pfs, spec, runtime)`.
///
/// ```ignore
/// let outcome = TransferJob::builder(&cfg, &spec)
///     .source_pfs(source)
///     .sink_pfs(sink)
///     .runtime(runtime)            // only needed for integrity = pjrt
///     .run()?;
/// ```
///
/// Under [`serve`] each job additionally gets a [`Self::job_id`] (its
/// own FT logger namespace, `<ft_dir>/job-<id>`) and a pair of shared
/// OST registry handles so concurrently running jobs steer around each
/// other's in-flight load. At the defaults (no id, no registry) the job
/// is behavior- and wire-identical to a standalone `run_transfer`.
pub struct TransferJob {
    cfg: Config,
    spec: TransferSpec,
    source_pfs: Option<Arc<dyn Pfs>>,
    sink_pfs: Option<Arc<dyn Pfs>>,
    runtime: Option<RuntimeHandle>,
    job_id: u64,
    shared_source_osts: Option<Arc<JobOstHandle>>,
    shared_sink_osts: Option<Arc<JobOstHandle>>,
    torture: Option<TortureSpec>,
}

impl TransferJob {
    /// Start describing a job. The config and spec are cloned so the
    /// job owns its state and can run on a daemon worker thread.
    pub fn builder(cfg: &Config, spec: &TransferSpec) -> TransferJob {
        TransferJob {
            cfg: cfg.clone(),
            spec: spec.clone(),
            source_pfs: None,
            sink_pfs: None,
            runtime: None,
            job_id: 0,
            shared_source_osts: None,
            shared_sink_osts: None,
            torture: None,
        }
    }

    /// The PFS the files are read from (required).
    pub fn source_pfs(mut self, pfs: Arc<dyn Pfs>) -> Self {
        self.source_pfs = Some(pfs);
        self
    }

    /// The PFS the files are written to (required).
    pub fn sink_pfs(mut self, pfs: Arc<dyn Pfs>) -> Self {
        self.sink_pfs = Some(pfs);
        self
    }

    /// PJRT runtime handle, required when `cfg.integrity == Pjrt` (the
    /// sink's verify path executes the compiled digest artifact
    /// through it).
    pub fn runtime(mut self, runtime: Option<RuntimeHandle>) -> Self {
        self.runtime = runtime;
        self
    }

    /// A daemon job id. Non-zero ids give the job its own FT logger
    /// namespace (`<ft_dir>/job-<id>`) so concurrent jobs' object logs
    /// never interleave — and each resumes from exactly its own log.
    /// 0 (the default) keeps the configured `ft_dir` as-is.
    pub fn job_id(mut self, id: u64) -> Self {
        self.job_id = id;
        self
    }

    /// Attach the job's handle on a daemon-wide *source-side* OST
    /// registry (see [`crate::pfs::OstRegistry`]).
    pub fn shared_source_osts(mut self, handle: Arc<JobOstHandle>) -> Self {
        self.shared_source_osts = Some(handle);
        self
    }

    /// Attach the job's handle on a daemon-wide *sink-side* OST
    /// registry.
    pub fn shared_sink_osts(mut self, handle: Arc<JobOstHandle>) -> Self {
        self.shared_sink_osts = Some(handle);
        self
    }

    /// Wrap every connection of this job in the adversarial torture
    /// transport (tests and property checks construct specs directly;
    /// the CLI arms one via `--torture-seed`/`--torture-profile`, which
    /// this override takes precedence over).
    pub fn torture(mut self, spec: TortureSpec) -> Self {
        self.torture = Some(spec);
        self
    }

    /// Run the job over the in-process channel transport, to completion
    /// or injected fault.
    pub fn run(self) -> Result<TransferOutcome> {
        let TransferJob {
            mut cfg,
            spec,
            source_pfs,
            sink_pfs,
            runtime,
            job_id,
            shared_source_osts,
            shared_sink_osts,
            torture,
        } = self;
        let source_pfs =
            source_pfs.ok_or_else(|| anyhow::anyhow!("TransferJob needs a source_pfs"))?;
        let sink_pfs =
            sink_pfs.ok_or_else(|| anyhow::anyhow!("TransferJob needs a sink_pfs"))?;
        if job_id != 0 {
            // Per-job FT namespace: logs (and §5.2.2 resume) are scoped
            // to the job, independent of the wire-level job tag.
            cfg.ft_dir = cfg.ft_dir.join(format!("job-{job_id}"));
        }
        cfg.validate()?;
        if cfg.integrity == crate::integrity::IntegrityMode::Pjrt {
            let rt = runtime
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("integrity=pjrt requires a RuntimeHandle"))?;
            anyhow::ensure!(
                rt.manifest.object_bytes as u64 == cfg.object_size,
                "object_size {} does not match artifact object size {} — rebuild artifacts \
                 or set object_size = {}",
                cfg.object_size,
                rt.manifest.object_bytes,
                rt.manifest.object_bytes
            );
        }

        // Total dataset bytes — the denominator for %-of-transfer fault
        // points.
        let mut total_bytes = 0u64;
        for name in &spec.files {
            let (_, meta) = source_pfs
                .lookup(name)
                .ok_or_else(|| anyhow::anyhow!("file '{name}' not on source PFS"))?;
            anyhow::ensure!(meta.size > 0, "zero-size file '{name}' not supported");
            total_bytes += meta.size;
        }

        let fault = spec.fault.arm(total_bytes);

        // Adversarial torture transport: an explicit builder override
        // wins, else the config's `--torture-seed`/`--torture-profile`
        // pair. With no spec (the default) the closure is the identity —
        // no wrapper type exists on the wire path at all.
        let torture = torture.or_else(|| cfg.torture());
        let wrap = |ep: Arc<dyn Endpoint>,
                    side: crate::net::Side,
                    stream: Option<u32>|
         -> Arc<dyn Endpoint> {
            match &torture {
                Some(spec) => Arc::new(crate::net::adversary::AdversaryEndpoint::new(
                    ep,
                    spec.clone(),
                    side,
                    stream,
                )),
                None => ep,
            }
        };

        let (src_ep, sink_ep) = channel::pair(cfg.wire(), fault.clone());
        let src_ep = wrap(Arc::new(src_ep), crate::net::Side::Source, None);
        let sink_ep = wrap(Arc::new(sink_ep), crate::net::Side::Sink, None);

        // Pre-establish the data plane: one extra channel pair per
        // requested stream, all sharing the session's fault controller —
        // a payload-threshold fault severs the control AND every data
        // connection at once, like a real node failure. The nodes only
        // consume these when CONNECT negotiates data_streams ≥ 2; a
        // fused session (K = 1) leaves them untouched (and unbuilt: no
        // pairs at K = 1, so the default path allocates exactly what the
        // seed did).
        let k = cfg.data_streams.max(1);
        let mut src_data: Vec<Arc<dyn Endpoint>> = Vec::new();
        let mut snk_data: Vec<Arc<dyn Endpoint>> = Vec::new();
        if k >= 2 {
            for s_id in 0..k {
                let (s, d) = channel::pair(cfg.wire(), fault.clone());
                src_data.push(wrap(Arc::new(s), crate::net::Side::Source, Some(s_id)));
                snk_data.push(wrap(Arc::new(d), crate::net::Side::Sink, Some(s_id)));
            }
        }

        let sampler = Sampler::start(Duration::from_millis(20));
        let started = Instant::now();

        let mut sink_session = sink::SinkSession::new(&cfg, sink_pfs, sink_ep)
            .data_plane(DataPlane::Ready(snk_data))
            .runtime(runtime);
        if let Some(h) = shared_sink_osts {
            sink_session = sink_session.shared_osts(h);
        }
        let sink_node = sink_session.spawn()?;
        let mut source_session =
            source::SourceSession::new(&cfg, source_pfs, src_ep.clone())
                .data_plane(DataPlane::Ready(src_data.clone()));
        if let Some(h) = shared_source_osts {
            source_session = source_session.shared_osts(h);
        }
        let source_report = source_session.run(&spec)?;
        let sink_report = sink_node.join();
        let elapsed = started.elapsed();
        let resources = sampler.finish();

        let fault_msg = source_report.fault.clone().or(sink_report.fault);
        let completed =
            fault_msg.is_none() && source_report.files_done as usize == spec.files.len();

        Ok(assemble_outcome(
            completed,
            fault_msg,
            elapsed,
            resources,
            src_ep.payload_sent()
                + src_data.iter().map(|ep| ep.payload_sent()).sum::<u64>(),
            source_report,
            sink_report,
        ))
    }
}

/// Fold the two session reports into the job's [`TransferOutcome`].
fn assemble_outcome(
    completed: bool,
    fault_msg: Option<String>,
    elapsed: Duration,
    resources: ResourceReport,
    payload_bytes: u64,
    source_report: source::SourceReport,
    sink_report: sink::SinkReport,
) -> TransferOutcome {
    TransferOutcome {
        completed,
        fault: fault_msg,
        elapsed,
        source: source_report.counters,
        sink: sink_report.counters,
        log_space: source_report.log_space,
        resources,
        // NEW_BLOCK payload crosses whichever connection carried it:
        // the fused control connection at K = 1, the data connections
        // at K ≥ 2 — the caller sums the endpoints it created.
        payload_bytes,
        rma_stalls_src: source_report.rma_stalls,
        rma_stalls_snk: sink_report.rma_stalls,
        source_sched: source_report.sched,
        sink_sched: sink_report.sched,
        send_window: source_report.send_window,
        send_window_effective: source_report.send_window_effective,
        ack_batch_effective: sink_report.ack_batch_effective,
        rma_bytes_effective: source_report.rma_bytes_effective,
        data_streams: source_report.data_streams,
        tune_epochs: source_report.counters.tune_epochs + sink_report.counters.tune_epochs,
        tune_grows: source_report.counters.tune_grows + sink_report.counters.tune_grows,
        tune_shrinks: source_report.counters.tune_shrinks
            + sink_report.counters.tune_shrinks,
        tune_reverts: source_report.counters.tune_reverts
            + sink_report.counters.tune_reverts,
        // The source controller differentiates end-to-end acked bytes, so
        // its best epoch IS the session's goodput figure.
        goodput_final: source_report.goodput_final,
        tune_trajectory: source_report
            .tune_trajectory
            .iter()
            .map(|t| format!("src {t}"))
            .chain(sink_report.tune_trajectory.iter().map(|t| format!("snk {t}")))
            .collect(),
    }
}

/// Run one transfer session over the in-process channel transport.
///
/// `runtime` is required when `cfg.integrity == Pjrt` (the sink's verify
/// path executes the compiled digest artifact through it).
#[deprecated(
    note = "use TransferJob::builder(cfg, spec).source_pfs(..).sink_pfs(..).runtime(..).run()"
)]
pub fn run_transfer(
    cfg: &Config,
    source_pfs: Arc<dyn Pfs>,
    sink_pfs: Arc<dyn Pfs>,
    spec: &TransferSpec,
    runtime: Option<RuntimeHandle>,
) -> Result<TransferOutcome> {
    TransferJob::builder(cfg, spec)
        .source_pfs(source_pfs)
        .sink_pfs(sink_pfs)
        .runtime(runtime)
        .run()
}

/// Convenience harness: a SimPfs pair populated with a workload. Used by
/// tests, examples and the figure benches.
pub struct SimEnv {
    pub cfg: Config,
    pub source: Arc<crate::pfs::sim::SimPfs>,
    pub sink: Arc<crate::pfs::sim::SimPfs>,
    pub files: Vec<String>,
}

impl SimEnv {
    pub fn new(cfg: Config, workload: &crate::workload::Workload) -> SimEnv {
        let source = Arc::new(crate::pfs::sim::SimPfs::new(
            cfg.layout(),
            cfg.ost_config(),
            cfg.seed,
        ));
        source.populate(&workload.as_tuples());
        let sink = Arc::new(crate::pfs::sim::SimPfs::new(
            cfg.layout(),
            cfg.ost_config(),
            cfg.seed,
        ));
        let files = workload.files.iter().map(|f| f.name.clone()).collect();
        SimEnv { cfg, source, sink, files }
    }

    pub fn run(&self, spec: &TransferSpec) -> Result<TransferOutcome> {
        self.run_with_runtime(spec, None)
    }

    pub fn run_with_runtime(
        &self,
        spec: &TransferSpec,
        runtime: Option<RuntimeHandle>,
    ) -> Result<TransferOutcome> {
        TransferJob::builder(&self.cfg, spec)
            .source_pfs(self.source.clone())
            .sink_pfs(self.sink.clone())
            .runtime(runtime)
            .run()
    }

    /// Check every byte of every file arrived intact at the sink: all
    /// object writes present with the digests the source data implies,
    /// and all files committed.
    pub fn verify_sink_complete(&self) -> Result<()> {
        for name in &self.files {
            let (_, meta) = self
                .sink
                .lookup(name)
                .ok_or_else(|| anyhow::anyhow!("'{name}' missing at sink"))?;
            anyhow::ensure!(meta.committed, "'{name}' not committed at sink");
            let (_, src_meta) = self.source.lookup(name).unwrap();
            anyhow::ensure!(
                meta.size == src_meta.size,
                "'{name}' size mismatch: {} vs {}",
                meta.size,
                src_meta.size
            );
            let objects = crate::util::div_ceil(src_meta.size, self.cfg.object_size);
            for b in 0..objects {
                let offset = b * self.cfg.object_size;
                let len = (src_meta.size - offset).min(self.cfg.object_size) as usize;
                let (got, glen) = self
                    .sink
                    .written_digest(name, offset)
                    .ok_or_else(|| anyhow::anyhow!("'{name}' block {b} never written"))?;
                anyhow::ensure!(glen as usize == len, "'{name}' block {b} length mismatch");
                let want = self.source.expected_digest(name, offset, len);
                anyhow::ensure!(got == want, "'{name}' block {b} digest mismatch");
            }
        }
        Ok(())
    }
}
