//! Per-OST work queues, policy-parametric dequeue.
//!
//! LADS's core scheduling idea (§2.1): requests are queued *per OST*, and
//! an IO thread picks its next request from whichever OST the configured
//! [`Scheduler`] policy chooses (see [`crate::sched`] for the policy
//! layer). The default, [`CongestionAware`], is the paper's behavior: the
//! least-congested OST that has work, so if one OST is slow (external
//! load, deep queue), threads naturally drain the others — "the N−1
//! threads are free to issue new requests to other OSTs".
//!
//! With a multi-stream data plane (`data_streams = K ≥ 2`) the *source*
//! builds one `OstQueues` per stream over that stream's OST shard
//! (`ost % K`), so each stream's IO threads run the policy over their own
//! pick domain and layout-aware scheduling is preserved per stream; the
//! *sink* keeps a single shared `OstQueues` — however the wire was
//! sharded, the storage side drains one policy-governed queue set.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::pfs::ost::{OstId, OstModel};
use crate::sched::{CongestionAware, OstCongestion, QueueView, SchedStats, Scheduler};

/// Work queues for one side's IO threads. `T` is the request type
/// (source: block reads; sink: block writes).
pub struct OstQueues<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

/// Per-item decision of a [`OstQueues::drain_chain`] callback.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainVerdict {
    /// Remove the item from the queue and append it to the run.
    Take,
    /// Leave the item queued and keep scanning.
    Skip,
    /// Abort the drain immediately — nothing further can chain.
    Stop,
}

struct Inner<T> {
    /// Per-OST FIFO of (global arrival sequence, request).
    queues: Vec<VecDeque<(u64, T)>>,
    queued: usize,
    /// Next arrival sequence number (strictly increasing across pushes).
    next_seq: u64,
    closed: bool,
    /// Reusable [`QueueView`] backing stores (rebuilt under the lock on
    /// every pick — no per-pop allocation on the hot path).
    len_scratch: Vec<usize>,
    seq_scratch: Vec<u64>,
}

impl<T> OstQueues<T> {
    pub fn new(ost_count: u32) -> Self {
        OstQueues {
            inner: Mutex::new(Inner {
                queues: (0..ost_count).map(|_| VecDeque::new()).collect(),
                queued: 0,
                next_seq: 0,
                closed: false,
                len_scratch: vec![0; ost_count as usize],
                seq_scratch: vec![u64::MAX; ost_count as usize],
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request for `ost` and wake one IO thread.
    pub fn push(&self, ost: OstId, item: T) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let seq = g.next_seq;
        g.next_seq += 1;
        g.queues[ost.0 as usize].push_back((seq, item));
        g.queued += 1;
        drop(g);
        self.cv.notify_one();
    }

    /// Enqueue a whole batch — e.g. every pending object of a file at
    /// admission — under a single lock acquisition, then wake *all* IO
    /// threads. One `notify_all` after the batch (instead of one
    /// `notify_one` per item) means no wakeup can be lost to a thread
    /// that is mid-pop and not yet waiting: any thread that misses the
    /// broadcast finds `queued > 0` when it next takes the lock. Returns
    /// the number of requests enqueued.
    pub fn push_batch(&self, items: impl IntoIterator<Item = (OstId, T)>) -> usize {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let mut n = 0usize;
        for (ost, item) in items {
            let seq = g.next_seq;
            g.next_seq += 1;
            g.queues[ost.0 as usize].push_back((seq, item));
            n += 1;
        }
        g.queued += n;
        drop(g);
        if n > 0 {
            self.cv.notify_all();
        }
        n
    }

    /// Dequeue from whichever non-empty OST `sched` picks. Blocks until
    /// work arrives or the queues are closed (returns None once drained).
    ///
    /// The policy is consulted under the queue lock with a fresh
    /// [`QueueView`]; a policy that returns `None` or an empty/
    /// out-of-range OST falls back to the lowest-id non-empty queue, so
    /// progress never depends on policy correctness.
    pub fn pop_next(&self, sched: &dyn Scheduler, osts: &OstModel) -> Option<(OstId, T)> {
        self.pop_next_inner(sched, &OstCongestion::local(osts), None)
    }

    /// [`pop_next`](Self::pop_next) that dequeues through a full
    /// [`OstCongestion`] view (own depth + cross-job foreign load under a
    /// serve daemon) and records pick count, pick latency, fallback picks,
    /// and cross-job steering into `stats` — the coordinator entry point
    /// behind the per-policy counters in `TransferOutcome`.
    pub fn pop_next_timed(
        &self,
        sched: &dyn Scheduler,
        cong: &OstCongestion<'_>,
        stats: &SchedStats,
    ) -> Option<(OstId, T)> {
        self.pop_next_inner(sched, cong, Some(stats))
    }

    fn pop_next_inner(
        &self,
        sched: &dyn Scheduler,
        cong: &OstCongestion<'_>,
        stats: Option<&SchedStats>,
    ) -> Option<(OstId, T)> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if g.queued > 0 {
                let n = g.queues.len();
                for i in 0..n {
                    let len = g.queues[i].len();
                    let seq = g.queues[i].front().map(|(s, _)| *s).unwrap_or(u64::MAX);
                    g.len_scratch[i] = len;
                    g.seq_scratch[i] = seq;
                }
                let view = QueueView { len: &g.len_scratch, head_seq: &g.seq_scratch };
                let pick_started = stats.map(|_| std::time::Instant::now());
                let picked = sched.pick(&view, cong);
                let (idx, fallback) = match picked {
                    Some(o) if (o.0 as usize) < n && !g.queues[o.0 as usize].is_empty() => {
                        (o.0 as usize, false)
                    }
                    _ => (
                        g.queues
                            .iter()
                            .position(|q| !q.is_empty())
                            .expect("queued > 0 implies a non-empty queue"),
                        true,
                    ),
                };
                if let (Some(stats), Some(t0)) = (stats, pick_started) {
                    stats.record_pick(t0.elapsed(), fallback);
                    // Cross-job steering accounting: the pick counts as
                    // "shared" when another job's load was visible on at
                    // least one candidate, and as an "avoid" when the
                    // chosen OST itself carried none of it. One pass, no
                    // second `pick` — policies like RoundRobin mutate
                    // state per consultation.
                    if cong.has_shared() {
                        let any_foreign = (0..n).any(|i| {
                            g.len_scratch[i] > 0 && cong.foreign(OstId(i as u32)) > 0
                        });
                        if any_foreign {
                            stats.record_shared(cong.foreign(OstId(idx as u32)) == 0);
                        }
                    }
                }
                let (_, item) = g.queues[idx].pop_front().unwrap();
                g.queued -= 1;
                return Some((OstId(idx as u32), item));
            }
            if g.closed {
                return None;
            }
            // Wake periodically so a closed/fault flag set without a
            // notify (e.g. panicking peer) cannot strand us.
            let (guard, _) = self
                .cv
                .wait_timeout(g, Duration::from_millis(20))
                .unwrap_or_else(|e| e.into_inner());
            g = guard;
        }
    }

    /// Drain further requests from `ost`'s queue that chain onto a head
    /// the caller already popped — the sink's write-coalescing gather.
    ///
    /// `accept` is consulted for each queued item in arrival order and is
    /// expected to be *stateful* (tracking the run's next byte offset and
    /// remaining budget): [`DrainVerdict::Take`] removes the item and
    /// appends it to the returned run, [`DrainVerdict::Skip`] leaves it
    /// in place, and [`DrainVerdict::Stop`] ends the whole drain
    /// immediately (the caller proved nothing further can chain — e.g.
    /// the unique next-contiguous block busts the byte budget). The scan
    /// repeats until a full pass takes nothing, so out-of-order arrivals
    /// (block N+1 queued before block N) still chain once their
    /// predecessor is taken; `Stop` keeps the scan from re-walking the
    /// backlog under the queue lock once the run cannot grow.
    ///
    /// This deliberately bypasses the [`Scheduler`]: the policy already
    /// picked this OST for the head, and the drained items ride the same
    /// service round. The tie-break contract is preserved — non-taken
    /// items keep their relative arrival order and head sequence numbers,
    /// so subsequent `pick` consultations see exactly the queue state the
    /// contract promises.
    pub fn drain_chain(&self, ost: OstId, mut accept: impl FnMut(&T) -> DrainVerdict) -> Vec<T> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let qi = ost.0 as usize;
        let mut out = Vec::new();
        if qi >= g.queues.len() {
            return out;
        }
        loop {
            let mut progressed = false;
            let mut i = 0;
            while i < g.queues[qi].len() {
                match accept(&g.queues[qi][i].1) {
                    DrainVerdict::Take => {
                        let (_, item) = g.queues[qi].remove(i).expect("index checked");
                        out.push(item);
                        g.queued -= 1;
                        progressed = true;
                        // Do not advance: the next item shifted into slot i.
                    }
                    DrainVerdict::Skip => i += 1,
                    DrainVerdict::Stop => return out,
                }
            }
            if !progressed {
                break;
            }
        }
        out
    }

    /// Seed-compatible entry point: dequeue with the paper's
    /// congestion-aware policy (depth, then queue length, then OstId).
    /// Equivalent to `pop_next(&CongestionAware, osts)`.
    pub fn pop_least_congested(&self, osts: &OstModel) -> Option<(OstId, T)> {
        self.pop_next(&CongestionAware, osts)
    }

    /// Close the queues: blocked and future pops return None once drained.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.closed = true;
        drop(g);
        self.cv.notify_all();
    }

    /// Close and drop all queued work (abort path).
    pub fn close_and_clear(&self) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.closed = true;
        g.queued = 0;
        for q in &mut g.queues {
            q.clear();
        }
        drop(g);
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).queued
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::ost::OstConfig;
    use crate::sched::{FifoFile, RoundRobin};
    use std::sync::Arc;

    fn model(n: u32) -> OstModel {
        OstModel::new(n, OstConfig { time_scale: 0.0, ..Default::default() })
    }

    #[test]
    fn push_pop_fifo_within_ost() {
        let q: OstQueues<u32> = OstQueues::new(3);
        let m = model(3);
        q.push(OstId(1), 10);
        q.push(OstId(1), 11);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_least_congested(&m), Some((OstId(1), 10)));
        assert_eq!(q.pop_least_congested(&m), Some((OstId(1), 11)));
        assert!(q.is_empty());
    }

    #[test]
    fn prefers_longer_queue_when_equally_idle() {
        let q: OstQueues<u32> = OstQueues::new(3);
        let m = model(3);
        q.push(OstId(0), 1);
        q.push(OstId(2), 2);
        q.push(OstId(2), 3);
        // Both OSTs idle -> deeper backlog first (drain pressure).
        assert_eq!(q.pop_least_congested(&m), Some((OstId(2), 2)));
    }

    #[test]
    fn avoids_congested_ost() {
        let q: OstQueues<u32> = OstQueues::new(2);
        let m = Arc::new(OstModel::new(
            2,
            OstConfig {
                base_latency: Duration::from_millis(50),
                max_concurrent: 1,
                time_scale: 1.0,
                ..Default::default()
            },
        ));
        q.push(OstId(0), 1);
        q.push(OstId(1), 2);
        // Busy out OST 0.
        let m2 = m.clone();
        let h = std::thread::spawn(move || m2.service(OstId(0), 0, false));
        std::thread::sleep(Duration::from_millis(10));
        // Scheduler must pick OST 1's work even though OST 0 enqueued first.
        assert_eq!(q.pop_least_congested(&m), Some((OstId(1), 2)));
        h.join().unwrap();
    }

    #[test]
    fn close_unblocks_waiters() {
        let q: Arc<OstQueues<u32>> = Arc::new(OstQueues::new(1));
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let m = model(1);
            q2.pop_least_congested(&m)
        });
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn close_drains_remaining_work_first() {
        let q: OstQueues<u32> = OstQueues::new(1);
        let m = model(1);
        q.push(OstId(0), 7);
        q.close();
        assert_eq!(q.pop_least_congested(&m), Some((OstId(0), 7)));
        assert_eq!(q.pop_least_congested(&m), None);
    }

    #[test]
    fn close_and_clear_drops_work() {
        let q: OstQueues<u32> = OstQueues::new(1);
        let m = model(1);
        q.push(OstId(0), 7);
        q.close_and_clear();
        assert_eq!(q.pop_least_congested(&m), None);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q: Arc<OstQueues<u64>> = Arc::new(OstQueues::new(4));
        let m = Arc::new(model(4));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    q.push(OstId((i % 4) as u32), t * 1000 + i);
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = q.clone();
            let m = m.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((_, v)) = q.pop_least_congested(&m) {
                    got.push(v);
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap().len()).sum();
        assert_eq!(total, 400);
    }

    #[test]
    fn push_batch_enqueues_everything_in_order() {
        let q: OstQueues<u32> = OstQueues::new(3);
        let m = model(3);
        let n = q.push_batch([(OstId(0), 1u32), (OstId(2), 2), (OstId(0), 3)]);
        assert_eq!(n, 3);
        assert_eq!(q.len(), 3);
        // Global arrival order is preserved across push and push_batch.
        assert_eq!(q.pop_next(&FifoFile, &m), Some((OstId(0), 1)));
        assert_eq!(q.pop_next(&FifoFile, &m), Some((OstId(2), 2)));
        assert_eq!(q.pop_next(&FifoFile, &m), Some((OstId(0), 3)));
        assert_eq!(q.push_batch(std::iter::empty()), 0);
    }

    #[test]
    fn push_batch_wakes_all_blocked_consumers() {
        let q: Arc<OstQueues<u32>> = Arc::new(OstQueues::new(4));
        let m = Arc::new(model(4));
        let mut consumers = Vec::new();
        for _ in 0..4 {
            let q = q.clone();
            let m = m.clone();
            consumers.push(std::thread::spawn(move || q.pop_least_congested(&m)));
        }
        std::thread::sleep(Duration::from_millis(20));
        q.push_batch((0..4u32).map(|i| (OstId(i), i)));
        let mut got: Vec<Option<(OstId, u32)>> =
            consumers.into_iter().map(|c| c.join().unwrap()).collect();
        got.sort();
        let items: Vec<u32> = got.into_iter().map(|o| o.unwrap().1).collect();
        assert_eq!(items, vec![0, 1, 2, 3]);
        q.close();
    }

    #[test]
    fn drain_chain_takes_matching_items_and_keeps_order() {
        let q: OstQueues<u32> = OstQueues::new(2);
        let m = model(2);
        q.push_batch([
            (OstId(0), 10u32),
            (OstId(0), 99), // non-matching, must survive in place
            (OstId(0), 11),
            (OstId(1), 12), // other OST, never touched
            (OstId(0), 12),
        ]);
        // Chain 10 -> 11 -> 12 (stateful accept), leaving 99 queued.
        let mut next = 10u32;
        let run = q.drain_chain(OstId(0), |&v| {
            if v == next {
                next += 1;
                DrainVerdict::Take
            } else {
                DrainVerdict::Skip
            }
        });
        assert_eq!(run, vec![10, 11, 12]);
        assert_eq!(q.len(), 2);
        // The survivor kept its position; the other OST is untouched.
        assert_eq!(q.pop_least_congested(&m), Some((OstId(0), 99)));
        assert_eq!(q.pop_least_congested(&m), Some((OstId(1), 12)));
        assert!(q.is_empty());
    }

    #[test]
    fn drain_chain_chains_out_of_order_arrivals() {
        let q: OstQueues<u32> = OstQueues::new(1);
        // Successor queued BEFORE its predecessor: one pass would miss it,
        // the fixpoint rescan must not.
        q.push_batch([(OstId(0), 2u32), (OstId(0), 1)]);
        let mut next = 1u32;
        let run = q.drain_chain(OstId(0), |&v| {
            if v == next {
                next += 1;
                DrainVerdict::Take
            } else {
                DrainVerdict::Skip
            }
        });
        assert_eq!(run, vec![1, 2]);
        assert!(q.is_empty());
        // Out-of-range OST is a no-op.
        assert!(q
            .drain_chain(OstId(9), |_| DrainVerdict::Take)
            .is_empty());
    }

    #[test]
    fn drain_chain_stop_ends_the_scan_immediately() {
        let q: OstQueues<u32> = OstQueues::new(1);
        let m = model(1);
        q.push_batch([(OstId(0), 1u32), (OstId(0), 2), (OstId(0), 3)]);
        let mut calls = 0;
        let run = q.drain_chain(OstId(0), |&v| {
            calls += 1;
            match v {
                1 => DrainVerdict::Take,
                2 => DrainVerdict::Stop, // e.g. budget exhausted
                _ => DrainVerdict::Skip,
            }
        });
        assert_eq!(run, vec![1]);
        assert_eq!(calls, 2, "Stop must end the drain without rescanning");
        // Both survivors stay queued in arrival order.
        assert_eq!(q.pop_least_congested(&m), Some((OstId(0), 2)));
        assert_eq!(q.pop_least_congested(&m), Some((OstId(0), 3)));
    }

    #[test]
    fn pop_next_round_robin_cycles() {
        let q: OstQueues<u32> = OstQueues::new(3);
        let m = model(3);
        let rr = RoundRobin::new();
        q.push_batch([
            (OstId(0), 0u32),
            (OstId(0), 1),
            (OstId(1), 2),
            (OstId(2), 3),
        ]);
        assert_eq!(q.pop_next(&rr, &m), Some((OstId(0), 0)));
        assert_eq!(q.pop_next(&rr, &m), Some((OstId(1), 2)));
        assert_eq!(q.pop_next(&rr, &m), Some((OstId(2), 3)));
        assert_eq!(q.pop_next(&rr, &m), Some((OstId(0), 1)));
    }

    #[test]
    fn pop_next_falls_back_when_policy_misbehaves() {
        struct Bogus;
        impl Scheduler for Bogus {
            fn name(&self) -> &'static str {
                "bogus"
            }
            fn pick(&self, _view: &QueueView<'_>, _cong: &OstCongestion<'_>) -> Option<OstId> {
                Some(OstId(999)) // out of range
            }
        }
        let q: OstQueues<u32> = OstQueues::new(2);
        let m = model(2);
        q.push(OstId(1), 5);
        // Progress guaranteed: falls back to the lowest-id non-empty queue.
        assert_eq!(q.pop_next(&Bogus, &m), Some((OstId(1), 5)));
        // And the timed variant counts the fallback.
        q.push(OstId(0), 6);
        let stats = SchedStats::default();
        assert_eq!(
            q.pop_next_timed(&Bogus, &OstCongestion::local(&m), &stats),
            Some((OstId(0), 6))
        );
        let snap = stats.snapshot();
        assert_eq!(snap.picks, 1);
        assert_eq!(snap.fallback_picks, 1);
    }

    #[test]
    fn pop_next_timed_records_pick_counters() {
        let q: OstQueues<u32> = OstQueues::new(3);
        let m = model(3);
        let stats = SchedStats::default();
        q.push_batch([(OstId(0), 1u32), (OstId(1), 2), (OstId(2), 3)]);
        for _ in 0..3 {
            assert!(q
                .pop_next_timed(&CongestionAware, &OstCongestion::local(&m), &stats)
                .is_some());
        }
        let snap = stats.snapshot();
        assert_eq!(snap.picks, 3);
        assert_eq!(snap.fallback_picks, 0);
        // No registry handle: never counted as a shared pick.
        assert_eq!(snap.shared_picks, 0);
        assert_eq!(snap.shared_avoids, 0);
    }

    #[test]
    fn pop_next_timed_counts_cross_job_steering() {
        use crate::pfs::registry::OstRegistry;
        let q: OstQueues<u32> = OstQueues::new(3);
        let m = model(3);
        let reg = OstRegistry::new(3);
        let me = reg.handle();
        let other = reg.handle();
        // Another job saturates OST 0.
        for _ in 0..4 {
            other.begin(OstId(0));
        }
        q.push_batch([(OstId(0), 1u32), (OstId(1), 2)]);
        let stats = SchedStats::default();
        let cong = OstCongestion::with_shared(&m, Some(&me));
        // Foreign depth 4 on OST 0 steers the pick to OST 1 → an avoid.
        assert_eq!(
            q.pop_next_timed(&CongestionAware, &cong, &stats),
            Some((OstId(1), 2))
        );
        // Only the hot OST remains: forced onto it → shared, not avoided.
        assert_eq!(
            q.pop_next_timed(&CongestionAware, &cong, &stats),
            Some((OstId(0), 1))
        );
        let snap = stats.snapshot();
        assert_eq!(snap.picks, 2);
        assert_eq!(snap.shared_picks, 2);
        assert_eq!(snap.shared_avoids, 1);
        // Once the other job drains, picks stop counting as shared.
        for _ in 0..4 {
            other.end(OstId(0));
        }
        q.push(OstId(2), 3);
        assert_eq!(
            q.pop_next_timed(&CongestionAware, &cong, &stats),
            Some((OstId(2), 3))
        );
        assert_eq!(stats.snapshot().shared_picks, 2);
    }

    #[test]
    fn pop_next_close_unblocks_all_policies() {
        let q: Arc<OstQueues<u32>> = Arc::new(OstQueues::new(2));
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let m = model(2);
            q2.pop_next(&FifoFile, &m)
        });
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }
}
