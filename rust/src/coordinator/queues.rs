//! Per-OST work queues + the layout/congestion-aware dequeue policy.
//!
//! LADS's core scheduling idea (§2.1): requests are queued *per OST*, and
//! an IO thread picks its next request from the least-congested OST that
//! has work. If one OST is slow (external load, deep queue), threads
//! naturally drain the others — "the N−1 threads are free to issue new
//! requests to other OSTs".

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::pfs::ost::{OstId, OstModel};

/// Work queues for one side's IO threads. `T` is the request type
/// (source: block reads; sink: block writes).
pub struct OstQueues<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

struct Inner<T> {
    queues: Vec<VecDeque<T>>,
    queued: usize,
    closed: bool,
}

impl<T> OstQueues<T> {
    pub fn new(ost_count: u32) -> Self {
        OstQueues {
            inner: Mutex::new(Inner {
                queues: (0..ost_count).map(|_| VecDeque::new()).collect(),
                queued: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request for `ost` and wake one IO thread.
    pub fn push(&self, ost: OstId, item: T) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.queues[ost.0 as usize].push_back(item);
        g.queued += 1;
        drop(g);
        self.cv.notify_one();
    }

    /// Dequeue from the least-congested non-empty OST (congestion signal =
    /// the OST model's in-service depth; ties by queue length then id).
    /// Blocks until work arrives or the queues are closed (returns None).
    pub fn pop_least_congested(&self, osts: &OstModel) -> Option<(OstId, T)> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if g.queued > 0 {
                let pick = g
                    .queues
                    .iter()
                    .enumerate()
                    .filter(|(_, q)| !q.is_empty())
                    .min_by_key(|(i, q)| {
                        (osts.queue_depth(OstId(*i as u32)), usize::MAX - q.len(), *i)
                    })
                    .map(|(i, _)| i);
                if let Some(i) = pick {
                    let item = g.queues[i].pop_front().unwrap();
                    g.queued -= 1;
                    return Some((OstId(i as u32), item));
                }
            }
            if g.closed {
                return None;
            }
            // Wake periodically so a closed/fault flag set without a
            // notify (e.g. panicking peer) cannot strand us.
            let (guard, _) = self
                .cv
                .wait_timeout(g, Duration::from_millis(20))
                .unwrap_or_else(|e| e.into_inner());
            g = guard;
        }
    }

    /// Close the queues: blocked and future pops return None once drained.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.closed = true;
        drop(g);
        self.cv.notify_all();
    }

    /// Close and drop all queued work (abort path).
    pub fn close_and_clear(&self) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.closed = true;
        g.queued = 0;
        for q in &mut g.queues {
            q.clear();
        }
        drop(g);
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).queued
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::ost::OstConfig;
    use std::sync::Arc;

    fn model(n: u32) -> OstModel {
        OstModel::new(n, OstConfig { time_scale: 0.0, ..Default::default() })
    }

    #[test]
    fn push_pop_fifo_within_ost() {
        let q: OstQueues<u32> = OstQueues::new(3);
        let m = model(3);
        q.push(OstId(1), 10);
        q.push(OstId(1), 11);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_least_congested(&m), Some((OstId(1), 10)));
        assert_eq!(q.pop_least_congested(&m), Some((OstId(1), 11)));
        assert!(q.is_empty());
    }

    #[test]
    fn prefers_longer_queue_when_equally_idle() {
        let q: OstQueues<u32> = OstQueues::new(3);
        let m = model(3);
        q.push(OstId(0), 1);
        q.push(OstId(2), 2);
        q.push(OstId(2), 3);
        // Both OSTs idle -> deeper backlog first (drain pressure).
        assert_eq!(q.pop_least_congested(&m), Some((OstId(2), 2)));
    }

    #[test]
    fn avoids_congested_ost() {
        let q: OstQueues<u32> = OstQueues::new(2);
        let m = Arc::new(OstModel::new(
            2,
            OstConfig {
                base_latency: Duration::from_millis(50),
                max_concurrent: 1,
                time_scale: 1.0,
                ..Default::default()
            },
        ));
        q.push(OstId(0), 1);
        q.push(OstId(1), 2);
        // Busy out OST 0.
        let m2 = m.clone();
        let h = std::thread::spawn(move || m2.service(OstId(0), 0, false));
        std::thread::sleep(Duration::from_millis(10));
        // Scheduler must pick OST 1's work even though OST 0 enqueued first.
        assert_eq!(q.pop_least_congested(&m), Some((OstId(1), 2)));
        h.join().unwrap();
    }

    #[test]
    fn close_unblocks_waiters() {
        let q: Arc<OstQueues<u32>> = Arc::new(OstQueues::new(1));
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let m = model(1);
            q2.pop_least_congested(&m)
        });
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn close_drains_remaining_work_first() {
        let q: OstQueues<u32> = OstQueues::new(1);
        let m = model(1);
        q.push(OstId(0), 7);
        q.close();
        assert_eq!(q.pop_least_congested(&m), Some((OstId(0), 7)));
        assert_eq!(q.pop_least_congested(&m), None);
    }

    #[test]
    fn close_and_clear_drops_work() {
        let q: OstQueues<u32> = OstQueues::new(1);
        let m = model(1);
        q.push(OstId(0), 7);
        q.close_and_clear();
        assert_eq!(q.pop_least_congested(&m), None);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q: Arc<OstQueues<u64>> = Arc::new(OstQueues::new(4));
        let m = Arc::new(model(4));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    q.push(OstId((i % 4) as u32), t * 1000 + i);
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = q.clone();
            let m = m.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((_, v)) = q.pop_least_congested(&m) {
                    got.push(v);
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap().len()).sum();
        assert_eq!(total, 400);
    }
}
