//! Sink node: comm + master + N IO threads + optional PJRT verifier
//! (paper §3.1/Fig 4 with the §5.1 BLOCK_SYNC change).
//!
//! - **comm** receives NEW_FILE (running the §5.2.2 metadata match),
//!   NEW_BLOCK (reserving an RMA slot — the §3.1 bounded-buffer credit;
//!   if the pool is dry the request parks with the master; the payload
//!   itself stays refcounted off the transport and is never copied into
//!   the slot), and FILE_CLOSE (commit + ack).
//! - **master** sleeps on the RMA pool and requeues parked blocks once a
//!   slot frees up — the paper's buffer-wait path.
//! - **IO threads** pull the OST write queue picked by the sink's
//!   scheduling policy (`cfg.sink_scheduler`/`cfg.scheduler`, default:
//!   least-congested — see [`crate::sched`]), `pwrite` the object
//!   straight from the refcounted payload (zero-copy; charging the OST
//!   model), verify the digest, release the slot, and — with
//!   `write_coalesce_bytes > 0` — first drain further byte-contiguous
//!   objects of the same file from the same OST queue and submit the
//!   gathered run as ONE vectored `pwrite`
//!   ([`crate::pfs::Pfs::write_at_vectored`]; one syscall, one OST
//!   service round), while every constituent block keeps its own digest
//!   verify and BLOCK_SYNC ack. When a run's chain broke with budget to
//!   spare (the byte-successor simply hadn't arrived yet — e.g. it was
//!   held behind the source's credit window until this run's acks went
//!   out), the thread re-checks the queue after acking and *continues*
//!   the run from the successor instead of returning to the scheduler
//!   (`Counters::coalesce_continuations`). Then
//!   send BLOCK_SYNC — directly when `ack_batch = 1` (the paper's
//!   per-object path), or through the **ack coalescer**, which folds up
//!   to `ack_batch` acknowledgements of a file into one
//!   BLOCK_SYNC_BATCH, flushing on a full batch, on a failed write
//!   (prompt retransmission), on FILE_CLOSE, or when a dedicated flusher
//!   thread notices the batch's oldest entry aged past `ack_flush_us`.
//!   With `ack_adaptive` on, the applied batch size floats between 1 and
//!   the negotiated cap: count-driven flushes grow it, timer-driven
//!   flushes shrink it (see `AckCoalescer`).
//! - **verifier** (integrity = pjrt): IO threads hand written objects
//!   over; it batches them into the compiled Pallas digest artifact's
//!   fixed (B, W) shape, executes it via the PJRT service, and emits the
//!   BLOCK_SYNCs. This is the L1/L2 integration point on the hot path.
//!
//! # Multi-stream data plane (`data_streams > 1`)
//!
//! With a negotiated `data_streams = K ≥ 2` the sink serves one
//! **control** connection (CONNECT, NEW_FILE, FILE_CLOSE, BYE) plus K
//! **data** connections, one comm thread each. NEW_BLOCK only arrives on
//! data connections, sharded by the source's bytes-weighted LPT plan
//! ([`super::shard`]); each data
//! stream owns its own RMA slot pool (its half of the per-stream credit
//! accounting) and its own ack coalescer, and BLOCK_SYNC(_BATCH) for a
//! block returns on the stream that carried it — which is exactly the
//! stream whose credit window the source charged. The sink never needs
//! the plan on the wire: it *learns* each OST's stream from the data
//! connection its first NEW_BLOCK arrives on. The write path is
//! unchanged: all streams feed the one set of per-OST write queues and
//! the same IO threads. The negotiated `data_streams = 1` (default, and
//! the legacy field-less peer fallback) runs the single fused connection
//! exactly as before — byte-identical to the pre-multi-stream wire.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::queues::{DrainVerdict, OstQueues};
use super::DataPlane;
use crate::config::Config;
use crate::integrity::{Digest, DigestEngine, IntegrityMode, NativeEngine, PjrtEngine};
use crate::metrics::{Counters, CounterSnapshot};
use crate::net::{Endpoint, Message, NetError, RmaPool, RmaSlot};
use crate::pfs::ost::OstId;
use crate::pfs::registry::JobOstHandle;
use crate::pfs::{FileId, Pfs};
use crate::runtime::RuntimeHandle;
use crate::sched::{OstCongestion, SchedSnapshot, SchedStats, Scheduler};
use crate::util::bytes::Bytes;

/// One received object awaiting pwrite.
struct WriteReq {
    file_idx: u32,
    block_idx: u32,
    fid: FileId,
    offset: u64,
    digest: u64,
    /// The OST serving this block — fixes which data stream's coalescer/
    /// endpoint the acknowledgement returns on (`ost % K`, the same
    /// shard the source charged a credit on).
    ost: OstId,
    /// The object payload, refcounted straight off the transport —
    /// `pwrite` runs from this view, no copy into the slot buffer.
    payload: Bytes,
    /// Storage fidelity, stamped after the write: `false` when the PFS
    /// reported that what it persisted differs from the payload (the
    /// §3.2 read-back verification channel) — the block then fails
    /// verification and is retransmitted.
    faithful: bool,
    /// Held for pool accounting only: the §3.1 bounded-buffer credit
    /// (back-pressure + park/wake path); released on drop after the
    /// write finishes.
    _slot: RmaSlot,
}

struct SnkFile {
    fid: FileId,
    start_ost: u32,
    /// Blocks whose write finished AND verified: the sink half of the
    /// idempotency ledger. A NEW_BLOCK for a member is a duplicate
    /// delivery — never re-written, only re-acked `ok` so a source that
    /// lost the first ack can still make progress. Failed-verify blocks
    /// leave the ledger entirely: their retransmission must be writable.
    done: BTreeSet<u32>,
    /// Blocks accepted onto a write queue but not yet finished. A
    /// duplicate arriving while the original is in flight is dropped
    /// silently — the pending write will ack it exactly once.
    inflight: BTreeSet<u32>,
    /// Set on FILE_CLOSE, when both block sets are cleared: a committed
    /// file's every block is durable, so the per-block ledger entries
    /// carry no information anymore — dropping them bounds ledger
    /// memory by the largest OPEN file, not by the whole transfer. A
    /// late duplicate for a closed file is answered like a `done`
    /// member (re-acked `ok`, payload dropped), and a write that lands
    /// after the close must not resurrect ledger entries.
    closed: bool,
}

/// Per-file acknowledgements waiting to be coalesced into one
/// BLOCK_SYNC_BATCH.
struct PendingAcks {
    /// When the oldest entry was queued — the flush-window clock.
    oldest: Instant,
    blocks: Vec<(u32, bool)>,
}

/// The ack coalescer's shared state (one per connection that carries
/// acks: the fused connection at K = 1, each data stream at K ≥ 2).
/// `batch <= 1` bypasses coalescing entirely, reproducing the seed's
/// one-BLOCK_SYNC-per-object wire behavior exactly.
///
/// With `adaptive` on, `batch` is only the *cap*: the effective batch
/// (`eff`) starts at 1, doubles toward the cap every time a batch fills
/// on count (the wire is keeping up, coalesce harder), and halves every
/// time the `ack_flush_us` straggler window fires on a partial batch
/// (coalescing is adding latency without amortizing anything, back off).
struct AckCoalescer {
    /// Batch-size cap: the sink's configured `ack_batch`, negotiated
    /// down to the peer's CONNECT advertisement.
    batch: AtomicU32,
    /// Effective batch size actually applied per ack (== `batch` when
    /// adaptation is off).
    eff: AtomicU32,
    /// Grow/shrink `eff` from flush feedback (`Config::ack_adaptive`).
    adaptive: bool,
    /// The unified epoch tuner drives `eff` (`Config::tune`): like
    /// `adaptive` it starts the effective batch at the floor, but the
    /// movements come from [`crate::tune::HillClimb`] instead of flush
    /// feedback.
    tuned: bool,
    /// Straggler bound: flush a partial batch once its oldest entry is
    /// this old.
    window: Duration,
    pending: Mutex<BTreeMap<u32, PendingAcks>>,
}

impl AckCoalescer {
    fn new(cap: u32, adaptive: bool, tuned: bool, window: Duration) -> AckCoalescer {
        AckCoalescer {
            batch: AtomicU32::new(cap.max(1)),
            // Adaptive/tuned coalescing starts at the seed's per-object
            // floor and earns its way up; fixed mode pins eff to the cap.
            eff: AtomicU32::new(if adaptive || tuned { 1 } else { cap.max(1) }),
            adaptive,
            tuned,
            window,
            pending: Mutex::new(BTreeMap::new()),
        }
    }

    /// A batch filled on count: the coalescer can afford a bigger one.
    /// Atomic read-modify-write: IO threads (grow) and the flusher
    /// (shrink) race on `eff`, and a lost update would silently erase a
    /// feedback step.
    fn feedback_grow(&self, counters: &Counters) {
        if !self.adaptive {
            return;
        }
        let cap = self.batch.load(Ordering::SeqCst);
        let grown = self.eff.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |eff| {
            if eff < cap {
                Some(eff.saturating_mul(2).min(cap))
            } else {
                None
            }
        });
        if grown.is_ok() {
            counters.ack_batch_grows.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The flush window fired on a partial batch: back off.
    fn feedback_shrink(&self, counters: &Counters) {
        if !self.adaptive {
            return;
        }
        let shrunk = self.eff.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |eff| {
            if eff > 1 {
                Some((eff / 2).max(1))
            } else {
                None
            }
        });
        if shrunk.is_ok() {
            counters.ack_batch_shrinks.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// One data stream's receive state at K ≥ 2: its wire endpoint, its RMA
/// slot pool (this side of the per-stream credit accounting) and its ack
/// coalescer. Built by the control comm thread once CONNECT negotiates
/// the stream count.
struct SnkStream {
    ep: Arc<dyn Endpoint>,
    acks: AckCoalescer,
    rma: RmaPool,
}

struct Shared {
    pfs: Arc<dyn Pfs>,
    /// The control connection. At `data_streams = 1` it doubles as the
    /// (only) data connection — the fused legacy path.
    ep: Arc<dyn Endpoint>,
    /// The write-queue set is SHARED across streams: data comm threads
    /// all enqueue here and the same IO-thread pool drains it, so the
    /// storage side is indifferent to how the wire was sharded.
    queues: OstQueues<WriteReq>,
    /// The sink's OST dequeue policy (`cfg.sink_scheduler`, falling back
    /// to the session-wide `cfg.scheduler`).
    sched: Box<dyn Scheduler>,
    sched_stats: SchedStats,
    /// The fused connection's ack coalescer (used only at K = 1).
    acks: AckCoalescer,
    /// The sink's configured NEW_BLOCK send-window cap; the CONNECT
    /// handshake replies with `min(this, peer's advertisement)`.
    send_window: AtomicU32,
    /// The sink's configured data-stream cap; CONNECT negotiates
    /// `min(this, peer's advertisement)`.
    data_streams_cfg: u32,
    /// Per-stream pools at K ≥ 2 are carved with this same budget
    /// (`Config::rma_bytes`).
    rma_bytes: usize,
    /// Contiguous-write coalescing budget (`Config::write_coalesce_bytes`);
    /// 0 = the seed-exact one-pwrite-per-object path. Atomic because the
    /// unified tuner walks it mid-transfer; IO threads snapshot it once
    /// per run.
    coalesce_bytes: AtomicU64,
    /// Ceiling the tuner may grow the coalesce budget to
    /// (`Config::coalesce_cap`).
    coalesce_cap: u64,
    /// Run the sink half of the unified epoch tuner (`Config::tune`).
    tune: bool,
    /// The tuner's sampling period (`Config::tune_epoch_ms`).
    tune_epoch_ms: u64,
    /// OST → stream map, learned from which data connection each OST's
    /// first NEW_BLOCK arrived on (the source's LPT plan, observed
    /// passively). Acks must return on the stream whose credit was
    /// charged; an OST not yet seen falls back to `ost % K` (only
    /// reachable for the ack of the very block that would have taught
    /// us, which enqueue_block records first).
    ost_stream: Mutex<BTreeMap<u32, usize>>,
    /// The sink tuner's move/revert log, drained into the session report.
    tune_trajectory: Mutex<Vec<String>>,
    /// Grow the RMA pool(s) toward the negotiated window at CONNECT
    /// (`Config::rma_autosize`).
    autosize: bool,
    /// The fused connection's RMA pool (the only pool at K = 1; unused
    /// once a K ≥ 2 plane materializes).
    rma: RmaPool,
    /// The data plane at K ≥ 2, set exactly once by the control comm
    /// thread after negotiation, before any data comm thread exists.
    /// Empty (unset) for the whole life of a fused session.
    data: OnceLock<Vec<SnkStream>>,
    /// Data streams whose connection died (K ≥ 2 only). The source
    /// re-homes the dead stream's OSTs onto survivors, so a single
    /// stream's death is survivable; only when EVERY data stream is gone
    /// does the sink abort.
    data_dead: AtomicUsize,
    counters: Counters,
    files: Mutex<BTreeMap<u32, SnkFile>>,
    /// This job's charge handle on the daemon's shared sink-side
    /// [`crate::pfs::OstRegistry`] (None for standalone transfers). IO
    /// threads fold its foreign load into every dequeue's congestion
    /// view; enqueue/complete charge and discharge it, and dropping the
    /// session drains whatever a killed job still had in flight.
    shared_osts: Option<Arc<JobOstHandle>>,
    abort: Mutex<Option<String>>,
    aborted: AtomicBool,
    done: AtomicBool,
    integrity: IntegrityMode,
    padded_words: usize,
    /// Set from the CONNECT handshake: the peer is resuming, so the
    /// §5.2.2 metadata match may skip committed files. A *fresh* transfer
    /// must rewrite everything (stock-LADS restart retransmits all).
    resume: AtomicBool,
}

impl Shared {
    fn abort_with(&self, msg: String) {
        let mut g = self.abort.lock().unwrap_or_else(|e| e.into_inner());
        if g.is_none() {
            *g = Some(msg);
        }
        drop(g);
        self.aborted.store(true, Ordering::SeqCst);
        self.queues.close_and_clear();
    }

    fn is_aborted(&self) -> bool {
        self.aborted.load(Ordering::SeqCst)
    }

    /// Negotiated stream count: 1 until (unless) a K ≥ 2 plane is set.
    fn k(&self) -> usize {
        self.data.get().map(|d| d.len()).unwrap_or(1)
    }

    /// Which stream a block's acknowledgement returns on — the stream
    /// the source's shard plan sent the OST's blocks over, learned from
    /// arrivals (`ost_stream`), so the credit released by the ack is the
    /// credit that was charged.
    fn stream_for_ost(&self, ost: OstId) -> usize {
        let k = self.k();
        if k == 1 {
            return 0;
        }
        self.ost_stream
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&ost.0)
            .copied()
            .unwrap_or(ost.0 as usize % k)
    }

    /// Stream `s`'s RMA pool (the fused pool when no plane is set).
    fn pool(&self, s: usize) -> &RmaPool {
        match self.data.get() {
            Some(d) => &d[s].rma,
            None => &self.rma,
        }
    }

    /// Stream `s`'s ack coalescer (the fused one when no plane is set).
    fn coalescer(&self, s: usize) -> &AckCoalescer {
        match self.data.get() {
            Some(d) => &d[s].acks,
            None => &self.acks,
        }
    }

    /// The endpoint stream `s`'s acknowledgements ride.
    fn ack_ep(&self, s: usize) -> &Arc<dyn Endpoint> {
        match self.data.get() {
            Some(d) => &d[s].ep,
            None => &self.ep,
        }
    }

    /// Queue one object acknowledgement on its stream. With an effective
    /// batch `<= 1` this sends the seed's single BLOCK_SYNC immediately;
    /// otherwise the ack joins the file's pending batch on that stream's
    /// coalescer, which flushes when full or when the write failed (so
    /// retransmission is never delayed by coalescing). Count-driven
    /// flushes feed the adaptive coalescer's grow signal.
    fn push_ack(&self, stream: usize, file_idx: u32, block_idx: u32, ok: bool) {
        let acks = self.coalescer(stream);
        let batch = acks.eff.load(Ordering::SeqCst) as usize;
        if batch <= 1 {
            self.counters.ack_messages.fetch_add(1, Ordering::Relaxed);
            let _ = self
                .ack_ep(stream)
                .send(Message::BlockSync { file_idx, block_idx, ok });
            if ok {
                // An adaptive coalescer ramps off the floor from here: a
                // one-ack "batch" trivially filled on count.
                acks.feedback_grow(&self.counters);
            }
            return;
        }
        let (full, filled) = {
            let mut pending = acks.pending.lock().unwrap_or_else(|e| e.into_inner());
            let entry = pending.entry(file_idx).or_insert_with(|| PendingAcks {
                oldest: Instant::now(),
                // Cap the eager reservation: huge negotiated batches must
                // not preallocate huge buffers per file.
                blocks: Vec::with_capacity(batch.min(1024)),
            });
            entry.blocks.push((block_idx, ok));
            let filled = entry.blocks.len() >= batch;
            if !ok || filled {
                (pending.remove(&file_idx), filled && ok)
            } else {
                (None, false)
            }
        };
        if filled {
            acks.feedback_grow(&self.counters);
        }
        if let Some(p) = full {
            self.send_ack_batch(stream, file_idx, p.blocks);
        }
    }

    /// Emit one coalesced ack message (called outside the pending lock).
    fn send_ack_batch(&self, stream: usize, file_idx: u32, blocks: Vec<(u32, bool)>) {
        if blocks.is_empty() {
            return;
        }
        self.counters.ack_messages.fetch_add(1, Ordering::Relaxed);
        let _ = self
            .ack_ep(stream)
            .send(Message::BlockSyncBatch { file_idx, blocks });
    }

    /// Flush one file's pending acks on EVERY stream (FILE_CLOSE
    /// hygiene: nothing of the file may linger once it commits — and at
    /// K ≥ 2 a file's blocks were sharded across all of them).
    fn flush_acks_for(&self, file_idx: u32) {
        for s in 0..self.k() {
            let p = {
                let mut pending =
                    self.coalescer(s).pending.lock().unwrap_or_else(|e| e.into_inner());
                pending.remove(&file_idx)
            };
            if let Some(p) = p {
                self.send_ack_batch(s, file_idx, p.blocks);
            }
        }
    }

    /// Flush, on every stream, each batch whose oldest entry aged past
    /// the flush window — or everything when `all` (shutdown path). A
    /// timer-driven flush of a partial batch is the adaptive coalescer's
    /// shrink signal (one step per stream per sweep, not per file, so a
    /// multi-file burst does not collapse the window to 1 in one tick).
    fn flush_expired_acks(&self, all: bool) {
        for s in 0..self.k() {
            let acks = self.coalescer(s);
            let expired: Vec<(u32, PendingAcks)> = {
                let mut pending = acks.pending.lock().unwrap_or_else(|e| e.into_inner());
                let keys: Vec<u32> = pending
                    .iter()
                    .filter(|(_, p)| all || p.oldest.elapsed() >= acks.window)
                    .map(|(&k, _)| k)
                    .collect();
                keys.into_iter()
                    .map(|k| {
                        let p = pending.remove(&k).expect("key collected under this lock");
                        (k, p)
                    })
                    .collect()
            };
            if !all && !expired.is_empty() {
                acks.feedback_shrink(&self.counters);
            }
            for (file_idx, p) in expired {
                self.send_ack_batch(s, file_idx, p.blocks);
            }
        }
    }
}

pub struct SinkReport {
    pub fault: Option<String>,
    pub counters: CounterSnapshot,
    pub rma_stalls: (u64, u64),
    /// Write-queue scheduling counters (picks, pick latency, service).
    pub sched: SchedSnapshot,
    /// The effective ack batch at session end: the negotiated cap in
    /// fixed mode, wherever the grow/shrink feedback left it in adaptive
    /// mode. With several streams, the most constrained (minimum)
    /// stream's effective batch.
    pub ack_batch_effective: u32,
    /// The NEW_BLOCK send window granted to the peer at CONNECT.
    pub send_window: u32,
    /// RMA DRAM actually registered at session end (`slots ×
    /// object_size` per pool, i.e. `rma_bytes` rounded down to whole
    /// slots), unless `rma_autosize` grew each pool toward the
    /// negotiated send window at CONNECT. Summed over the data streams
    /// at K ≥ 2 (the idle fused pool is excluded).
    pub rma_bytes_effective: u64,
    /// The sink tuner's move/revert log, one line per knob step.
    pub tune_trajectory: Vec<String>,
    /// `(fid, block)` dedup-ledger entries still held at session end
    /// (done + in-flight, summed over files). FILE_CLOSE retires a
    /// file's entries, so a fault-free session ends at 0 no matter how
    /// many blocks it moved — the ledger is bounded by open files.
    pub ledger_blocks: u64,
}

/// Handle to the running sink node.
pub struct SinkNode {
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

/// A configured-but-not-yet-running sink job: the entry point for
/// serving the sink half of a transfer. Construct with [`new`]
/// (`SinkSession::new`), optionally attach a multi-stream data plane, a
/// PJRT runtime, or a shared OST registry handle, then [`spawn`]
/// (`SinkSession::spawn`) to get a joinable [`SinkNode`].
///
/// ```ignore
/// let node = SinkSession::new(&cfg, pfs, ep)
///     .data_plane(plane)          // only needed for data_streams >= 2
///     .runtime(handle)            // only needed for integrity = pjrt
///     .spawn()?;
/// let report = node.join();
/// ```
///
/// With all options at their defaults this is behavior- and
/// wire-identical to the historical `spawn_sink(cfg, pfs, ep, None)`.
pub struct SinkSession<'a> {
    cfg: &'a Config,
    pfs: Arc<dyn Pfs>,
    ep: Arc<dyn Endpoint>,
    plane: DataPlane,
    runtime: Option<RuntimeHandle>,
    shared_osts: Option<Arc<JobOstHandle>>,
}

impl<'a> SinkSession<'a> {
    /// A session over a single control connection, with no data plane
    /// (fused single-stream unless [`Self::data_plane`] is attached), no
    /// PJRT runtime, and no shared OST registry.
    pub fn new(cfg: &'a Config, pfs: Arc<dyn Pfs>, ep: Arc<dyn Endpoint>) -> SinkSession<'a> {
        SinkSession { cfg, pfs, ep, plane: DataPlane::none(), runtime: None, shared_osts: None }
    }

    /// Supply the per-stream data connections, consumed only when the
    /// CONNECT handshake negotiates `data_streams ≥ 2`.
    pub fn data_plane(mut self, plane: DataPlane) -> Self {
        self.plane = plane;
        self
    }

    /// Supply the PJRT runtime handle (required for `integrity = pjrt`).
    pub fn runtime(mut self, runtime: Option<RuntimeHandle>) -> Self {
        self.runtime = runtime;
        self
    }

    /// Attach this job's handle on a daemon-wide sink-side
    /// [`crate::pfs::OstRegistry`], so dequeues steer around other jobs'
    /// in-flight load and this job's own load is visible to them.
    pub fn shared_osts(mut self, handle: Arc<JobOstHandle>) -> Self {
        self.shared_osts = Some(handle);
        self
    }

    /// Spawn the sink: comm + master + IO threads (+ verifier with
    /// pjrt). Never blocks — negotiation happens asynchronously in the
    /// comm thread, so the in-process harness can spawn the sink and run
    /// the source on the same thread.
    pub fn spawn(self) -> Result<SinkNode> {
        spawn_session(self.cfg, self.pfs, self.ep, self.plane, self.runtime, self.shared_osts)
    }
}

/// Spawn the sink over a single fused connection (the legacy /
/// `data_streams = 1` path). Fails fast when `cfg.data_streams > 1` —
/// a multi-stream session needs a data-plane provider.
#[deprecated(note = "use SinkSession::new(cfg, pfs, ep).runtime(runtime).spawn()")]
pub fn spawn_sink(
    cfg: &Config,
    pfs: Arc<dyn Pfs>,
    ep: Arc<dyn Endpoint>,
    runtime: Option<RuntimeHandle>,
) -> Result<SinkNode> {
    anyhow::ensure!(
        cfg.data_streams <= 1,
        "data_streams = {} needs a data-plane provider: attach a data plane",
        cfg.data_streams
    );
    spawn_session(cfg, pfs, ep, DataPlane::none(), runtime, None)
}

/// Spawn the sink with an explicit data plane.
#[deprecated(note = "use SinkSession::new(cfg, pfs, ep).data_plane(plane).spawn()")]
pub fn spawn_sink_multi(
    cfg: &Config,
    pfs: Arc<dyn Pfs>,
    ep: Arc<dyn Endpoint>,
    plane: DataPlane,
    runtime: Option<RuntimeHandle>,
) -> Result<SinkNode> {
    spawn_session(cfg, pfs, ep, plane, runtime, None)
}

/// The session body behind [`SinkSession::spawn`] (and the deprecated
/// free-function wrappers).
fn spawn_session(
    cfg: &Config,
    pfs: Arc<dyn Pfs>,
    ep: Arc<dyn Endpoint>,
    plane: DataPlane,
    runtime: Option<RuntimeHandle>,
    shared_osts: Option<Arc<JobOstHandle>>,
) -> Result<SinkNode> {
    let shared = Arc::new(Shared {
        pfs,
        ep,
        queues: OstQueues::new(cfg.ost_count),
        sched: cfg.sink_sched().build(cfg.ost_count),
        sched_stats: SchedStats::default(),
        acks: AckCoalescer::new(
            cfg.ack_batch_cap(),
            cfg.ack_adaptive,
            cfg.tune,
            Duration::from_micros(cfg.ack_flush_us.max(1)),
        ),
        send_window: AtomicU32::new(cfg.send_window_cap()),
        data_streams_cfg: cfg.data_streams.max(1),
        rma_bytes: cfg.rma_bytes,
        coalesce_bytes: AtomicU64::new(cfg.write_coalesce_bytes),
        coalesce_cap: cfg.coalesce_cap(),
        tune: cfg.tune,
        tune_epoch_ms: cfg.tune_epoch_ms,
        ost_stream: Mutex::new(BTreeMap::new()),
        tune_trajectory: Mutex::new(Vec::new()),
        autosize: cfg.rma_autosize,
        rma: RmaPool::new(cfg.rma_bytes, cfg.object_size as usize),
        data: OnceLock::new(),
        data_dead: AtomicUsize::new(0),
        counters: Counters::default(),
        files: Mutex::new(BTreeMap::new()),
        shared_osts,
        abort: Mutex::new(None),
        aborted: AtomicBool::new(false),
        done: AtomicBool::new(false),
        integrity: cfg.integrity,
        padded_words: (cfg.object_size as usize).div_ceil(4),
        resume: AtomicBool::new(false),
    });

    let mut threads = Vec::new();

    // Verifier channel (pjrt mode only).
    let verify_tx: Option<mpsc::Sender<WriteReq>> = if cfg.integrity == IntegrityMode::Pjrt {
        let handle = runtime
            .ok_or_else(|| anyhow::anyhow!("integrity=pjrt requires a RuntimeHandle"))?;
        let engine = PjrtEngine::new(handle)?;
        let (tx, rx) = mpsc::channel::<WriteReq>();
        let sh = shared.clone();
        threads.push(
            std::thread::Builder::new()
                .name("snk-verify".into())
                .spawn(move || verifier_thread(&sh, engine, rx))?,
        );
        Some(tx)
    } else {
        None
    };

    // Parked-block channel: comm -> master when a stream's RMA pool is
    // dry; tagged with the stream so the master waits on the RIGHT pool.
    let (park_tx, park_rx) = mpsc::channel::<(usize, Message)>();

    // IO threads.
    for t in 0..cfg.io_threads {
        let sh = shared.clone();
        let vtx = verify_tx.clone();
        threads.push(
            std::thread::Builder::new()
                .name(format!("snk-io-{t}"))
                .spawn(move || io_thread(&sh, vtx))?,
        );
    }

    // Master (buffer-wait path).
    {
        let sh = shared.clone();
        threads.push(
            std::thread::Builder::new()
                .name("snk-master".into())
                .spawn(move || master_thread(&sh, park_rx))?,
        );
    }

    // Ack flusher (only when coalescing can leave partial batches
    // behind — with `tune` on the cap is raised, so the tuner's walks
    // are always covered by a flusher).
    if cfg.ack_batch_cap() > 1 {
        let sh = shared.clone();
        threads.push(
            std::thread::Builder::new()
                .name("snk-ack-flush".into())
                .spawn(move || ack_flusher_thread(&sh))?,
        );
    }

    // Control comm (receive loop + CONNECT negotiation; owns the data
    // plane until the negotiated stream count is known, and spawns/joins
    // the per-stream comm threads itself).
    {
        let sh = shared.clone();
        threads.push(
            std::thread::Builder::new()
                .name("snk-comm".into())
                .spawn(move || comm_thread(&sh, park_tx, Some(plane)))?,
        );
    }

    Ok(SinkNode { shared, threads })
}

impl SinkNode {
    /// Wait for the sink to finish (BYE or fault) and collect its report.
    pub fn join(self) -> SinkReport {
        for t in self.threads {
            let _ = t.join();
        }
        let shared = &self.shared;
        let (mut stall_count, mut stall_ns) = shared.rma.stall_stats();
        let mut rma_bytes = shared.rma.total_bytes();
        let mut eff = shared.acks.eff.load(Ordering::SeqCst);
        if let Some(data) = shared.data.get() {
            // Multi-stream session: the fused pool/coalescer sat idle —
            // report the data plane's aggregate (stall counts still sum
            // both; the fused side contributes zero).
            rma_bytes = 0;
            eff = u32::MAX;
            for s in data {
                let (c, ns) = s.rma.stall_stats();
                stall_count += c;
                stall_ns += ns;
                rma_bytes += s.rma.total_bytes();
                eff = eff.min(s.acks.eff.load(Ordering::SeqCst));
            }
        }
        SinkReport {
            fault: shared.abort.lock().unwrap_or_else(|e| e.into_inner()).clone(),
            counters: shared.counters.snapshot(),
            rma_stalls: (stall_count, stall_ns),
            sched: shared.sched_stats.snapshot(),
            ack_batch_effective: eff,
            send_window: shared.send_window.load(Ordering::SeqCst),
            rma_bytes_effective: rma_bytes,
            tune_trajectory: shared
                .tune_trajectory
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .clone(),
            ledger_blocks: shared
                .files
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .values()
                .map(|f| (f.done.len() + f.inflight.len()) as u64)
                .sum(),
        }
    }
}

/// Total RMA reservation stalls across the pools that are actually in
/// service — the sink tuner's pressure signal (a dry pool means the
/// write path can't drain as fast as the wire fills).
fn pool_stalls(shared: &Shared) -> u64 {
    match shared.data.get() {
        Some(d) => d.iter().map(|s| s.rma.stall_stats().0).sum(),
        None => shared.rma.stall_stats().0,
    }
}

/// The sink half of the unified epoch tuner (`Config::tune`): every
/// `tune_epoch_ms` it turns the written-byte delta into a goodput
/// sample, feeds it (with RMA-pool stall pressure as the tiebreak
/// signal) to one [`HillClimb`](crate::tune::HillClimb) over {effective
/// ack batch, write-coalesce budget}, and applies the proposed move —
/// the ack batch within the cap negotiated at CONNECT (every stream's
/// coalescer walks together), the coalesce budget within
/// `Config::coalesce_cap`. The wire never renegotiates mid-transfer.
fn sink_tuner(shared: &Arc<Shared>, batch_cap: u32) {
    use crate::tune::{HillClimb, KnobSpec};
    let batch_cap = batch_cap.max(1);
    let mut hc = HillClimb::new(vec![
        KnobSpec {
            name: "ack_batch",
            floor: 1,
            cap: u64::from(batch_cap),
            seed: 2,
            start: u64::from(shared.coalescer(0).eff.load(Ordering::SeqCst)),
        },
        KnobSpec {
            name: "write_coalesce",
            floor: 0,
            cap: shared.coalesce_cap,
            seed: 1 << 20,
            start: shared.coalesce_bytes.load(Ordering::Relaxed),
        },
    ]);
    let epoch = Duration::from_millis(shared.tune_epoch_ms.max(1));
    let tick = epoch.min(Duration::from_millis(5)).max(Duration::from_millis(1));
    let mut last = Instant::now();
    let mut last_written = shared.counters.bytes_written.load(Ordering::Relaxed);
    let mut last_stalls = pool_stalls(shared);
    while !shared.is_aborted() && !shared.done.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        let now = Instant::now();
        let dt = now.duration_since(last);
        if dt < epoch {
            continue;
        }
        last = now;
        let written = shared.counters.bytes_written.load(Ordering::Relaxed);
        let stalls = pool_stalls(shared);
        let goodput = (written - last_written) as f64 / dt.as_secs_f64();
        let pressure = stalls - last_stalls;
        last_written = written;
        last_stalls = stalls;
        if let Some((idx, value)) = hc.observe(goodput, pressure) {
            if idx == 0 {
                let v = (value.min(u64::from(batch_cap)) as u32).max(1);
                for s in 0..shared.k() {
                    shared.coalescer(s).eff.store(v, Ordering::SeqCst);
                }
            } else {
                shared.coalesce_bytes.store(value, Ordering::Relaxed);
            }
        }
        shared.counters.tune_epochs.store(hc.epochs, Ordering::Relaxed);
        shared.counters.tune_grows.store(hc.grows, Ordering::Relaxed);
        shared.counters.tune_shrinks.store(hc.shrinks, Ordering::Relaxed);
        shared.counters.tune_reverts.store(hc.reverts, Ordering::Relaxed);
    }
    *shared.tune_trajectory.lock().unwrap_or_else(|e| e.into_inner()) =
        std::mem::take(&mut hc.trajectory);
}

/// The control-connection comm thread. At K = 1 it is the ONLY comm
/// thread and handles every message class (the fused legacy path); at
/// K ≥ 2 it handles control traffic and NEW_BLOCK on a data connection
/// is someone else's job — seeing one here is a protocol violation.
fn comm_thread(
    shared: &Arc<Shared>,
    park_tx: mpsc::Sender<(usize, Message)>,
    mut plane: Option<DataPlane>,
) {
    // Data comm threads this thread spawned after negotiation; joined on
    // the way out so SinkNode::join transitively waits for them.
    let mut data_threads: Vec<std::thread::JoinHandle<()>> = Vec::new();
    // The answer the first CONNECT negotiated, kept so a retried CONNECT
    // (the source timed out waiting for an ack that was merely slow or
    // lost) is answered verbatim instead of renegotiating mid-session.
    let mut connect_ack: Option<Message> = None;
    loop {
        if shared.is_aborted() {
            break;
        }
        let msg = match shared.ep.recv_timeout(Duration::from_millis(50)) {
            Ok(m) => m,
            Err(NetError::Timeout) => continue,
            Err(NetError::Closed) => {
                if !shared.done.load(Ordering::SeqCst) {
                    shared.abort_with("connection closed by source".into());
                }
                break;
            }
            Err(NetError::Fault(e)) => {
                shared.abort_with(e);
                break;
            }
        };
        match msg {
            Message::Connect {
                max_object_size,
                resume,
                ack_batch,
                send_window,
                data_streams,
                ..
            } => {
                if let Some(ack) = &connect_ack {
                    // Duplicate CONNECT: the handshake is idempotent.
                    shared.counters.retries.fetch_add(1, Ordering::Relaxed);
                    let _ = shared.ep.send(ack.clone());
                    continue;
                }
                shared.resume.store(resume, Ordering::SeqCst);
                if max_object_size as usize > shared.rma.slot_bytes() {
                    shared.abort_with(format!(
                        "peer object size {} exceeds RMA slot {}",
                        max_object_size,
                        shared.rma.slot_bytes()
                    ));
                    break;
                }
                // Negotiate the ack batch down to what the peer can
                // consume (1 for legacy single-BLOCK_SYNC sources).
                let ours = shared.acks.batch.load(Ordering::SeqCst);
                let negotiated = ours.min(ack_batch.max(1));
                shared.acks.batch.store(negotiated, Ordering::SeqCst);
                // The effective batch can never exceed the new cap; in
                // fixed mode it IS the cap.
                let eff = shared.acks.eff.load(Ordering::SeqCst);
                shared.acks.eff.store(
                    if shared.acks.adaptive || shared.acks.tuned {
                        eff.min(negotiated).max(1)
                    } else {
                        negotiated
                    },
                    Ordering::SeqCst,
                );
                // Grant the peer a NEW_BLOCK send window: its ask, capped
                // by our configured bound (1 for legacy lockstep peers).
                let win_ours = shared.send_window.load(Ordering::SeqCst);
                let win = win_ours.min(send_window.max(1));
                shared.send_window.store(win, Ordering::SeqCst);
                // Negotiate the data-stream count the same way: the
                // peer's ask, capped by ours (1 for legacy field-less
                // peers — the fused fallback).
                let k = shared.data_streams_cfg.min(data_streams.max(1));
                // Pool autosizer: register enough slots to absorb the
                // whole negotiated in-flight window (zero-copy pins each
                // payload's slot until the write releases it), BEFORE
                // advertising the slot count back to the peer.
                if shared.autosize {
                    shared.rma.grow_to(win as usize);
                }
                // Ack BEFORE materializing the data plane: over TCP the
                // source only dials its K data connections once it sees
                // the negotiated count, so an accept-first order would
                // deadlock the handshake.
                let ack = Message::ConnectAck {
                    rma_slots: shared.rma.slots() as u32,
                    ack_batch: negotiated,
                    send_window: win,
                    data_streams: k,
                };
                connect_ack = Some(ack.clone());
                let _ = shared.ep.send(ack);
                if k >= 2 {
                    let Some(plane) = plane.take() else {
                        shared.abort_with("duplicate multi-stream CONNECT".into());
                        break;
                    };
                    let eps = match plane.materialize(k) {
                        Ok(eps) => eps,
                        Err(e) => {
                            shared.abort_with(format!("data plane ({k} streams): {e}"));
                            break;
                        }
                    };
                    let streams: Vec<SnkStream> = eps
                        .into_iter()
                        .map(|ep| {
                            let rma =
                                RmaPool::new(shared.rma_bytes, shared.rma.slot_bytes());
                            // Same autosize rule as the fused pool, per
                            // stream: each stream's credit window is the
                            // full negotiated `win`.
                            if shared.autosize {
                                rma.grow_to(win as usize);
                            }
                            SnkStream {
                                ep,
                                acks: AckCoalescer::new(
                                    negotiated,
                                    shared.acks.adaptive,
                                    shared.acks.tuned,
                                    shared.acks.window,
                                ),
                                rma,
                            }
                        })
                        .collect();
                    if shared.data.set(streams).is_err() {
                        shared.abort_with("data plane already materialized".into());
                        break;
                    }
                    // Spawn the per-stream comm threads only now — the
                    // plane is published, so every `pool()`/`coalescer()`
                    // lookup they make resolves to their own stream.
                    let mut spawn_err = false;
                    for s in 0..k as usize {
                        let sh = shared.clone();
                        let ptx = park_tx.clone();
                        match std::thread::Builder::new()
                            .name(format!("snk-comm-{s}"))
                            .spawn(move || data_comm_thread(&sh, s, ptx))
                        {
                            Ok(h) => data_threads.push(h),
                            Err(e) => {
                                shared.abort_with(format!(
                                    "spawn stream {s} comm: {e}"
                                ));
                                spawn_err = true;
                                break;
                            }
                        }
                    }
                    if spawn_err {
                        break;
                    }
                }
                // The sink half of the unified epoch tuner, spawned only
                // now: the negotiated ack-batch cap and the final stream
                // count are both known, so every coalescer it walks
                // exists. Joined through `data_threads` on the way out.
                if shared.tune {
                    let sh = shared.clone();
                    match std::thread::Builder::new()
                        .name("snk-tune".into())
                        .spawn(move || sink_tuner(&sh, negotiated))
                    {
                        Ok(h) => data_threads.push(h),
                        Err(e) => {
                            shared.abort_with(format!("spawn sink tuner: {e}"));
                            break;
                        }
                    }
                }
            }
            Message::NewFile { file_idx, name, size, start_ost } => {
                handle_new_file(shared, file_idx, &name, size, start_ost);
            }
            Message::NewBlock { .. } => {
                if shared.k() > 1 {
                    // The source shards NEW_BLOCK onto data connections;
                    // payload on the control connection means the peer is
                    // confused — fail loudly rather than double-route.
                    shared.abort_with(
                        "NEW_BLOCK on the control connection of a multi-stream session"
                            .into(),
                    );
                    break;
                }
                // Fused path: reserve an RMA slot; park with the master
                // if dry (§3.1).
                if let Some(slot) = shared.rma.try_reserve() {
                    enqueue_block(shared, msg, slot, 0);
                } else {
                    let _ = park_tx.send((0, msg));
                }
            }
            Message::FileClose { file_idx } => {
                // Nothing of the file may linger in the coalescers once it
                // commits (defensive: the source only closes after every
                // ack arrived, so this is normally a no-op).
                shared.flush_acks_for(file_idx);
                let fid = {
                    let files = shared.files.lock().unwrap_or_else(|e| e.into_inner());
                    files.get(&file_idx).map(|f| f.fid)
                };
                if let Some(fid) = fid {
                    if let Err(e) = shared.pfs.commit_file(fid) {
                        shared.abort_with(format!("commit failed: {e}"));
                        break;
                    }
                    shared.counters.files_completed.fetch_add(1, Ordering::Relaxed);
                    // Commit durable: retire the file's ledger entries.
                    // The entry itself stays (its `closed` flag keeps
                    // answering late duplicates) — only the per-block
                    // sets are dropped, so ledger memory is bounded by
                    // open files, not by transfer size.
                    {
                        let mut files =
                            shared.files.lock().unwrap_or_else(|e| e.into_inner());
                        if let Some(f) = files.get_mut(&file_idx) {
                            f.done.clear();
                            f.inflight.clear();
                            f.closed = true;
                        }
                    }
                    let _ = shared.ep.send(Message::FileCloseAck { file_idx });
                }
            }
            Message::Bye => {
                shared.done.store(true, Ordering::SeqCst);
                shared.queues.close();
                break;
            }
            other => {
                shared.abort_with(format!("sink comm: unexpected {}", other.type_name()));
                break;
            }
        }
    }
    // Comm gone: drain stops; make sure nothing waits forever.
    shared.queues.close();
    for h in data_threads {
        let _ = h.join();
    }
}

/// One data stream's comm thread (K ≥ 2 only): NEW_BLOCK in, slot from
/// THIS stream's pool, parked against this stream when dry.
fn data_comm_thread(
    shared: &Arc<Shared>,
    s: usize,
    park_tx: mpsc::Sender<(usize, Message)>,
) {
    let ep = shared.data.get().expect("plane published before spawn")[s].ep.clone();
    loop {
        if shared.is_aborted() || shared.done.load(Ordering::SeqCst) {
            break;
        }
        let msg = match ep.recv_timeout(Duration::from_millis(50)) {
            Ok(m) => m,
            Err(NetError::Timeout) => continue,
            Err(NetError::Closed) => {
                if !shared.done.load(Ordering::SeqCst) {
                    // One dead data stream is survivable: the source
                    // re-homes its OSTs onto the survivors and duplicates
                    // are absorbed by the write ledger. Only a fully
                    // severed data plane is fatal.
                    let dead = shared.data_dead.fetch_add(1, Ordering::SeqCst) + 1;
                    if dead >= shared.k() {
                        shared.abort_with("all data streams closed".into());
                    }
                }
                break;
            }
            Err(NetError::Fault(e)) => {
                shared.abort_with(e);
                break;
            }
        };
        match msg {
            Message::StreamHello { stream_id, .. } => {
                // The source introduces each data connection with its
                // stream id. The in-process channel transport delivers it
                // here; the TCP acceptor already consumed it to order the
                // accepted connections — so it is validated when present,
                // required never.
                if stream_id as usize != s {
                    shared.abort_with(format!(
                        "data stream {s}: STREAM_HELLO for stream {stream_id}"
                    ));
                    break;
                }
            }
            Message::NewBlock { .. } => {
                if let Some(slot) = shared.pool(s).try_reserve() {
                    enqueue_block(shared, msg, slot, s);
                } else {
                    let _ = park_tx.send((s, msg));
                }
            }
            other => {
                shared.abort_with(format!(
                    "sink stream {s} comm: unexpected {}",
                    other.type_name()
                ));
                break;
            }
        }
    }
}

/// §5.2.2 sink half (resume only): metadata match -> skip, else
/// (re)create the file. Fresh transfers always rewrite.
fn handle_new_file(shared: &Arc<Shared>, file_idx: u32, name: &str, size: u64, start_ost: u32) {
    let resuming = shared.resume.load(Ordering::SeqCst);
    if let Some((_, meta)) = shared.pfs.lookup(name) {
        if resuming && meta.committed && meta.size == size {
            let _ = shared
                .ep
                .send(Message::FileId { file_idx, sink_fd: 0, skip: true });
            return;
        }
        // Exists but partial/mismatched: LADS rewrites objects in place on
        // resume; a non-committed file is reopened, a size-mismatched one
        // is recreated.
        if meta.size != size {
            let _ = shared.pfs.remove(name);
        }
    }
    let fid = match shared.pfs.lookup(name) {
        Some((fid, _)) => fid,
        None => match shared.pfs.create(name, size, start_ost) {
            Ok(fid) => fid,
            Err(e) => {
                shared.abort_with(format!("sink create '{name}': {e}"));
                return;
            }
        },
    };
    shared
        .files
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .insert(
            file_idx,
            SnkFile {
                fid,
                start_ost,
                done: BTreeSet::new(),
                inflight: BTreeSet::new(),
                closed: false,
            },
        );
    let _ = shared
        .ep
        .send(Message::FileId { file_idx, sink_fd: fid.0, skip: false });
}

/// Queue the received object on its OST write queue (§5.1: "determines
/// the appropriate OST by the object's file offset and queues it on the
/// OST's work queue"). The "RMA read" is the refcounted payload handoff
/// itself — the slot is held purely as the §3.1 bounded-buffer credit,
/// its buffer untouched; `pwrite` later runs straight from the payload.
fn enqueue_block(shared: &Arc<Shared>, msg: Message, slot: RmaSlot, stream: usize) {
    let Message::NewBlock { file_idx, block_idx, offset, digest, data } = msg else {
        return;
    };
    // Ledger verdict under the files lock; duplicate handling (counter +
    // re-ack) runs after the lock drops.
    let mut dup_done = false;
    let mut dup_inflight = false;
    let looked_up = {
        let mut files = shared.files.lock().unwrap_or_else(|e| e.into_inner());
        match files.get_mut(&file_idx) {
            Some(f) => {
                if f.closed || f.done.contains(&block_idx) {
                    // A closed file's blocks are all durable (commit
                    // already ran) — a late duplicate is answered the
                    // same way as a `done` member.
                    dup_done = true;
                    None
                } else if !f.inflight.insert(block_idx) {
                    dup_inflight = true;
                    None
                } else {
                    Some((f.fid, f.start_ost))
                }
            }
            None => None,
        }
    };
    let Some((fid, start_ost)) = looked_up else {
        if dup_done || dup_inflight {
            shared
                .counters
                .dup_blocks_dropped
                .fetch_add(1, Ordering::Relaxed);
            if dup_done {
                // The write already verified: re-ack on the arrival stream
                // so a peer whose first acknowledgement went missing still
                // advances. The payload and slot drop here — nothing of a
                // duplicate ever reaches the write queues.
                shared.push_ack(stream, file_idx, block_idx, true);
            }
            // An in-flight original acks exactly once, when it lands:
            // drop the duplicate silently.
            return;
        }
        shared.abort_with(format!("NEW_BLOCK for unknown file {file_idx}"));
        return;
    };
    let ost = shared.pfs.layout().ost_for(start_ost, offset);
    if shared.k() > 1 {
        // Learn the source's OST → stream shard from the arrival itself:
        // the ack for this block (and every later block of this OST)
        // must return on the stream whose credit window was charged.
        shared
            .ost_stream
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(ost.0, stream);
    }
    shared.sched.on_enqueue(ost);
    if let Some(h) = &shared.shared_osts {
        h.begin(ost);
    }
    shared.queues.push(
        ost,
        WriteReq {
            file_idx,
            block_idx,
            fid,
            offset,
            digest,
            ost,
            payload: data,
            faithful: true,
            _slot: slot,
        },
    );
}

/// Ack flusher: ticks at a fraction of the flush window and pushes out
/// any partially-filled batch whose oldest acknowledgement aged past
/// `ack_flush_us` — the straggler bound that keeps coalescing from ever
/// stalling the source's logging/close path. One thread sweeps every
/// stream's coalescer (they share the window).
fn ack_flusher_thread(shared: &Arc<Shared>) {
    // Tick at a fraction of the window, but capped so shutdown (join)
    // never stalls behind a huge configured window.
    let tick = (shared.acks.window / 4)
        .max(Duration::from_micros(100))
        .min(Duration::from_millis(50));
    loop {
        std::thread::sleep(tick);
        if shared.is_aborted() {
            break;
        }
        if shared.done.load(Ordering::SeqCst) {
            // BYE seen: defensively push out anything still pending.
            shared.flush_expired_acks(true);
            break;
        }
        shared.flush_expired_acks(false);
    }
}

/// Master: the RMA buffer wait queue (§3.1's "master thread will sleep on
/// the RMA buffer's wait queue until a buffer is released") — parked
/// blocks carry their stream, so the master sleeps on the pool whose
/// stream actually ran dry.
fn master_thread(shared: &Arc<Shared>, park_rx: mpsc::Receiver<(usize, Message)>) {
    loop {
        let (stream, msg) = match park_rx.recv_timeout(Duration::from_millis(50)) {
            Ok(m) => m,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shared.is_aborted() || shared.done.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        // Block (abort-aware) until a slot frees.
        let slot = loop {
            match shared.pool(stream).reserve_timeout(Duration::from_millis(50)) {
                Some(s) => break Some(s),
                None if shared.is_aborted() => break None,
                None => continue,
            }
        };
        let Some(slot) = slot else { break };
        enqueue_block(shared, msg, slot, stream);
    }
}

/// IO thread: policy-picked dequeue (+ contiguity-aware coalescing
/// drain) + pwrite + per-block verify + BLOCK_SYNC (or hand to the
/// verifier).
///
/// When coalescing is on and a run's chain broke with budget to spare
/// (no byte-successor was queued yet), the thread checks the queue once
/// more after submitting and acking the run: the successor frequently
/// arrives exactly then, freed by the credits those acks returned. If it
/// has, the thread coalesces onward from it (a fresh budget, counted in
/// `Counters::coalesce_continuations`) instead of returning to the
/// scheduler — so an ack-batch flush mid-file no longer permanently cuts
/// the run short. Each continuation removes a queued block, so the loop
/// strictly drains.
fn io_thread(shared: &Arc<Shared>, verify_tx: Option<mpsc::Sender<WriteReq>>) {
    let osts = shared.pfs.ost_model();
    // Under `ftlads serve` the congestion view folds other jobs' in-flight
    // load (from the daemon's shared registry) into every policy pick.
    let cong = OstCongestion::with_shared(osts, shared.shared_osts.as_deref());
    'pop: while let Some((ost, first)) =
        shared
            .queues
            .pop_next_timed(&*shared.sched, &cong, &shared.sched_stats)
    {
        if shared.is_aborted() {
            break;
        }
        let mut next = Some(first);
        while let Some(head) = next.take() {
            // Gather a byte-contiguous same-file run off the SAME OST
            // queue the policy picked (a gate of 0 bytes never drains —
            // the seed-exact per-object path). The drained blocks ride
            // this thread's service round; the policy is not
            // re-consulted. The budget is snapshotted once per run: the
            // unified tuner may move it mid-transfer, and a run must be
            // sized against one coherent value.
            let coalesce_budget = shared.coalesce_bytes.load(Ordering::Relaxed);
            let mut run = vec![head];
            let mut budget_stop = false;
            if coalesce_budget > 0 {
                // Cap runs at POSIX's IOV_MAX so one gathered run is ONE
                // `pwritev` on the disk backend (past the cap the backend
                // would split silently and `write_syscalls` would
                // under-count), keeping the counter == real submissions.
                const MAX_RUN_BLOCKS: usize = crate::pfs::IOV_MAX_GATHER;
                let fid = run[0].fid;
                let mut end = run[0].offset + run[0].payload.len() as u64;
                let mut run_bytes = run[0].payload.len() as u64;
                let mut run_blocks = 1usize;
                let extra = shared.queues.drain_chain(ost, |cand: &WriteReq| {
                    if cand.fid != fid || cand.offset != end {
                        return DrainVerdict::Skip;
                    }
                    // The chain is linear: exactly one queued block can be
                    // the run's next byte. If that unique successor busts
                    // the budget (or the run hit the iov cap), nothing
                    // further can ever chain — stop the scan instead of
                    // re-walking the backlog.
                    let len = cand.payload.len() as u64;
                    if run_blocks == MAX_RUN_BLOCKS || run_bytes + len > coalesce_budget {
                        budget_stop = true;
                        return DrainVerdict::Stop;
                    }
                    end += len;
                    run_bytes += len;
                    run_blocks += 1;
                    DrainVerdict::Take
                });
                run.extend(extra);
            }

            // Where a continuation would have to pick up, captured before
            // the run is consumed below. Only a chain that ended for LACK
            // of a successor (not because the budget/cap said stop) is
            // worth re-checking — a budget stop is deliberate.
            let chain_open = coalesce_budget > 0 && !budget_stop;
            let cont_fid = run[0].fid;
            let cont_end = {
                let last = run.last().expect("run is never empty");
                last.offset + last.payload.len() as u64
            };

            if !write_run(shared, ost, &mut run) {
                break 'pop; // aborted (pwrite failure with no per-block recovery)
            }

            match shared.integrity {
                IntegrityMode::Pjrt => {
                    // Hand off to the batched PJRT verifier (payload +
                    // slot + fidelity move along, one request per block).
                    if let Some(tx) = &verify_tx {
                        for req in run.drain(..) {
                            if tx.send(req).is_err() {
                                shared.abort_with("verifier gone".into());
                                break 'pop;
                            }
                        }
                    }
                }
                IntegrityMode::Native => {
                    // One digest batch for the run; every block keeps its
                    // own verdict (wire digest match AND storage
                    // fidelity).
                    let objects: Vec<&[u8]> =
                        run.iter().map(|r| r.payload.as_slice()).collect();
                    match NativeEngine.digest_batch(&objects, shared.padded_words) {
                        Ok(digests) => {
                            for (req, d) in run.iter().zip(digests) {
                                let ok = req.faithful && d == Digest::from_u64(req.digest);
                                finish_block(shared, req, ok);
                            }
                        }
                        Err(_) => {
                            for req in &run {
                                finish_block(shared, req, false);
                            }
                        }
                    }
                }
                IntegrityMode::Off => {
                    // Stock LADS: acknowledge without verification (§3.2's
                    // silent-corruption window, reproduced for A/B runs).
                    for req in &run {
                        finish_block(shared, req, true);
                    }
                }
            }
            // Slot credits released as the run drops.

            if chain_open && !shared.is_aborted() {
                // One-shot re-check: did the run's byte-successor arrive
                // while we were writing/acking? Take exactly it (and
                // nothing else — later chaining happens in the next
                // gather pass above).
                let mut taken = false;
                let cont = shared.queues.drain_chain(ost, |cand: &WriteReq| {
                    if taken {
                        return DrainVerdict::Stop;
                    }
                    if cand.fid == cont_fid && cand.offset == cont_end {
                        taken = true;
                        DrainVerdict::Take
                    } else {
                        DrainVerdict::Skip
                    }
                });
                next = cont.into_iter().next();
                if next.is_some() {
                    shared
                        .counters
                        .coalesce_continuations
                        .fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

/// Submit one gathered run: a run of 1 takes the seed's plain
/// [`Pfs::write_at`] path exactly; longer runs go down as ONE vectored
/// write, and a failed vectored submission degrades to per-block writes
/// so fault semantics match the uncoalesced path. Stamps each block's
/// storage fidelity and feeds the scheduler one evenly-split service
/// sample per constituent block (comparable with uncoalesced samples).
/// Returns `false` when the sink aborted.
fn write_run(shared: &Arc<Shared>, ost: OstId, run: &mut [WriteReq]) -> bool {
    let total: u64 = run.iter().map(|r| r.payload.len() as u64).sum();
    let io_started = std::time::Instant::now();
    if run.len() == 1 {
        if !write_one(shared, &mut run[0]) {
            return false;
        }
    } else {
        let gathered = {
            let iovs: Vec<&[u8]> = run.iter().map(|r| r.payload.as_slice()).collect();
            shared.pfs.write_at_vectored(run[0].fid, run[0].offset, &iovs)
        };
        match gathered {
            Ok(corrupted) => {
                shared.counters.write_syscalls.fetch_add(1, Ordering::Relaxed);
                shared.counters.coalesced_runs.fetch_add(1, Ordering::Relaxed);
                shared
                    .counters
                    .coalesce_bytes_max
                    .fetch_max(total, Ordering::Relaxed);
                for i in corrupted {
                    run[i].faithful = false;
                }
            }
            Err(_) => {
                // Degrade to per-block retry: every block still lands (or
                // aborts) exactly as it would have without coalescing.
                for req in run.iter_mut() {
                    if !write_one(shared, req) {
                        return false;
                    }
                }
            }
        }
    }
    // Feed the storage feedback per CONSTITUENT BLOCK, with the run's
    // wall time split evenly: stateful policies (StragglerAware's EWMA)
    // compare per-request samples across OSTs, and a whole-run sample
    // would read "8 blocks in one submission" as "8× slower OST" —
    // penalizing exactly the OSTs where coalescing works best. A run of
    // 1 degenerates to the seed's one-sample-per-object behavior.
    let service = io_started.elapsed() / run.len() as u32;
    for _ in 0..run.len() {
        shared.sched.on_complete(ost, service);
        shared.sched_stats.record_complete(service);
        if let Some(h) = &shared.shared_osts {
            h.end(ost);
        }
    }
    shared
        .counters
        .bytes_written
        .fetch_add(total, Ordering::Relaxed);
    true
}

/// One plain `write_at`: count the submission, stamp the block's
/// storage fidelity; a write error aborts the sink (seed semantics).
/// Returns `false` on abort. Used by the run-of-1 path and by the
/// failed-vectored degrade loop, which must stay byte-identical.
fn write_one(shared: &Arc<Shared>, req: &mut WriteReq) -> bool {
    match shared.pfs.write_at(req.fid, req.offset, req.payload.as_slice()) {
        Ok(faithful) => {
            shared.counters.write_syscalls.fetch_add(1, Ordering::Relaxed);
            req.faithful = faithful;
            true
        }
        Err(e) => {
            shared.abort_with(format!("pwrite failed: {e}"));
            false
        }
    }
}

fn finish_block(shared: &Arc<Shared>, req: &WriteReq, ok: bool) {
    // Ledger first, ack second: the moment the ack hits the wire a
    // duplicate of this block may arrive, and it must see the final
    // state. A failed block leaves the ledger entirely — the source
    // retransmits it and the retry must be writable again.
    {
        let mut files = shared.files.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(f) = files.get_mut(&req.file_idx) {
            f.inflight.remove(&req.block_idx);
            // A write landing after FILE_CLOSE retired the ledger must
            // not resurrect entries — the closed flag already answers
            // every future duplicate.
            if ok && !f.closed {
                f.done.insert(req.block_idx);
            }
        }
    }
    if ok {
        shared.counters.objects_synced.fetch_add(1, Ordering::Relaxed);
    } else {
        shared
            .counters
            .objects_failed_verify
            .fetch_add(1, Ordering::Relaxed);
    }
    shared.push_ack(shared.stream_for_ost(req.ost), req.file_idx, req.block_idx, ok);
}

/// Verifier thread: batch written objects into the compiled digest
/// artifact's fixed (B, W) batch, execute via PJRT, emit BLOCK_SYNCs.
fn verifier_thread(shared: &Arc<Shared>, engine: PjrtEngine, rx: mpsc::Receiver<WriteReq>) {
    let batch_max = engine.batch_size();
    let mut batch: Vec<WriteReq> = Vec::with_capacity(batch_max);
    loop {
        // Collect up to batch_max requests, waiting briefly for stragglers
        // so the artifact's batch dimension is actually used.
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shared.is_aborted() || shared.done.load(Ordering::SeqCst) {
                    // done is set on BYE, which the source only sends after
                    // every BLOCK_SYNC arrived — the channel is empty here.
                    break;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        batch.push(first);
        let deadline = std::time::Instant::now() + Duration::from_millis(2);
        while batch.len() < batch_max {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }

        let objects: Vec<&[u8]> = batch.iter().map(|r| r.payload.as_slice()).collect();
        match engine.digest_batch(&objects, shared.padded_words) {
            Ok(digests) => {
                for (req, d) in batch.drain(..).zip(digests) {
                    // Wire digest match AND storage fidelity (§3.2): a
                    // corrupted persist fails even if the payload is good.
                    let ok = req.faithful && d == Digest::from_u64(req.digest);
                    finish_block(shared, &req, ok);
                }
            }
            Err(e) => {
                shared.abort_with(format!("PJRT verify failed: {e}"));
                batch.clear();
                break;
            }
        }
    }
}
