//! Bytes-weighted OST → stream sharding (longest-processing-time).
//!
//! The first multi-stream cut assigned OSTs to data streams as `ost %
//! K`. On a lumpy layout (stripe widths that don't divide K, files
//! clustered on a few OSTs) that leaves some streams carrying several
//! times the bytes of others, which is exactly the sub-linear K = 4
//! point §A11 measured. This module replaces it with the classic greedy
//! LPT bound: sort OSTs by projected bytes descending and hand each to
//! the currently least-loaded stream. LPT's makespan is within 4/3 of
//! optimal, and for the common near-uniform case it degenerates to the
//! old round-robin.
//!
//! Determinism matters more than the last percent of balance here — the
//! sink learns the map passively from which stream each NEW_BLOCK
//! arrives on, and resume must re-derive byte-identical plans — so all
//! ties break on identity: equal weights order by ascending OST id,
//! equal loads pick the lowest stream index.

use std::collections::BTreeMap;

/// Greedily assign OSTs to `k` streams by descending projected bytes,
/// each to the least-loaded stream so far.
///
/// Ties are deterministic: equal-weight OSTs are placed in ascending
/// OST-id order, and equal-load streams resolve to the lowest index.
/// `k == 0` yields an empty map (the caller treats that as "no data
/// plane", same as K = 1).
pub fn lpt_assignment(weights: &BTreeMap<u32, u64>, k: usize) -> BTreeMap<u32, usize> {
    let mut out = BTreeMap::new();
    if k == 0 {
        return out;
    }
    // BTreeMap iteration is ascending by OST id, and the sort is
    // stable, so equal weights keep that order.
    let mut order: Vec<(u32, u64)> = weights.iter().map(|(&o, &w)| (o, w)).collect();
    order.sort_by(|a, b| b.1.cmp(&a.1));
    let mut load = vec![0u64; k];
    for (ost, w) in order {
        let s = (0..k)
            .min_by_key(|&i| (load[i], i))
            .expect("k >= 1 streams to pick from");
        load[s] += w;
        out.insert(ost, s);
    }
    out
}

/// Re-home OST weights onto the surviving stream ids after a stream
/// death: an LPT plan over `survivors.len()` virtual slots, mapped back
/// through the survivor list so the assignment names real stream
/// indices. Empty `survivors` yields an empty map — the caller treats
/// that as "no stream left to carry the backlog". Determinism carries
/// over from [`lpt_assignment`] as long as `survivors` is sorted (the
/// natural order of a `BTreeSet` of dead streams' complement).
pub fn rehome_assignment(
    weights: &BTreeMap<u32, u64>,
    survivors: &[usize],
) -> BTreeMap<u32, usize> {
    lpt_assignment(weights, survivors.len())
        .into_iter()
        .map(|(ost, idx)| (ost, survivors[idx]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weights(pairs: &[(u32, u64)]) -> BTreeMap<u32, u64> {
        pairs.iter().copied().collect()
    }

    fn stream_loads(w: &BTreeMap<u32, u64>, assign: &BTreeMap<u32, usize>, k: usize) -> Vec<u64> {
        let mut load = vec![0u64; k];
        for (ost, s) in assign {
            load[*s] += w[ost];
        }
        load
    }

    #[test]
    fn uniform_weights_round_robin_by_ost_id() {
        let w = weights(&[(0, 10), (1, 10), (2, 10), (3, 10), (4, 10), (5, 10)]);
        let a = lpt_assignment(&w, 3);
        // Equal weights: ascending OST ids land on streams 0,1,2,0,1,2.
        assert_eq!(a[&0], 0);
        assert_eq!(a[&1], 1);
        assert_eq!(a[&2], 2);
        assert_eq!(a[&3], 0);
        assert_eq!(a[&4], 1);
        assert_eq!(a[&5], 2);
    }

    #[test]
    fn lumpy_layout_beats_mod_k() {
        // One hot OST (80) plus small ones: `ost % 2` would pair the
        // hot OST 0 with OSTs 2 and 4 (load 100 vs 20); LPT isolates
        // it (80 vs 40).
        let w = weights(&[(0, 80), (1, 10), (2, 10), (3, 10), (4, 10)]);
        let a = lpt_assignment(&w, 2);
        let lpt = stream_loads(&w, &a, 2);
        assert_eq!(lpt.iter().max(), Some(&80));
        let mut modk = vec![0u64; 2];
        for (&ost, &bytes) in &w {
            modk[ost as usize % 2] += bytes;
        }
        assert!(modk.iter().max() > lpt.iter().max(), "{modk:?} vs {lpt:?}");
    }

    #[test]
    fn every_stream_carries_when_osts_cover_k() {
        // 11 near-equal OSTs over 4 streams (the §A11 shape): no stream
        // may be left idle.
        let w: BTreeMap<u32, u64> = (0..11u32).map(|o| (o, 64 + u64::from(o))).collect();
        let a = lpt_assignment(&w, 4);
        let loads = stream_loads(&w, &a, 4);
        assert!(loads.iter().all(|&l| l > 0), "{loads:?}");
        assert_eq!(a.len(), 11);
    }

    #[test]
    fn rehome_maps_onto_surviving_ids() {
        let w = weights(&[(0, 80), (1, 10), (2, 10), (3, 10)]);
        // Streams 0 and 2 survive (1 died): every OST lands on one of
        // them, and the plan is the K = 2 LPT plan renamed.
        let a = rehome_assignment(&w, &[0, 2]);
        assert_eq!(a.len(), 4);
        assert!(a.values().all(|s| [0, 2].contains(s)), "{a:?}");
        let base = lpt_assignment(&w, 2);
        for (ost, s) in &a {
            assert_eq!(*s, [0, 2][base[ost]]);
        }
        assert!(rehome_assignment(&w, &[]).is_empty());
    }

    #[test]
    fn deterministic_across_calls_and_degenerate_k() {
        let w = weights(&[(3, 7), (9, 7), (1, 50), (4, 0)]);
        assert_eq!(lpt_assignment(&w, 3), lpt_assignment(&w, 3));
        assert!(lpt_assignment(&w, 0).is_empty());
        let all_zero = lpt_assignment(&w, 1);
        assert!(all_zero.values().all(|&s| s == 0));
    }
}
