//! Multi-transfer service mode: one long-lived daemon running many
//! concurrent transfer jobs, each a job-scoped coordinator session.
//!
//! Two front-ends share the same machinery:
//!
//! - **In-process** ([`Serve`]): a job manager embedded in one process.
//!   [`Serve::submit`] queues a [`JobRequest`] under a tenant name and a
//!   fair-share weight; an admission gate (`Config::serve_max_jobs`)
//!   bounds how many jobs run at once, and queued jobs dispatch in
//!   weighted fair-share order (the queued tenant with the smallest
//!   `dispatched / weight` ratio goes first). Every job runs a full
//!   [`TransferJob`] — source and sink halves — on its own worker
//!   thread, with its own job id, FT logger namespace and
//!   [`TransferOutcome`].
//! - **TCP** ([`serve_sink`] / [`serve_source`]): the `ftlads serve`
//!   subcommand. The sink daemon serves many jobs over ONE listener —
//!   every inbound connection introduces itself with its first message,
//!   and the wire-level job tag (trailing field of CONNECT and
//!   STREAM_HELLO, absent/0 for standalone transfers) demultiplexes
//!   control and data connections onto the right job session. The
//!   source daemon drives N tagged jobs against such a sink.
//!
//! Either way, all jobs of a daemon share one cross-job
//! [`OstRegistry`](crate::pfs::OstRegistry) per side (gated by
//! `Config::serve_registry`): each job charges its in-flight per-OST
//! requests into the registry, and every job's dequeue policy folds the
//! *other* jobs' load into its congestion view — so the §2.1
//! layout-aware scheduler steers around OSTs a concurrent job is
//! already hammering, not just its own queue depths.
//!
//! With `Config::serve_recover` on, both front-ends are additionally
//! **crash-consistent**: every job state change appends a durable
//! record to the [`manifest`] store under `<ft_dir>/manifest/`, and a
//! restarted daemon replays it — [`Serve::recover`] re-admits every
//! incomplete job through the normal fair-share path with `resume`
//! forced, and [`serve_sink`] hands a reconnecting client whose CONNECT
//! carries a known incomplete job tag its recovered session (queue-jump
//! re-admission) instead of a fresh one. Each re-admitted job resumes
//! from its own `job-<id>` object log, so the §5.2.2 retransmit bound
//! (`resent <= total - logged`) holds across a daemon kill too.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::sink::{SinkReport, SinkSession};
use super::source::{SourceReport, SourceSession};
use super::{DataPlane, TransferJob, TransferOutcome, TransferSpec};
use crate::config::Config;
use crate::ftlog::manifest::{self, JobState, ManifestRecord, ManifestStore};
use crate::ftlog::recover::recover_all;
use crate::metrics::{DaemonSnapshot, DaemonStats};
use crate::net::{tcp, Endpoint, FaultController, Message, NetError};
use crate::pfs::{OstRegistry, Pfs};
use crate::runtime::RuntimeHandle;

/// FNV-1a over `bytes`, continuing from `acc`.
fn fnv1a(mut acc: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        acc ^= b as u64;
        acc = acc.wrapping_mul(0x0000_0100_0000_01b3);
    }
    acc
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fingerprint of WHAT a job transfers: its ordered file list. Stored
/// in the manifest so recovery can refuse a provider that hands back a
/// different transfer under a recycled job id (`resume` itself is not
/// part of the digest — recovery forces it on).
pub fn spec_digest(spec: &TransferSpec) -> u64 {
    let mut acc = FNV_OFFSET;
    for name in &spec.files {
        acc = fnv1a(acc, name.as_bytes());
        acc = fnv1a(acc, &[0]); // name separator
    }
    acc
}

/// Fingerprint of HOW a job logs: the config knobs a restarted daemon
/// must match for the job's FT log to stay readable (mechanism, method,
/// object size, txn size).
pub fn knobs_digest(cfg: &Config) -> u64 {
    let mut acc = FNV_OFFSET;
    acc = fnv1a(acc, cfg.mechanism.as_str().as_bytes());
    acc = fnv1a(acc, cfg.method.as_str().as_bytes());
    acc = fnv1a(acc, &cfg.object_size.to_le_bytes());
    acc = fnv1a(acc, &(cfg.txn_size as u64).to_le_bytes());
    acc
}

/// How long a session waits for the pieces of a job to arrive over TCP
/// (data connections routed by the demultiplexer).
const TCP_JOB_TIMEOUT: Duration = Duration::from_secs(30);

/// Everything one in-process job needs: what to move and where.
pub struct JobRequest {
    pub spec: TransferSpec,
    pub source_pfs: Arc<dyn Pfs>,
    pub sink_pfs: Arc<dyn Pfs>,
    /// PJRT runtime handle (required when `cfg.integrity == Pjrt`).
    pub runtime: Option<RuntimeHandle>,
}

/// A submitted job's claim ticket: [`wait`](JobHandle::wait) blocks
/// until the job ran (or failed to) and yields its outcome.
pub struct JobHandle {
    id: u64,
    rx: mpsc::Receiver<Result<TransferOutcome>>,
}

impl JobHandle {
    /// The daemon-assigned job id (also the job's FT namespace suffix:
    /// its logs live under `<ft_dir>/job-<id>`).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the job finishes and collect its outcome. An `Err`
    /// means the job could not run at all (e.g. bad request); a
    /// completed-with-fault transfer is an `Ok` outcome with
    /// `completed == false`.
    pub fn wait(self) -> Result<TransferOutcome> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("serve: job {} worker vanished", self.id))?
    }
}

/// What [`Serve::recover`] knows about an incomplete job from the
/// manifest alone, handed to the recovery provider so it can rebuild
/// the job's [`JobRequest`] (PFS handles and runtimes do not survive a
/// daemon crash; the durable parts — id, tenant, weight, digests, and
/// the per-job FT log — do).
#[derive(Debug, Clone)]
pub struct RecoveredJob {
    /// Original daemon job id — re-admission keeps it, so the job's
    /// `job-<id>` FT log keeps matching.
    pub id: u64,
    pub tenant: String,
    pub weight: u32,
    /// Latest manifest state (never [`JobState::Completed`] here).
    pub state: JobState,
    pub spec_digest: u64,
    pub knobs_digest: u64,
    /// Objects already committed to this job's FT log — the `logged`
    /// term of the §5.2.2 retransmit bound `resent <= total - logged`.
    pub logged_objects: u64,
}

/// One queued-but-not-yet-dispatched job.
struct Queued {
    id: u64,
    tenant: String,
    weight: u32,
    req: JobRequest,
    tx: mpsc::Sender<Result<TransferOutcome>>,
}

struct Inner {
    queue: VecDeque<Queued>,
    running: usize,
    /// Jobs dispatched so far per tenant — the weighted-fair-share
    /// numerator (`dispatched / weight` picks the next tenant).
    dispatched: BTreeMap<String, u64>,
    /// Cumulative source bytes accepted per tenant — the
    /// `serve_quota_bytes` denominator (only tracked when the quota is
    /// armed).
    tenant_bytes: BTreeMap<String, u64>,
    shutting_down: bool,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// The in-process serve manager. Create once per daemon with
/// [`Serve::new`]; submit any number of jobs from any thread; call
/// [`Serve::drain`] to wait for everything to finish.
pub struct Serve {
    cfg: Config,
    src_registry: Arc<OstRegistry>,
    snk_registry: Arc<OstRegistry>,
    stats: DaemonStats,
    next_id: AtomicU64,
    inner: Mutex<Inner>,
    idle: Condvar,
    /// Durable job manifest (`serve_recover`), opened lazily on the
    /// first append so a recover-off daemon never creates
    /// `<ft_dir>/manifest/` (startup stays identical to a
    /// manifest-free build).
    manifest: Mutex<Option<ManifestStore>>,
}

impl Serve {
    pub fn new(cfg: Config) -> Arc<Serve> {
        let osts = cfg.ost_count;
        Arc::new(Serve {
            cfg,
            src_registry: OstRegistry::new(osts),
            snk_registry: OstRegistry::new(osts),
            stats: DaemonStats::default(),
            next_id: AtomicU64::new(1),
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                running: 0,
                dispatched: BTreeMap::new(),
                tenant_bytes: BTreeMap::new(),
                shutting_down: false,
                workers: Vec::new(),
            }),
            idle: Condvar::new(),
            manifest: Mutex::new(None),
        })
    }

    /// Append one manifest record for a job state change. A no-op with
    /// `serve_recover` off; with it on, the record is on disk (fsynced)
    /// when this returns. Lock order is inner → manifest everywhere, so
    /// callers may hold the inner lock.
    fn manifest_append(
        &self,
        id: u64,
        tenant: &str,
        weight: u32,
        spec: &TransferSpec,
        state: JobState,
    ) -> Result<()> {
        if !self.cfg.serve_recover {
            return Ok(());
        }
        let mut guard = self.manifest.lock().unwrap_or_else(|e| e.into_inner());
        if guard.is_none() {
            *guard = Some(ManifestStore::open(&self.cfg.ft_dir)?);
        }
        let store = guard.as_mut().expect("opened above");
        store.append(&ManifestRecord {
            job: id,
            state,
            tenant: tenant.to_string(),
            weight,
            spec_digest: spec_digest(spec),
            knobs_digest: knobs_digest(&self.cfg),
        })?;
        self.stats.manifest_records.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// The daemon-wide source-side congestion registry (all jobs'
    /// in-flight read requests, per OST).
    pub fn source_registry(&self) -> &Arc<OstRegistry> {
        &self.src_registry
    }

    /// The daemon-wide sink-side congestion registry.
    pub fn sink_registry(&self) -> &Arc<OstRegistry> {
        &self.snk_registry
    }

    pub fn stats(&self) -> DaemonSnapshot {
        self.stats.snapshot()
    }

    /// Queue a job under `tenant` with a fair-share `weight` (≥ 1;
    /// among queued jobs, the tenant with the smallest
    /// `dispatched / weight` ratio dispatches first, so a weight-4
    /// tenant gets 4× the dispatch slots of a weight-1 tenant under
    /// contention). Returns immediately with the job's claim ticket.
    pub fn submit(
        self: &Arc<Serve>,
        tenant: &str,
        weight: u32,
        req: JobRequest,
    ) -> Result<JobHandle> {
        self.stats.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if inner.shutting_down {
            self.stats.note_rejected(tenant);
            anyhow::bail!("serve: daemon is shutting down, job rejected");
        }
        if self.cfg.serve_quota_bytes > 0 {
            // The job's source bytes (files the PFS does not know are
            // charged as 0 — the job will fault on them anyway).
            let bytes: u64 = req
                .spec
                .files
                .iter()
                .filter_map(|n| req.source_pfs.lookup(n).map(|(_, m)| m.size))
                .sum();
            let used = inner.tenant_bytes.get(tenant).copied().unwrap_or(0);
            if used.saturating_add(bytes) > self.cfg.serve_quota_bytes {
                self.stats.note_rejected(tenant);
                anyhow::bail!(
                    "serve: tenant '{tenant}' over serve_quota_bytes \
                     ({used} used + {bytes} requested > {})",
                    self.cfg.serve_quota_bytes
                );
            }
            *inner.tenant_bytes.entry(tenant.to_string()).or_insert(0) += bytes;
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let q = Queued {
            id,
            tenant: tenant.to_string(),
            weight: weight.max(1),
            req,
            tx,
        };
        // The durable SUBMITTED record precedes queueing: if the append
        // fails the job was never accepted (the error surfaces here),
        // and once it succeeds a crash at any later point replays it.
        self.manifest_append(q.id, &q.tenant, q.weight, &q.req.spec, JobState::Submitted)?;
        inner.queue.push_back(q);
        self.dispatch_locked(&mut inner);
        Ok(JobHandle { id, rx })
    }

    /// Wait until every submitted job has finished, then reap the
    /// worker threads. New submissions are rejected from this point on.
    pub fn drain(self: &Arc<Serve>) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.shutting_down = true;
        while inner.running > 0 || !inner.queue.is_empty() {
            inner = self
                .idle
                .wait(inner)
                .unwrap_or_else(|e| e.into_inner());
        }
        let workers = std::mem::take(&mut inner.workers);
        drop(inner);
        for w in workers {
            let _ = w.join();
        }
    }

    /// Dispatch queued jobs (fair-share order) while admission slots
    /// are free. Called with the lock held, from submit and from worker
    /// exit.
    fn dispatch_locked(self: &Arc<Serve>, inner: &mut Inner) {
        while inner.running < self.cfg.serve_max_jobs {
            let Some(pos) = fair_pick(
                inner.queue.iter().map(|q| (q.tenant.as_str(), q.weight)),
                &inner.dispatched,
            ) else {
                break;
            };
            let q = inner.queue.remove(pos).expect("fair_pick returns a valid index");
            *inner.dispatched.entry(q.tenant.clone()).or_insert(0) += 1;
            inner.running += 1;
            self.stats.jobs_admitted.fetch_add(1, Ordering::Relaxed);
            self.stats.note_concurrent(inner.running as u64);
            // Best-effort ADMITTED record: losing it degrades the
            // manifest's story, not its safety (the job is still
            // SUBMITTED — recovery re-admits either state).
            let _ =
                self.manifest_append(q.id, &q.tenant, q.weight, &q.req.spec, JobState::Admitted);
            let this = self.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("serve-job-{}", q.id))
                .spawn(move || this.run_job(q));
            match spawned {
                Ok(h) => inner.workers.push(h),
                Err(e) => {
                    // The queue entry is consumed either way; report the
                    // spawn failure through the job's own channel.
                    inner.running -= 1;
                    self.stats.jobs_faulted.fetch_add(1, Ordering::Relaxed);
                    // q was moved into the closure only on success; on
                    // failure the closure was never created, but `q` is
                    // gone with it — nothing further to notify.
                    let _ = e;
                }
            }
        }
    }

    /// Worker body: run one job to completion, record how it ended,
    /// free the admission slot and dispatch successors. With
    /// `Config::job_deadline_ms > 0` the job body runs under a watchdog:
    /// a job silent past the deadline is faulted and its admission slot
    /// freed immediately (the wedged body thread is detached — it holds
    /// only job-scoped state and its late result is discarded), so one
    /// stuck transfer can never starve the daemon.
    fn run_job(self: Arc<Serve>, q: Queued) {
        let mut builder = TransferJob::builder(&self.cfg, &q.req.spec)
            .source_pfs(q.req.source_pfs)
            .sink_pfs(q.req.sink_pfs)
            .runtime(q.req.runtime)
            .job_id(q.id);
        if self.cfg.serve_registry {
            builder = builder
                .shared_source_osts(Arc::new(self.src_registry.handle()))
                .shared_sink_osts(Arc::new(self.snk_registry.handle()));
        }
        let result = if self.cfg.job_deadline_ms > 0 {
            let deadline = Duration::from_millis(self.cfg.job_deadline_ms);
            let id = q.id;
            let (rtx, rrx) = mpsc::channel();
            let spawned = std::thread::Builder::new()
                .name(format!("serve-job-{id}-body"))
                .spawn(move || {
                    let _ = rtx.send(builder.run());
                });
            match spawned {
                Ok(h) => match rrx.recv_timeout(deadline) {
                    Ok(r) => {
                        let _ = h.join();
                        r
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        drop(h); // detach: the zombie's late send is discarded
                        Err(anyhow::anyhow!(
                            "serve: job {id} exceeded job_deadline_ms = {}",
                            self.cfg.job_deadline_ms
                        ))
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        let _ = h.join();
                        Err(anyhow::anyhow!("serve: job {id} body panicked"))
                    }
                },
                Err(e) => Err(anyhow::anyhow!("serve: spawn job {id} body: {e}")),
            }
        } else {
            builder.run()
        };
        let terminal = match &result {
            Ok(out) if out.completed => {
                self.stats.jobs_completed.fetch_add(1, Ordering::Relaxed);
                JobState::Completed
            }
            _ => {
                self.stats.jobs_faulted.fetch_add(1, Ordering::Relaxed);
                JobState::Faulted
            }
        };
        // Best-effort terminal record, written before the inner lock is
        // retaken (lock order inner → manifest). A FAULTED record — the
        // watchdog path included — is deliberately non-terminal for
        // recovery: `Serve::recover` re-admits the job from its FT log.
        let _ = self.manifest_append(q.id, &q.tenant, q.weight, &q.req.spec, terminal);
        let _ = q.tx.send(result);
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.running -= 1;
        self.dispatch_locked(&mut inner);
        drop(inner);
        self.idle.notify_all();
    }

    /// Replay the durable job manifest under the daemon's `ft_dir` and
    /// re-admit every incomplete job through the normal fair-share
    /// admission path. For each incomplete record the `provide`
    /// callback is asked to rebuild the job's I/O endpoints (see
    /// [`RecoveredJob`]); returning `None` skips that job (it stays
    /// incomplete in the manifest), returning a request re-admits it
    /// under its ORIGINAL id with `resume` forced on, so it replays
    /// only the complement of its `job-<id>` object log (§5.2.2:
    /// `resent <= total - logged`). The provided spec must hash to the
    /// recorded `spec_digest` and the daemon's knobs to `knobs_digest`
    /// — a mismatch is an error, not silent log corruption.
    ///
    /// Replays whatever manifest exists regardless of `serve_recover`
    /// (no manifest → nothing to do); re-admission writes fresh
    /// manifest records only when the knob is on, as usual. Recovered
    /// jobs count in `DaemonSnapshot::jobs_recovered`, not
    /// `jobs_submitted`.
    pub fn recover(
        self: &Arc<Serve>,
        mut provide: impl FnMut(&RecoveredJob) -> Option<JobRequest>,
    ) -> Result<Vec<JobHandle>> {
        let replay = manifest::replay(&self.cfg.ft_dir)?;
        self.stats
            .manifest_records
            .fetch_add(replay.records, Ordering::Relaxed);
        // Fresh submissions must never recycle a recovered job's id
        // (and with it, its FT log namespace).
        self.next_id.fetch_max(replay.max_job() + 1, Ordering::Relaxed);
        let mut handles = Vec::new();
        for rec in replay.incomplete() {
            let mut ft = self.cfg.ft();
            ft.dir = self.cfg.ft_dir.join(format!("job-{}", rec.job));
            let logged_objects: u64 =
                recover_all(&ft)?.values().map(|s| s.count() as u64).sum();
            let info = RecoveredJob {
                id: rec.job,
                tenant: rec.tenant.clone(),
                weight: rec.weight,
                state: rec.state,
                spec_digest: rec.spec_digest,
                knobs_digest: rec.knobs_digest,
                logged_objects,
            };
            let Some(mut req) = provide(&info) else {
                continue;
            };
            anyhow::ensure!(
                spec_digest(&req.spec) == rec.spec_digest,
                "serve: recover job {}: provided spec does not match the manifest",
                rec.job
            );
            anyhow::ensure!(
                knobs_digest(&self.cfg) == rec.knobs_digest,
                "serve: recover job {}: daemon FT knobs changed since the manifest was written",
                rec.job
            );
            // Resume from the job's own FT log — recovery's whole point.
            req.spec.resume = true;
            let (tx, rx) = mpsc::channel();
            let q = Queued {
                id: rec.job,
                tenant: rec.tenant.clone(),
                weight: rec.weight.max(1),
                req,
                tx,
            };
            let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
            if inner.shutting_down {
                anyhow::bail!("serve: daemon is shutting down, recovery aborted");
            }
            inner.queue.push_back(q);
            self.dispatch_locked(&mut inner);
            drop(inner);
            self.stats.jobs_recovered.fetch_add(1, Ordering::Relaxed);
            handles.push(JobHandle { id: rec.job, rx });
        }
        Ok(handles)
    }
}

/// Weighted fair share: among the queued jobs, pick the index whose
/// tenant has the smallest `dispatched / weight` ratio (compared
/// cross-multiplied in integers — no float drift), breaking ties by
/// queue order. `None` when the queue is empty.
fn fair_pick<'a>(
    queue: impl Iterator<Item = (&'a str, u32)>,
    dispatched: &BTreeMap<String, u64>,
) -> Option<usize> {
    let mut best: Option<(usize, u64, u32)> = None; // (index, dispatched, weight)
    for (i, (tenant, weight)) in queue.enumerate() {
        let d = dispatched.get(tenant).copied().unwrap_or(0);
        let better = match best {
            None => true,
            // d/w < bd/bw  <=>  d*bw < bd*w (weights are >= 1).
            Some((_, bd, bw)) => (d as u128) * (bw as u128) < (bd as u128) * (weight as u128),
        };
        if better {
            best = Some((i, d, weight));
        }
    }
    best.map(|(i, _, _)| i)
}

// ---------------------------------------------------------------------------
// TCP front-end
// ---------------------------------------------------------------------------

/// An endpoint whose first received message was already consumed by the
/// serve demultiplexer (to route the connection): hand it back to the
/// session before delegating to the real connection.
struct ReplayEndpoint {
    head: Mutex<Option<Message>>,
    inner: Arc<dyn Endpoint>,
}

impl Endpoint for ReplayEndpoint {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        self.inner.send(msg)
    }

    fn recv(&self) -> Result<Message, NetError> {
        if let Some(m) = self.head.lock().unwrap_or_else(|e| e.into_inner()).take() {
            return Ok(m);
        }
        self.inner.recv()
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        if let Some(m) = self.head.lock().unwrap_or_else(|e| e.into_inner()).take() {
            return Ok(m);
        }
        self.inner.recv_timeout(timeout)
    }

    fn payload_sent(&self) -> u64 {
        self.inner.payload_sent()
    }
}

/// One TCP job waiting for (or holding) an admission slot.
struct TcpPending {
    job: u64,
    ctrl: Arc<dyn Endpoint>,
    data_rx: mpsc::Receiver<(u32, Arc<dyn Endpoint>)>,
}

/// Shared dispatch state of the sink daemon's accept loop and its
/// session threads.
struct TcpDispatch {
    pending: Mutex<VecDeque<TcpPending>>,
    running: Mutex<usize>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Append one manifest record from the TCP sink daemon. A no-op
/// without a store (i.e. `serve_recover` off). The sink side learns a
/// job's file list only in-session, so its records carry a zero
/// `spec_digest` (recovery on this path matches jobs by wire tag, not
/// by re-provided spec) under the fixed tenant `"tcp"`.
fn tcp_manifest_append(
    store: &Option<Arc<Mutex<ManifestStore>>>,
    stats: &DaemonStats,
    cfg: &Config,
    job: u64,
    state: JobState,
) {
    let Some(store) = store else { return };
    let mut guard = store.lock().unwrap_or_else(|e| e.into_inner());
    let ok = guard.append(&ManifestRecord {
        job,
        state,
        tenant: "tcp".to_string(),
        weight: 1,
        spec_digest: 0,
        knobs_digest: knobs_digest(cfg),
    });
    if ok.is_ok() {
        stats.manifest_records.fetch_add(1, Ordering::Relaxed);
    }
}

/// Serve `jobs` transfer jobs as the **sink** role of an `ftlads serve`
/// daemon: one listener, many concurrent job sessions, demultiplexed by
/// the wire-level job tag each connection leads with (CONNECT for
/// control, STREAM_HELLO for data). Jobs beyond `cfg.serve_max_jobs`
/// queue for an admission slot. Returns each job's sink report (in
/// completion order) plus the daemon counters.
///
/// With `cfg.serve_recover` on, the daemon first replays the manifest
/// under `cfg.ft_dir`: a reconnecting client whose CONNECT carries a
/// known incomplete job tag is handed the recovered session — it
/// queue-jumps admission (front of the pending queue, counted in
/// `jobs_recovered` rather than `jobs_submitted`) and its session
/// resumes against the surviving sink files and `job-<tag>` FT log.
/// Every accepted job's lifecycle is recorded durably (SUBMITTED →
/// ADMITTED → COMPLETED | FAULTED) for the next restart.
pub fn serve_sink(
    cfg: &Config,
    listener: &TcpListener,
    pfs: Arc<dyn Pfs>,
    runtime: Option<RuntimeHandle>,
    jobs: usize,
) -> Result<(Vec<(u64, Result<SinkReport>)>, DaemonSnapshot)> {
    let stats = Arc::new(DaemonStats::default());
    let registry = OstRegistry::new(cfg.ost_count);
    let (manifest, mut recovered) = if cfg.serve_recover {
        let replay = manifest::replay(&cfg.ft_dir)?;
        stats.manifest_records.fetch_add(replay.records, Ordering::Relaxed);
        let incomplete: BTreeSet<u64> = replay.incomplete().map(|r| r.job).collect();
        let store = Arc::new(Mutex::new(ManifestStore::open(&cfg.ft_dir)?));
        (Some(store), incomplete)
    } else {
        (None, BTreeSet::new())
    };
    let dispatch = Arc::new(TcpDispatch {
        pending: Mutex::new(VecDeque::new()),
        running: Mutex::new(0),
        workers: Mutex::new(Vec::new()),
    });
    // Job tag → that job's data-connection mailbox. Registered the
    // moment the control connection arrives (even while the job queues
    // for admission), so data connections never race the session.
    type Mailboxes = Mutex<BTreeMap<u64, mpsc::Sender<(u32, Arc<dyn Endpoint>)>>>;
    let mailboxes: Arc<Mailboxes> = Arc::new(Mutex::new(BTreeMap::new()));
    let (done_tx, done_rx) = mpsc::channel::<(u64, Result<SinkReport>)>();

    let mut accepted = 0usize;
    while accepted < jobs {
        let ep = tcp::accept(listener, cfg.wire(), FaultController::unarmed())?;
        let ep: Arc<dyn Endpoint> = Arc::new(ep);
        let first = match ep.recv_timeout(TCP_JOB_TIMEOUT) {
            Ok(m) => m,
            Err(_) => continue, // connection never introduced itself
        };
        match &first {
            Message::Connect { job, .. } => {
                let job = *job;
                // Listener-side resume handoff: a CONNECT carrying a
                // job tag the manifest knows is incomplete is the
                // job's owner reconnecting after the daemon died — it
                // gets its recovered session back (front of the
                // admission queue), not a fresh submission.
                let handoff = recovered.remove(&job);
                if handoff {
                    stats.jobs_recovered.fetch_add(1, Ordering::Relaxed);
                } else {
                    stats.jobs_submitted.fetch_add(1, Ordering::Relaxed);
                    tcp_manifest_append(&manifest, &stats, cfg, job, JobState::Submitted);
                }
                let (tx, rx) = mpsc::channel();
                mailboxes
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .insert(job, tx);
                let ctrl: Arc<dyn Endpoint> = Arc::new(ReplayEndpoint {
                    head: Mutex::new(Some(first)),
                    inner: ep,
                });
                let mut pending =
                    dispatch.pending.lock().unwrap_or_else(|e| e.into_inner());
                let entry = TcpPending { job, ctrl, data_rx: rx };
                if handoff {
                    pending.push_front(entry);
                } else {
                    pending.push_back(entry);
                }
                drop(pending);
                accepted += 1;
                pump_tcp_jobs(
                    cfg,
                    &dispatch,
                    &registry,
                    &stats,
                    &mailboxes,
                    &pfs,
                    &runtime,
                    &manifest,
                    &done_tx,
                );
            }
            Message::StreamHello { stream_id, job } => {
                // A data connection for a registered job: route it to
                // that job's connector. Unknown tags are dropped — the
                // 30 s connector timeout surfaces the fault on the job.
                let g = mailboxes.lock().unwrap_or_else(|e| e.into_inner());
                if let Some(tx) = g.get(job) {
                    let _ = tx.send((*stream_id, ep));
                }
            }
            _ => {
                // A fresh connection must lead with CONNECT or
                // STREAM_HELLO; anything else is dropped.
            }
        }
    }

    // All jobs accepted; collect their reports as the sessions finish.
    let mut results = Vec::with_capacity(jobs);
    for _ in 0..jobs {
        match done_rx.recv() {
            Ok(r) => results.push(r),
            Err(_) => break, // every worker gone — results are complete
        }
    }
    let workers = std::mem::take(
        &mut *dispatch.workers.lock().unwrap_or_else(|e| e.into_inner()),
    );
    for w in workers {
        let _ = w.join();
    }
    Ok((results, stats.snapshot()))
}

/// Start pending TCP sink sessions while admission slots are free.
/// Called from the accept loop (new job) and from finishing sessions
/// (freed slot) — never blocks, so data connections keep flowing.
#[allow(clippy::too_many_arguments)]
fn pump_tcp_jobs(
    cfg: &Config,
    dispatch: &Arc<TcpDispatch>,
    registry: &Arc<OstRegistry>,
    stats: &Arc<DaemonStats>,
    mailboxes: &Arc<Mutex<BTreeMap<u64, mpsc::Sender<(u32, Arc<dyn Endpoint>)>>>>,
    pfs: &Arc<dyn Pfs>,
    runtime: &Option<RuntimeHandle>,
    manifest: &Option<Arc<Mutex<ManifestStore>>>,
    done_tx: &mpsc::Sender<(u64, Result<SinkReport>)>,
) {
    loop {
        let next = {
            let mut running = dispatch.running.lock().unwrap_or_else(|e| e.into_inner());
            if *running >= cfg.serve_max_jobs {
                return;
            }
            let Some(p) = dispatch
                .pending
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .pop_front()
            else {
                return;
            };
            *running += 1;
            stats.jobs_admitted.fetch_add(1, Ordering::Relaxed);
            stats.note_concurrent(*running as u64);
            p
        };
        let TcpPending { job, ctrl, data_rx } = next;
        tcp_manifest_append(manifest, stats, cfg, job, JobState::Admitted);
        let plane = DataPlane::Connector(Box::new(move |k| {
            let mut slots: Vec<Option<Arc<dyn Endpoint>>> =
                (0..k).map(|_| None).collect();
            for _ in 0..k {
                let (sid, dep) = data_rx.recv_timeout(TCP_JOB_TIMEOUT).map_err(|_| {
                    anyhow::anyhow!("job {job}: timed out waiting for data connections")
                })?;
                let idx = sid as usize;
                anyhow::ensure!(
                    idx < k as usize,
                    "job {job}: STREAM_HELLO stream {sid} out of range (k = {k})"
                );
                anyhow::ensure!(
                    slots[idx].is_none(),
                    "job {job}: duplicate STREAM_HELLO for stream {sid}"
                );
                slots[idx] = Some(dep);
            }
            Ok(slots
                .into_iter()
                .map(|s| s.expect("k distinct in-range hellos fill every slot"))
                .collect())
        }));
        let cfg_job = cfg.clone();
        let pfs_job = pfs.clone();
        let runtime_job = runtime.clone();
        let shared = if cfg.serve_registry {
            Some(Arc::new(registry.handle()))
        } else {
            None
        };
        let dispatch_job = dispatch.clone();
        let registry_job = registry.clone();
        let stats_job = stats.clone();
        let mailboxes_job = mailboxes.clone();
        let manifest_job = manifest.clone();
        let done_job = done_tx.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("serve-sink-{job}"))
            .spawn(move || {
                let mut session = SinkSession::new(&cfg_job, pfs_job, ctrl)
                    .data_plane(plane)
                    .runtime(runtime_job);
                if let Some(h) = shared {
                    session = session.shared_osts(h);
                }
                let report = session.spawn().map(|node| node.join());
                let terminal = match &report {
                    Ok(r) if r.fault.is_none() => {
                        stats_job.jobs_completed.fetch_add(1, Ordering::Relaxed);
                        JobState::Completed
                    }
                    _ => {
                        stats_job.jobs_faulted.fetch_add(1, Ordering::Relaxed);
                        JobState::Faulted
                    }
                };
                tcp_manifest_append(&manifest_job, &stats_job, &cfg_job, job, terminal);
                mailboxes_job
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .remove(&job);
                let _ = done_job.send((job, report));
                {
                    let mut running =
                        dispatch_job.running.lock().unwrap_or_else(|e| e.into_inner());
                    *running -= 1;
                }
                pump_tcp_jobs(
                    &cfg_job,
                    &dispatch_job,
                    &registry_job,
                    &stats_job,
                    &mailboxes_job,
                    &pfs_job,
                    &runtime_job,
                    &manifest_job,
                    &done_job,
                );
            });
        match spawned {
            Ok(h) => dispatch
                .workers
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .push(h),
            Err(e) => {
                let mut running =
                    dispatch.running.lock().unwrap_or_else(|e| e.into_inner());
                *running -= 1;
                stats.jobs_faulted.fetch_add(1, Ordering::Relaxed);
                let _ = done_tx.send((job, Err(anyhow::anyhow!("spawn session: {e}"))));
            }
        }
    }
}

/// Drive N tagged jobs as the **source** role of an `ftlads serve`
/// daemon against a serve sink at `addr`: job i runs `specs[i]` under
/// wire tag `i + 1`, up to `cfg.serve_max_jobs` concurrently, all
/// sharing one source-side congestion registry. Each job logs (and
/// resumes) under its own `<ft_dir>/job-<tag>` namespace. Returns each
/// job's report, in spec order.
///
/// With `cfg.serve_recover` on every job runs with `resume` forced: a
/// restarted source replays only the complement of each job's
/// surviving `job-<tag>` FT log (a job with no log resumes from
/// nothing, i.e. sends everything — so the flag is safe for the
/// mixed case where some jobs completed before the crash).
pub fn serve_source(
    cfg: &Config,
    addr: std::net::SocketAddr,
    pfs: Arc<dyn Pfs>,
    specs: Vec<TransferSpec>,
) -> Result<Vec<(u64, Result<SourceReport>)>> {
    let registry = OstRegistry::new(cfg.ost_count);
    // Admission: a counting gate at `serve_max_jobs` (source jobs only
    // dial out, so blocking here cannot deadlock the daemon).
    let gate = Arc::new((Mutex::new(0usize), Condvar::new()));
    let mut workers = Vec::with_capacity(specs.len());
    for (i, mut spec) in specs.into_iter().enumerate() {
        let job = i as u64 + 1;
        if cfg.serve_recover {
            spec.resume = true;
        }
        {
            let (lock, cv) = &*gate;
            let mut running = lock.lock().unwrap_or_else(|e| e.into_inner());
            while *running >= cfg.serve_max_jobs {
                running = cv.wait(running).unwrap_or_else(|e| e.into_inner());
            }
            *running += 1;
        }
        let mut cfg_job = cfg.clone();
        // Per-job FT namespace, mirroring TransferJob::job_id.
        cfg_job.ft_dir = cfg_job.ft_dir.join(format!("job-{job}"));
        let shared = if cfg.serve_registry {
            Some(Arc::new(registry.handle()))
        } else {
            None
        };
        let pfs_job = pfs.clone();
        let gate_job = gate.clone();
        workers.push(
            std::thread::Builder::new()
                .name(format!("serve-src-{job}"))
                .spawn(move || {
                    let report = run_tcp_source_job(&cfg_job, addr, pfs_job, job, shared, &spec);
                    let (lock, cv) = &*gate_job;
                    let mut running = lock.lock().unwrap_or_else(|e| e.into_inner());
                    *running -= 1;
                    drop(running);
                    cv.notify_all();
                    (job, report)
                })?,
        );
    }
    let mut results = Vec::with_capacity(workers.len());
    for w in workers {
        match w.join() {
            Ok(r) => results.push(r),
            Err(_) => anyhow::bail!("serve: source job worker panicked"),
        }
    }
    Ok(results)
}

/// One tagged source job: dial the control connection, run the session
/// (its CONNECT and STREAM_HELLOs carry the job tag; data connections
/// are dialed on demand, the serve sink's demultiplexer routes them by
/// that tag).
fn run_tcp_source_job(
    cfg: &Config,
    addr: std::net::SocketAddr,
    pfs: Arc<dyn Pfs>,
    job: u64,
    shared: Option<Arc<crate::pfs::JobOstHandle>>,
    spec: &TransferSpec,
) -> Result<SourceReport> {
    // Arm the job's fault plan against its payload size, exactly like
    // the in-process path (`TransferJob::run`): a `FaultPlan::none()`
    // arms to the unarmed controller, so fault-free jobs keep the seed
    // behavior bit for bit.
    let total_bytes: u64 = spec
        .files
        .iter()
        .filter_map(|n| pfs.lookup(n).map(|(_, m)| m.size))
        .sum();
    let fault = spec.fault.arm(total_bytes);
    let ep = tcp::connect(addr, cfg.wire(), fault.clone())?;
    let ep: Arc<dyn Endpoint> = Arc::new(ep);
    let wire = cfg.wire();
    let fault_data = fault.clone();
    let plane = DataPlane::Connector(Box::new(move |k| {
        let mut eps: Vec<Arc<dyn Endpoint>> = Vec::with_capacity(k as usize);
        for _ in 0..k {
            let dep = tcp::connect(addr, wire.clone(), fault_data.clone())?;
            eps.push(Arc::new(dep));
        }
        Ok(eps)
    }));
    let mut session = SourceSession::new(cfg, pfs, ep).data_plane(plane).job(job);
    if let Some(h) = shared {
        session = session.shared_osts(h);
    }
    session.run(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pick(queue: &[(&str, u32)], dispatched: &[(&str, u64)]) -> Option<usize> {
        let d: BTreeMap<String, u64> =
            dispatched.iter().map(|(t, n)| (t.to_string(), *n)).collect();
        fair_pick(queue.iter().copied(), &d)
    }

    #[test]
    fn fair_pick_prefers_underserved_tenant() {
        // a has had 3 of weight 1 (ratio 3); b has had 1 of weight 1
        // (ratio 1) — b dispatches first regardless of queue order.
        assert_eq!(pick(&[("a", 1), ("b", 1)], &[("a", 3), ("b", 1)]), Some(1));
        // Equal ratios tie-break to queue order.
        assert_eq!(pick(&[("a", 1), ("b", 1)], &[("a", 2), ("b", 2)]), Some(0));
        assert_eq!(pick(&[], &[]), None);
    }

    #[test]
    fn fair_pick_honors_weights() {
        // a: 4 dispatched at weight 4 (ratio 1); b: 2 dispatched at
        // weight 1 (ratio 2) — a is still the less-served tenant.
        assert_eq!(pick(&[("b", 1), ("a", 4)], &[("a", 4), ("b", 2)]), Some(1));
        // Fresh tenants (ratio 0) beat everyone.
        assert_eq!(pick(&[("a", 1), ("new", 1)], &[("a", 1)]), Some(1));
    }

    #[test]
    fn fair_pick_weighted_round_robin_sequence() {
        // One tenant at weight 2 vs one at weight 1: over 6 dispatches
        // the weight-2 tenant gets 4 slots — the 2:1 share.
        let mut dispatched: BTreeMap<String, u64> = BTreeMap::new();
        let queue = [("heavy", 2u32), ("light", 1u32)];
        let mut got = Vec::new();
        for _ in 0..6 {
            let i = fair_pick(queue.iter().copied(), &dispatched).unwrap();
            got.push(queue[i].0);
            *dispatched.entry(queue[i].0.to_string()).or_insert(0) += 1;
        }
        let heavy = got.iter().filter(|t| **t == "heavy").count();
        assert_eq!(heavy, 4, "weight-2 tenant gets 2/3 of slots: {got:?}");
    }
}
