//! Source node: master + comm + N IO threads (paper §3.1 / Fig 4).
//!
//! - **master** walks the dataset (windowed), runs the NEW_FILE/FILE_ID
//!   handshake, and on FILE_ID splits the file into objects, excluding
//!   anything the FT log proved durable (resume, §5.2.2), and enqueues
//!   the rest on the per-OST work queues.
//! - **IO threads** pull from the OST queue the configured scheduling
//!   policy picks (`cfg.scheduler`, default: least-congested — see
//!   [`crate::sched`]), reserve an RMA slot, `pread` the object from the
//!   PFS (charging the OST model — the data path's ONE payload copy),
//!   freeze the slot into refcounted [`Bytes`] and hand it to the wire
//!   as NEW_BLOCK with zero further copies; the buffer returns to the
//!   pool when the sink drops the last reference, like a registered RMA
//!   region. With `read_gather_bytes > 0` the IO thread first drains
//!   further byte-contiguous objects of the same file from the popped
//!   OST queue ([`OstQueues::drain_chain`], one RMA slot reserved per
//!   block) and fills the whole run with ONE vectored `preadv`
//!   ([`crate::pfs::Pfs::read_at_vectored`]) — the source mirror of the
//!   sink's write coalescing; each block still gets its own digest,
//!   credit and NEW_BLOCK. With a negotiated `send_window > 1` the issue
//!   loop is *credit-based* (`SendWindow`): up to the applied window of
//!   un-acknowledged NEW_BLOCKs ride per connection, credits
//!   replenished as BLOCK_SYNC/BLOCK_SYNC_BATCH acks arrive;
//!   `send_window = 1` (the default, and the legacy/PR 2 negotiation
//!   fallback) keeps the lockstep issue-and-wait discipline. With
//!   `send_window_adaptive` the applied window floats in
//!   1..=negotiated: credit waits grow it, RMA-pool stalls shrink it
//!   (pinned zero-copy payloads starve preads when the window outruns
//!   the pool).
//! - **comm** owns the receive side: routes FILE_ID / FILE_CLOSE_ACK to
//!   the master and handles BLOCK_SYNC / BLOCK_SYNC_BATCH — *synchronous
//!   logging* in the comm thread's context (§5.1), group-committed when
//!   the sink coalesced several acks into one batch (one `log_blocks`
//!   logger write per wire message), FILE_CLOSE when a file's last
//!   object is synced, retransmission when the sink reports a failed
//!   write.
//!
//! # Multi-stream data plane (`data_streams > 1`)
//!
//! With a negotiated `data_streams = K ≥ 2` the transfer runs over one
//! **control** connection plus K **data** connections (GridFTP-style
//! parallel streams). OSTs are sharded across streams by projected
//! bytes with a greedy LPT pass ([`super::shard::lpt_assignment`] — the
//! old `ost % K` remains only as the fallback for an OST the plan never
//! saw), so layout-aware scheduling stays intact *per
//! stream*: every stream owns its own [`OstQueues`] pick domain, its own
//! credit [`SendWindow`] and its own RMA slot pool, and NEW_BLOCK /
//! BLOCK_SYNC(_BATCH) for an OST only ever ride that OST's stream.
//! CONNECT, NEW_FILE/FILE_ID, FILE_CLOSE(_ACK) and BYE stay on the
//! control connection; FILE_CLOSE is only sent once every stream's
//! outstanding acks for the file arrived (the shared per-file
//! `CompletedSet` is the barrier). The comm side splits accordingly: a
//! control comm thread (FILE_ID / FILE_CLOSE_ACK) plus one data comm
//! thread per stream (acks → that stream's credit window). IO threads
//! are partitioned `ceil(io_threads / K)` per stream. The negotiated
//! `data_streams = 1` (default, and the legacy field-less peer
//! fallback) runs the single fused connection exactly as before —
//! byte-identical to the pre-multi-stream wire.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::queues::{DrainVerdict, OstQueues};
use super::shard;
use super::{DataPlane, TransferSpec};
use crate::config::Config;
use crate::ftlog::{self, CompletedSet, FileKey, FtLogger, SpaceStats};
use crate::integrity::{self, IntegrityMode};
use crate::metrics::{Counters, CounterSnapshot};
use crate::net::{Endpoint, Message, NetError, RmaPool, RmaSlot};
use crate::pfs::ost::OstId;
use crate::pfs::registry::JobOstHandle;
use crate::pfs::{FileId, Pfs};
use crate::sched::{OstCongestion, SchedSnapshot, SchedStats, Scheduler};

/// One object read+send request.
#[derive(Debug, Clone)]
struct BlockReq {
    file_idx: u32,
    block_idx: u32,
    fid: FileId,
    offset: u64,
    len: u32,
}

/// Per-file transfer state (comm + master shared).
struct SrcFile {
    name: String,
    size: u64,
    fid: FileId,
    start_ost: u32,
    total_blocks: u32,
    /// Blocks durable at the sink (seeded from the FT log on resume).
    synced: CompletedSet,
    log_key: Option<FileKey>,
    close_sent: bool,
}

enum MasterEvent {
    FileId { file_idx: u32, skip: bool },
    CloseAck { file_idx: u32 },
    Abort,
}

/// Credit-based NEW_BLOCK send window (one per connection — with
/// `data_streams = K ≥ 2`, one per data stream).
///
/// Armed once after the CONNECT handshake with the negotiated window
/// cap. `max <= 1` disables the gate entirely — the legacy lockstep path
/// is taken and no credit accounting happens. Otherwise each NEW_BLOCK
/// takes one in-flight slot before it goes on the wire and the comm
/// thread returns them as BLOCK_SYNC / BLOCK_SYNC_BATCH acknowledgements
/// arrive (floored at 0, so duplicate acks after a resume can never
/// overfill the window).
///
/// With `adaptive` on (`Config::send_window_adaptive`), the *applied*
/// window `eff` floats in 1..=`max`, mirroring the sink's adaptive ack
/// coalescer: an issue that had to wait on a credit doubles it (the
/// window is the binding constraint), a dry RMA pool halves it (zero-copy
/// pins payload buffers while un-acked, so a window wider than the pool
/// starves the issue loop's preads). Both movements are atomic RMWs —
/// IO threads race on `eff` and a lost update would erase a feedback
/// step.
struct SendWindow {
    /// Negotiated window cap; read once by the IO threads after arming.
    max: AtomicU32,
    /// Applied window (== `max` unless the autotuner floats it).
    eff: AtomicU32,
    /// Grow/shrink `eff` from issue-loop feedback.
    adaptive: bool,
    /// The unified epoch tuner drives `eff` (`Config::tune`); like
    /// `adaptive`, the applied window starts at the floor and earns its
    /// way up, but the movements come from [`crate::tune::HillClimb`]
    /// via [`SendWindow::set_eff`] instead of issue-loop feedback.
    tuned: bool,
    /// NEW_BLOCKs currently on the wire and un-acknowledged.
    inflight: Mutex<u32>,
    available: Condvar,
}

impl SendWindow {
    fn new(adaptive: bool, tuned: bool) -> SendWindow {
        SendWindow {
            max: AtomicU32::new(1),
            eff: AtomicU32::new(1),
            adaptive,
            tuned,
            inflight: Mutex::new(0),
            available: Condvar::new(),
        }
    }

    /// Set the negotiated window cap. Called between the handshake and
    /// the IO-thread spawn, so every issue-loop thread observes the
    /// final value. The adaptive applied window starts at the floor and
    /// earns its way up, like the sink's ack coalescer; fixed mode pins
    /// it to the cap.
    fn arm(&self, window: u32) {
        let window = window.max(1);
        self.max.store(window, Ordering::SeqCst);
        self.eff.store(
            if (self.adaptive || self.tuned) && window > 1 { 1 } else { window },
            Ordering::SeqCst,
        );
        self.available.notify_all();
    }

    /// Pin the applied window to `v` (clamped into 1..=cap) — the
    /// unified tuner's entry point. Notified under the in-flight lock
    /// for the same park-past-the-wakeup race `feedback_grow` documents.
    fn set_eff(&self, v: u32) {
        self.eff.store(v.clamp(1, self.window()), Ordering::SeqCst);
        let _guard = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
        self.available.notify_all();
    }

    fn window(&self) -> u32 {
        self.max.load(Ordering::SeqCst)
    }

    /// The applied window: where the autotuner currently sits (== the
    /// negotiated cap in fixed mode).
    fn effective(&self) -> u32 {
        self.eff.load(Ordering::SeqCst)
    }

    /// Windowing is a no-op at `send_window = 1`: the issue loop runs the
    /// exact lockstep path and never touches the credit state.
    fn enabled(&self) -> bool {
        self.window() > 1
    }

    /// Take one in-flight slot without blocking; false when the applied
    /// window is full of un-acknowledged blocks.
    fn try_acquire(&self) -> bool {
        let mut inflight = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
        if *inflight < self.effective() {
            *inflight += 1;
            true
        } else {
            false
        }
    }

    /// Wait up to `timeout` for an in-flight slot (the stall path;
    /// callers loop with a short tick so aborts interrupt the wait). The
    /// applied window is re-read every pass, so an autotuner grow
    /// unblocks waiters immediately.
    fn acquire_timeout(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut inflight = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
        while *inflight >= self.effective() {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, res) = self
                .available
                .wait_timeout(inflight, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            inflight = guard;
            if res.timed_out() && *inflight >= self.effective() {
                return false;
            }
        }
        *inflight += 1;
        true
    }

    /// Return `n` in-flight slots (acks arrived), floored at 0.
    fn release(&self, n: u32) {
        if n == 0 || !self.enabled() {
            return;
        }
        let mut inflight = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
        *inflight = inflight.saturating_sub(n);
        drop(inflight);
        self.available.notify_all();
    }

    /// An issue had to wait on a credit: the window is what binds —
    /// double the applied window toward the cap.
    fn feedback_grow(&self, counters: &Counters) {
        if !self.adaptive {
            return;
        }
        let cap = self.window();
        let grown = self.eff.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |eff| {
            if eff < cap {
                Some(eff.saturating_mul(2).min(cap))
            } else {
                None
            }
        });
        if grown.is_ok() {
            counters.send_window_grows.fetch_add(1, Ordering::Relaxed);
            // Waiters gate on the applied window; a grow widens it.
            // Notify while holding the inflight lock: a waiter that just
            // evaluated the old window under the lock either re-checks
            // after we release it or is already parked and receives this
            // wakeup — without the lock it could park right past the
            // notification and sleep out its full tick.
            let _guard = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
            self.available.notify_all();
        }
    }

    /// The RMA pool ran dry: in-flight zero-copy payloads are pinning
    /// buffers the issue loop needs — halve the applied window.
    fn feedback_shrink(&self, counters: &Counters) {
        if !self.adaptive {
            return;
        }
        let shrunk = self.eff.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |eff| {
            if eff > 1 {
                Some((eff / 2).max(1))
            } else {
                None
            }
        });
        if shrunk.is_ok() {
            counters.send_window_shrinks.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// One data stream's sending state: its wire endpoint, its private OST
/// pick domain (only OSTs the LPT shard plan assigned to this stream
/// are ever pushed here), its credit window and its RMA slot pool. At
/// `data_streams = 1` the single stream's endpoint IS the control
/// connection (the fused legacy path).
struct SrcStream {
    ep: Arc<dyn Endpoint>,
    queues: OstQueues<BlockReq>,
    /// Credit gate for in-flight NEW_BLOCKs (disabled at window 1).
    window: SendWindow,
    rma: RmaPool,
    /// Bytes acknowledged on this stream — the unified tuner's weight
    /// for splitting the joint send window across streams.
    acked: AtomicU64,
}

struct Shared {
    pfs: Arc<dyn Pfs>,
    /// The control connection (CONNECT, NEW_FILE/FILE_ID,
    /// FILE_CLOSE(_ACK), BYE). At `data_streams = 1` it doubles as the
    /// single data stream's endpoint.
    ep: Arc<dyn Endpoint>,
    /// The data plane: one entry per negotiated stream.
    streams: Vec<SrcStream>,
    /// The configured OST dequeue policy (`cfg.scheduler`), shared
    /// across streams — each OST belongs to exactly one stream, so
    /// stateful policies (e.g. straggler-EWMA) keep one coherent per-OST
    /// view even though picks happen per stream.
    sched: Box<dyn Scheduler>,
    sched_stats: SchedStats,
    counters: Counters,
    /// Contiguous-read gather budget (`Config::read_gather_bytes`);
    /// 0 = the seed-exact one-pread-per-object path. Atomic because the
    /// unified tuner walks it mid-transfer; IO threads snapshot it once
    /// per dequeue.
    read_gather_bytes: AtomicU64,
    /// Bytes-weighted OST → stream plan ([`shard::lpt_assignment`]),
    /// computed once from the dataset layout (empty at K = 1) — and
    /// re-homed by [`Shared::fail_stream`] when a data stream dies, so
    /// it lives behind a lock.
    shard: Mutex<BTreeMap<u32, usize>>,
    /// Data streams whose connection died mid-transfer and whose OST
    /// shard has been re-homed onto the survivors. A stream in this set
    /// is never picked by [`Shared::stream_of`] again; its IO threads
    /// wind down on their next abort/dead check.
    dead: Mutex<BTreeSet<usize>>,
    /// The tuner's move/revert log, drained into the session report.
    tune_trajectory: Mutex<Vec<String>>,
    /// Best observed epoch goodput (bytes/s), stored as `f64` bits.
    goodput_final: AtomicU64,
    files: Mutex<BTreeMap<u32, SrcFile>>,
    /// This job's charge handle on the daemon's shared source-side
    /// [`crate::pfs::OstRegistry`] (None for standalone transfers). IO
    /// threads fold its foreign load into every dequeue's congestion
    /// view; enqueue/complete charge and discharge it, and dropping the
    /// session drains whatever a killed job still had in flight.
    shared_osts: Option<Arc<JobOstHandle>>,
    logger: Mutex<Box<dyn FtLogger>>,
    abort: Mutex<Option<String>>,
    aborted: AtomicBool,
    done: AtomicBool,
    integrity: IntegrityMode,
    object_size: u64,
    padded_words: usize,
}

impl Shared {
    fn abort_with(&self, msg: String) {
        let mut g = self.abort.lock().unwrap_or_else(|e| e.into_inner());
        if g.is_none() {
            *g = Some(msg);
        }
        drop(g);
        self.aborted.store(true, Ordering::SeqCst);
        for s in &self.streams {
            s.queues.close_and_clear();
        }
    }

    fn is_aborted(&self) -> bool {
        self.aborted.load(Ordering::SeqCst)
    }

    /// OST → stream shard from the bytes-weighted LPT plan. Every OST's
    /// objects ride exactly one stream, so per-stream scheduling stays
    /// layout-aware; an OST the plan never saw (a file that appeared
    /// after planning) falls back to the old `ost % K`. A pick that
    /// lands on a dead stream (the `ost % K` fallback, or the race
    /// window while [`Shared::fail_stream`] is still re-homing) is
    /// redirected to the first surviving stream.
    fn stream_of(&self, ost: OstId) -> usize {
        let k = self.streams.len();
        if k == 1 {
            return 0;
        }
        let raw = self
            .shard
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&ost.0)
            .copied()
            .unwrap_or(ost.0 as usize % k);
        let dead = self.dead.lock().unwrap_or_else(|e| e.into_inner());
        if dead.contains(&raw) {
            (0..k).find(|s| !dead.contains(s)).unwrap_or(raw)
        } else {
            raw
        }
    }

    fn is_dead(&self, s: usize) -> bool {
        self.dead.lock().unwrap_or_else(|e| e.into_inner()).contains(&s)
    }

    /// Data stream `s`'s connection died. Returns true when the transfer
    /// can continue: the dead stream's OST shard is re-homed across the
    /// survivors with a fresh LPT pass and every one of its
    /// not-yet-synced blocks — queued, in flight, or acked on the wire
    /// when it went down — is re-derived from the files ledger and
    /// re-enqueued (the sink's (fid, block) write ledger absorbs any
    /// resulting duplicates). Returns false when no stream survives; the
    /// caller aborts, and the synchronous FT log makes the fault
    /// resumable.
    fn fail_stream(&self, s: usize) -> bool {
        let k = self.streams.len();
        {
            let mut dead = self.dead.lock().unwrap_or_else(|e| e.into_inner());
            if !dead.insert(s) {
                return dead.len() < k; // another thread already re-homed it
            }
            if dead.len() >= k {
                return false;
            }
        }
        // Discard the dead stream's queued work: the ledger walk below
        // re-derives it (and everything in flight) uniformly.
        self.streams[s].queues.close_and_clear();
        let survivors: Vec<usize> = {
            let dead = self.dead.lock().unwrap_or_else(|e| e.into_inner());
            (0..k).filter(|i| !dead.contains(i)).collect()
        };

        // Collect the dead stream's pending backlog and per-OST byte
        // weights from the files ledger. Files not yet scheduled
        // (no log key) are skipped — FILE_ID will shard them against
        // the updated plan.
        let layout = self.pfs.layout();
        let mut weights: BTreeMap<u32, u64> = BTreeMap::new();
        let mut backlog: Vec<(OstId, BlockReq)> = Vec::new();
        {
            let files = self.files.lock().unwrap_or_else(|e| e.into_inner());
            let shard = self.shard.lock().unwrap_or_else(|e| e.into_inner()).clone();
            for (&file_idx, f) in files.iter() {
                if f.log_key.is_none() {
                    continue;
                }
                for b in f.synced.pending() {
                    let offset = b as u64 * self.object_size;
                    let ost = layout.ost_for(f.start_ost, offset);
                    let raw =
                        shard.get(&ost.0).copied().unwrap_or(ost.0 as usize % k);
                    if raw != s {
                        continue;
                    }
                    let len = (f.size - offset).min(self.object_size) as u32;
                    *weights.entry(ost.0).or_insert(0) += len as u64;
                    backlog.push((
                        ost,
                        BlockReq { file_idx, block_idx: b, fid: f.fid, offset, len },
                    ));
                }
            }
        }

        // Re-home the orphaned OSTs: LPT over the survivors, named by
        // their real stream indices.
        let plan = shard::rehome_assignment(&weights, &survivors);
        {
            let mut shard = self.shard.lock().unwrap_or_else(|e| e.into_inner());
            shard.extend(plan);
        }
        // Congestion accounting: charge the re-homed backlog as new load
        // (blocks that were still queued get double-counted — a
        // transient heuristic-only skew, and those OSTs really do have
        // the work ahead of them again).
        for (ost, _) in &backlog {
            self.sched.on_enqueue(*ost);
            if let Some(h) = &self.shared_osts {
                h.begin(*ost);
            }
        }
        self.push_to_streams(backlog);
        true
    }

    /// Partition a batch across the stream shards and enqueue each
    /// stream's share with one batched push (single wakeup per stream).
    fn push_to_streams(&self, batch: Vec<(OstId, BlockReq)>) {
        if self.streams.len() == 1 {
            self.streams[0].queues.push_batch(batch);
            return;
        }
        let mut per: Vec<Vec<(OstId, BlockReq)>> =
            (0..self.streams.len()).map(|_| Vec::new()).collect();
        for (ost, req) in batch {
            per[self.stream_of(ost)].push((ost, req));
        }
        for (s, share) in per.into_iter().enumerate() {
            if !share.is_empty() {
                self.streams[s].queues.push_batch(share);
            }
        }
    }
}

/// Source-side session report.
pub struct SourceReport {
    pub fault: Option<String>,
    pub counters: CounterSnapshot,
    pub log_space: SpaceStats,
    /// Files fully accounted for (committed at sink or skipped by resume).
    pub files_done: u64,
    /// Read-queue scheduling counters (picks, pick latency, service).
    pub sched: SchedSnapshot,
    /// The NEW_BLOCK send window actually negotiated at CONNECT (1 = the
    /// lockstep issue path; also the legacy-peer fallback). Per stream.
    pub send_window: u32,
    /// The applied send window at session end: the negotiated cap in
    /// fixed mode, wherever the autotuner's grow/shrink feedback left it
    /// in `send_window_adaptive` mode. With several streams, the most
    /// constrained (minimum) stream's applied window.
    pub send_window_effective: u32,
    /// (count, total ns) of source-side RMA reservation stalls — the
    /// issue loop found the slot pool dry (with zero-copy, buffers stay
    /// pinned until the sink releases the payload). Summed over streams.
    pub rma_stalls: (u64, u64),
    /// RMA DRAM actually registered at session end, summed over the
    /// per-stream pools (`slots × object_size` each, i.e. `rma_bytes`
    /// rounded down to whole slots per pool), unless `rma_autosize` grew
    /// each pool toward the negotiated send window at CONNECT.
    pub rma_bytes_effective: u64,
    /// The parallel data-stream count negotiated at CONNECT (1 = the
    /// fused single-connection path; also the legacy-peer fallback).
    pub data_streams: u32,
    /// Best epoch goodput the unified tuner observed (bytes/s); 0.0
    /// with `tune` off.
    pub goodput_final: f64,
    /// The source tuner's move/revert log, one line per knob step.
    pub tune_trajectory: Vec<String>,
}

/// A configured-but-not-yet-running source job: the entry point for
/// driving the source half of a transfer. Construct with [`new`]
/// (`SourceSession::new`), optionally attach a multi-stream data plane,
/// a daemon wire tag, or a shared OST registry handle, then [`run`]
/// (`SourceSession::run`) to completion/fault.
///
/// ```ignore
/// let report = SourceSession::new(&cfg, pfs, ctrl)
///     .data_plane(plane)          // only needed for data_streams >= 2
///     .job(7)                     // only needed under `ftlads serve`
///     .run(&spec)?;
/// ```
///
/// With all options at their defaults this is behavior- and
/// wire-identical to the historical `run_source(cfg, pfs, ep, spec)`.
pub struct SourceSession<'a> {
    cfg: &'a Config,
    pfs: Arc<dyn Pfs>,
    ctrl: Arc<dyn Endpoint>,
    plane: DataPlane,
    job: u64,
    shared_osts: Option<Arc<JobOstHandle>>,
}

impl<'a> SourceSession<'a> {
    /// A session over a single control connection, with no data plane
    /// (fused single-stream unless [`Self::data_plane`] is attached), no
    /// daemon job tag, and no shared OST registry.
    pub fn new(cfg: &'a Config, pfs: Arc<dyn Pfs>, ctrl: Arc<dyn Endpoint>) -> SourceSession<'a> {
        SourceSession { cfg, pfs, ctrl, plane: DataPlane::none(), job: 0, shared_osts: None }
    }

    /// Supply the per-stream data connections, consumed only when the
    /// CONNECT handshake negotiates `data_streams ≥ 2` (a legacy peer
    /// negotiates 1 and the whole session stays fused on the control
    /// connection).
    pub fn data_plane(mut self, plane: DataPlane) -> Self {
        self.plane = plane;
        self
    }

    /// Tag every CONNECT / STREAM_HELLO with a daemon job id so a shared
    /// `ftlads serve` listener can demultiplex sessions. 0 (the default)
    /// keeps the wire byte-identical to a standalone transfer.
    pub fn job(mut self, job: u64) -> Self {
        self.job = job;
        self
    }

    /// Attach this job's handle on a daemon-wide source-side
    /// [`crate::pfs::OstRegistry`], so dequeues steer around other jobs'
    /// in-flight load and this job's own load is visible to them.
    pub fn shared_osts(mut self, handle: Arc<JobOstHandle>) -> Self {
        self.shared_osts = Some(handle);
        self
    }

    /// Run the source node to completion/fault. Blocks the calling
    /// thread (which acts as the orchestrator); master/comm/IO threads
    /// are spawned internally and joined before returning.
    pub fn run(self, spec: &TransferSpec) -> Result<SourceReport> {
        run_session(self.cfg, self.pfs, self.ctrl, self.plane, self.job, self.shared_osts, spec)
    }
}

/// Run the source node over a single fused connection (the legacy /
/// `data_streams = 1` path). Fails fast when `cfg.data_streams > 1` —
/// a multi-stream session needs a data-plane provider.
#[deprecated(note = "use SourceSession::new(cfg, pfs, ep).run(spec)")]
pub fn run_source(
    cfg: &Config,
    pfs: Arc<dyn Pfs>,
    ep: Arc<dyn Endpoint>,
    spec: &TransferSpec,
) -> Result<SourceReport> {
    anyhow::ensure!(
        cfg.data_streams <= 1,
        "data_streams = {} needs a data-plane provider: attach a data plane",
        cfg.data_streams
    );
    run_session(cfg, pfs, ep, DataPlane::none(), 0, None, spec)
}

/// Run the source node with an explicit data plane.
#[deprecated(note = "use SourceSession::new(cfg, pfs, ctrl).data_plane(plane).run(spec)")]
pub fn run_source_multi(
    cfg: &Config,
    pfs: Arc<dyn Pfs>,
    ctrl: Arc<dyn Endpoint>,
    plane: DataPlane,
    spec: &TransferSpec,
) -> Result<SourceReport> {
    run_session(cfg, pfs, ctrl, plane, 0, None, spec)
}

/// The session body behind [`SourceSession::run`] (and the deprecated
/// free-function wrappers).
fn run_session(
    cfg: &Config,
    pfs: Arc<dyn Pfs>,
    ctrl: Arc<dyn Endpoint>,
    plane: DataPlane,
    job: u64,
    shared_osts: Option<Arc<JobOstHandle>>,
    spec: &TransferSpec,
) -> Result<SourceReport> {
    let logger = Mutex::new(ftlog::create_logger_with_mode(&cfg.ft(), cfg.logging)?);
    // Created ahead of the shared state so handshake retries are counted
    // even when the session dies before the data plane exists.
    let counters = Counters::default();

    // Connect handshake (control connection). Stream 0's pool doubles as
    // the CONNECT-time slot advertisement — every stream's pool is
    // carved with the same `rma_bytes` budget, so one number describes
    // each of them.
    let rma0 = RmaPool::new(cfg.rma_bytes, cfg.object_size as usize);
    let connect = Message::Connect {
        max_object_size: cfg.object_size,
        rma_slots: rma0.slots() as u32,
        resume: spec.resume,
        // Advertise the largest ack batch we are willing to consume, the
        // NEW_BLOCK send window we would like to run, and the number of
        // parallel data streams we can drive; the sink answers with the
        // negotiated (min) values it will use. With `tune` on the
        // advertisements are the tuner's caps (the knobs float *within*
        // them mid-transfer, so the wire never renegotiates); with it
        // off they are exactly the configured values.
        ack_batch: cfg.ack_batch_cap(),
        send_window: cfg.send_window_cap(),
        data_streams: cfg.data_streams.max(1),
        job,
    };
    if let Err(e) = ctrl.send(connect.clone()) {
        return Ok(handshake_fault_report(&counters, &logger, format!("connect: {e}")));
    }
    // Wait for the CONNECT_ACK under the negotiated handshake budget,
    // re-sending CONNECT with exponential backoff up to
    // `connect_retries` times (the sink re-acks a duplicate CONNECT
    // idempotently, so a retry races its own late ack safely). The
    // defaults — 10 s, 0 retries — reproduce the legacy single wait
    // exactly.
    let mut attempt: u32 = 0;
    let (win, k) = loop {
        let budget =
            Duration::from_millis(cfg.connect_timeout_ms << attempt.min(6));
        match ctrl.recv_timeout(budget) {
            Ok(Message::ConnectAck { send_window, data_streams, .. }) => {
                // Honor the sink's negotiated values, but never exceed our own
                // configured advertisements (defensive against a bad peer). A
                // legacy field-less CONNECT_ACK decodes as window 1 (lockstep)
                // and 1 data stream (fused).
                break (
                    send_window.max(1).min(cfg.send_window_cap()),
                    data_streams.max(1).min(cfg.data_streams.max(1)),
                );
            }
            Ok(m) => anyhow::bail!("handshake: unexpected {}", m.type_name()),
            Err(NetError::Timeout) if attempt < cfg.connect_retries => {
                attempt += 1;
                counters.retries.fetch_add(1, Ordering::Relaxed);
                if let Err(e) = ctrl.send(connect.clone()) {
                    return Ok(handshake_fault_report(
                        &counters,
                        &logger,
                        format!("connect retry {attempt}: {e}"),
                    ));
                }
            }
            Err(e) => {
                return Ok(handshake_fault_report(
                    &counters,
                    &logger,
                    format!("connect ack: {e}"),
                ))
            }
        }
    };

    // Materialize the data plane: K = 1 fuses the single stream onto the
    // control connection (today's path, byte-identical); K ≥ 2 brings up
    // K dedicated data connections, each introduced to the sink by a
    // STREAM_HELLO carrying its stream id.
    let data_eps: Vec<Arc<dyn Endpoint>> = if k <= 1 {
        vec![ctrl.clone()]
    } else {
        let eps = match plane.materialize(k) {
            Ok(eps) => eps,
            Err(e) => {
                return Ok(handshake_fault_report(
                    &counters,
                    &logger,
                    format!("data plane ({k} streams): {e}"),
                ))
            }
        };
        for (s, ep) in eps.iter().enumerate() {
            if let Err(e) = ep.send(Message::StreamHello { stream_id: s as u32, job }) {
                return Ok(handshake_fault_report(
                    &counters,
                    &logger,
                    format!("stream {s} hello: {e}"),
                ));
            }
        }
        eps
    };
    let mut rma0 = Some(rma0);
    let streams: Vec<SrcStream> = data_eps
        .into_iter()
        .map(|ep| {
            let window = SendWindow::new(cfg.send_window_adaptive, cfg.tune);
            window.arm(win);
            let rma = rma0
                .take()
                .unwrap_or_else(|| RmaPool::new(cfg.rma_bytes, cfg.object_size as usize));
            // Pool autosizer: with zero-copy, every in-flight NEW_BLOCK
            // pins its slot buffer until the sink releases the payload —
            // register enough slots for the whole negotiated window
            // instead of letting the window autotuner shrink around a
            // starved pool. The window (and therefore the pool) is per
            // stream.
            if cfg.rma_autosize {
                rma.grow_to(win as usize);
            }
            SrcStream {
                ep,
                queues: OstQueues::new(cfg.ost_count),
                window,
                rma,
                acked: AtomicU64::new(0),
            }
        })
        .collect();

    // Bytes-weighted OST → stream plan (satellite of the autotuner PR):
    // project every object of the dataset onto its OST, then LPT the
    // per-OST byte totals across the K streams. One deterministic pass
    // up front — resume re-derives the identical plan from the same
    // spec, and the sink learns the map passively from arrivals.
    let ost_shard = if k >= 2 {
        let layout = pfs.layout();
        let mut weights: BTreeMap<u32, u64> = BTreeMap::new();
        for name in &spec.files {
            if let Some((_fid, meta)) = pfs.lookup(name) {
                let mut off = 0u64;
                while off < meta.size {
                    let len = (meta.size - off).min(cfg.object_size);
                    let ost = layout.ost_for(meta.start_ost, off);
                    *weights.entry(ost.0).or_insert(0) += len;
                    off += cfg.object_size;
                }
            }
        }
        shard::lpt_assignment(&weights, k as usize)
    } else {
        BTreeMap::new()
    };

    let shared = Arc::new(Shared {
        pfs,
        ep: ctrl,
        streams,
        sched: cfg.scheduler.build(cfg.ost_count),
        sched_stats: SchedStats::default(),
        counters,
        read_gather_bytes: AtomicU64::new(cfg.read_gather_bytes),
        shard: Mutex::new(ost_shard),
        dead: Mutex::new(BTreeSet::new()),
        tune_trajectory: Mutex::new(Vec::new()),
        goodput_final: AtomicU64::new(0),
        files: Mutex::new(BTreeMap::new()),
        shared_osts,
        logger,
        abort: Mutex::new(None),
        aborted: AtomicBool::new(false),
        done: AtomicBool::new(false),
        integrity: cfg.integrity,
        object_size: cfg.object_size,
        padded_words: (cfg.object_size as usize).div_ceil(4),
    });

    let (master_tx, master_rx) = mpsc::channel::<MasterEvent>();

    // IO threads, partitioned across streams (K = 1 keeps the exact
    // seed thread count on the single stream).
    let per_stream_io = cfg.io_threads.div_ceil(k as usize).max(1);
    let mut io_threads = Vec::new();
    for s in 0..shared.streams.len() {
        for t in 0..per_stream_io {
            let sh = shared.clone();
            io_threads.push(
                std::thread::Builder::new()
                    .name(format!("src-io-{s}-{t}"))
                    .spawn(move || io_thread(&sh, s))?,
            );
        }
    }

    // Comm threads (receive side): one fused thread at K = 1; a control
    // thread plus one per data stream at K ≥ 2.
    let mut comm_threads = Vec::new();
    if k <= 1 {
        let sh = shared.clone();
        let tx = master_tx.clone();
        comm_threads.push(
            std::thread::Builder::new()
                .name("src-comm".into())
                .spawn(move || comm_thread(&sh, CommRole::Fused, tx))?,
        );
    } else {
        let sh = shared.clone();
        let tx = master_tx.clone();
        comm_threads.push(
            std::thread::Builder::new()
                .name("src-comm".into())
                .spawn(move || comm_thread(&sh, CommRole::Control, tx))?,
        );
        for s in 0..shared.streams.len() {
            let sh = shared.clone();
            let tx = master_tx.clone();
            comm_threads.push(
                std::thread::Builder::new()
                    .name(format!("src-comm-{s}"))
                    .spawn(move || comm_thread(&sh, CommRole::Data(s), tx))?,
            );
        }
    }

    // The unified epoch tuner (source half): samples goodput every
    // `tune_epoch_ms` and walks {send window, read-gather budget}.
    let tune_thread = if cfg.tune {
        let sh = shared.clone();
        let epoch = Duration::from_millis(cfg.tune_epoch_ms.max(1));
        let gather_cap = cfg.gather_cap();
        Some(
            std::thread::Builder::new()
                .name("src-tune".into())
                .spawn(move || source_tuner(&sh, epoch, gather_cap))?,
        )
    } else {
        None
    };

    // Master runs on the calling thread.
    let files_done = master_loop(cfg, &shared, spec, master_rx);

    // Teardown: stop IO threads, then the comm threads.
    shared.done.store(true, Ordering::SeqCst);
    for s in &shared.streams {
        s.queues.close();
    }
    for h in io_threads {
        let _ = h.join();
    }
    for h in comm_threads {
        let _ = h.join();
    }
    if let Some(h) = tune_thread {
        let _ = h.join();
    }

    Ok(aggregate_report(&shared, files_done))
}

/// Assemble the session report from the shared state, aggregating the
/// per-stream window/pool figures.
fn aggregate_report(shared: &Shared, files_done: u64) -> SourceReport {
    let (mut stall_count, mut stall_ns, mut rma_bytes) = (0u64, 0u64, 0u64);
    let mut eff = u32::MAX;
    for s in &shared.streams {
        let (c, ns) = s.rma.stall_stats();
        stall_count += c;
        stall_ns += ns;
        rma_bytes += s.rma.total_bytes();
        eff = eff.min(s.window.effective());
    }
    SourceReport {
        fault: shared.abort.lock().unwrap_or_else(|e| e.into_inner()).clone(),
        counters: shared.counters.snapshot(),
        log_space: shared.logger.lock().unwrap_or_else(|e| e.into_inner()).space(),
        files_done,
        sched: shared.sched_stats.snapshot(),
        send_window: shared.streams[0].window.window(),
        send_window_effective: eff,
        rma_stalls: (stall_count, stall_ns),
        rma_bytes_effective: rma_bytes,
        data_streams: shared.streams.len() as u32,
        goodput_final: f64::from_bits(shared.goodput_final.load(Ordering::Relaxed)),
        tune_trajectory: shared
            .tune_trajectory
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone(),
    }
}

/// A session that died during the CONNECT handshake, before any data
/// plane (or shared state) existed.
fn handshake_fault_report(
    counters: &Counters,
    logger: &Mutex<Box<dyn FtLogger>>,
    msg: String,
) -> SourceReport {
    SourceReport {
        fault: Some(msg),
        counters: counters.snapshot(),
        log_space: logger.lock().unwrap_or_else(|e| e.into_inner()).space(),
        files_done: 0,
        sched: SchedStats::default().snapshot(),
        send_window: 1,
        send_window_effective: 1,
        rma_stalls: (0, 0),
        rma_bytes_effective: 0,
        data_streams: 1,
        goodput_final: 0.0,
        tune_trajectory: Vec::new(),
    }
}

/// The source half of the unified epoch tuner (`Config::tune`): every
/// `epoch` it turns the acked-byte delta into a goodput sample, feeds it
/// (with issue-loop stall pressure as the tiebreak signal) to one
/// [`HillClimb`](crate::tune::HillClimb) over {applied send window,
/// read-gather budget}, and applies whatever move the climber proposes —
/// all within the caps negotiated at CONNECT, so the wire never changes
/// mid-transfer. The joint window budget is re-split across streams
/// every epoch in proportion to per-stream acked bytes.
fn source_tuner(shared: &Arc<Shared>, epoch: Duration, gather_cap: u64) {
    use crate::tune::{HillClimb, KnobSpec};
    let win_cap = u64::from(shared.streams[0].window.window());
    let mut hc = HillClimb::new(vec![
        KnobSpec {
            name: "send_window",
            floor: 1,
            cap: win_cap,
            seed: 2,
            start: u64::from(shared.streams[0].window.effective()),
        },
        KnobSpec {
            name: "read_gather",
            floor: 0,
            cap: gather_cap,
            seed: 1 << 20,
            start: shared.read_gather_bytes.load(Ordering::Relaxed),
        },
    ]);
    let tick = epoch.min(Duration::from_millis(5)).max(Duration::from_millis(1));
    let mut last = std::time::Instant::now();
    let mut last_acked = shared.counters.bytes_acked.load(Ordering::Relaxed);
    let mut last_stalls = shared.counters.send_stalls.load(Ordering::Relaxed);
    while !shared.is_aborted() && !shared.done.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        let now = std::time::Instant::now();
        let dt = now.duration_since(last);
        if dt < epoch {
            continue;
        }
        last = now;
        let acked = shared.counters.bytes_acked.load(Ordering::Relaxed);
        let stalls = shared.counters.send_stalls.load(Ordering::Relaxed);
        let goodput = (acked - last_acked) as f64 / dt.as_secs_f64();
        let pressure = stalls - last_stalls;
        last_acked = acked;
        last_stalls = stalls;
        if let Some((idx, value)) = hc.observe(goodput, pressure) {
            if idx == 1 {
                shared.read_gather_bytes.store(value, Ordering::Relaxed);
            }
            // idx 0 (the window) is applied by the rebalance below.
        }
        rebalance_windows(shared, hc.value(0) as u32);
        shared.counters.tune_epochs.store(hc.epochs, Ordering::Relaxed);
        shared.counters.tune_grows.store(hc.grows, Ordering::Relaxed);
        shared.counters.tune_shrinks.store(hc.shrinks, Ordering::Relaxed);
        shared.counters.tune_reverts.store(hc.reverts, Ordering::Relaxed);
    }
    shared
        .goodput_final
        .store(hc.best.to_bits(), Ordering::Relaxed);
    *shared.tune_trajectory.lock().unwrap_or_else(|e| e.into_inner()) =
        std::mem::take(&mut hc.trajectory);
}

/// Split the tuner's joint window budget (`w` credits × K streams)
/// across streams in proportion to the bytes each has moved, clamped
/// into 1..=cap per stream. With no history yet (or K = 1) every stream
/// gets `w`. No-op while windowing is disabled (negotiated window 1 —
/// the lockstep path never reads `eff`).
fn rebalance_windows(shared: &Arc<Shared>, w: u32) {
    if !shared.streams[0].window.enabled() {
        return;
    }
    if shared.streams.len() == 1 {
        shared.streams[0].window.set_eff(w);
        return;
    }
    let acked: Vec<u64> = shared
        .streams
        .iter()
        .map(|s| s.acked.load(Ordering::Relaxed))
        .collect();
    let sum: u64 = acked.iter().sum();
    let total = u64::from(w) * shared.streams.len() as u64;
    for (s, a) in shared.streams.iter().zip(&acked) {
        let share = if sum == 0 { u64::from(w) } else { (total * a / sum).max(1) };
        s.window.set_eff(share.min(u64::from(u32::MAX)) as u32);
    }
}

/// Master: windowed file admission + handshake bookkeeping (§5.2.1).
fn master_loop(
    cfg: &Config,
    shared: &Arc<Shared>,
    spec: &TransferSpec,
    master_rx: mpsc::Receiver<MasterEvent>,
) -> u64 {
    // §5.2.2: on resume, parse the FT logs left by the interrupted run.
    let recovered: BTreeMap<String, CompletedSet> = if spec.resume {
        ftlog::recover::recover_all(&cfg.ft()).unwrap_or_default()
    } else {
        BTreeMap::new()
    };

    let total_files = spec.files.len();
    let mut next_file = 0usize;
    let mut inflight = 0usize;
    let mut done_files = 0u64;

    while done_files < total_files as u64 && !shared.is_aborted() {
        // Admit files up to the window.
        while next_file < total_files && inflight < cfg.file_window && !shared.is_aborted() {
            let name = &spec.files[next_file];
            let file_idx = next_file as u32;
            next_file += 1;
            let Some((fid, meta)) = shared.pfs.lookup(name) else {
                shared.abort_with(format!("source file '{name}' disappeared"));
                break;
            };
            let total_blocks =
                crate::util::div_ceil(meta.size, shared.object_size) as u32;
            let mut synced = CompletedSet::new(total_blocks);
            if let Some(rec) = recovered.get(name) {
                if rec.total() == total_blocks {
                    for b in rec.iter_completed() {
                        synced.insert(b);
                    }
                }
            }
            shared.files.lock().unwrap_or_else(|e| e.into_inner()).insert(
                file_idx,
                SrcFile {
                    name: name.clone(),
                    size: meta.size,
                    fid,
                    start_ost: meta.start_ost,
                    total_blocks,
                    synced,
                    log_key: None,
                    close_sent: false,
                },
            );
            if shared
                .ep
                .send(Message::NewFile {
                    file_idx,
                    name: name.clone(),
                    size: meta.size,
                    start_ost: meta.start_ost,
                })
                .is_err()
            {
                shared.abort_with("NEW_FILE send failed".into());
                break;
            }
            inflight += 1;
        }

        if done_files >= total_files as u64 || shared.is_aborted() {
            break;
        }

        // Wait for one event, then drain whatever else arrived.
        let ev = match master_rx.recv_timeout(Duration::from_millis(50)) {
            Ok(ev) => ev,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        let mut events = vec![ev];
        while let Ok(ev) = master_rx.try_recv() {
            events.push(ev);
        }
        for ev in events {
            match ev {
                MasterEvent::FileId { file_idx, skip } => {
                    if skip {
                        // Sink metadata matched a committed file: skip it
                        // (§5.2.2) and account every object as saved.
                        let mut files =
                            shared.files.lock().unwrap_or_else(|e| e.into_inner());
                        if let Some(f) = files.remove(&file_idx) {
                            shared
                                .counters
                                .files_skipped_resume
                                .fetch_add(1, Ordering::Relaxed);
                            shared.counters.objects_skipped_resume.fetch_add(
                                f.total_blocks as u64,
                                Ordering::Relaxed,
                            );
                        }
                        drop(files);
                        inflight -= 1;
                        done_files += 1;
                    } else {
                        schedule_file_blocks(shared, file_idx);
                    }
                }
                MasterEvent::CloseAck { file_idx } => {
                    let mut files =
                        shared.files.lock().unwrap_or_else(|e| e.into_inner());
                    files.remove(&file_idx);
                    drop(files);
                    shared.counters.files_completed.fetch_add(1, Ordering::Relaxed);
                    inflight -= 1;
                    done_files += 1;
                }
                MasterEvent::Abort => {}
            }
        }
    }

    if !shared.is_aborted() && done_files == total_files as u64 {
        // Dataset complete: tear the session down cleanly.
        let _ = shared.ep.send(Message::Bye);
        let mut logger = shared.logger.lock().unwrap_or_else(|e| e.into_inner());
        let _ = logger.finish_dataset();
    }
    done_files
}

/// On FILE_ID: register with the FT logger (seeded from recovery) and
/// enqueue the pending objects on their OST queues (sharded per stream).
fn schedule_file_blocks(shared: &Arc<Shared>, file_idx: u32) {
    let mut files = shared.files.lock().unwrap_or_else(|e| e.into_inner());
    let Some(f) = files.get_mut(&file_idx) else { return };

    // Register with the logger, seeding already-durable blocks so a second
    // fault cannot lose pre-first-fault progress. The seed is one
    // group-committed write, not a per-block append storm.
    {
        let mut logger = shared.logger.lock().unwrap_or_else(|e| e.into_inner());
        match logger.register_file(&f.name, f.total_blocks) {
            Ok(key) => {
                f.log_key = Some(key);
                let durable: Vec<u32> = f.synced.iter_completed().collect();
                let _ = logger.log_blocks(key, &durable);
            }
            Err(e) => {
                drop(logger);
                drop(files);
                shared.abort_with(format!("FT log registration failed: {e}"));
                return;
            }
        }
    }

    let pending = f.synced.pending();
    shared
        .counters
        .objects_skipped_resume
        .fetch_add((f.total_blocks - pending.len() as u32) as u64, Ordering::Relaxed);

    if pending.is_empty() {
        // Everything was durable before the fault but the file was never
        // closed: close it now.
        f.close_sent = true;
        if let Some(key) = f.log_key {
            let mut logger = shared.logger.lock().unwrap_or_else(|e| e.into_inner());
            let _ = logger.complete_file(key);
        }
        let _ = shared.ep.send(Message::FileClose { file_idx });
        return;
    }

    // Whole-file admission is the batch enqueue path: take each stream's
    // queue lock once for its share of the pending objects and broadcast
    // a single wakeup per stream.
    let layout = shared.pfs.layout();
    let mut batch = Vec::with_capacity(pending.len());
    for b in pending {
        let offset = b as u64 * shared.object_size;
        let len = (f.size - offset).min(shared.object_size) as u32;
        let ost = layout.ost_for(f.start_ost, offset);
        batch.push((ost, BlockReq { file_idx, block_idx: b, fid: f.fid, offset, len }));
    }
    for (ost, _) in &batch {
        shared.sched.on_enqueue(*ost);
        if let Some(h) = &shared.shared_osts {
            h.begin(*ost);
        }
    }
    shared.push_to_streams(batch);
}

/// IO thread (pinned to one stream): policy-picked OST dequeue → RMA
/// reserve → pread → freeze → digest → NEW_BLOCK.
///
/// The `pread` into the RMA slot is the data path's ONE payload copy
/// (`Counters::payload_copies`); the slot is then frozen into refcounted
/// [`Bytes`] and everything downstream — wire serialization, the peer's
/// `pwrite` — runs off that buffer. It returns to the pool when the last
/// reference drops, i.e. once the sink has written and released it,
/// exactly like an RMA-registered region stays pinned until the remote
/// read completes.
///
/// With `read_gather_bytes > 0` the thread first drains further
/// byte-contiguous same-file objects from the popped OST queue
/// (`drain_chain`, one `try_reserve`d slot per block — a dry pool ends
/// the run rather than stalling the scan) and fills all their slots with
/// ONE vectored `preadv` ([`Pfs::read_at_vectored`]); runs are capped at
/// [`crate::pfs::IOV_MAX_GATHER`] blocks so one gathered run is one real
/// syscall on the disk backend (`Counters::read_syscalls` stays an
/// honest submission count). Every block of the run still gets its own
/// freeze/digest/credit/NEW_BLOCK — the wire is unchanged by gathering.
///
/// Two issue disciplines, selected by the negotiated send window:
///
/// - **lockstep** (`send_window = 1`, the legacy/PR 2 negotiation
///   fallback): issue-and-wait — the send is not gated and the pool
///   bounds what is in flight.
/// - **windowed** (`send_window > 1`): the send is gated on a
///   [`SendWindow`] in-flight slot, bounding un-acknowledged blocks per
///   stream; with `send_window_adaptive` the applied window floats from
///   issue-loop feedback.
///
/// A failed *first* slot reservation counts as one issue-loop stall in
/// `Counters::send_stalls` (and, in adaptive mode, shrinks the applied
/// window — in-flight payloads pin pool buffers); a failed first credit
/// grab counts in `Counters::credit_waits` (back-pressure, not slot
/// starvation; in adaptive mode it grows the applied window).
fn io_thread(shared: &Arc<Shared>, stream_idx: usize) {
    let stream = &shared.streams[stream_idx];
    let osts = shared.pfs.ost_model();
    // Under `ftlads serve` the congestion view folds other jobs' in-flight
    // load (from the daemon's shared registry) into every policy pick.
    let cong = OstCongestion::with_shared(osts, shared.shared_osts.as_deref());
    let windowed = stream.window.enabled();
    'pop: while let Some((ost, req)) =
        stream
            .queues
            .pop_next_timed(&*shared.sched, &cong, &shared.sched_stats)
    {
        if shared.is_aborted() {
            break;
        }
        // Reserve an RMA slot (bounded buffer registration), abort-aware.
        let first_slot = match stream.rma.try_reserve() {
            Some(s) => Some(s),
            None => {
                shared.counters.send_stalls.fetch_add(1, Ordering::Relaxed);
                stream.window.feedback_shrink(&shared.counters);
                loop {
                    match stream.rma.reserve_timeout(Duration::from_millis(50)) {
                        Some(s) => break Some(s),
                        // A dead stream's pool can stay dry forever (its
                        // in-flight payloads are pinned in the severed
                        // connection) — wind the thread down instead.
                        None if shared.is_aborted()
                            || shared.done.load(Ordering::SeqCst)
                            || shared.is_dead(stream_idx) =>
                        {
                            break None
                        }
                        None => continue,
                    }
                }
            }
        };
        let Some(first_slot) = first_slot else { break };

        // Gather a byte-contiguous same-file run off the SAME OST queue
        // the policy picked (a budget of 0 never drains — the seed-exact
        // per-object path), reserving one slot per block as the scan
        // takes it. The drained blocks ride this thread's service round;
        // the policy is not re-consulted mid-run.
        let mut run: Vec<(BlockReq, RmaSlot)> = vec![(req, first_slot)];
        // Snapshot the budget once per dequeue: the unified tuner may
        // move it mid-transfer, and a run must be sized against one
        // coherent value.
        let gather_budget = shared.read_gather_bytes.load(Ordering::Relaxed);
        if gather_budget > 0 {
            // Cap runs at POSIX's IOV_MAX so one gathered run is ONE
            // `preadv` on the disk backend (past the cap the backend
            // would split silently and `read_syscalls` would
            // under-count), keeping the counter == real submissions.
            const MAX_RUN_BLOCKS: usize = crate::pfs::IOV_MAX_GATHER;
            let fid = run[0].0.fid;
            let mut end = run[0].0.offset + run[0].0.len as u64;
            let mut run_bytes = run[0].0.len as u64;
            let mut run_blocks = 1usize;
            let mut extra_slots: Vec<RmaSlot> = Vec::new();
            let extra = stream.queues.drain_chain(ost, |cand: &BlockReq| {
                if cand.fid != fid || cand.offset != end {
                    return DrainVerdict::Skip;
                }
                // The chain is linear: exactly one queued block can be
                // the run's next byte. If that unique successor busts
                // the budget (or the run hit the iov cap), nothing
                // further can ever chain — stop the scan instead of
                // re-walking the backlog.
                let len = cand.len as u64;
                if run_blocks == MAX_RUN_BLOCKS || run_bytes + len > gather_budget {
                    return DrainVerdict::Stop;
                }
                // One slot per gathered block, non-blocking: a dry pool
                // ends the run instead of stalling under the queue lock.
                let Some(slot) = stream.rma.try_reserve() else {
                    return DrainVerdict::Stop;
                };
                extra_slots.push(slot);
                end += len;
                run_bytes += len;
                run_blocks += 1;
                DrainVerdict::Take
            });
            run.extend(extra.into_iter().zip(extra_slots));
        }

        // Stage the whole run with one storage submission: the plain
        // `pread` for a run of 1 (the seed path), one vectored `preadv`
        // otherwise. Either way this is the data path's ONE payload copy
        // per object.
        let io_started = std::time::Instant::now();
        if run.len() == 1 {
            let (first_req, slot) = &mut run[0];
            let buf = slot.buf();
            buf.resize(first_req.len as usize, 0);
            match shared.pfs.read_at(first_req.fid, first_req.offset, buf) {
                Ok(n) if n == first_req.len as usize => {
                    shared.counters.read_syscalls.fetch_add(1, Ordering::Relaxed);
                }
                Ok(n) => {
                    shared.abort_with(format!(
                        "short read: file {} block {} got {n} of {}",
                        first_req.file_idx, first_req.block_idx, first_req.len
                    ));
                    break;
                }
                Err(e) => {
                    shared.abort_with(format!("pread failed: {e}"));
                    break;
                }
            }
        } else {
            let fid = run[0].0.fid;
            let base = run[0].0.offset;
            let total: usize = run.iter().map(|(r, _)| r.len as usize).sum();
            for (r, slot) in run.iter_mut() {
                slot.buf().resize(r.len as usize, 0);
            }
            let got = {
                let mut iovs: Vec<&mut [u8]> = run
                    .iter_mut()
                    .map(|(_, slot)| slot.buf().as_mut_slice())
                    .collect();
                shared.pfs.read_at_vectored(fid, base, &mut iovs)
            };
            match got {
                Ok(n) if n == total => {
                    shared.counters.read_syscalls.fetch_add(1, Ordering::Relaxed);
                    shared.counters.gathered_runs.fetch_add(1, Ordering::Relaxed);
                    shared
                        .counters
                        .gather_bytes_max
                        .fetch_max(total as u64, Ordering::Relaxed);
                }
                Ok(n) => {
                    shared.abort_with(format!(
                        "short gathered read: file {} at {base} got {n} of {total}",
                        run[0].0.file_idx
                    ));
                    break;
                }
                Err(e) => {
                    shared.abort_with(format!("preadv failed: {e}"));
                    break;
                }
            }
        }
        // Feed the measured storage service time back to stateful
        // policies (e.g. straggler-aware EWMA) and the counters — one
        // evenly-split sample per constituent block, so gathered and
        // ungathered samples stay comparable (mirrors the sink's
        // write_run accounting).
        let service = io_started.elapsed() / run.len() as u32;
        for _ in 0..run.len() {
            shared.sched.on_complete(ost, service);
            shared.sched_stats.record_complete(service);
            if let Some(h) = &shared.shared_osts {
                h.end(ost);
            }
        }
        for (r, _) in &run {
            // The staging pread is the zero-copy path's single payload
            // copy per object.
            shared.counters.payload_copies.fetch_add(1, Ordering::Relaxed);
            shared
                .counters
                .bytes_copied
                .fetch_add(r.len as u64, Ordering::Relaxed);
        }

        // Per-block freeze → digest → credit → NEW_BLOCK: the wire is
        // identical whether the payload was staged alone or in a run.
        for (req, slot) in run.drain(..) {
            // Freeze the slot into the refcounted payload: no copy, and
            // the buffer stays registered (out of the pool) until the
            // sink releases its view.
            let payload = slot.freeze();

            let digest = match shared.integrity {
                IntegrityMode::Off => 0u64,
                // Send-side digests are always computed natively — they
                // must exist *before* the object leaves the node; the
                // sink side is where the batched PJRT verify runs (see
                // sink::verifier).
                _ => integrity::digest_bytes_padded(&payload, shared.padded_words)
                    .as_u64(),
            };

            let msg = Message::NewBlock {
                file_idx: req.file_idx,
                block_idx: req.block_idx,
                offset: req.offset,
                digest,
                data: payload,
            };
            if windowed {
                // Gate the send on an in-flight slot of the applied
                // window.
                if !stream.window.try_acquire() {
                    shared.counters.credit_waits.fetch_add(1, Ordering::Relaxed);
                    stream.window.feedback_grow(&shared.counters);
                    let mut granted = false;
                    // A dead stream's credits never come back (its acks
                    // are lost with the connection) — the dead check
                    // keeps this wait from spinning forever.
                    while !shared.is_aborted()
                        && !shared.done.load(Ordering::SeqCst)
                        && !shared.is_dead(stream_idx)
                    {
                        if stream.window.acquire_timeout(Duration::from_millis(50)) {
                            granted = true;
                            break;
                        }
                    }
                    if !granted {
                        break 'pop;
                    }
                }
            }
            match stream.ep.send(msg) {
                Ok(()) => {
                    shared.counters.objects_sent.fetch_add(1, Ordering::Relaxed);
                    shared
                        .counters
                        .bytes_sent
                        .fetch_add(req.len as u64, Ordering::Relaxed);
                }
                Err(NetError::Fault(e)) => {
                    // The injected payload-threshold fault severs the
                    // whole session (every connection shares the
                    // controller) — the FT kill-point semantics, not a
                    // single-stream death.
                    shared.abort_with(e);
                    break 'pop;
                }
                Err(NetError::Closed) if !shared.done.load(Ordering::SeqCst) => {
                    // This stream's connection died. Fail over: re-home
                    // its backlog (including the block we just failed to
                    // send — it is still unsynced in the ledger) onto the
                    // survivors, or fault cleanly when none remain.
                    if !shared.fail_stream(stream_idx) {
                        shared.abort_with(format!(
                            "data stream {stream_idx} closed with no surviving streams"
                        ));
                    }
                    break 'pop;
                }
                Err(e) => {
                    shared.abort_with(format!("send failed: {e}"));
                    break 'pop;
                }
            }
        }
    }
}

/// Which connection a comm thread serves — and therefore which message
/// classes it may legally see there.
#[derive(Clone, Copy)]
enum CommRole {
    /// The single `data_streams = 1` connection: every message class
    /// (the legacy path — today's comm thread, unchanged).
    Fused,
    /// The control connection at K ≥ 2: FILE_ID and FILE_CLOSE_ACK.
    Control,
    /// Data stream `s` at K ≥ 2: BLOCK_SYNC(_BATCH) feeding that
    /// stream's credit window (plus the introductory STREAM_HELLO echo
    /// when the transport delivers it end-to-end rather than consuming
    /// it during accept).
    Data(usize),
}

/// Comm thread: the receive loop. BLOCK_SYNC handling — synchronous FT
/// logging in the receiving comm thread's context — is the paper's §5.1
/// change.
fn comm_thread(shared: &Arc<Shared>, role: CommRole, master_tx: mpsc::Sender<MasterEvent>) {
    let ep: &Arc<dyn Endpoint> = match role {
        CommRole::Fused | CommRole::Control => &shared.ep,
        CommRole::Data(s) => &shared.streams[s].ep,
    };
    loop {
        if shared.is_aborted() || shared.done.load(Ordering::SeqCst) {
            break;
        }
        let msg = match ep.recv_timeout(Duration::from_millis(50)) {
            Ok(m) => m,
            Err(NetError::Timeout) => continue,
            Err(NetError::Closed) => {
                if !shared.done.load(Ordering::SeqCst) {
                    if let CommRole::Data(s) = role {
                        // A single data stream died: fail over to the
                        // survivors instead of killing the session.
                        if shared.fail_stream(s) {
                            break;
                        }
                        shared.abort_with(format!(
                            "data stream {s} closed with no surviving streams"
                        ));
                    } else {
                        shared.abort_with("connection closed by sink".into());
                    }
                    let _ = master_tx.send(MasterEvent::Abort);
                }
                break;
            }
            Err(NetError::Fault(e)) => {
                shared.abort_with(e);
                let _ = master_tx.send(MasterEvent::Abort);
                break;
            }
        };
        match (role, msg) {
            (CommRole::Fused | CommRole::Control, Message::FileId { file_idx, skip, .. }) => {
                let _ = master_tx.send(MasterEvent::FileId { file_idx, skip });
            }
            (CommRole::Fused | CommRole::Control, Message::FileCloseAck { file_idx }) => {
                let _ = master_tx.send(MasterEvent::CloseAck { file_idx });
            }
            (CommRole::Fused, Message::BlockSync { file_idx, block_idx, ok }) => {
                // Every *fresh* acknowledged block returns one send
                // credit — failed writes too: the object left the window
                // and its retransmit will take a fresh credit. Duplicate
                // acks (a torture replay, or a batch retransmit after
                // resume) return nothing — crediting them would overfill
                // the window past the un-acked in-flight count.
                let credits = handle_block_syncs(shared, file_idx, &[(block_idx, ok)]);
                shared.streams[0].window.release(credits);
                shared.streams[0]
                    .acked
                    .fetch_add(credits as u64 * shared.object_size, Ordering::Relaxed);
            }
            (CommRole::Fused, Message::BlockSyncBatch { file_idx, blocks }) => {
                let credits = handle_block_syncs(shared, file_idx, &blocks);
                shared.streams[0].window.release(credits);
                shared.streams[0]
                    .acked
                    .fetch_add(credits as u64 * shared.object_size, Ordering::Relaxed);
            }
            (CommRole::Data(s), Message::BlockSync { file_idx, block_idx, ok }) => {
                let credits = handle_block_syncs(shared, file_idx, &[(block_idx, ok)]);
                shared.streams[s].window.release(credits);
                shared.streams[s]
                    .acked
                    .fetch_add(credits as u64 * shared.object_size, Ordering::Relaxed);
            }
            (CommRole::Data(s), Message::BlockSyncBatch { file_idx, blocks }) => {
                let credits = handle_block_syncs(shared, file_idx, &blocks);
                shared.streams[s].window.release(credits);
                shared.streams[s]
                    .acked
                    .fetch_add(credits as u64 * shared.object_size, Ordering::Relaxed);
            }
            (CommRole::Fused | CommRole::Control, Message::ConnectAck { .. }) => {
                // A duplicated (or retry-raced) CONNECT_ACK arriving
                // after the handshake already completed: idempotent,
                // ignore.
                shared.counters.dup_acks_dropped.fetch_add(1, Ordering::Relaxed);
            }
            (role, other) => {
                shared.abort_with(format!(
                    "source {} comm: unexpected {}",
                    match role {
                        CommRole::Fused => "fused".to_string(),
                        CommRole::Control => "control".to_string(),
                        CommRole::Data(s) => format!("stream {s}"),
                    },
                    other.type_name()
                ));
                let _ = master_tx.send(MasterEvent::Abort);
                break;
            }
        }
    }
}

/// Apply one wire acknowledgement message — a single BLOCK_SYNC arrives
/// as a one-element slice, a BLOCK_SYNC_BATCH as the whole batch. Failed
/// writes are rescheduled (§3.2) onto their OST's stream; fresh syncs
/// are group-committed to the FT logger in ONE `log_blocks` write per
/// wire message — the §5.1 synchronous logging, amortized over the
/// negotiated ack batch. FILE_CLOSE rides the control connection and is
/// only emitted once the file's shared `CompletedSet` is complete — the
/// cross-stream barrier: every stream's outstanding acks for the file
/// must have arrived, whichever stream carried them.
///
/// Returns the number of entries that should release a send credit:
/// fresh syncs and failed-write reports. Duplicate acks — a torture-
/// transport replay, a batch retransmit after resume, or a late ack for
/// a file already closed — are counted in `dup_acks_dropped`, write no
/// second FT-log record, and release nothing.
fn handle_block_syncs(shared: &Arc<Shared>, file_idx: u32, acks: &[(u32, bool)]) -> u32 {
    let mut resched: Vec<(OstId, BlockReq)> = Vec::new();
    let mut log_err: Option<String> = None;
    let mut proto_err: Option<String> = None;
    let mut close = false;
    let mut credits: u32 = 0;
    {
        let mut files = shared.files.lock().unwrap_or_else(|e| e.into_inner());
        let Some(f) = files.get_mut(&file_idx) else {
            // The file is already closed and retired — every entry is a
            // stale duplicate.
            shared
                .counters
                .dup_acks_dropped
                .fetch_add(acks.len() as u64, Ordering::Relaxed);
            return 0;
        };
        let mut fresh: Vec<u32> = Vec::with_capacity(acks.len());
        for &(block_idx, ok) in acks {
            if block_idx >= f.total_blocks {
                // Never trust wire-supplied indices: a correct sink can
                // only ack blocks we sent, and an out-of-range index
                // would underflow the `f.size - offset` length math on
                // the reschedule path below. Treat it as a severed/
                // corrupt connection instead.
                proto_err = Some(format!(
                    "protocol violation: ack for out-of-range block {block_idx} \
                     of file {file_idx} ({} blocks)",
                    f.total_blocks
                ));
                break;
            }
            if !ok {
                // Sink write/verify failed: reschedule the object (§3.2 —
                // without this, the corruption would go unnoticed).
                shared
                    .counters
                    .objects_failed_verify
                    .fetch_add(1, Ordering::Relaxed);
                let offset = block_idx as u64 * shared.object_size;
                let len = (f.size - offset).min(shared.object_size) as u32;
                let ost = shared.pfs.layout().ost_for(f.start_ost, offset);
                resched.push((
                    ost,
                    BlockReq { file_idx, block_idx, fid: f.fid, offset, len },
                ));
                credits += 1;
                continue;
            }
            if !f.synced.insert(block_idx) {
                // Duplicate sync (torture replay / batch retransmit
                // after resume): already durable and logged.
                shared.counters.dup_acks_dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            credits += 1;
            shared.counters.objects_synced.fetch_add(1, Ordering::Relaxed);
            // The tuner's goodput signal: unique durable bytes (dupes
            // and failed writes don't count as progress).
            shared.counters.bytes_acked.fetch_add(
                (f.size - block_idx as u64 * shared.object_size).min(shared.object_size),
                Ordering::Relaxed,
            );
            fresh.push(block_idx);
        }

        // Synchronous logging (§5.1): log in the comm thread's context,
        // one group commit for the whole message.
        if proto_err.is_none() && !fresh.is_empty() {
            if let Some(key) = f.log_key {
                let mut logger = shared.logger.lock().unwrap_or_else(|e| e.into_inner());
                match logger.log_blocks(key, &fresh) {
                    Ok(()) => {
                        shared
                            .counters
                            .log_appends
                            .fetch_add(fresh.len() as u64, Ordering::Relaxed);
                        shared.counters.log_writes.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => log_err = Some(e.to_string()),
                }
            }
        }

        if proto_err.is_none() && log_err.is_none() && f.synced.is_complete() && !f.close_sent {
            f.close_sent = true;
            // §5.2.1: all objects synced -> delete the file's log entry
            // and tell the sink to commit.
            if let Some(key) = f.log_key {
                let mut logger = shared.logger.lock().unwrap_or_else(|e| e.into_inner());
                let _ = logger.complete_file(key);
            }
            close = true;
        }
    }
    if let Some(e) = proto_err {
        shared.abort_with(e);
        return 0;
    }
    if let Some(e) = log_err {
        shared.abort_with(format!("FT logging failed: {e}"));
        return 0;
    }
    if !resched.is_empty() {
        for (ost, _) in &resched {
            shared.sched.on_enqueue(*ost);
            if let Some(h) = &shared.shared_osts {
                h.begin(*ost);
            }
        }
        shared.push_to_streams(resched);
    }
    if close {
        let _ = shared.ep.send(Message::FileClose { file_idx });
    }
    credits
}
