//! Run metrics: wall time, CPU load, memory, transfer counters.
//!
//! Figures 5 and 6 report three axes per (mechanism, method): total
//! transfer time, CPU load while transferring, and memory load. CPU and
//! RSS are sampled from `/proc/self` by a background sampler thread at a
//! fixed cadence, matching how one would measure the paper's C tool with
//! `pidstat`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Counters a transfer session updates as it runs.
#[derive(Debug, Default)]
pub struct Counters {
    pub objects_sent: AtomicU64,
    pub objects_synced: AtomicU64,
    pub objects_failed_verify: AtomicU64,
    pub objects_skipped_resume: AtomicU64,
    pub files_completed: AtomicU64,
    pub files_skipped_resume: AtomicU64,
    pub bytes_sent: AtomicU64,
    pub bytes_written: AtomicU64,
    pub log_appends: AtomicU64,
    pub log_bytes: AtomicU64,
    /// BLOCK_SYNC wire messages actually sent (sink side): one per object
    /// when `ack_batch = 1`, one per coalesced batch otherwise.
    pub ack_messages: AtomicU64,
    /// FT-logger write invocations (source side): one per `log_block` at
    /// `ack_batch = 1`, one group commit per ack batch otherwise.
    pub log_writes: AtomicU64,
    /// Source issue-loop stalls: times an IO thread found the RMA slot
    /// pool dry and had to wait before it could stage the next pread. On
    /// the lockstep path slots are held across the wire serialization,
    /// so this is the send side's fixed-overhead bottleneck; the windowed
    /// path releases the slot pre-send and mostly eliminates these.
    pub send_stalls: AtomicU64,
    /// Times an IO thread had to wait for a send credit (`send_window`
    /// full of un-acked blocks) — intentional back-pressure, counted
    /// separately from `send_stalls`; always 0 on the lockstep path.
    pub credit_waits: AtomicU64,
    /// Adaptive ack coalescing (sink side): effective-batch growth steps
    /// (a batch filled on count) and shrink steps (the flush window
    /// fired on a partial batch).
    pub ack_batch_grows: AtomicU64,
    pub ack_batch_shrinks: AtomicU64,
    /// Payload memcpys on the data path. The zero-copy pipeline performs
    /// exactly ONE per object — the `pread` that stages it into the RMA
    /// slot (source side); everything after rides refcounted `Bytes` to
    /// the wire and the sink's `pwrite`, which takes the payload as a
    /// shared `&[u8]` (no copy-on-write detach even for shared views).
    pub payload_copies: AtomicU64,
    /// Bytes moved by those copies (`payload_copies` weighted by size).
    pub bytes_copied: AtomicU64,
    /// Send-window autotuner (source side, `send_window_adaptive`):
    /// applied-window growth steps (an issue had to wait on a credit —
    /// the window is the binding constraint) and shrink steps (the RMA
    /// pool ran dry — pinned payloads are starving the issue loop).
    pub send_window_grows: AtomicU64,
    pub send_window_shrinks: AtomicU64,
    /// Sink write submissions: one per `write_at` call and one per
    /// gathered `write_at_vectored` run. At `write_coalesce_bytes = 0`
    /// this equals the object count (the seed's one-pwrite-per-object
    /// path); coalescing drives it *below* the object count — the §A10
    /// syscalls-per-byte claim.
    pub write_syscalls: AtomicU64,
    /// Gathered runs of length ≥ 2 actually submitted through
    /// `write_at_vectored` (a run of 1 takes the plain `write_at` path).
    pub coalesced_runs: AtomicU64,
    /// Largest gathered run submitted, in bytes (high-water mark).
    pub coalesce_bytes_max: AtomicU64,
    /// Source read submissions: one per `read_at` call and one per
    /// gathered `read_at_vectored` run — the source mirror of
    /// `write_syscalls`. At `read_gather_bytes = 0` this equals the
    /// object count (the seed's one-pread-per-object path); gathering
    /// drives it *below* the object count.
    pub read_syscalls: AtomicU64,
    /// Gathered runs of length ≥ 2 actually submitted through
    /// `read_at_vectored` (a run of 1 takes the plain `read_at` path).
    pub gathered_runs: AtomicU64,
    /// Largest gathered read run submitted, in bytes (high-water mark).
    pub gather_bytes_max: AtomicU64,
    /// Payload bytes acknowledged end-to-end (source side): bumped when a
    /// BLOCK_SYNC for a freshly-sent object arrives, by that object's
    /// true byte length. This is the goodput numerator the `tune`
    /// controller differentiates per epoch — unlike `bytes_sent` it only
    /// counts bytes the sink has durably accepted.
    pub bytes_acked: AtomicU64,
    /// Unified autotuner (`tune`): epochs observed, knob moves accepted
    /// upward/downward, and moves rolled back on goodput regression.
    /// Written by the side's tuner thread only; summed across sides into
    /// `TransferOutcome`.
    pub tune_epochs: AtomicU64,
    pub tune_grows: AtomicU64,
    pub tune_shrinks: AtomicU64,
    pub tune_reverts: AtomicU64,
    /// Sink write-coalescer continuations: times an IO thread, after
    /// submitting a gathered run whose chain broke with budget to spare,
    /// found the run's byte-successor queued (it arrived while the run
    /// was being written/acked — e.g. released by a mid-run ack-batch
    /// flush) and extended the logical run instead of returning to the
    /// policy pick.
    pub coalesce_continuations: AtomicU64,
    /// Duplicate NEW_BLOCKs the sink refused to write twice: the (fid,
    /// block) was already in the write ledger (done or in flight), so the
    /// payload was dropped and — when already durable — re-acked.
    pub dup_blocks_dropped: AtomicU64,
    /// Duplicate/stray BLOCK_SYNC entries the source ignored (object
    /// already marked synced, or for an unknown file) — no credit
    /// released, no second FT-log record.
    pub dup_acks_dropped: AtomicU64,
    /// Handshake retransmissions: CONNECTs re-sent after a
    /// `connect_timeout_ms` expiry and extra STREAM_HELLOs under a lossy
    /// handshake, plus duplicate CONNECTs the sink re-acked.
    pub retries: AtomicU64,
}

impl Counters {
    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            objects_sent: self.objects_sent.load(Ordering::Relaxed),
            objects_synced: self.objects_synced.load(Ordering::Relaxed),
            objects_failed_verify: self.objects_failed_verify.load(Ordering::Relaxed),
            objects_skipped_resume: self.objects_skipped_resume.load(Ordering::Relaxed),
            files_completed: self.files_completed.load(Ordering::Relaxed),
            files_skipped_resume: self.files_skipped_resume.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            log_appends: self.log_appends.load(Ordering::Relaxed),
            log_bytes: self.log_bytes.load(Ordering::Relaxed),
            ack_messages: self.ack_messages.load(Ordering::Relaxed),
            log_writes: self.log_writes.load(Ordering::Relaxed),
            send_stalls: self.send_stalls.load(Ordering::Relaxed),
            credit_waits: self.credit_waits.load(Ordering::Relaxed),
            ack_batch_grows: self.ack_batch_grows.load(Ordering::Relaxed),
            ack_batch_shrinks: self.ack_batch_shrinks.load(Ordering::Relaxed),
            payload_copies: self.payload_copies.load(Ordering::Relaxed),
            bytes_copied: self.bytes_copied.load(Ordering::Relaxed),
            send_window_grows: self.send_window_grows.load(Ordering::Relaxed),
            send_window_shrinks: self.send_window_shrinks.load(Ordering::Relaxed),
            write_syscalls: self.write_syscalls.load(Ordering::Relaxed),
            coalesced_runs: self.coalesced_runs.load(Ordering::Relaxed),
            coalesce_bytes_max: self.coalesce_bytes_max.load(Ordering::Relaxed),
            read_syscalls: self.read_syscalls.load(Ordering::Relaxed),
            gathered_runs: self.gathered_runs.load(Ordering::Relaxed),
            gather_bytes_max: self.gather_bytes_max.load(Ordering::Relaxed),
            bytes_acked: self.bytes_acked.load(Ordering::Relaxed),
            tune_epochs: self.tune_epochs.load(Ordering::Relaxed),
            tune_grows: self.tune_grows.load(Ordering::Relaxed),
            tune_shrinks: self.tune_shrinks.load(Ordering::Relaxed),
            tune_reverts: self.tune_reverts.load(Ordering::Relaxed),
            coalesce_continuations: self.coalesce_continuations.load(Ordering::Relaxed),
            dup_blocks_dropped: self.dup_blocks_dropped.load(Ordering::Relaxed),
            dup_acks_dropped: self.dup_acks_dropped.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
        }
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub objects_sent: u64,
    pub objects_synced: u64,
    pub objects_failed_verify: u64,
    pub objects_skipped_resume: u64,
    pub files_completed: u64,
    pub files_skipped_resume: u64,
    pub bytes_sent: u64,
    pub bytes_written: u64,
    pub log_appends: u64,
    pub log_bytes: u64,
    pub ack_messages: u64,
    pub log_writes: u64,
    pub send_stalls: u64,
    pub credit_waits: u64,
    pub ack_batch_grows: u64,
    pub ack_batch_shrinks: u64,
    pub payload_copies: u64,
    pub bytes_copied: u64,
    pub send_window_grows: u64,
    pub send_window_shrinks: u64,
    pub write_syscalls: u64,
    pub coalesced_runs: u64,
    pub coalesce_bytes_max: u64,
    pub read_syscalls: u64,
    pub gathered_runs: u64,
    pub gather_bytes_max: u64,
    pub bytes_acked: u64,
    pub tune_epochs: u64,
    pub tune_grows: u64,
    pub tune_shrinks: u64,
    pub tune_reverts: u64,
    pub coalesce_continuations: u64,
    pub dup_blocks_dropped: u64,
    pub dup_acks_dropped: u64,
    pub retries: u64,
}

/// Daemon-wide (`ftlads serve`) counters, spanning every job the serve
/// manager has seen. Per-job figures live in each job's
/// [`TransferOutcome`](crate::coordinator::TransferOutcome); these
/// describe the daemon itself — admission, concurrency, and how jobs
/// ended.
#[derive(Debug, Default)]
pub struct DaemonStats {
    /// Jobs handed to the manager (admitted or rejected).
    pub jobs_submitted: AtomicU64,
    /// Jobs dispatched onto a worker (within the `serve_max_jobs` cap).
    pub jobs_admitted: AtomicU64,
    /// Jobs that ran to a completed transfer.
    pub jobs_completed: AtomicU64,
    /// Jobs that ended in a fault (including injected leg kills).
    pub jobs_faulted: AtomicU64,
    /// Jobs refused at submission (daemon shutting down, or a tenant
    /// over its `serve_quota_bytes` byte quota).
    pub jobs_rejected: AtomicU64,
    /// High-water mark of concurrently running jobs.
    pub peak_concurrent: AtomicU64,
    /// Incomplete jobs the manifest replay re-admitted (`--recover`).
    pub jobs_recovered: AtomicU64,
    /// Durable manifest records written by this daemon plus records
    /// replayed from a pre-crash manifest at recovery.
    pub manifest_records: AtomicU64,
    /// Rejections broken down by tenant (quota enforcement evidence).
    pub rejected_by_tenant: std::sync::Mutex<std::collections::BTreeMap<String, u64>>,
}

impl DaemonStats {
    pub fn snapshot(&self) -> DaemonSnapshot {
        DaemonSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_admitted: self.jobs_admitted.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_faulted: self.jobs_faulted.load(Ordering::Relaxed),
            jobs_rejected: self.jobs_rejected.load(Ordering::Relaxed),
            peak_concurrent: self.peak_concurrent.load(Ordering::Relaxed),
            jobs_recovered: self.jobs_recovered.load(Ordering::Relaxed),
            manifest_records: self.manifest_records.load(Ordering::Relaxed),
            rejected_by_tenant: self
                .rejected_by_tenant
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .iter()
                .map(|(t, n)| (t.clone(), *n))
                .collect(),
        }
    }

    /// Record that `running` jobs are in flight right now (ratchets the
    /// high-water mark).
    pub fn note_concurrent(&self, running: u64) {
        self.peak_concurrent.fetch_max(running, Ordering::Relaxed);
    }

    /// Count one rejection, attributed to `tenant`.
    pub fn note_rejected(&self, tenant: &str) {
        self.jobs_rejected.fetch_add(1, Ordering::Relaxed);
        *self
            .rejected_by_tenant
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(tenant.to_string())
            .or_insert(0) += 1;
    }
}

#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DaemonSnapshot {
    pub jobs_submitted: u64,
    pub jobs_admitted: u64,
    pub jobs_completed: u64,
    pub jobs_faulted: u64,
    pub jobs_rejected: u64,
    pub peak_concurrent: u64,
    pub jobs_recovered: u64,
    pub manifest_records: u64,
    /// `(tenant, rejections)` pairs in tenant order; empty when nothing
    /// was ever rejected.
    pub rejected_by_tenant: Vec<(String, u64)>,
}

/// One `/proc/self` sample.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProcSample {
    /// Cumulative user+sys jiffies of the process.
    pub cpu_jiffies: u64,
    /// Resident set size, bytes.
    pub rss_bytes: u64,
    pub at: f64, // seconds since sampler start
}

/// Read cumulative CPU jiffies (utime+stime) and RSS from /proc/self.
pub fn read_proc_self() -> ProcSample {
    let stat = std::fs::read_to_string("/proc/self/stat").unwrap_or_default();
    // comm can contain spaces; fields after the closing paren are stable.
    let after = stat.rsplit_once(')').map(|x| x.1).unwrap_or("");
    let fields: Vec<&str> = after.split_whitespace().collect();
    // fields[11]=utime, fields[12]=stime, fields[21]=rss pages
    // (1-based stat fields 14, 15, 24 minus the 2 consumed + comm).
    let utime: u64 = fields.get(11).and_then(|s| s.parse().ok()).unwrap_or(0);
    let stime: u64 = fields.get(12).and_then(|s| s.parse().ok()).unwrap_or(0);
    let rss_pages: u64 = fields.get(21).and_then(|s| s.parse().ok()).unwrap_or(0);
    let page = unsafe { libc::sysconf(libc::_SC_PAGESIZE) } as u64;
    ProcSample { cpu_jiffies: utime + stime, rss_bytes: rss_pages * page, at: 0.0 }
}

/// Background sampler: records CPU% (of one core) and RSS over a run.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<Vec<ProcSample>>>,
    started: Instant,
}

impl Sampler {
    pub fn start(period: Duration) -> Sampler {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let started = Instant::now();
        let handle = std::thread::Builder::new()
            .name("metrics-sampler".into())
            .spawn(move || {
                let mut samples = Vec::new();
                let t0 = Instant::now();
                while !stop2.load(Ordering::Relaxed) {
                    let mut s = read_proc_self();
                    s.at = t0.elapsed().as_secs_f64();
                    samples.push(s);
                    std::thread::sleep(period);
                }
                let mut s = read_proc_self();
                s.at = t0.elapsed().as_secs_f64();
                samples.push(s);
                samples
            })
            .expect("spawn sampler");
        Sampler { stop, handle: Some(handle), started }
    }

    /// Stop and reduce to a [`ResourceReport`].
    pub fn finish(mut self) -> ResourceReport {
        self.stop.store(true, Ordering::Relaxed);
        let samples = self
            .handle
            .take()
            .unwrap()
            .join()
            .unwrap_or_default();
        let wall = self.started.elapsed();
        ResourceReport::from_samples(&samples, wall)
    }
}

/// CPU/memory summary of one run.
#[derive(Debug, Clone, Copy, Default)]
pub struct ResourceReport {
    pub wall: Duration,
    /// Average CPU utilization over the run, in percent of one core
    /// (can exceed 100 with multiple threads).
    pub cpu_percent: f64,
    pub peak_rss_bytes: u64,
    pub mean_rss_bytes: u64,
}

impl ResourceReport {
    fn from_samples(samples: &[ProcSample], wall: Duration) -> ResourceReport {
        if samples.len() < 2 {
            return ResourceReport { wall, ..Default::default() };
        }
        let first = samples.first().unwrap();
        let last = samples.last().unwrap();
        let jiffies = last.cpu_jiffies.saturating_sub(first.cpu_jiffies);
        let hz = unsafe { libc::sysconf(libc::_SC_CLK_TCK) } as f64;
        let span = (last.at - first.at).max(1e-9);
        let cpu_percent = (jiffies as f64 / hz) / span * 100.0;
        let peak = samples.iter().map(|s| s.rss_bytes).max().unwrap_or(0);
        let mean =
            samples.iter().map(|s| s.rss_bytes as u128).sum::<u128>() / samples.len() as u128;
        ResourceReport { wall, cpu_percent, peak_rss_bytes: peak, mean_rss_bytes: mean as u64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proc_sample_reads_something() {
        let s = read_proc_self();
        assert!(s.rss_bytes > 0, "rss should be nonzero");
    }

    #[test]
    fn sampler_measures_busy_loop() {
        let sampler = Sampler::start(Duration::from_millis(10));
        // Burn ~80ms of CPU.
        let t0 = Instant::now();
        let mut x = 0u64;
        while t0.elapsed() < Duration::from_millis(80) {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(x);
        let report = sampler.finish();
        assert!(report.wall >= Duration::from_millis(75));
        assert!(report.peak_rss_bytes > 0);
        // A busy loop should register noticeable CPU (jiffy granularity is
        // 10ms, so keep the bar low but nonzero).
        assert!(report.cpu_percent > 10.0, "cpu {}%", report.cpu_percent);
    }

    #[test]
    fn daemon_stats_snapshot_and_peak_ratchet() {
        let d = DaemonStats::default();
        d.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        d.jobs_admitted.fetch_add(2, Ordering::Relaxed);
        d.note_concurrent(2);
        d.note_concurrent(1); // lower load must not regress the peak
        let s = d.snapshot();
        assert_eq!(s.jobs_submitted, 3);
        assert_eq!(s.jobs_admitted, 2);
        assert_eq!(s.peak_concurrent, 2);
        assert_eq!(s.jobs_faulted, 0);
        assert_eq!(s.jobs_recovered, 0);
        assert_eq!(s.manifest_records, 0);
        assert!(s.rejected_by_tenant.is_empty());

        d.jobs_recovered.fetch_add(4, Ordering::Relaxed);
        d.manifest_records.fetch_add(9, Ordering::Relaxed);
        d.note_rejected("greedy");
        d.note_rejected("greedy");
        d.note_rejected("alice");
        let s = d.snapshot();
        assert_eq!(s.jobs_recovered, 4);
        assert_eq!(s.manifest_records, 9);
        assert_eq!(s.jobs_rejected, 3, "note_rejected must bump the total");
        assert_eq!(
            s.rejected_by_tenant,
            vec![("alice".to_string(), 1), ("greedy".to_string(), 2)]
        );
    }

    #[test]
    fn counters_snapshot() {
        let c = Counters::default();
        c.objects_sent.fetch_add(3, Ordering::Relaxed);
        c.bytes_sent.fetch_add(999, Ordering::Relaxed);
        let s = c.snapshot();
        assert_eq!(s.objects_sent, 3);
        assert_eq!(s.bytes_sent, 999);
        assert_eq!(s.objects_synced, 0);
        assert_eq!(s.dup_blocks_dropped, 0);
        c.dup_blocks_dropped.fetch_add(2, Ordering::Relaxed);
        c.dup_acks_dropped.fetch_add(1, Ordering::Relaxed);
        c.retries.fetch_add(4, Ordering::Relaxed);
        let s = c.snapshot();
        assert_eq!((s.dup_blocks_dropped, s.dup_acks_dropped, s.retries), (2, 1, 4));
    }
}
