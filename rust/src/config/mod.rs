//! Session configuration: typed settings + a TOML-subset file parser +
//! CLI override layer (the offline crate set has no serde/toml/clap).
//!
//! Precedence: built-in defaults < config file (`--config path.toml`) <
//! command-line flags. The defaults mirror the paper's §6.1 configuration
//! (4 IO threads, 1 master, 1 comm, transaction size 4, 256 MB RMA,
//! 11 OSTs, 1 MB stripes), scaled per DESIGN.md §Substitutions.

pub mod toml_lite;
pub mod torture;

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;

use crate::ftlog::{FtConfig, LoggingMode, Mechanism, Method};
use crate::integrity::IntegrityMode;
use crate::net::WireModel;
use crate::pfs::layout::StripeLayout;
use crate::pfs::ost::OstConfig;
use crate::sched::SchedPolicy;

pub use toml_lite::TomlLite;
pub use torture::{TortureSpec, TORTURE_PROFILES};

/// Everything a transfer session needs.
#[derive(Debug, Clone)]
pub struct Config {
    /// Transfer MTU — one object (paper: 1 MiB; scaled default 256 KiB,
    /// which equals the AOT artifact's object size).
    pub object_size: u64,
    /// IO threads per side (paper evaluation: 4).
    pub io_threads: usize,
    /// RMA DRAM per side (paper: max 256 MB). Scaled with object size.
    pub rma_bytes: usize,
    /// Files allowed in flight concurrently at the source.
    pub file_window: usize,
    /// FT logging.
    pub mechanism: Mechanism,
    pub method: Method,
    pub txn_size: usize,
    pub ft_dir: PathBuf,
    /// Synchronous (comm-thread context) or asynchronous (logger thread)
    /// FT logging (§5.1).
    pub logging: LoggingMode,
    /// Max BLOCK_SYNC acknowledgements the sink coalesces into one wire
    /// message (and the source group-commits as one logger write). 1 =
    /// the paper's per-object ack path, reproduced exactly. Negotiated
    /// down to the peer's advertised value at CONNECT.
    pub ack_batch: u32,
    /// Straggler bound for a partially-filled ack batch: the sink flushes
    /// a batch once its oldest pending ack is this many microseconds old.
    pub ack_flush_us: u64,
    /// Adaptive ack coalescing: when true, the sink's applied batch size
    /// floats between 1 and the negotiated `ack_batch` cap — growing on
    /// count-driven flushes, shrinking when the `ack_flush_us` window
    /// keeps firing. False (default) pins the batch to the negotiated
    /// value, reproducing the fixed-batch behavior exactly.
    pub ack_adaptive: bool,
    /// Credit-based NEW_BLOCK send window: how many un-acknowledged
    /// objects the source keeps in flight per connection. 1 (default) is
    /// the lockstep issue-and-wait path, reproduced exactly; negotiated
    /// to min(src, sink) at CONNECT, and legacy peers without the field
    /// read as 1.
    pub send_window: u32,
    /// Send-window autotuner: when true, the source floats the *applied*
    /// window in 1..=the negotiated `send_window` — growing when issues
    /// wait on credits (the window binds), shrinking when the RMA pool
    /// runs dry (zero-copy pins payload buffers while in flight, so an
    /// oversized window starves the issue loop's preads). False
    /// (default) pins the applied window to the negotiated value. The
    /// wire handshake always carries the cap; adaptation is local to the
    /// source's issue discipline.
    pub send_window_adaptive: bool,
    /// Sink-side contiguous-write coalescing budget: when an IO thread
    /// dequeues a write, it drains further byte-contiguous objects of the
    /// same file from the same OST queue until the gathered run reaches
    /// this many bytes, and submits the run as ONE vectored `pwrite`
    /// (`Pfs::write_at_vectored`). 0 (default) disables coalescing — the
    /// seed-exact one-pwrite-per-object sink path. Every constituent
    /// block keeps its own digest verify, BLOCK_SYNC ack, and FT-log
    /// record regardless.
    pub write_coalesce_bytes: u64,
    /// Parallel data plane: how many OST-sharded data connections to run
    /// alongside the control connection. 1 (default) is today's single
    /// fused connection, reproduced byte-identically; K >= 2 dials K data
    /// connections (identified by STREAM_HELLO), shards OSTs across them
    /// (`ost % K`), and gives every stream its own credit window, RMA
    /// slot pool, and ack coalescer. Negotiated to min(src, sink) at
    /// CONNECT; legacy peers without the field read as 1 and keep the
    /// fused path. Note `rma_bytes` and `send_window` are per stream.
    pub data_streams: u32,
    /// Source-side contiguous-read gather budget: when an IO thread
    /// dequeues a block, it drains further byte-contiguous blocks of the
    /// same file from the same OST queue until the gathered run reaches
    /// this many bytes, reserves one RMA slot per block, and fills them
    /// all with ONE vectored `preadv` (`Pfs::read_at_vectored`) — the
    /// source mirror of `write_coalesce_bytes`. 0 (default) disables
    /// gathering — the seed-exact one-pread-per-object path. Per-block
    /// digest and NEW_BLOCK framing are unchanged regardless.
    pub read_gather_bytes: u64,
    /// RMA pool autosizer: at CONNECT, grow each side's slot pool toward
    /// `negotiated send_window × object_size` so zero-copy payload
    /// pinning can never starve the issue loop (the alternative is the
    /// window autotuner shrinking around the undersized pool). The
    /// applied pool lands in `TransferOutcome::rma_bytes_effective`.
    /// False (default) keeps the configured `rma_bytes` exactly.
    pub rma_autosize: bool,
    /// Unified epoch-based online autotuner: when true, one goodput-
    /// driven controller per side walks the whole knob vector mid-
    /// transfer — applied send window, applied ack batch, write-coalesce
    /// and read-gather byte budgets, plus the per-stream window split —
    /// via a bounded hill-climb with hysteresis (see [`crate::tune`]).
    /// CONNECT then advertises raised caps (`send_window_cap`,
    /// `ack_batch_cap`) so the applied values can float without any wire
    /// change. Supersedes (and rejects) the per-knob `ack_adaptive` /
    /// `send_window_adaptive` loops. False (default) changes nothing:
    /// caps collapse to the configured values and the seed wire bytes
    /// are reproduced exactly.
    pub tune: bool,
    /// Autotuner epoch length in milliseconds: the controller samples
    /// goodput and moves at most one knob per epoch.
    pub tune_epoch_ms: u64,
    /// `ftlads serve` admission cap: how many transfer jobs the daemon
    /// runs concurrently; excess submissions queue (weighted fair-share
    /// order) until a slot frees. Irrelevant outside serve mode.
    pub serve_max_jobs: usize,
    /// `ftlads serve` cross-job congestion registry: when true (default)
    /// every job charges its in-flight per-OST requests into one shared
    /// daemon-wide registry, and each job's dequeue policy folds the
    /// *other* jobs' load into its congestion view — steering around
    /// OSTs a concurrent job is hammering. False runs each job
    /// registry-blind (the A/B baseline for §A13).
    pub serve_registry: bool,
    /// `ftlads serve` crash consistency: when true the daemon keeps a
    /// durable job manifest under `<ft_dir>/manifest/` (one fsynced
    /// record per job state change) and, at startup, replays it to
    /// re-admit every incomplete job so it resumes from its own
    /// `job-<id>` FT log — including handing a reconnecting TCP client
    /// its recovered session by job tag. False (the default) writes no
    /// manifest at all: startup and wire bytes are identical to a
    /// manifest-free build.
    pub serve_recover: bool,
    /// `ftlads serve` per-tenant byte quota: a submission whose source
    /// bytes would push its tenant's cumulative submitted bytes over
    /// this cap is rejected (counted in `jobs_rejected`, broken down
    /// per tenant in `DaemonSnapshot::rejected_by_tenant`). 0 (the
    /// default) = unlimited.
    pub serve_quota_bytes: u64,
    /// Integrity verification backend.
    pub integrity: IntegrityMode,
    /// OST dequeue policy for the source's IO threads (§2.1; see
    /// [`crate::sched`] for the built-in policies).
    pub scheduler: SchedPolicy,
    /// Sink-side override: the sink's write queues may run a different
    /// policy than the source's read queues. `None` = same as `scheduler`.
    pub sink_scheduler: Option<SchedPolicy>,
    /// Artifacts directory for the PJRT runtime (integrity = pjrt).
    pub artifacts_dir: PathBuf,
    /// PFS geometry + service model (both ends).
    pub stripe_size: u64,
    pub stripe_count: u32,
    pub ost_count: u32,
    pub ost_bandwidth: f64,
    pub ost_latency_us: u64,
    pub ost_concurrent: usize,
    /// Wire model.
    pub net_latency_us: u64,
    pub net_bandwidth: f64,
    /// Handshake patience: how long one CONNECT (or CONNECT_ACK) wait
    /// lasts before the source re-sends, and the budget a sink-side
    /// connection grants its first inbound message.
    pub connect_timeout_ms: u64,
    /// Bounded exponential-backoff handshake retries: after a
    /// `connect_timeout_ms` wait expires the source re-sends CONNECT
    /// (doubling the wait each attempt) up to this many times. 0 (the
    /// default) reproduces the legacy single-wait behavior exactly.
    pub connect_retries: u32,
    /// `ftlads serve` per-job watchdog: a job still running after this
    /// many milliseconds is faulted and its admission slot freed (a
    /// silent peer can no longer pin a slot forever). 0 (default) = off.
    pub job_deadline_ms: u64,
    /// Adversarial-network torture seed (see [`torture`]): 0 (default)
    /// disarms the adversary entirely — endpoints are not even wrapped,
    /// so the wire is byte-identical to a torture-free build.
    pub torture_seed: u64,
    /// Named torture profile ([`TORTURE_PROFILES`]); "off" disarms.
    pub torture_profile: String,
    /// Global time scaling for the simulated service times (0 = no sleeps).
    pub time_scale: f64,
    /// Workload seed (synthetic data + mixed distribution).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            object_size: 256 << 10,
            io_threads: 4,
            rma_bytes: 16 << 20, // 64 slots of 256 KiB (256 MB / same 1:64 scale)
            file_window: 8,
            mechanism: Mechanism::File,
            method: Method::Bit64,
            txn_size: 4,
            ft_dir: default_ft_dir(),
            logging: LoggingMode::Sync,
            ack_batch: 1,
            ack_flush_us: 1000,
            ack_adaptive: false,
            send_window: 1,
            send_window_adaptive: false,
            write_coalesce_bytes: 0,
            data_streams: 1,
            read_gather_bytes: 0,
            rma_autosize: false,
            tune: false,
            tune_epoch_ms: 100,
            serve_max_jobs: 4,
            serve_registry: true,
            serve_recover: false,
            serve_quota_bytes: 0,
            integrity: IntegrityMode::Native,
            scheduler: SchedPolicy::CongestionAware,
            sink_scheduler: None,
            artifacts_dir: PathBuf::from("artifacts"),
            stripe_size: 1 << 20,
            stripe_count: 1,
            ost_count: 11,
            ost_bandwidth: 1.5e9,
            ost_latency_us: 80,
            ost_concurrent: 1,
            net_latency_us: 15,
            net_bandwidth: 6.0e9,
            connect_timeout_ms: 10_000,
            connect_retries: 0,
            job_deadline_ms: 0,
            torture_seed: 0,
            torture_profile: String::from("off"),
            time_scale: 1.0,
            seed: 42,
        }
    }
}

/// `~/ftlads` per §5.2 ("logger file will be created in *ftlads*
/// subdirectory under user home directory").
pub fn default_ft_dir() -> PathBuf {
    std::env::var_os("HOME")
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir)
        .join("ftlads")
}

impl Config {
    pub fn layout(&self) -> StripeLayout {
        StripeLayout::new(self.stripe_size, self.stripe_count, self.ost_count)
    }

    pub fn ost_config(&self) -> OstConfig {
        OstConfig {
            bandwidth: self.ost_bandwidth,
            base_latency: Duration::from_micros(self.ost_latency_us),
            max_concurrent: self.ost_concurrent,
            time_scale: self.time_scale,
        }
    }

    pub fn wire(&self) -> WireModel {
        WireModel {
            latency: Duration::from_micros(self.net_latency_us),
            bandwidth: self.net_bandwidth,
            time_scale: self.time_scale,
        }
    }

    /// The policy the sink's IO threads run: the explicit sink override,
    /// or the session-wide `scheduler` when none is set.
    pub fn sink_sched(&self) -> SchedPolicy {
        self.sink_scheduler.unwrap_or(self.scheduler)
    }

    pub fn ft(&self) -> FtConfig {
        FtConfig {
            mechanism: self.mechanism,
            method: self.method,
            dir: self.ft_dir.clone(),
            txn_size: self.txn_size,
        }
    }

    /// Fast-test profile: no simulated sleeping, tiny RMA, temp FT dir.
    pub fn for_tests(tag: &str) -> Config {
        let dir = std::env::temp_dir().join(format!(
            "ftlads-test-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        Config {
            time_scale: 0.0,
            object_size: 64 << 10,
            rma_bytes: 8 * (64 << 10),
            ft_dir: dir,
            ..Default::default()
        }
    }

    /// The send window to ADVERTISE at CONNECT: the configured value,
    /// raised to [`crate::tune::TUNE_WINDOW_CAP`] when the autotuner is
    /// on so the applied window has room to float. With `tune` off this
    /// is exactly `send_window` — the seed wire bytes are untouched.
    pub fn send_window_cap(&self) -> u32 {
        let w = self.send_window.max(1);
        if self.tune {
            w.max(crate::tune::TUNE_WINDOW_CAP)
        } else {
            w
        }
    }

    /// The ack batch to advertise at CONNECT — `ack_batch`, raised to
    /// [`crate::tune::TUNE_ACK_CAP`] when the autotuner is on.
    pub fn ack_batch_cap(&self) -> u32 {
        let b = self.ack_batch.max(1);
        if self.tune {
            b.max(crate::tune::TUNE_ACK_CAP)
        } else {
            b
        }
    }

    /// Ceiling for the tuned read-gather budget: the configured value,
    /// raised to [`crate::tune::TUNE_BUDGET_CAP`] when the autotuner is
    /// on (local to the source — nothing on the wire).
    pub fn gather_cap(&self) -> u64 {
        if self.tune {
            self.read_gather_bytes.max(crate::tune::TUNE_BUDGET_CAP)
        } else {
            self.read_gather_bytes
        }
    }

    /// Ceiling for the tuned write-coalesce budget (sink-local).
    pub fn coalesce_cap(&self) -> u64 {
        if self.tune {
            self.write_coalesce_bytes.max(crate::tune::TUNE_BUDGET_CAP)
        } else {
            self.write_coalesce_bytes
        }
    }

    /// The armed adversarial-network policy, if any: a nonzero
    /// `torture_seed` plus a profile other than "off". With the seed at
    /// 0 (the default) this is `None` and the transports are not even
    /// wrapped — byte-identical to a torture-free build.
    pub fn torture(&self) -> Option<TortureSpec> {
        if self.torture_seed == 0 {
            return None;
        }
        TortureSpec::profile(&self.torture_profile, self.torture_seed)
            .ok()
            .flatten()
    }

    /// Apply `key = value` (config file or CLI `--set key=value`).
    pub fn apply_kv(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "object_size" => self.object_size = parse_bytes(value)?,
            "io_threads" => self.io_threads = value.parse()?,
            "rma_bytes" => self.rma_bytes = parse_bytes(value)? as usize,
            "file_window" => self.file_window = value.parse()?,
            "mechanism" => self.mechanism = Mechanism::parse(value)?,
            "method" => self.method = Method::parse(value)?,
            "txn_size" => self.txn_size = value.parse()?,
            "ft_dir" => self.ft_dir = PathBuf::from(value),
            "logging" => self.logging = LoggingMode::parse(value)?,
            "ack_batch" => self.ack_batch = value.parse()?,
            "ack_flush_us" => self.ack_flush_us = value.parse()?,
            "ack_adaptive" => self.ack_adaptive = parse_bool(value)?,
            "send_window" => self.send_window = value.parse()?,
            "send_window_adaptive" => self.send_window_adaptive = parse_bool(value)?,
            "write_coalesce_bytes" => self.write_coalesce_bytes = parse_bytes(value)?,
            "data_streams" => self.data_streams = value.parse()?,
            "read_gather_bytes" => self.read_gather_bytes = parse_bytes(value)?,
            "rma_autosize" => self.rma_autosize = parse_bool(value)?,
            "tune" => self.tune = parse_bool(value)?,
            "tune_epoch_ms" => self.tune_epoch_ms = value.parse()?,
            "serve_max_jobs" => self.serve_max_jobs = value.parse()?,
            "serve_registry" => self.serve_registry = parse_bool(value)?,
            "serve_recover" => self.serve_recover = parse_bool(value)?,
            "serve_quota_bytes" => self.serve_quota_bytes = parse_bytes(value)?,
            "integrity" => self.integrity = IntegrityMode::parse(value)?,
            "scheduler" => self.scheduler = SchedPolicy::parse(value)?,
            "sink_scheduler" => {
                // `default` clears the override (sink follows `scheduler`).
                self.sink_scheduler = match value {
                    "default" | "same" => None,
                    _ => Some(SchedPolicy::parse(value)?),
                }
            }
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(value),
            "stripe_size" => self.stripe_size = parse_bytes(value)?,
            "stripe_count" => self.stripe_count = value.parse()?,
            "ost_count" => self.ost_count = value.parse()?,
            "ost_bandwidth" => self.ost_bandwidth = value.parse()?,
            "ost_latency_us" => self.ost_latency_us = value.parse()?,
            "ost_concurrent" => self.ost_concurrent = value.parse()?,
            "net_latency_us" => self.net_latency_us = value.parse()?,
            "net_bandwidth" => self.net_bandwidth = value.parse()?,
            "connect_timeout_ms" => self.connect_timeout_ms = value.parse()?,
            "connect_retries" => self.connect_retries = value.parse()?,
            "job_deadline_ms" => self.job_deadline_ms = value.parse()?,
            "torture_seed" => self.torture_seed = value.parse()?,
            "torture_profile" => self.torture_profile = value.to_string(),
            "time_scale" => self.time_scale = value.parse()?,
            "seed" => self.seed = value.parse()?,
            _ => anyhow::bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    /// Load a TOML-subset config file over the current values. Sections
    /// are flattened (`[pfs] ost_count = 11` == `ost_count = 11`).
    pub fn apply_file(&mut self, path: &std::path::Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let parsed = TomlLite::parse(&text)?;
        for (key, value) in parsed.flat_items() {
            self.apply_kv(&key, &value)?;
        }
        Ok(())
    }

    /// Sanity-check cross-field constraints.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.object_size > 0, "object_size must be positive");
        anyhow::ensure!(self.io_threads >= 1, "need at least one IO thread");
        anyhow::ensure!(
            self.rma_bytes as u64 >= self.object_size,
            "RMA pool smaller than one object"
        );
        anyhow::ensure!(self.file_window >= 1, "file_window must be >= 1");
        anyhow::ensure!(self.txn_size >= 1, "txn_size must be >= 1");
        anyhow::ensure!(
            (1..=1u32 << 16).contains(&self.ack_batch),
            "ack_batch must be in 1..=65536 (wire sanity cap)"
        );
        anyhow::ensure!(
            (1..=1u32 << 16).contains(&self.send_window),
            "send_window must be in 1..=65536 (wire sanity cap)"
        );
        self.validate_adaptive()?;
        anyhow::ensure!(
            (1..=self.ost_count).contains(&self.stripe_count),
            "stripe_count must be in 1..=ost_count"
        );
        anyhow::ensure!(
            (1..=64u32).contains(&self.data_streams),
            "data_streams must be in 1..=64"
        );
        anyhow::ensure!(
            (1..=1024).contains(&self.serve_max_jobs),
            "serve_max_jobs must be in 1..=1024"
        );
        anyhow::ensure!(
            self.connect_timeout_ms >= 1,
            "connect_timeout_ms must be >= 1"
        );
        anyhow::ensure!(
            self.connect_retries <= 16,
            "connect_retries must be <= 16 (exponential backoff sanity cap)"
        );
        anyhow::ensure!(
            self.torture_seed == 0 || self.torture_profile != "off",
            "torture_seed is set but torture_profile is 'off' — pick one of {}",
            TORTURE_PROFILES.join("|")
        );
        // Resolve the profile name eagerly so a typo fails at validate
        // time, not mid-transfer; also bounds-check the resolved spec.
        if let Some(spec) = TortureSpec::profile(&self.torture_profile, self.torture_seed)? {
            spec.validate()?;
        }
        Ok(())
    }

    /// Cross-check the feedback-loop flags (`ack_adaptive`,
    /// `send_window_adaptive`, `rma_autosize`, `tune`) against each
    /// other and their caps. The per-knob loops and the unified tuner
    /// both drive the same applied-value cells, so running them together
    /// would have two controllers fighting over one knob — `tune`
    /// supersedes and rejects the per-knob flags with an actionable
    /// message. `rma_autosize` stays compatible with all of them: it is
    /// a one-shot pool sizing at CONNECT, not an online loop.
    pub fn validate_adaptive(&self) -> Result<()> {
        anyhow::ensure!(
            !self.ack_adaptive || self.ack_batch > 1,
            "ack_adaptive needs an ack_batch cap > 1 to adapt within"
        );
        anyhow::ensure!(
            !self.send_window_adaptive || self.send_window > 1,
            "send_window_adaptive needs a send_window cap > 1 to adapt within"
        );
        if self.tune {
            anyhow::ensure!(
                !self.ack_adaptive,
                "--tune supersedes --ack-adaptive: the unified tuner already \
                 drives the applied ack batch — drop --ack-adaptive"
            );
            anyhow::ensure!(
                !self.send_window_adaptive,
                "--tune supersedes --send-window-adaptive: the unified tuner \
                 already drives the applied send window — drop \
                 --send-window-adaptive"
            );
            anyhow::ensure!(
                self.tune_epoch_ms >= 1,
                "tune_epoch_ms must be >= 1 (the tuner needs a nonzero epoch)"
            );
        }
        Ok(())
    }
}

/// Parse a boolean config value ("true"/"false", "1"/"0", "on"/"off").
pub fn parse_bool(s: &str) -> Result<bool> {
    match s.trim() {
        "true" | "1" | "on" | "yes" => Ok(true),
        "false" | "0" | "off" | "no" => Ok(false),
        other => anyhow::bail!("bad boolean '{other}' (true|false|1|0|on|off|yes|no)"),
    }
}

/// Parse "4096", "256K", "16M", "1G" (binary units).
pub fn parse_bytes(s: &str) -> Result<u64> {
    let s = s.trim();
    let (num, mult) = match s.chars().last() {
        Some('K') | Some('k') => (&s[..s.len() - 1], 1u64 << 10),
        Some('M') | Some('m') => (&s[..s.len() - 1], 1u64 << 20),
        Some('G') | Some('g') => (&s[..s.len() - 1], 1u64 << 30),
        _ => (s, 1),
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad byte size '{s}'"))?;
    anyhow::ensure!(v >= 0.0, "negative byte size '{s}'");
    Ok((v * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_configuration() {
        let c = Config::default();
        assert_eq!(c.io_threads, 4);
        assert_eq!(c.txn_size, 4);
        assert_eq!(c.ost_count, 11);
        assert_eq!(c.stripe_count, 1);
        assert_eq!(c.stripe_size, 1 << 20);
        assert!(c.validate().is_ok());
        // RMA slots: pool / object = 64 (same count as 256MB/4MB... scaled).
        assert_eq!(c.rma_bytes as u64 / c.object_size, 64);
    }

    #[test]
    fn parse_bytes_units() {
        assert_eq!(parse_bytes("4096").unwrap(), 4096);
        assert_eq!(parse_bytes("256K").unwrap(), 256 << 10);
        assert_eq!(parse_bytes("16M").unwrap(), 16 << 20);
        assert_eq!(parse_bytes("1G").unwrap(), 1 << 30);
        assert_eq!(parse_bytes("1.5k").unwrap(), 1536);
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("-5").is_err());
    }

    #[test]
    fn apply_kv_typed() {
        let mut c = Config::default();
        c.apply_kv("object_size", "1M").unwrap();
        assert_eq!(c.object_size, 1 << 20);
        c.apply_kv("mechanism", "universal").unwrap();
        assert_eq!(c.mechanism, Mechanism::Universal);
        c.apply_kv("method", "bit8").unwrap();
        assert_eq!(c.method, Method::Bit8);
        c.apply_kv("integrity", "pjrt").unwrap();
        assert_eq!(c.integrity, IntegrityMode::Pjrt);
        assert!(c.apply_kv("nonsense", "1").is_err());
        assert!(c.apply_kv("io_threads", "lots").is_err());
    }

    #[test]
    fn ack_batch_kv_defaults_and_validation() {
        let mut c = Config::default();
        // Default is the paper's per-object ack path.
        assert_eq!(c.ack_batch, 1);
        assert!(c.ack_flush_us > 0);
        c.apply_kv("ack_batch", "8").unwrap();
        c.apply_kv("ack_flush_us", "500").unwrap();
        assert_eq!(c.ack_batch, 8);
        assert_eq!(c.ack_flush_us, 500);
        assert!(c.validate().is_ok());
        c.ack_batch = 0;
        assert!(c.validate().is_err());
        c.ack_batch = (1 << 16) + 1;
        assert!(c.validate().is_err(), "ack_batch above the wire cap rejected");
        c.ack_batch = 1 << 16;
        assert!(c.validate().is_ok());
        let mut c = Config::default();
        assert!(c.apply_kv("ack_batch", "lots").is_err());
    }

    #[test]
    fn send_window_kv_defaults_and_validation() {
        let mut c = Config::default();
        // Default is the lockstep issue path — the PR 2 equivalence pin.
        assert_eq!(c.send_window, 1);
        assert!(!c.ack_adaptive);
        c.apply_kv("send_window", "8").unwrap();
        assert_eq!(c.send_window, 8);
        assert!(c.validate().is_ok());
        c.send_window = 0;
        assert!(c.validate().is_err(), "send_window 0 rejected");
        c.send_window = (1 << 16) + 1;
        assert!(c.validate().is_err(), "send_window above the wire cap rejected");
        c.send_window = 1 << 16;
        assert!(c.validate().is_ok());
        let mut c = Config::default();
        assert!(c.apply_kv("send_window", "lots").is_err());
    }

    #[test]
    fn send_window_adaptive_kv_and_validation() {
        let mut c = Config::default();
        assert!(!c.send_window_adaptive, "autotuning must be opt-in");
        c.apply_kv("send_window_adaptive", "true").unwrap();
        assert!(c.send_window_adaptive);
        // Adaptation needs headroom: a cap of 1 leaves nothing to float.
        assert!(c.validate().is_err());
        c.apply_kv("send_window", "8").unwrap();
        assert!(c.validate().is_ok());
        c.apply_kv("send_window_adaptive", "off").unwrap();
        assert!(!c.send_window_adaptive);
        assert!(c.apply_kv("send_window_adaptive", "maybe").is_err());
    }

    #[test]
    fn ack_adaptive_kv_and_validation() {
        let mut c = Config::default();
        c.apply_kv("ack_adaptive", "true").unwrap();
        assert!(c.ack_adaptive);
        // Adaptation needs headroom: a cap of 1 leaves nothing to adapt.
        assert!(c.validate().is_err());
        c.apply_kv("ack_batch", "16").unwrap();
        assert!(c.validate().is_ok());
        c.apply_kv("ack_adaptive", "off").unwrap();
        assert!(!c.ack_adaptive);
        c.apply_kv("ack_adaptive", "1").unwrap();
        assert!(c.ack_adaptive);
        assert!(c.apply_kv("ack_adaptive", "maybe").is_err());
    }

    #[test]
    fn serve_kv_defaults_and_validation() {
        let mut c = Config::default();
        // Serve defaults: a small admission cap, registry-informed
        // scheduling on.
        assert_eq!(c.serve_max_jobs, 4);
        assert!(c.serve_registry);
        c.apply_kv("serve_max_jobs", "2").unwrap();
        assert_eq!(c.serve_max_jobs, 2);
        assert!(c.validate().is_ok());
        c.apply_kv("serve_registry", "off").unwrap();
        assert!(!c.serve_registry);
        assert!(c.validate().is_ok(), "registry-blind serve is a valid A/B mode");
        c.serve_max_jobs = 0;
        assert!(c.validate().is_err(), "serve_max_jobs 0 rejected");
        c.serve_max_jobs = 1025;
        assert!(c.validate().is_err(), "serve_max_jobs above cap rejected");
        c.serve_max_jobs = 1024;
        assert!(c.validate().is_ok());
        assert!(c.apply_kv("serve_max_jobs", "lots").is_err());
        assert!(c.apply_kv("serve_registry", "maybe").is_err());
    }

    #[test]
    fn serve_recover_and_quota_kv_defaults() {
        let mut c = Config::default();
        // Crash consistency and quotas are opt-in: off/unlimited keeps
        // the daemon byte-identical to a manifest-free build.
        assert!(!c.serve_recover);
        assert_eq!(c.serve_quota_bytes, 0);
        assert!(c.validate().is_ok());
        c.apply_kv("serve_recover", "on").unwrap();
        assert!(c.serve_recover);
        assert!(c.validate().is_ok());
        c.apply_kv("serve_recover", "false").unwrap();
        assert!(!c.serve_recover);
        c.apply_kv("serve_quota_bytes", "16M").unwrap();
        assert_eq!(c.serve_quota_bytes, 16 << 20);
        assert!(c.validate().is_ok());
        c.apply_kv("serve_quota_bytes", "0").unwrap();
        assert_eq!(c.serve_quota_bytes, 0);
        assert!(c.apply_kv("serve_recover", "maybe").is_err());
        assert!(c.apply_kv("serve_quota_bytes", "plenty").is_err());
    }

    #[test]
    fn write_coalesce_kv_defaults_and_units() {
        let mut c = Config::default();
        // Default is the seed-exact one-pwrite-per-object sink path.
        assert_eq!(c.write_coalesce_bytes, 0);
        assert!(c.validate().is_ok());
        c.apply_kv("write_coalesce_bytes", "4M").unwrap();
        assert_eq!(c.write_coalesce_bytes, 4 << 20);
        assert!(c.validate().is_ok());
        c.apply_kv("write_coalesce_bytes", "0").unwrap();
        assert_eq!(c.write_coalesce_bytes, 0);
        assert!(c.apply_kv("write_coalesce_bytes", "lots").is_err());
    }

    #[test]
    fn data_streams_kv_defaults_and_validation() {
        let mut c = Config::default();
        // Default is the single fused connection — the PR 5 equivalence pin.
        assert_eq!(c.data_streams, 1);
        c.apply_kv("data_streams", "4").unwrap();
        assert_eq!(c.data_streams, 4);
        assert!(c.validate().is_ok());
        c.data_streams = 0;
        assert!(c.validate().is_err(), "data_streams 0 rejected");
        c.data_streams = 65;
        assert!(c.validate().is_err(), "data_streams above 64 rejected");
        c.data_streams = 64;
        assert!(c.validate().is_ok());
        let mut c = Config::default();
        assert!(c.apply_kv("data_streams", "many").is_err());
    }

    #[test]
    fn read_gather_kv_defaults_and_units() {
        let mut c = Config::default();
        // Default is the seed-exact one-pread-per-object source path.
        assert_eq!(c.read_gather_bytes, 0);
        assert!(c.validate().is_ok());
        c.apply_kv("read_gather_bytes", "4M").unwrap();
        assert_eq!(c.read_gather_bytes, 4 << 20);
        assert!(c.validate().is_ok());
        c.apply_kv("read_gather_bytes", "0").unwrap();
        assert_eq!(c.read_gather_bytes, 0);
        assert!(c.apply_kv("read_gather_bytes", "lots").is_err());
    }

    #[test]
    fn rma_autosize_kv_defaults() {
        let mut c = Config::default();
        assert!(!c.rma_autosize, "autosizing must be opt-in");
        c.apply_kv("rma_autosize", "true").unwrap();
        assert!(c.rma_autosize);
        assert!(c.validate().is_ok());
        c.apply_kv("rma_autosize", "off").unwrap();
        assert!(!c.rma_autosize);
        assert!(c.apply_kv("rma_autosize", "maybe").is_err());
    }

    #[test]
    fn tune_kv_defaults_and_validation() {
        let mut c = Config::default();
        assert!(!c.tune, "the autotuner must be opt-in");
        assert_eq!(c.tune_epoch_ms, 100);
        c.apply_kv("tune", "true").unwrap();
        assert!(c.tune);
        assert!(c.validate().is_ok(), "tune alone needs no other knobs");
        c.apply_kv("tune_epoch_ms", "10").unwrap();
        assert_eq!(c.tune_epoch_ms, 10);
        assert!(c.validate().is_ok());
        c.tune_epoch_ms = 0;
        assert!(c.validate().is_err(), "a zero epoch cannot sample goodput");
        c.tune_epoch_ms = 100;
        assert!(c.apply_kv("tune", "maybe").is_err());
        assert!(c.apply_kv("tune_epoch_ms", "soon").is_err());
    }

    #[test]
    fn tune_supersedes_the_per_knob_adaptive_flags() {
        // One knob, one controller: the unified tuner rejects the
        // per-knob loops with errors that say what to drop.
        let mut c = Config::default();
        c.apply_kv("tune", "true").unwrap();
        c.apply_kv("ack_adaptive", "true").unwrap();
        c.apply_kv("ack_batch", "16").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("supersedes"), "{err}");
        assert!(err.contains("ack-adaptive"), "{err}");
        c.apply_kv("ack_adaptive", "off").unwrap();
        assert!(c.validate().is_ok());
        c.apply_kv("send_window_adaptive", "true").unwrap();
        c.apply_kv("send_window", "8").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("send-window-adaptive"), "{err}");
        c.apply_kv("send_window_adaptive", "off").unwrap();
        // rma_autosize is a one-shot CONNECT sizing, not an online loop:
        // it composes with the tuner.
        c.apply_kv("rma_autosize", "true").unwrap();
        assert!(c.validate().is_ok());
    }

    #[test]
    fn tune_caps_raise_the_advertised_knobs_only_when_on() {
        let c = Config::default();
        // Off: caps collapse to the configured values (seed-exact wire).
        assert_eq!(c.send_window_cap(), c.send_window);
        assert_eq!(c.ack_batch_cap(), c.ack_batch);
        assert_eq!(c.gather_cap(), 0);
        assert_eq!(c.coalesce_cap(), 0);
        let mut c = Config::default();
        c.tune = true;
        assert_eq!(c.send_window_cap(), crate::tune::TUNE_WINDOW_CAP);
        assert_eq!(c.ack_batch_cap(), crate::tune::TUNE_ACK_CAP);
        assert_eq!(c.gather_cap(), crate::tune::TUNE_BUDGET_CAP);
        assert_eq!(c.coalesce_cap(), crate::tune::TUNE_BUDGET_CAP);
        // A configured value above the tuner ceiling wins the max.
        c.send_window = 128;
        c.write_coalesce_bytes = 64 << 20;
        assert_eq!(c.send_window_cap(), 128);
        assert_eq!(c.coalesce_cap(), 64 << 20);
    }

    #[test]
    fn torture_kv_defaults_and_validation() {
        let mut c = Config::default();
        // Off by default: no adversary, no wire change.
        assert_eq!(c.torture_seed, 0);
        assert_eq!(c.torture_profile, "off");
        assert!(c.torture().is_none());
        assert!(c.validate().is_ok());
        c.apply_kv("torture_seed", "7").unwrap();
        // A seed without a profile is a likely operator mistake: reject.
        assert!(c.validate().is_err());
        c.apply_kv("torture_profile", "reorder").unwrap();
        assert!(c.validate().is_ok());
        let spec = c.torture().expect("armed");
        assert_eq!(spec.seed, 7);
        assert!(spec.delay_data > 0.0);
        // Profile without a seed stays disarmed (seed gates the arming).
        c.apply_kv("torture_seed", "0").unwrap();
        assert!(c.torture().is_none());
        assert!(c.validate().is_ok());
        // Typos fail at validate time with the profile list.
        c.apply_kv("torture_profile", "chaos-monkey").unwrap();
        c.apply_kv("torture_seed", "7").unwrap();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("chaos-monkey"), "{err}");
        assert!(c.apply_kv("torture_seed", "many").is_err());
    }

    #[test]
    fn connect_retry_kv_defaults_and_validation() {
        let mut c = Config::default();
        // Defaults reproduce the legacy single 10 s handshake wait.
        assert_eq!(c.connect_timeout_ms, 10_000);
        assert_eq!(c.connect_retries, 0);
        c.apply_kv("connect_timeout_ms", "50").unwrap();
        c.apply_kv("connect_retries", "5").unwrap();
        assert_eq!(c.connect_timeout_ms, 50);
        assert_eq!(c.connect_retries, 5);
        assert!(c.validate().is_ok());
        c.connect_timeout_ms = 0;
        assert!(c.validate().is_err(), "zero handshake patience rejected");
        c.connect_timeout_ms = 1;
        c.connect_retries = 17;
        assert!(c.validate().is_err(), "retry cap enforced");
        c.connect_retries = 16;
        assert!(c.validate().is_ok());
        assert!(c.apply_kv("connect_retries", "lots").is_err());
    }

    #[test]
    fn job_deadline_kv_defaults() {
        let mut c = Config::default();
        assert_eq!(c.job_deadline_ms, 0, "watchdog must be opt-in");
        c.apply_kv("job_deadline_ms", "250").unwrap();
        assert_eq!(c.job_deadline_ms, 250);
        assert!(c.validate().is_ok());
        assert!(c.apply_kv("job_deadline_ms", "soon").is_err());
    }

    #[test]
    fn parse_bool_spellings() {
        for t in ["true", "1", "on", "yes"] {
            assert!(parse_bool(t).unwrap(), "{t}");
        }
        for f in ["false", "0", "off", "no"] {
            assert!(!parse_bool(f).unwrap(), "{f}");
        }
        assert!(parse_bool("2").is_err());
    }

    #[test]
    fn scheduler_kv_and_sink_override() {
        let mut c = Config::default();
        assert_eq!(c.scheduler, SchedPolicy::CongestionAware);
        assert_eq!(c.sink_sched(), SchedPolicy::CongestionAware);
        c.apply_kv("scheduler", "round_robin").unwrap();
        assert_eq!(c.scheduler, SchedPolicy::RoundRobin);
        // Sink follows the session policy until explicitly overridden.
        assert_eq!(c.sink_sched(), SchedPolicy::RoundRobin);
        c.apply_kv("sink_scheduler", "straggler").unwrap();
        assert_eq!(c.sink_sched(), SchedPolicy::StragglerAware);
        assert_eq!(c.scheduler, SchedPolicy::RoundRobin);
        c.apply_kv("sink_scheduler", "default").unwrap();
        assert_eq!(c.sink_sched(), SchedPolicy::RoundRobin);
        // A typo produces an error listing every valid policy name.
        let err = c.apply_kv("scheduler", "fastest").unwrap_err().to_string();
        for name in ["congestion", "round_robin", "fifo_file", "straggler"] {
            assert!(err.contains(name), "error should list '{name}': {err}");
        }
    }

    #[test]
    fn scheduler_toml_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ftlads-sched-cfg-{}.toml", std::process::id()));
        std::fs::write(
            &path,
            "scheduler = \"fifo_file\"\n[coordinator]\nsink_scheduler = \"congestion\"\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_file(&path).unwrap();
        assert_eq!(c.scheduler, SchedPolicy::FifoFile);
        assert_eq!(c.sink_sched(), SchedPolicy::CongestionAware);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn validate_catches_bad_combos() {
        let mut c = Config::default();
        c.rma_bytes = 4;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.stripe_count = 99;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.io_threads = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn apply_file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ftlads-cfg-{}.toml", std::process::id()));
        std::fs::write(
            &path,
            "# comment\nio_threads = 8\n[pfs]\nost_count = 5\nstripe_size = \"2M\"\n",
        )
        .unwrap();
        let mut c = Config::default();
        c.apply_file(&path).unwrap();
        assert_eq!(c.io_threads, 8);
        assert_eq!(c.ost_count, 5);
        assert_eq!(c.stripe_size, 2 << 20);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn test_profile_is_fast() {
        let c = Config::for_tests("x");
        assert_eq!(c.time_scale, 0.0);
        assert!(c.validate().is_ok());
    }
}
