//! Adversarial-network torture specification.
//!
//! A [`TortureSpec`] parameterizes the [`crate::net::adversary`]
//! transport adapter: per-message-class probabilities for delay
//! (bounded reorder), duplication and drop, plus timed partition/heal
//! windows and an optional deterministic data-stream cut. Everything is
//! driven by one seed — the i-th message sent on a given endpoint gets
//! an identical verdict on every run — so a failing torture case is
//! replayable by seed alone.
//!
//! The spec deliberately stays inside the protocol's *recoverable
//! envelope*:
//!
//! - **Drops apply only to the handshake class** (CONNECT / CONNECT_ACK
//!   / STREAM_HELLO), which is covered by the `connect_timeout_ms` /
//!   `connect_retries` retry loop. Control messages (NEW_FILE,
//!   FILE_CLOSE, BYE, …) have no retransmit path, so the adversary
//!   never drops or duplicates them — it only ever *delays the traffic
//!   around* them.
//! - **Duplication and delay apply to the data and ack classes**
//!   (NEW_BLOCK, BLOCK_SYNC), which the hardened endpoints dedup by
//!   `(fid, block)`.
//! - **Partitions defer, never drop**: a partition window buffers
//!   data/ack sends in order and releases them when the window heals,
//!   so byte-exact delivery is preserved.
//!
//! Profiles are selected by name (`--torture-profile`) and armed by a
//! nonzero `--torture-seed`; with the seed at 0 (the default) no
//! adversary is constructed at all and the wire is byte-identical to a
//! build without this module.

use anyhow::Result;

/// Seeded, deterministic adversarial-network policy. Constructed from a
/// named profile ([`TortureSpec::profile`]) or directly (property tests
/// randomize the fields inside the recoverable envelope).
#[derive(Debug, Clone, PartialEq)]
pub struct TortureSpec {
    /// Master seed; each wrapped endpoint derives its own PCG32 stream
    /// from (seed, side, stream id).
    pub seed: u64,
    /// P(drop) for handshake-class messages (retried by the peer).
    pub drop_handshake: f64,
    /// P(duplicate) for handshake-class messages.
    pub dup_handshake: f64,
    /// P(duplicate) for NEW_BLOCK.
    pub dup_data: f64,
    /// P(duplicate) for BLOCK_SYNC / BLOCK_SYNC_BATCH.
    pub dup_ack: f64,
    /// P(hold back into the reorder window) for NEW_BLOCK.
    pub delay_data: f64,
    /// P(hold back into the reorder window) for BLOCK_SYNC(_BATCH).
    pub delay_ack: f64,
    /// Max logical-clock ticks a delayed message slips past later
    /// traffic (the bounded reorder window; min 1 when any delay
    /// probability is nonzero).
    pub reorder_window: u32,
    /// Start a partition after every N data/ack sends (0 = never).
    pub partition_every: u64,
    /// Partition duration in logical-clock ticks; deferred traffic is
    /// released in order when the window heals.
    pub partition_len: u64,
    /// Deterministically sever data stream `cut_stream` (both
    /// directions) once its endpoints' logical clocks pass
    /// [`TortureSpec::cut_after_ops`] — the stream-failover drill.
    pub cut_stream: Option<u32>,
    pub cut_after_ops: u64,
}

/// The named profiles `--torture-profile` accepts ("off" disarms).
pub const TORTURE_PROFILES: &[&str] =
    &["off", "reorder", "dup", "lossy-handshake", "partition", "cut-stream"];

impl TortureSpec {
    /// A spec that perturbs nothing (useful as a fields base).
    pub fn quiet(seed: u64) -> TortureSpec {
        TortureSpec {
            seed,
            drop_handshake: 0.0,
            dup_handshake: 0.0,
            dup_data: 0.0,
            dup_ack: 0.0,
            delay_data: 0.0,
            delay_ack: 0.0,
            reorder_window: 1,
            partition_every: 0,
            partition_len: 0,
            cut_stream: None,
            cut_after_ops: 0,
        }
    }

    /// Resolve a named profile. `None` for "off"; an error for names
    /// not in [`TORTURE_PROFILES`].
    pub fn profile(name: &str, seed: u64) -> Result<Option<TortureSpec>> {
        let q = TortureSpec::quiet(seed);
        Ok(Some(match name {
            "off" => return Ok(None),
            // Delay-heavy: ~30% of data/ack traffic slips up to 4 ticks.
            "reorder" => TortureSpec {
                delay_data: 0.3,
                delay_ack: 0.3,
                reorder_window: 4,
                ..q
            },
            // Duplicate-heavy: the dedup drill. No delays, so the
            // emitted frame sequence is a pure function of the send
            // sequence — the schedule-determinism pin uses this.
            "dup" => TortureSpec {
                dup_handshake: 0.5,
                dup_data: 0.3,
                dup_ack: 0.3,
                ..q
            },
            // Handshake attrition: CONNECT/CONNECT_ACK/STREAM_HELLO
            // flips a 30% drop coin; the retry loop must carry it.
            "lossy-handshake" => TortureSpec {
                drop_handshake: 0.3,
                dup_handshake: 0.2,
                ..q
            },
            // Periodic partition/heal with mild reordering.
            "partition" => TortureSpec {
                partition_every: 32,
                partition_len: 8,
                delay_data: 0.1,
                delay_ack: 0.1,
                reorder_window: 2,
                ..q
            },
            // Sever data stream 1 mid-transfer: at K ≥ 2 the source
            // must re-home its queues onto survivors; at K = 1 the job
            // must fault cleanly with a resumable log.
            "cut-stream" => TortureSpec {
                cut_stream: Some(1),
                cut_after_ops: 60,
                dup_data: 0.1,
                dup_ack: 0.1,
                ..q
            },
            other => anyhow::bail!(
                "unknown torture profile '{other}' (expected one of {})",
                TORTURE_PROFILES.join("|")
            ),
        }))
    }

    /// True when every perturbation is disabled (a quiet spec wraps the
    /// wire in pure pass-through).
    pub fn is_quiet(&self) -> bool {
        self.drop_handshake == 0.0
            && self.dup_handshake == 0.0
            && self.dup_data == 0.0
            && self.dup_ack == 0.0
            && self.delay_data == 0.0
            && self.delay_ack == 0.0
            && self.partition_every == 0
            && self.cut_stream.is_none()
    }

    /// Sanity bounds: probabilities in [0, 1], a usable reorder window.
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("drop_handshake", self.drop_handshake),
            ("dup_handshake", self.dup_handshake),
            ("dup_data", self.dup_data),
            ("dup_ack", self.dup_ack),
            ("delay_data", self.delay_data),
            ("delay_ack", self.delay_ack),
        ] {
            anyhow::ensure!(
                (0.0..=1.0).contains(&p),
                "torture {name} must be a probability in [0, 1], got {p}"
            );
        }
        anyhow::ensure!(
            self.reorder_window >= 1,
            "torture reorder_window must be >= 1"
        );
        anyhow::ensure!(
            self.partition_every == 0 || self.partition_len >= 1,
            "torture partition_every set but partition_len is 0"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_profile_resolves_to_none() {
        assert!(TortureSpec::profile("off", 7).unwrap().is_none());
        assert!(TortureSpec::profile("warp-speed", 7).is_err());
    }

    #[test]
    fn every_named_profile_resolves_and_validates() {
        for name in TORTURE_PROFILES {
            let spec = TortureSpec::profile(name, 9).unwrap();
            if *name == "off" {
                assert!(spec.is_none());
                continue;
            }
            let spec = spec.unwrap();
            spec.validate().unwrap();
            assert_eq!(spec.seed, 9);
            assert!(!spec.is_quiet(), "profile '{name}' must perturb something");
        }
    }

    #[test]
    fn profiles_stay_inside_the_recoverable_envelope() {
        for name in TORTURE_PROFILES {
            let Some(spec) = TortureSpec::profile(name, 1).unwrap() else {
                continue;
            };
            // Drops only ever hit the handshake class — everything else
            // must be delivered (possibly late, possibly twice).
            assert!(
                spec.drop_handshake <= 1.0
                    && spec.dup_data <= 0.5
                    && spec.dup_ack <= 0.5,
                "profile '{name}' leaves the completable envelope"
            );
        }
    }

    #[test]
    fn quiet_spec_is_quiet_and_valid() {
        let q = TortureSpec::quiet(3);
        assert!(q.is_quiet());
        q.validate().unwrap();
        let mut bad = q.clone();
        bad.dup_data = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = TortureSpec::quiet(3);
        bad.reorder_window = 0;
        assert!(bad.validate().is_err());
        let mut bad = TortureSpec::quiet(3);
        bad.partition_every = 8;
        assert!(bad.validate().is_err(), "partition window needs a length");
        bad.partition_len = 4;
        assert!(bad.validate().is_ok());
    }
}
