//! TOML-subset parser: `key = value` lines, `[section]` headers, `#`
//! comments, quoted or bare values. No arrays-of-tables, no multiline
//! strings — config files here are flat settings, and the offline crate
//! set has no `toml`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TomlLite {
    /// section -> key -> value ("" section = top level).
    pub sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl TomlLite {
    pub fn parse(text: &str) -> Result<TomlLite> {
        let mut out = TomlLite::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", lineno + 1);
                };
                section = name.trim().to_string();
                out.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{line}'", lineno + 1);
            };
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = unquote(value.trim());
            out.sections
                .entry(section.clone())
                .or_default()
                .insert(key.to_string(), value);
        }
        Ok(out)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// All (key, value) pairs with sections flattened away (section names
    /// are organizational only for our config).
    pub fn flat_items(&self) -> Vec<(String, String)> {
        let mut out = Vec::new();
        for kv in self.sections.values() {
            for (k, v) in kv {
                out.push((k.clone(), v.clone()));
            }
        }
        out
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside quotes is content, not a comment.
    let mut in_quote = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quote = !in_quote,
            '#' if !in_quote => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    let v = v.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_flat_and_sections() {
        let t = TomlLite::parse(
            "a = 1\n# full comment\nb = \"two\" # trailing\n[sec]\nc = 3.5\n",
        )
        .unwrap();
        assert_eq!(t.get("", "a"), Some("1"));
        assert_eq!(t.get("", "b"), Some("two"));
        assert_eq!(t.get("sec", "c"), Some("3.5"));
        assert_eq!(t.get("sec", "missing"), None);
        assert_eq!(t.flat_items().len(), 3);
    }

    #[test]
    fn hash_inside_quotes_kept() {
        let t = TomlLite::parse("key = \"a#b\"\n").unwrap();
        assert_eq!(t.get("", "key"), Some("a#b"));
    }

    #[test]
    fn errors_are_lined() {
        let err = TomlLite::parse("ok = 1\nbroken line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        assert!(TomlLite::parse("[unterminated\n").is_err());
        assert!(TomlLite::parse(" = novalue\n").is_err());
    }

    #[test]
    fn empty_and_whitespace() {
        let t = TomlLite::parse("\n\n  \n# only comments\n").unwrap();
        assert!(t.sections.is_empty());
    }

    #[test]
    fn last_duplicate_wins() {
        let t = TomlLite::parse("a = 1\na = 2\n").unwrap();
        assert_eq!(t.get("", "a"), Some("2"));
    }
}
