//! Epoch-based online autotuner: one goodput-driven hill-climb over the
//! joint knob vector, applied mid-transfer through the applied-value
//! paths (`SendWindow::eff`, `AckCoalescer` effective batch, the atomic
//! coalesce/gather byte budgets).
//!
//! The paper's transfer engine (and PRs 2–6 here) grew four independent,
//! locally-greedy feedback loops — adaptive ack batch, adaptive send
//! window, RMA pool autosizing, fixed byte budgets — each watching its
//! own pressure signal and none watching goodput. Arslan & Kosar
//! (arXiv:1708.05425) and the Globus production experience (Zheng et
//! al., arXiv:2503.22981) both find that a single online controller
//! over the whole vector beats per-knob heuristics. This module is that
//! controller's decision core: a deterministic, single-threaded bounded
//! hill-climb with hysteresis. The coordinator threads own the clocks
//! and the atomics; [`HillClimb`] only ever sees one `(goodput,
//! pressure)` sample per epoch and answers with at most one knob move.
//!
//! Behavior contract (pinned by the unit tests below):
//! - **Exponential step.** A knob grows by doubling (through a `seed`
//!   value when leaving its floor, so `0 -> 1 MiB`, not `0 -> 0`) and
//!   shrinks by halving (collapsing to the floor at/below the seed).
//! - **Hysteresis.** A probe only counts as a gain/loss outside a
//!   ±[`HYSTERESIS`] band around the pre-move goodput; inside the band
//!   the move is kept and the walk advances to the next axis, unless
//!   the pressure signal worsened while goodput slipped — that
//!   tiebreak reverts.
//! - **Revert on regression + cooldown.** A losing move is rolled back
//!   (the caller re-applies the previous value), the knob's direction
//!   flips, the knob sits out the next [`REVERT_SKIP`] proposals, and
//!   the whole walk idles for [`COOLDOWN`] epochs so the revert's
//!   effect is measured before the next probe.
//! - **Momentum.** A winning axis is walked again immediately.

/// Negotiation ceiling the tuner may raise the send window to.
///
/// With `tune` on, CONNECT advertises at least this cap (see
/// `Config::send_window_cap`) so the applied window can float up to it
/// without any wire change mid-transfer.
pub const TUNE_WINDOW_CAP: u32 = 32;

/// Negotiation ceiling the tuner may raise the ack batch to.
pub const TUNE_ACK_CAP: u32 = 64;

/// Ceiling for the tuned byte budgets (write-coalesce, read-gather).
pub const TUNE_BUDGET_CAP: u64 = 16 << 20;

/// Relative goodput band treated as noise: a probe is a gain only above
/// `base * (1 + HYSTERESIS)` and a loss only below `base * (1 -
/// HYSTERESIS)` (or on the pressure tiebreak).
pub const HYSTERESIS: f64 = 0.05;

/// Epochs the walk idles after a revert before probing again.
pub const COOLDOWN: u32 = 2;

/// Proposals a knob sits out after one of its moves was reverted,
/// damping oscillation against a cap or floor.
pub const REVERT_SKIP: u32 = 4;

/// Static description of one tunable axis.
#[derive(Debug, Clone, Copy)]
pub struct KnobSpec {
    /// Axis name, used verbatim in trajectory entries.
    pub name: &'static str,
    /// Lowest value a shrink may reach (0 = feature off).
    pub floor: u64,
    /// Highest value a grow may reach (the negotiated/configured cap).
    pub cap: u64,
    /// First value a grow reaches from below it; doubling starts here,
    /// so a floor of 0 can still leave the floor.
    pub seed: u64,
    /// Initial applied value (clamped into `floor..=cap`).
    pub start: u64,
}

#[derive(Debug)]
struct Knob {
    spec: KnobSpec,
    value: u64,
    /// Current probe direction: `true` = grow.
    grow: bool,
    /// Remaining proposals to sit out after a revert.
    skip: u32,
}

impl Knob {
    fn grow_target(&self) -> u64 {
        let t = if self.value < self.spec.seed {
            self.spec.seed
        } else {
            self.value.saturating_mul(2)
        };
        t.min(self.spec.cap).max(self.spec.floor)
    }

    fn shrink_target(&self) -> u64 {
        let t = if self.value <= self.spec.seed { self.spec.floor } else { self.value / 2 };
        t.clamp(self.spec.floor, self.spec.cap)
    }

    fn target(&self) -> u64 {
        if self.grow {
            self.grow_target()
        } else {
            self.shrink_target()
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum Phase {
    /// First epoch after start: its sample spans the ramp-up, discard.
    Warmup,
    /// Idle for `left` more epochs (cooldown), then propose.
    Settle { left: u32 },
    /// A move on `knob` is in flight; the next sample judges it
    /// against the pre-move `base` goodput and `base_pressure`.
    Probe { knob: usize, prev: u64, base: f64, base_pressure: u64 },
}

/// The deterministic hill-climb core. Feed it one goodput sample per
/// epoch via [`observe`](Self::observe); apply the `(knob index, new
/// value)` it returns, if any, before the next epoch.
#[derive(Debug)]
pub struct HillClimb {
    knobs: Vec<Knob>,
    phase: Phase,
    /// Next axis the round-robin proposal scan starts from.
    axis: usize,
    /// Epochs observed (including warmup/cooldown).
    pub epochs: u64,
    /// Accepted or in-flight upward moves.
    pub grows: u64,
    /// Accepted or in-flight downward moves.
    pub shrinks: u64,
    /// Moves rolled back on regression.
    pub reverts: u64,
    /// Best single-epoch goodput seen so far (the convergence figure).
    pub best: f64,
    /// Human-readable move log: `"e<epoch>: <name> <old> -> <new>"`.
    pub trajectory: Vec<String>,
}

impl HillClimb {
    pub fn new(specs: Vec<KnobSpec>) -> HillClimb {
        let knobs = specs
            .into_iter()
            .map(|spec| Knob {
                value: spec.start.clamp(spec.floor, spec.cap),
                grow: true,
                skip: 0,
                spec,
            })
            .collect();
        HillClimb {
            knobs,
            phase: Phase::Warmup,
            axis: 0,
            epochs: 0,
            grows: 0,
            shrinks: 0,
            reverts: 0,
            best: 0.0,
            trajectory: Vec::new(),
        }
    }

    /// Current applied value of knob `i`.
    pub fn value(&self, i: usize) -> u64 {
        self.knobs[i].value
    }

    /// Record one epoch's `(goodput, pressure)` sample and return the
    /// next move to apply, if any. Goodput units are the caller's
    /// (bytes/sec here); only ratios matter. Pressure is a
    /// monotone-per-epoch badness count (stalls) used to break ties
    /// inside the hysteresis band.
    pub fn observe(&mut self, goodput: f64, pressure: u64) -> Option<(usize, u64)> {
        self.epochs += 1;
        if goodput > self.best {
            self.best = goodput;
        }
        match self.phase {
            Phase::Warmup => {
                // The first full epoch still includes connection ramp-up;
                // settle one more before the first probe baseline.
                self.phase = Phase::Settle { left: 1 };
                None
            }
            Phase::Settle { left } if left > 0 => {
                self.phase = Phase::Settle { left: left - 1 };
                None
            }
            Phase::Settle { .. } => self.propose(goodput, pressure),
            Phase::Probe { knob, prev, base, base_pressure } => {
                let gain = goodput > base * (1.0 + HYSTERESIS);
                let loss = goodput < base * (1.0 - HYSTERESIS)
                    || (goodput < base && pressure > base_pressure);
                if gain {
                    // Momentum: keep walking the winning axis.
                    self.axis = knob;
                    self.propose(goodput, pressure)
                } else if loss {
                    let k = &mut self.knobs[knob];
                    let cur = k.value;
                    k.value = prev;
                    k.grow = !k.grow;
                    k.skip = REVERT_SKIP;
                    self.reverts += 1;
                    self.trajectory.push(format!(
                        "e{}: revert {} {cur} -> {prev}",
                        self.epochs, k.spec.name
                    ));
                    self.axis = knob + 1;
                    self.phase = Phase::Settle { left: COOLDOWN };
                    Some((knob, prev))
                } else {
                    // Inside the band: keep the move, advance the scan.
                    self.axis = knob + 1;
                    self.propose(goodput, pressure)
                }
            }
        }
    }

    /// Pick the next movable axis (round-robin from `self.axis`,
    /// honoring revert-skips, flipping direction once at a cap/floor)
    /// and start its probe.
    fn propose(&mut self, goodput: f64, pressure: u64) -> Option<(usize, u64)> {
        let n = self.knobs.len();
        for step in 0..n {
            let i = (self.axis + step) % n;
            let k = &mut self.knobs[i];
            if k.skip > 0 {
                k.skip -= 1;
                continue;
            }
            let mut target = k.target();
            if target == k.value {
                // Pinned at a cap or floor: turn around.
                k.grow = !k.grow;
                target = k.target();
            }
            if target == k.value {
                // floor == cap: this axis can never move.
                continue;
            }
            let prev = k.value;
            k.value = target;
            if target > prev {
                self.grows += 1;
            } else {
                self.shrinks += 1;
            }
            self.trajectory
                .push(format!("e{}: {} {prev} -> {target}", self.epochs, k.spec.name));
            self.axis = i;
            self.phase =
                Phase::Probe { knob: i, prev, base: goodput, base_pressure: pressure };
            return Some((i, target));
        }
        self.phase = Phase::Settle { left: 0 };
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_knob(start: u64) -> HillClimb {
        HillClimb::new(vec![KnobSpec {
            name: "window",
            floor: 1,
            cap: 32,
            seed: 2,
            start,
        }])
    }

    #[test]
    fn grows_exponentially_on_gain_and_reverts_the_overshoot() {
        // Goodput tracks the knob value exactly: every grow is a gain
        // until the cap, the post-cap shrink is a loss and reverts.
        let mut hc = one_knob(1);
        assert_eq!(hc.observe(1.0, 0), None, "warmup discards its epoch");
        assert_eq!(hc.observe(1.0, 0), None, "one settle epoch before probing");
        // Doubling walk through the seed: 1 -> 2 -> 4 -> ... -> 32.
        let mut expect = vec![];
        let mut v = 1.0f64;
        for step in [2u64, 4, 8, 16, 32] {
            assert_eq!(hc.observe(v, 0), Some((0, step)));
            v = step as f64;
            expect.push(step);
        }
        // At the cap with goodput still "up": the grow pins, direction
        // flips, the probe shrinks...
        assert_eq!(hc.observe(32.0, 0), Some((0, 16)));
        // ...and the shrink regresses, so it reverts back to the cap.
        assert_eq!(hc.observe(16.0, 0), Some((0, 32)), "loss must revert");
        assert_eq!(hc.value(0), 32);
        assert_eq!(hc.reverts, 1);
        assert!(hc.grows >= 5, "doubling walk: {} grows", hc.grows);
        assert!((hc.best - 32.0).abs() < 1e-9);
        assert!(
            hc.trajectory.iter().any(|t| t.contains("revert window 16 -> 32")),
            "{:?}",
            hc.trajectory
        );
    }

    #[test]
    fn revert_cooldown_then_knob_sits_out_proposals() {
        let mut hc = HillClimb::new(vec![KnobSpec {
            name: "batch",
            floor: 1,
            cap: 64,
            seed: 2,
            start: 4,
        }]);
        assert_eq!(hc.observe(10.0, 0), None); // warmup
        assert_eq!(hc.observe(10.0, 0), None); // settle
        assert_eq!(hc.observe(10.0, 0), Some((0, 8))); // probe grow
        // Hard regression: roll back to 4, flip direction, cool down.
        assert_eq!(hc.observe(1.0, 0), Some((0, 4)));
        assert_eq!(hc.reverts, 1);
        // COOLDOWN idle epochs...
        assert_eq!(hc.observe(10.0, 0), None);
        assert_eq!(hc.observe(10.0, 0), None);
        // ...then REVERT_SKIP proposal rounds where the only knob sits
        // out (single-axis walk: nothing else can move)...
        for _ in 0..REVERT_SKIP {
            assert_eq!(hc.observe(10.0, 0), None);
        }
        // ...and only then does it probe again, in the flipped
        // (shrink) direction.
        assert_eq!(hc.observe(10.0, 0), Some((0, 2)));
        assert_eq!(hc.value(0), 2);
    }

    #[test]
    fn pressure_breaks_ties_inside_the_hysteresis_band() {
        let mut hc = one_knob(4);
        assert_eq!(hc.observe(100.0, 0), None);
        assert_eq!(hc.observe(100.0, 0), None);
        assert_eq!(hc.observe(100.0, 0), Some((0, 8)));
        // 99 is inside the ±5% band, but pressure rose while goodput
        // slipped: the tiebreak calls it a loss and reverts.
        assert_eq!(hc.observe(99.0, 7), Some((0, 4)));
        assert_eq!(hc.reverts, 1);
    }

    #[test]
    fn neutral_band_keeps_the_move_and_advances_the_axis() {
        let mut hc = HillClimb::new(vec![
            KnobSpec { name: "a", floor: 1, cap: 32, seed: 2, start: 4 },
            KnobSpec { name: "b", floor: 0, cap: 1 << 20, seed: 1 << 10, start: 0 },
        ]);
        assert_eq!(hc.observe(100.0, 0), None);
        assert_eq!(hc.observe(100.0, 0), None);
        assert_eq!(hc.observe(100.0, 0), Some((0, 8)));
        // Flat response, no pressure change: keep a = 8, probe b next.
        assert_eq!(hc.observe(100.0, 0), Some((1, 1 << 10)));
        assert_eq!(hc.value(0), 8);
        assert_eq!(hc.reverts, 0);
    }

    #[test]
    fn seed_lifts_a_zero_floor_budget_off_zero() {
        let mut hc = HillClimb::new(vec![KnobSpec {
            name: "budget",
            floor: 0,
            cap: 16 << 20,
            seed: 1 << 20,
            start: 0,
        }]);
        assert_eq!(hc.observe(1.0, 0), None);
        assert_eq!(hc.observe(1.0, 0), None);
        // 0 doubles to nothing; the seed is the escape hatch.
        assert_eq!(hc.observe(1.0, 0), Some((0, 1 << 20)));
        assert_eq!(hc.observe(2.0, 0), Some((0, 2 << 20)));
        // And a shrink at/below the seed collapses back to the floor.
        let mut hc = HillClimb::new(vec![KnobSpec {
            name: "budget",
            floor: 0,
            cap: 16 << 20,
            seed: 1 << 20,
            start: 1 << 20,
        }]);
        assert_eq!(hc.observe(1.0, 0), None);
        assert_eq!(hc.observe(1.0, 0), None);
        assert_eq!(hc.observe(1.0, 0), Some((0, 2 << 20))); // grow first
        assert_eq!(hc.observe(0.1, 0), Some((0, 1 << 20))); // revert
        for _ in 0..(COOLDOWN + REVERT_SKIP) {
            assert_eq!(hc.observe(1.0, 0), None);
        }
        // Flipped to shrink by the revert: seed -> floor.
        assert_eq!(hc.observe(1.0, 0), Some((0, 0)));
    }

    #[test]
    fn identical_inputs_produce_identical_trajectories() {
        let samples: Vec<(f64, u64)> = (0..40)
            .map(|i| (((i * 7919) % 101) as f64 + 1.0, (i % 3) as u64))
            .collect();
        let run = || {
            let mut hc = HillClimb::new(vec![
                KnobSpec { name: "w", floor: 1, cap: 32, seed: 2, start: 1 },
                KnobSpec { name: "g", floor: 0, cap: 16 << 20, seed: 1 << 20, start: 0 },
            ]);
            let mut moves = Vec::new();
            for &(g, p) in &samples {
                moves.push(hc.observe(g, p));
            }
            (moves, hc.trajectory)
        };
        let (m1, t1) = run();
        let (m2, t2) = run();
        assert_eq!(m1, m2);
        assert_eq!(t1, t2);
        assert!(!t1.is_empty(), "40 epochs must move something");
    }
}
