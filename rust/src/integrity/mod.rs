//! Data-integrity engine: object digests on the transfer path.
//!
//! Paper §3.2 observes that in stock LADS a failed/corrupted PFS write at
//! the sink goes unnoticed — BLOCK_DONE only acknowledged the RMA read.
//! FT-LADS's BLOCK_SYNC acknowledges the *write*, and this module is what
//! makes that acknowledgement meaningful: the source digests every object
//! it sends, the digest travels in the NEW_BLOCK header, and the sink
//! re-digests what it actually wrote before emitting BLOCK_SYNC.
//!
//! Two interchangeable backends:
//! - [`native`]: pure-rust, bit-identical to `ref.py` (always available).
//! - [`PjrtEngine`]: batches objects and runs the AOT-compiled Pallas
//!   digest artifact via PJRT (the L1/L2 path; one executable per variant,
//!   compiled once at startup).
//!
//! `IntegrityMode::Off` reproduces stock-LADS behaviour for A/B runs.

pub mod native;


use anyhow::Result;

pub use native::{digest_bytes, digest_bytes_padded, digest_words, popcount_words, Digest};

use crate::runtime::RuntimeHandle;

/// Which digest backend the transfer uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntegrityMode {
    /// No digests (stock-LADS behaviour; write errors can go unnoticed).
    Off,
    /// Pure-rust digests, computed inline by the IO threads.
    Native,
    /// Batched digests through the compiled PJRT artifact.
    Pjrt,
}

impl IntegrityMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(Self::Off),
            "native" => Ok(Self::Native),
            "pjrt" => Ok(Self::Pjrt),
            _ => anyhow::bail!("integrity mode must be off|native|pjrt, got '{s}'"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Native => "native",
            Self::Pjrt => "pjrt",
        }
    }
}

/// A batch digest engine. The sink IO threads hand it whole RMA buffers'
/// worth of objects; it returns one digest per object.
pub trait DigestEngine: Send + Sync {
    /// Digest each object. `objects[i]` may be shorter than the MTU (the
    /// final object of a file); it is treated as zero-padded to
    /// `padded_words` u32 words, matching the AOT artifact's fixed W.
    fn digest_batch(&self, objects: &[&[u8]], padded_words: usize) -> Result<Vec<Digest>>;

    fn name(&self) -> &'static str;
}

/// Native backend: per-object wrapping-u32 dual sums.
pub struct NativeEngine;

impl DigestEngine for NativeEngine {
    fn digest_batch(&self, objects: &[&[u8]], padded_words: usize) -> Result<Vec<Digest>> {
        Ok(objects
            .iter()
            .map(|o| native::digest_bytes_padded(o, padded_words))
            .collect())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// PJRT backend: packs objects into the artifact's fixed `(B, W)` u32 batch
/// and executes the compiled Pallas digest kernel through the thread-
/// confined [`RuntimeHandle`]. Partial batches are zero-padded (a zero row
/// digests to [0, 0], which is simply discarded).
pub struct PjrtEngine {
    handle: RuntimeHandle,
    batch: usize,
    words: usize,
}

impl PjrtEngine {
    pub fn new(handle: RuntimeHandle) -> Result<Self> {
        let batch = handle.manifest.digest_batch;
        let words = handle.manifest.object_words;
        anyhow::ensure!(
            handle.manifest.entries.contains_key("digest"),
            "manifest has no 'digest' artifact"
        );
        Ok(PjrtEngine { handle, batch, words })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }
}

impl DigestEngine for PjrtEngine {
    fn digest_batch(&self, objects: &[&[u8]], padded_words: usize) -> Result<Vec<Digest>> {
        anyhow::ensure!(
            padded_words == self.words,
            "PJRT digest artifact is compiled for W={} words, got request for {}",
            self.words,
            padded_words
        );
        let mut out = Vec::with_capacity(objects.len());
        for chunk in objects.chunks(self.batch) {
            let mut staging = vec![0u32; self.batch * self.words];
            for (row, obj) in chunk.iter().enumerate() {
                anyhow::ensure!(
                    obj.len() <= self.words * 4,
                    "object of {} bytes exceeds artifact object size {}",
                    obj.len(),
                    self.words * 4
                );
                // Bulk byte copy into the u32 staging row (little-endian
                // host; one memcpy instead of a per-word conversion loop —
                // §Perf iteration 3). The trailing partial word stays
                // zero-padded from the allocation.
                let base = row * self.words;
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(
                        staging[base..].as_mut_ptr() as *mut u8,
                        self.words * 4,
                    )
                };
                dst[..obj.len()].copy_from_slice(obj);
            }
            let results = self.handle.execute_u32("digest", vec![staging])?;
            let digests = &results[0]; // (B, 2) row-major
            for row in 0..chunk.len() {
                out.push(Digest { a: digests[row * 2], b: digests[row * 2 + 1] });
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Run the recovery-summary artifact over a batch of FT-log bitmaps:
/// returns `(completed, pending)` counts per file. Used by the resume path
/// for Bit8/Bit64 logs; pads to the artifact's fixed (F, WB).
pub fn pjrt_recovery_summary(
    handle: &RuntimeHandle,
    bitmaps: &[Vec<u32>],
    totals: &[u32],
) -> Result<(Vec<u32>, Vec<u32>)> {
    anyhow::ensure!(bitmaps.len() == totals.len(), "bitmaps/totals length mismatch");
    let f = handle.manifest.recovery_files;
    let wb = handle.manifest.bitmap_words;
    let mut completed = Vec::with_capacity(totals.len());
    let mut pending = Vec::with_capacity(totals.len());
    for (chunk_idx, chunk) in bitmaps.chunks(f).enumerate() {
        let mut bm_buf = vec![0u32; f * wb];
        let mut tot_buf = vec![0u32; f];
        for (row, bm) in chunk.iter().enumerate() {
            anyhow::ensure!(
                bm.len() <= wb,
                "bitmap of {} words exceeds artifact WB={wb}",
                bm.len()
            );
            bm_buf[row * wb..row * wb + bm.len()].copy_from_slice(bm);
            tot_buf[row] = totals[chunk_idx * f + row];
        }
        let results = handle.execute_u32("recovery", vec![bm_buf, tot_buf])?;
        completed.extend_from_slice(&results[0][..chunk.len()]);
        pending.extend_from_slice(&results[1][..chunk.len()]);
    }
    Ok((completed, pending))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_batches() {
        let e = NativeEngine;
        let a = vec![1u8, 2, 3, 4];
        let b = vec![9u8; 11];
        let out = e.digest_batch(&[&a, &b], 16).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], native::digest_bytes_padded(&a, 16));
        assert_eq!(out[1], native::digest_bytes_padded(&b, 16));
    }

    #[test]
    fn mode_parse() {
        assert_eq!(IntegrityMode::parse("off").unwrap(), IntegrityMode::Off);
        assert_eq!(IntegrityMode::parse("native").unwrap(), IntegrityMode::Native);
        assert_eq!(IntegrityMode::parse("pjrt").unwrap(), IntegrityMode::Pjrt);
        assert!(IntegrityMode::parse("gpu").is_err());
        assert_eq!(IntegrityMode::Pjrt.as_str(), "pjrt");
    }
}
