//! Native (pure-rust) implementation of the integrity math.
//!
//! Bit-identical to `python/compile/kernels/ref.py` — the cross-language
//! contract: `digest(data)` here equals `digest_ref` there for the same
//! words, and both equal the Pallas kernel and the compiled PJRT artifact.
//!
//! The digest of one object (little-endian u32 words `d[0..W]`) is
//!
//! ```text
//! A = Σ d[i]            (mod 2^32)
//! B = Σ (W - i)·d[i]    (mod 2^32)
//! ```
//!
//! `A` detects value changes, `B` detects reorderings (it is
//! position-weighted). Both sums are wrapping, so partial digests combine —
//! which is also what lets the Pallas kernel tile the reduction.

/// A two-word object digest `[A, B]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Digest {
    pub a: u32,
    pub b: u32,
}

impl Digest {
    pub fn as_u64(self) -> u64 {
        ((self.b as u64) << 32) | self.a as u64
    }

    pub fn from_u64(v: u64) -> Self {
        Digest { a: v as u32, b: (v >> 32) as u32 }
    }
}

/// Digest a byte buffer. The buffer is interpreted as little-endian u32
/// words; a trailing partial word is zero-padded (same convention the rust
/// coordinator uses when padding an object to the artifact's W).
pub fn digest_bytes(data: &[u8]) -> Digest {
    let w = (data.len() + 3) / 4;
    let mut a = 0u32;
    let mut b = 0u32;
    let chunks = data.chunks_exact(4);
    let rem = chunks.remainder();
    let mut i = 0u32;
    let wt = w as u32;
    for c in chunks {
        let v = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        a = a.wrapping_add(v);
        b = b.wrapping_add(wt.wrapping_sub(i).wrapping_mul(v));
        i += 1;
    }
    if !rem.is_empty() {
        let mut last = [0u8; 4];
        last[..rem.len()].copy_from_slice(rem);
        let v = u32::from_le_bytes(last);
        a = a.wrapping_add(v);
        b = b.wrapping_add(wt.wrapping_sub(i).wrapping_mul(v));
    }
    Digest { a, b }
}

/// Digest a u32 word slice directly (the shape the PJRT artifact sees).
pub fn digest_words(words: &[u32]) -> Digest {
    let wt = words.len() as u32;
    let mut a = 0u32;
    let mut b = 0u32;
    for (i, &v) in words.iter().enumerate() {
        a = a.wrapping_add(v);
        b = b.wrapping_add(wt.wrapping_sub(i as u32).wrapping_mul(v));
    }
    Digest { a, b }
}

/// Digest of a buffer that was zero-padded from `len` bytes up to
/// `padded_words` u32 words. Zero words contribute nothing to either sum,
/// so the digest over the padded buffer equals the digest over the original
/// bytes *computed at the padded width*. This helper computes that without
/// materializing the padding.
pub fn digest_bytes_padded(data: &[u8], padded_words: usize) -> Digest {
    debug_assert!((data.len() + 3) / 4 <= padded_words);
    let wt = padded_words as u32;
    let mut a = 0u32;
    let mut b = 0u32;
    let chunks = data.chunks_exact(4);
    let rem = chunks.remainder();
    let mut i = 0u32;
    for c in chunks {
        let v = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        a = a.wrapping_add(v);
        b = b.wrapping_add(wt.wrapping_sub(i).wrapping_mul(v));
        i += 1;
    }
    if !rem.is_empty() {
        let mut last = [0u8; 4];
        last[..rem.len()].copy_from_slice(rem);
        let v = u32::from_le_bytes(last);
        a = a.wrapping_add(v);
        b = b.wrapping_add(wt.wrapping_sub(i).wrapping_mul(v));
    }
    Digest { a, b }
}

/// Per-row popcount of bitmap words — the native mirror of the recovery
/// kernel (`recovery.popcount`).
pub fn popcount_words(words: &[u32]) -> u32 {
    words.iter().map(|w| w.count_ones()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_zeros_is_zero() {
        assert_eq!(digest_bytes(&[0u8; 64]), Digest { a: 0, b: 0 });
        assert_eq!(digest_words(&[0u32; 16]), Digest { a: 0, b: 0 });
    }

    #[test]
    fn digest_single_word() {
        // W=1, d[0]=1: A=1, B=(1-0)*1=1.
        assert_eq!(digest_words(&[1]), Digest { a: 1, b: 1 });
        // W=4, d[0]=1: B = 4.
        assert_eq!(digest_words(&[1, 0, 0, 0]), Digest { a: 1, b: 4 });
        // W=4, d[3]=1: weight of last word is 1.
        assert_eq!(digest_words(&[0, 0, 0, 1]), Digest { a: 1, b: 1 });
    }

    #[test]
    fn digest_bytes_matches_words() {
        let bytes: Vec<u8> = (0..64u8).collect();
        let words: Vec<u32> = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(digest_bytes(&bytes), digest_words(&words));
    }

    #[test]
    fn digest_partial_word_zero_pads() {
        // 5 bytes -> 2 words, second is [4, 0, 0, 0].
        let d = digest_bytes(&[1, 0, 0, 0, 4]);
        assert_eq!(d, digest_words(&[1, 4]));
    }

    #[test]
    fn digest_detects_swap() {
        let x = digest_words(&[5, 0, 9, 0]);
        let y = digest_words(&[9, 0, 5, 0]);
        assert_eq!(x.a, y.a);
        assert_ne!(x.b, y.b);
    }

    #[test]
    fn digest_wraps() {
        let words = vec![u32::MAX; 1024];
        let d = digest_words(&words);
        // A = 1024 * (2^32 - 1) mod 2^32 = -1024 mod 2^32.
        assert_eq!(d.a, 0u32.wrapping_sub(1024));
    }

    #[test]
    fn padded_equals_materialized() {
        let data: Vec<u8> = (0..999u32).map(|i| (i * 7) as u8).collect();
        let padded_words = 512;
        let mut full = data.clone();
        full.resize(padded_words * 4, 0);
        let words: Vec<u32> = full
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(digest_bytes_padded(&data, padded_words), digest_words(&words));
    }

    #[test]
    fn digest_u64_roundtrip() {
        let d = Digest { a: 0xdeadbeef, b: 0x12345678 };
        assert_eq!(Digest::from_u64(d.as_u64()), d);
    }

    #[test]
    fn popcount() {
        assert_eq!(popcount_words(&[0]), 0);
        assert_eq!(popcount_words(&[u32::MAX; 3]), 96);
        assert_eq!(popcount_words(&[0b1011, 0b1]), 4);
    }
}
