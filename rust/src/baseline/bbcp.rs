//! bbcp-model baseline: sequential, file-oriented transfer with offset
//! checkpointing — the comparator of §6.4 and Related Work.
//!
//! Faithful properties (per the paper's description of bbcp):
//! - the workload is a list of *logical files* transferred **one file at
//!   a time, sequentially** — no layout awareness, no OST scheduling;
//! - multiple **streams** (paper config: 2) pipeline blocks of the
//!   current file within a **window** (paper config: 8 MB);
//! - FT is a per-file **checkpoint record**: the highest contiguous
//!   acked byte offset, *overwritten* on every advance (Fig 1a). On
//!   resume: if a checkpoint exists the transfer appends from its offset;
//!   else if the target file's attributes match, the file is skipped;
//!   else it restarts from zero.
//!
//! Because transmission is sequential, an offset checkpoint fully
//! describes progress — which is exactly the property LADS's
//! out-of-order object scheduling destroys, motivating FT-LADS.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::Config;
use crate::coordinator::TransferOutcome;
use crate::fault::FaultPlan;
use crate::ftlog::SpaceStats;
use crate::metrics::{Counters, Sampler};
use crate::net::{channel, Endpoint, Message, NetError};
use crate::pfs::Pfs;

/// bbcp tuning (paper §6.4: "2 tcp streams with window size of 8MB").
#[derive(Debug, Clone)]
pub struct BbcpConfig {
    pub streams: usize,
    pub window_bytes: u64,
    /// Transfer block size (kept equal to the LADS MTU for comparability).
    pub block_size: u64,
    /// Directory for checkpoint records.
    pub ckpt_dir: PathBuf,
}

impl BbcpConfig {
    pub fn paper_defaults(cfg: &Config) -> Self {
        BbcpConfig {
            streams: 2,
            window_bytes: 8 << 20,
            block_size: cfg.object_size,
            ckpt_dir: cfg.ft_dir.join("bbcp"),
        }
    }
}

fn ckpt_path(bcfg: &BbcpConfig, name: &str) -> PathBuf {
    bcfg.ckpt_dir
        .join(format!("{}.bbcp.ckpt", crate::ftlog::escape_name(name)))
}

/// Read a checkpoint record (contiguous acked offset).
fn read_ckpt(bcfg: &BbcpConfig, name: &str) -> Option<u64> {
    let text = std::fs::read_to_string(ckpt_path(bcfg, name)).ok()?;
    text.trim().parse().ok()
}

/// Overwrite the checkpoint record (Fig 1a: "overwrite the checkpoint
/// record with the updated file offset information").
fn write_ckpt(bcfg: &BbcpConfig, name: &str, offset: u64, stats: &Mutex<SpaceStats>) {
    let path = ckpt_path(bcfg, name);
    let body = format!("{offset}\n");
    let len = body.len() as u64;
    if std::fs::write(&path, body).is_ok() {
        let mut s = stats.lock().unwrap_or_else(|e| e.into_inner());
        s.bytes_written += len;
        s.appends += 1;
        s.current_bytes = s.current_bytes.max(len); // one live record at a time
        s.peak_bytes = s.peak_bytes.max(s.current_bytes);
        s.current_alloc_bytes = 4096;
        s.peak_alloc_bytes = s.peak_alloc_bytes.max(4096);
    }
}

fn remove_ckpt(bcfg: &BbcpConfig, name: &str, stats: &Mutex<SpaceStats>) {
    let _ = std::fs::remove_file(ckpt_path(bcfg, name));
    let mut s = stats.lock().unwrap_or_else(|e| e.into_inner());
    s.current_bytes = 0;
    s.current_alloc_bytes = 0;
}

/// In-flight byte window (the TCP window stand-in).
struct Window {
    inflight: Mutex<u64>,
    cv: Condvar,
    cap: u64,
}

impl Window {
    fn new(cap: u64) -> Self {
        Window { inflight: Mutex::new(0), cv: Condvar::new(), cap }
    }

    fn acquire(&self, bytes: u64, aborted: &AtomicBool) -> bool {
        let mut g = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
        while *g + bytes > self.cap {
            if aborted.load(Ordering::SeqCst) {
                return false;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(g, Duration::from_millis(20))
                .unwrap_or_else(|e| e.into_inner());
            g = guard;
        }
        *g += bytes;
        true
    }

    fn release(&self, bytes: u64) {
        let mut g = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
        *g = g.saturating_sub(bytes);
        drop(g);
        self.cv.notify_all();
    }
}

/// Run a bbcp-model transfer over the channel transport. Returns the same
/// outcome shape as the LADS coordinator so benches treat both uniformly
/// (`log_space` carries checkpoint-record accounting).
pub fn run_bbcp(
    cfg: &Config,
    bcfg: &BbcpConfig,
    source_pfs: Arc<dyn Pfs>,
    sink_pfs: Arc<dyn Pfs>,
    files: &[String],
    fault: FaultPlan,
) -> Result<TransferOutcome> {
    std::fs::create_dir_all(&bcfg.ckpt_dir)
        .with_context(|| format!("creating ckpt dir {}", bcfg.ckpt_dir.display()))?;

    let mut total_bytes = 0u64;
    for name in files {
        let (_, meta) = source_pfs
            .lookup(name)
            .ok_or_else(|| anyhow::anyhow!("file '{name}' not on source PFS"))?;
        total_bytes += meta.size;
    }
    let fault_ctl = fault.arm(total_bytes);
    let (src_ep, sink_ep) = channel::pair(cfg.wire(), fault_ctl);
    let src_ep: Arc<dyn Endpoint> = Arc::new(src_ep);
    let sink_ep: Arc<dyn Endpoint> = Arc::new(sink_ep);

    let sampler = Sampler::start(Duration::from_millis(20));
    let started = Instant::now();
    let counters = Arc::new(Counters::default());
    let sink_counters = Arc::new(Counters::default());

    // Sink: single service thread (bbcp's target side has no layout
    // scheduling — writes land in arrival order).
    let sink_thread = {
        let pfs = sink_pfs.clone();
        let ep = sink_ep.clone();
        let ctr = sink_counters.clone();
        std::thread::Builder::new()
            .name("bbcp-sink".into())
            .spawn(move || bbcp_sink(&*pfs, &*ep, &ctr))?
    };

    let space = Mutex::new(SpaceStats::default());
    let result = bbcp_source(bcfg, &*source_pfs, src_ep.clone(), files, &counters, &space);
    let _ = sink_thread.join();

    let elapsed = started.elapsed();
    let resources = sampler.finish();
    let fault_msg = result.err().map(|e: anyhow::Error| e.to_string());
    let log_space = *space.lock().unwrap_or_else(|e| e.into_inner());

    Ok(TransferOutcome {
        completed: fault_msg.is_none(),
        fault: fault_msg,
        elapsed,
        source: counters.snapshot(),
        sink: sink_counters.snapshot(),
        log_space,
        resources,
        payload_bytes: src_ep.payload_sent(),
        rma_stalls_src: (0, 0),
        rma_stalls_snk: (0, 0),
        source_sched: Default::default(),
        sink_sched: Default::default(),
        send_window: 1,
        send_window_effective: 1,
        ack_batch_effective: 1,
        rma_bytes_effective: 0, // bbcp has no RMA slot pool
        data_streams: 1,
        tune_epochs: 0, // bbcp has no online autotuner
        tune_grows: 0,
        tune_shrinks: 0,
        tune_reverts: 0,
        goodput_final: 0.0,
        tune_trajectory: Vec::new(),
    })
}

fn bbcp_sink(pfs: &dyn Pfs, ep: &dyn Endpoint, ctr: &Counters) {
    let mut current: Option<crate::pfs::FileId> = None;
    loop {
        let msg = match ep.recv_timeout(Duration::from_millis(100)) {
            Ok(m) => m,
            Err(NetError::Timeout) => continue,
            Err(_) => break,
        };
        match msg {
            Message::Connect { .. } => {
                let _ = ep.send(Message::ConnectAck {
                    rma_slots: 0,
                    ack_batch: 1,
                    send_window: 1,
                    data_streams: 1,
                });
            }
            Message::NewFile { file_idx, name, size, start_ost } => {
                // bbcp resume: attributes identical -> assume completed.
                if let Some((_, meta)) = pfs.lookup(&name) {
                    if meta.committed && meta.size == size {
                        let _ =
                            ep.send(Message::FileId { file_idx, sink_fd: 0, skip: true });
                        continue;
                    }
                }
                let fid = match pfs.lookup(&name) {
                    Some((fid, _)) => fid,
                    None => match pfs.create(&name, size, start_ost) {
                        Ok(fid) => fid,
                        Err(_) => break,
                    },
                };
                current = Some(fid);
                let _ = ep.send(Message::FileId { file_idx, sink_fd: fid.0, skip: false });
            }
            Message::NewBlock { file_idx, block_idx, offset, data, .. } => {
                let Some(fid) = current else { break };
                let len = data.len() as u64;
                // bbcp has no read-back verification: the fidelity flag is
                // deliberately ignored (§3.2's silent-corruption window).
                if pfs.write_at(fid, offset, data.as_slice()).is_err() {
                    break;
                }
                ctr.write_syscalls.fetch_add(1, Ordering::Relaxed);
                ctr.bytes_written.fetch_add(len, Ordering::Relaxed);
                ctr.objects_synced.fetch_add(1, Ordering::Relaxed);
                let _ = ep.send(Message::BlockSync { file_idx, block_idx, ok: true });
            }
            Message::FileClose { file_idx } => {
                if let Some(fid) = current.take() {
                    let _ = pfs.commit_file(fid);
                    ctr.files_completed.fetch_add(1, Ordering::Relaxed);
                }
                let _ = ep.send(Message::FileCloseAck { file_idx });
            }
            Message::Bye => break,
            _ => break,
        }
    }
}

fn bbcp_source(
    bcfg: &BbcpConfig,
    pfs: &dyn Pfs,
    ep: Arc<dyn Endpoint>,
    files: &[String],
    ctr: &Arc<Counters>,
    space: &Mutex<SpaceStats>,
) -> Result<()> {
    ep.send(Message::Connect {
        max_object_size: bcfg.block_size,
        rma_slots: 0,
        resume: false,
        ack_batch: 1,
        send_window: 1,
        data_streams: 1,
        job: 0,
    })
    .map_err(|e| anyhow::anyhow!("connect: {e}"))?;
    match ep.recv_timeout(Duration::from_secs(10)) {
        Ok(Message::ConnectAck { .. }) => {}
        other => anyhow::bail!("handshake failed: {other:?}"),
    }

    for (idx, name) in files.iter().enumerate() {
        let (fid, meta) = pfs
            .lookup(name)
            .ok_or_else(|| anyhow::anyhow!("'{name}' not on source"))?;
        let file_idx = idx as u32;

        // Resume decision (paper: ckpt record > attribute match > fresh).
        let ckpt = read_ckpt(bcfg, name);
        ep.send(Message::NewFile {
            file_idx,
            name: name.clone(),
            size: meta.size,
            start_ost: meta.start_ost,
        })
        .map_err(|e| anyhow::anyhow!("NEW_FILE: {e}"))?;
        let skip = loop {
            match ep.recv_timeout(Duration::from_secs(10)) {
                Ok(Message::FileId { skip, .. }) => break skip,
                Ok(Message::BlockSync { .. }) => continue, // stale ack
                Ok(m) => anyhow::bail!("unexpected {}", m.type_name()),
                Err(e) => anyhow::bail!("FILE_ID: {e}"),
            }
        };
        if skip {
            if ckpt.is_some() {
                remove_ckpt(bcfg, name, space);
            }
            ctr.files_skipped_resume.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        let start_offset = ckpt.unwrap_or(0).min(meta.size);
        if start_offset > 0 {
            let saved = start_offset / bcfg.block_size;
            ctr.objects_skipped_resume.fetch_add(saved, Ordering::Relaxed);
        }

        transfer_file_streams(
            bcfg,
            pfs,
            &ep,
            file_idx,
            name,
            fid,
            meta.size,
            start_offset,
            ctr,
            space,
        )?;

        ep.send(Message::FileClose { file_idx })
            .map_err(|e| anyhow::anyhow!("FILE_CLOSE: {e}"))?;
        loop {
            match ep.recv_timeout(Duration::from_secs(10)) {
                Ok(Message::FileCloseAck { .. }) => break,
                Ok(Message::BlockSync { .. }) => continue,
                Ok(m) => anyhow::bail!("unexpected {}", m.type_name()),
                Err(e) => anyhow::bail!("FILE_CLOSE_ACK: {e}"),
            }
        }
        remove_ckpt(bcfg, name, space);
        ctr.files_completed.fetch_add(1, Ordering::Relaxed);
    }
    let _ = ep.send(Message::Bye);
    Ok(())
}

/// Pipeline one file's blocks through `streams` sender threads inside the
/// window, acking on the calling thread and advancing the checkpoint.
#[allow(clippy::too_many_arguments)]
fn transfer_file_streams(
    bcfg: &BbcpConfig,
    pfs: &dyn Pfs,
    ep: &Arc<dyn Endpoint>,
    file_idx: u32,
    name: &str,
    fid: crate::pfs::FileId,
    size: u64,
    start_offset: u64,
    ctr: &Arc<Counters>,
    space: &Mutex<SpaceStats>,
) -> Result<()> {
    let window = Arc::new(Window::new(bcfg.window_bytes));
    let next = Arc::new(AtomicU64::new(start_offset));
    let aborted = Arc::new(AtomicBool::new(false));
    let abort_msg: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));

    let total_blocks = crate::util::div_ceil(size - start_offset, bcfg.block_size);
    if total_blocks == 0 {
        return Ok(());
    }

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for s in 0..bcfg.streams {
            let window = window.clone();
            let next = next.clone();
            let aborted = aborted.clone();
            let abort_msg = abort_msg.clone();
            let ep = ep.clone();
            let ctr = ctr.clone();
            let block = bcfg.block_size;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("bbcp-stream-{s}"))
                    .spawn_scoped(scope, move || loop {
                        if aborted.load(Ordering::SeqCst) {
                            break;
                        }
                        let offset = next.fetch_add(block, Ordering::SeqCst);
                        if offset >= size {
                            break;
                        }
                        let len = (size - offset).min(block) as usize;
                        if !window.acquire(len as u64, &aborted) {
                            break;
                        }
                        let mut buf = vec![0u8; len];
                        match pfs.read_at(fid, offset, &mut buf) {
                            Ok(n) if n == len => {}
                            _ => {
                                aborted.store(true, Ordering::SeqCst);
                                break;
                            }
                        }
                        let block_idx = (offset / block) as u32;
                        match ep.send(Message::NewBlock {
                            file_idx,
                            block_idx,
                            offset,
                            digest: 0, // bbcp has no object integrity digest
                            data: buf.into(),
                        }) {
                            Ok(()) => {
                                ctr.objects_sent.fetch_add(1, Ordering::Relaxed);
                                ctr.bytes_sent.fetch_add(len as u64, Ordering::Relaxed);
                            }
                            Err(e) => {
                                let mut g =
                                    abort_msg.lock().unwrap_or_else(|p| p.into_inner());
                                if g.is_none() {
                                    *g = Some(e.to_string());
                                }
                                aborted.store(true, Ordering::SeqCst);
                                break;
                            }
                        }
                    })?,
            );
        }

        // Ack loop: advance the contiguous watermark + overwrite the ckpt.
        let mut acked: BTreeSet<u64> = BTreeSet::new();
        let mut watermark = start_offset;
        let mut acked_blocks = 0u64;
        while acked_blocks < total_blocks {
            if aborted.load(Ordering::SeqCst) {
                break;
            }
            match ep.recv_timeout(Duration::from_millis(100)) {
                Ok(Message::BlockSync { block_idx, ok: true, .. }) => {
                    let offset = block_idx as u64 * bcfg.block_size;
                    let len = (size - offset).min(bcfg.block_size);
                    window.release(len);
                    acked.insert(offset);
                    acked_blocks += 1;
                    ctr.objects_synced.fetch_add(1, Ordering::Relaxed);
                    // Advance the contiguous prefix.
                    let mut advanced = false;
                    while acked.remove(&watermark) {
                        watermark += (size - watermark).min(bcfg.block_size);
                        advanced = true;
                    }
                    if advanced {
                        write_ckpt(bcfg, name, watermark, space);
                    }
                }
                Ok(Message::BlockSync { ok: false, .. }) => {
                    aborted.store(true, Ordering::SeqCst);
                    break;
                }
                Ok(_) => continue,
                Err(NetError::Timeout) => continue,
                Err(e) => {
                    let mut g = abort_msg.lock().unwrap_or_else(|p| p.into_inner());
                    if g.is_none() {
                        *g = Some(e.to_string());
                    }
                    aborted.store(true, Ordering::SeqCst);
                    break;
                }
            }
        }
        let fully_acked = acked_blocks >= total_blocks;
        aborted.store(true, Ordering::SeqCst); // release stragglers
        for h in handles {
            let _ = h.join();
        }
        let msg = abort_msg.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(m) = msg {
            anyhow::bail!("{m}");
        }
        if !fully_acked {
            anyhow::bail!("transfer aborted at watermark {watermark}");
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SimEnv;
    use crate::net::Side;
    use crate::workload;

    fn env(tag: &str, files: usize, size: u64) -> SimEnv {
        let cfg = Config::for_tests(tag);
        let wl = workload::big_workload(files, size);
        SimEnv::new(cfg, &wl)
    }

    fn bcfg(env: &SimEnv) -> BbcpConfig {
        BbcpConfig::paper_defaults(&env.cfg)
    }

    #[test]
    fn bbcp_transfers_dataset() {
        let env = env("bbcp1", 3, 256 << 10);
        let out = run_bbcp(
            &env.cfg,
            &bcfg(&env),
            env.source.clone(),
            env.sink.clone(),
            &env.files,
            FaultPlan::none(),
        )
        .unwrap();
        assert!(out.completed, "{:?}", out.fault);
        assert_eq!(out.sink.files_completed, 3);
        // bbcp writes once per block — the summary's write-path line
        // must report it (no coalescing in the baseline).
        assert_eq!(out.sink.write_syscalls, out.sink.objects_synced);
        env.verify_sink_complete().unwrap();
    }

    #[test]
    fn bbcp_fault_leaves_ckpt_and_resume_appends() {
        let env = env("bbcp2", 4, 512 << 10);
        let b = bcfg(&env);
        let out = run_bbcp(
            &env.cfg,
            &b,
            env.source.clone(),
            env.sink.clone(),
            &env.files,
            FaultPlan::at_fraction(0.5, Side::Source),
        )
        .unwrap();
        assert!(!out.completed);
        // At most the in-flight file has a checkpoint record.
        let ckpts = std::fs::read_dir(&b.ckpt_dir).unwrap().count();
        assert!(ckpts <= 1);
        let out2 = run_bbcp(
            &env.cfg,
            &b,
            env.source.clone(),
            env.sink.clone(),
            &env.files,
            FaultPlan::none(),
        )
        .unwrap();
        assert!(out2.completed, "{:?}", out2.fault);
        // Completed files skipped by attribute match.
        assert!(out2.source.files_skipped_resume > 0);
        env.verify_sink_complete().unwrap();
        assert_eq!(std::fs::read_dir(&b.ckpt_dir).unwrap().count(), 0);
    }

    #[test]
    fn bbcp_all_objects_acked() {
        let env = env("bbcp3", 3, 128 << 10);
        let out = run_bbcp(
            &env.cfg,
            &bcfg(&env),
            env.source.clone(),
            env.sink.clone(),
            &env.files,
            FaultPlan::none(),
        )
        .unwrap();
        assert!(out.completed);
        assert_eq!(out.source.objects_sent, out.source.objects_synced);
    }
}
