//! Baseline data movers (bbcp model).

pub mod bbcp;
