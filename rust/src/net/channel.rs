//! In-process transport: two endpoints joined by std mpsc channels, with
//! a modeled wire (latency + bandwidth) and fault-controller hooks.
//!
//! This is the Verbs-like path: messages move as structured values with
//! zero-copy buffer handoff (the refcounted `Bytes` in NEW_BLOCK passes
//! by refcount — the receiver's view IS the sender's registered RMA
//! buffer, which returns to its pool when the sink drops the last ref),
//! mirroring how CCI's RMA hands a registered buffer to the peer. The
//! modeled wire charges serialization time proportional to payload size
//! so bandwidth-bound behaviour is preserved.

use std::sync::mpsc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::message::Message;
use super::{Endpoint, FaultController, NetError, Side, WireModel};

pub struct ChannelEndpoint {
    side: Side,
    tx: mpsc::Sender<Message>,
    rx: Mutex<mpsc::Receiver<Message>>,
    wire: WireModel,
    fault: Arc<FaultController>,
    sent_payload: AtomicU64,
}

/// Create a connected (source, sink) endpoint pair.
pub fn pair(
    wire: WireModel,
    fault: Arc<FaultController>,
) -> (ChannelEndpoint, ChannelEndpoint) {
    let (tx_a, rx_b) = mpsc::channel();
    let (tx_b, rx_a) = mpsc::channel();
    let a = ChannelEndpoint {
        side: Side::Source,
        tx: tx_a,
        rx: Mutex::new(rx_a),
        wire: wire.clone(),
        fault: fault.clone(),
        sent_payload: AtomicU64::new(0),
    };
    let b = ChannelEndpoint {
        side: Side::Sink,
        tx: tx_b,
        rx: Mutex::new(rx_b),
        wire,
        fault,
        sent_payload: AtomicU64::new(0),
    };
    (a, b)
}

impl ChannelEndpoint {
    fn check_fault(&self) -> Result<(), NetError> {
        if self.fault.is_tripped() {
            Err(NetError::Fault(format!(
                "injected fault ({} side) after {} payload bytes",
                self.fault.side,
                self.fault.payload_so_far()
            )))
        } else {
            Ok(())
        }
    }
}

impl Endpoint for ChannelEndpoint {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        self.check_fault()?;
        let payload = msg.payload_len();
        // Charge the wire before delivery (sender-side serialization).
        let delay = self.wire.delay_for(payload);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        // Fault accounting: only data crossing source -> sink counts
        // toward the "X% of total data transferred" fault point.
        if self.side == Side::Source && payload > 0 {
            self.sent_payload.fetch_add(payload as u64, Ordering::Relaxed);
            if self.fault.account(payload as u64) {
                return Err(NetError::Fault(format!(
                    "injected fault ({} side) after {} payload bytes",
                    self.fault.side,
                    self.fault.payload_so_far()
                )));
            }
        } else if payload > 0 {
            self.sent_payload.fetch_add(payload as u64, Ordering::Relaxed);
        }
        self.tx.send(msg).map_err(|_| NetError::Closed)
    }

    fn recv(&self) -> Result<Message, NetError> {
        // Poll with a short tick so a fault trip interrupts a blocked recv
        // (a severed link kills in-flight receives too).
        let rx = self.rx.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            self.check_fault()?;
            match rx.recv_timeout(Duration::from_millis(5)) {
                Ok(m) => return Ok(m),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => return Err(NetError::Closed),
            }
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        let deadline = std::time::Instant::now() + timeout;
        let rx = self.rx.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            self.check_fault()?;
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(NetError::Timeout);
            }
            let tick = (deadline - now).min(Duration::from_millis(5));
            match rx.recv_timeout(tick) {
                Ok(m) => return Ok(m),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => return Err(NetError::Closed),
            }
        }
    }

    fn payload_sent(&self) -> u64 {
        self.sent_payload.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_pair() -> (ChannelEndpoint, ChannelEndpoint) {
        pair(WireModel::none(), FaultController::unarmed())
    }

    #[test]
    fn send_recv_roundtrip() {
        let (src, sink) = fast_pair();
        src.send(Message::Connect {
            max_object_size: 4,
            rma_slots: 1,
            resume: false,
            ack_batch: 1,
            send_window: 1,
            data_streams: 1,
            job: 0,
        })
        .unwrap();
        let m = sink.recv().unwrap();
        assert_eq!(m.type_name(), "CONNECT");
        sink.send(Message::ConnectAck {
            rma_slots: 2,
            ack_batch: 1,
            send_window: 1,
            data_streams: 1,
        })
        .unwrap();
        assert_eq!(src.recv().unwrap().type_name(), "CONNECT_ACK");
    }

    #[test]
    fn payload_passes_by_refcount_not_copy() {
        // The receiver's payload view is the sender's buffer: same
        // allocation, zero bytes moved in transit.
        let (src, sink) = fast_pair();
        let payload = crate::util::bytes::Bytes::from_vec((0..100u8).collect());
        let sent_ptr = payload.as_slice().as_ptr() as usize;
        src.send(Message::NewBlock {
            file_idx: 0,
            block_idx: 0,
            offset: 0,
            digest: 0,
            data: payload,
        })
        .unwrap();
        match sink.recv().unwrap() {
            Message::NewBlock { data, .. } => {
                assert_eq!(data.as_slice().as_ptr() as usize, sent_ptr);
                assert_eq!(data, (0..100u8).collect::<Vec<_>>());
            }
            m => panic!("unexpected {m:?}"),
        }
    }

    #[test]
    fn messages_preserve_order() {
        let (src, sink) = fast_pair();
        for i in 0..100 {
            src.send(Message::FileClose { file_idx: i }).unwrap();
        }
        for i in 0..100 {
            match sink.recv().unwrap() {
                Message::FileClose { file_idx } => assert_eq!(file_idx, i),
                m => panic!("unexpected {m:?}"),
            }
        }
    }

    #[test]
    fn recv_timeout_expires() {
        let (src, _sink) = fast_pair();
        let t0 = std::time::Instant::now();
        assert_eq!(
            src.recv_timeout(Duration::from_millis(30)),
            Err(NetError::Timeout)
        );
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn drop_peer_closes() {
        let (src, sink) = fast_pair();
        drop(sink);
        assert_eq!(src.send(Message::Bye), Err(NetError::Closed));
    }

    #[test]
    fn fault_kills_send_at_threshold() {
        let fault = FaultController::armed(100, Side::Source);
        let (src, sink) = pair(WireModel::none(), fault);
        let block = |n: u32| Message::NewBlock {
            file_idx: 0,
            block_idx: n,
            offset: 0,
            digest: 0,
            data: vec![0; 60].into(),
        };
        src.send(block(0)).unwrap(); // 60 bytes: under threshold
        assert!(matches!(src.send(block(1)), Err(NetError::Fault(_)))); // 120
        // Both directions now dead.
        assert!(matches!(sink.send(Message::Bye), Err(NetError::Fault(_))));
        assert!(matches!(sink.recv(), Err(NetError::Fault(_))));
    }

    #[test]
    fn fault_interrupts_blocked_recv() {
        let fault = FaultController::unarmed();
        let (src, _sink) = pair(WireModel::none(), fault.clone());
        let h = std::thread::spawn(move || src.recv());
        std::thread::sleep(Duration::from_millis(20));
        fault.trip();
        assert!(matches!(h.join().unwrap(), Err(NetError::Fault(_))));
    }

    #[test]
    fn control_messages_do_not_count_toward_fault() {
        let fault = FaultController::armed(10, Side::Source);
        let (src, _sink) = pair(WireModel::none(), fault.clone());
        for _ in 0..50 {
            src.send(Message::BlockSync { file_idx: 0, block_idx: 0, ok: true })
                .unwrap();
        }
        assert!(!fault.is_tripped());
        assert_eq!(src.payload_sent(), 0);
    }

    #[test]
    fn wire_model_charges_payload() {
        let wire = WireModel {
            latency: Duration::ZERO,
            bandwidth: 1e6, // 1 MB/s
            time_scale: 1.0,
        };
        let (src, sink) = pair(wire, FaultController::unarmed());
        let t0 = std::time::Instant::now();
        src.send(Message::NewBlock {
            file_idx: 0,
            block_idx: 0,
            offset: 0,
            digest: 0,
            data: vec![0; 50_000].into(), // 50 ms at 1 MB/s
        })
        .unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(45));
        sink.recv().unwrap();
    }
}
