//! Adversarial-network transport adapter: a deterministic, seeded
//! torture layer over any [`Endpoint`].
//!
//! [`AdversaryEndpoint`] wraps one side of a connection and perturbs its
//! *send* path per message class — delay into a bounded reorder window,
//! duplicate, drop (handshake class only), and timed partition/heal —
//! according to a [`TortureSpec`]. Wrapping both endpoints of a pair
//! tortures both directions. The receive path is passthrough except
//! that every `recv`/`recv_timeout` call advances the endpoint's
//! logical clock and flushes any held-back traffic that has come due,
//! so delayed and partitioned messages always drain as long as *someone*
//! polls the endpoint (every coordinator comm thread does, on a 50 ms
//! tick).
//!
//! **Determinism.** Each endpoint derives a private PCG32 stream from
//! `(spec.seed, side, stream id)` and draws verdicts only on sends, so
//! the i-th message sent on a given endpoint receives an identical
//! verdict (drop / duplicate / delay distance / partition entry) on
//! every run with the same seed. Release *timing* of held traffic rides
//! the logical clock, which also counts receive polls — schedules are
//! decision-deterministic always, and byte-for-byte reproducible for
//! specs without delay/partition (e.g. the "dup" profile).
//!
//! **Liveness rules** (why torture runs cannot deadlock):
//!
//! - Control-class messages (NEW_FILE, FILE_ID, FILE_CLOSE,
//!   FILE_CLOSE_ACK, BYE) are never dropped, duplicated, or held; each
//!   acts as a barrier that first flushes everything pending, so the
//!   protocol's ordering-sensitive spine is delivered exactly once, in
//!   order, relative to itself.
//! - Drops apply only to the handshake class, which the hardened
//!   endpoints retry (`connect_retries`).
//! - Partitions defer (in order) rather than drop, and heal on the
//!   logical clock.
//! - A [`TortureSpec::cut_stream`] cut makes the endpoint behave like a
//!   severed connection (`NetError::Closed`) — the stream-failover and
//!   clean-fault paths take over from there.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::TortureSpec;
use crate::testutil::Pcg32;

use super::{Endpoint, Message, NetError, Side};

/// Which torture policy a message falls under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MsgClass {
    /// CONNECT / CONNECT_ACK / STREAM_HELLO — droppable (retried).
    Handshake,
    /// NEW_BLOCK — dup/delay (receiver dedups by (fid, block)).
    Data,
    /// BLOCK_SYNC / BLOCK_SYNC_BATCH — dup/delay (sender dedups).
    Ack,
    /// Everything else — never perturbed, flushes pending traffic.
    Control,
}

fn class_of(msg: &Message) -> MsgClass {
    match msg {
        Message::Connect { .. } | Message::ConnectAck { .. } | Message::StreamHello { .. } => {
            MsgClass::Handshake
        }
        Message::NewBlock { .. } => MsgClass::Data,
        Message::BlockSync { .. } | Message::BlockSyncBatch { .. } => MsgClass::Ack,
        _ => MsgClass::Control,
    }
}

/// Counters for what the adversary actually did (per endpoint).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdversaryStats {
    pub dropped: u64,
    pub duplicated: u64,
    pub delayed: u64,
    pub partitions: u64,
}

struct AdvState {
    rng: Pcg32,
    /// Held-back (delayed) messages: (due logical tick, insertion seq,
    /// message), flushed in (due, seq) order once due.
    held: Vec<(u64, u64, Message)>,
    held_seq: u64,
    /// Partition buffer — deferred in order, released on heal.
    deferred: VecDeque<Message>,
    /// Logical tick the current partition heals at (0 = no partition).
    heal_at: u64,
    /// Data/ack sends since the last partition began.
    sends_since_partition: u64,
    stats: AdversaryStats,
}

/// The torture adapter. See the module docs for semantics.
pub struct AdversaryEndpoint {
    inner: Arc<dyn Endpoint>,
    spec: TortureSpec,
    /// Data stream id (None = the control connection, never cut).
    stream: Option<u32>,
    /// Logical clock: advances on every send and every receive poll.
    ops: AtomicU64,
    cut: std::sync::atomic::AtomicBool,
    st: Mutex<AdvState>,
}

impl AdversaryEndpoint {
    pub fn new(
        inner: Arc<dyn Endpoint>,
        spec: TortureSpec,
        side: Side,
        stream: Option<u32>,
    ) -> AdversaryEndpoint {
        // Private verdict stream per endpoint: same seed → same
        // schedule, different endpoints → independent schedules.
        let tag = ((side == Side::Sink) as u64) << 32
            | stream.map(|s| s as u64 + 1).unwrap_or(0);
        AdversaryEndpoint {
            inner,
            stream,
            ops: AtomicU64::new(0),
            cut: std::sync::atomic::AtomicBool::new(false),
            st: Mutex::new(AdvState {
                rng: Pcg32::with_stream(spec.seed, tag),
                held: Vec::new(),
                held_seq: 0,
                deferred: VecDeque::new(),
                heal_at: 0,
                sends_since_partition: 0,
                stats: AdversaryStats::default(),
            }),
            spec,
        }
    }

    pub fn stats(&self) -> AdversaryStats {
        self.st.lock().unwrap_or_else(|e| e.into_inner()).stats
    }

    /// Advance the logical clock; returns Closed once the cut tripped.
    fn tick(&self) -> Result<u64, NetError> {
        let now = self.ops.fetch_add(1, Ordering::Relaxed) + 1;
        if self.cut.load(Ordering::Relaxed) {
            return Err(NetError::Closed);
        }
        if let Some(cut) = self.spec.cut_stream {
            if self.stream == Some(cut) && now >= self.spec.cut_after_ops.max(1) {
                self.cut.store(true, Ordering::Relaxed);
                return Err(NetError::Closed);
            }
        }
        Ok(now)
    }

    /// Forward everything whose time has come: a healed partition's
    /// deferred run (in order), then held messages due by `now`.
    fn flush_due(&self, st: &mut AdvState, now: u64, all: bool) -> Result<(), NetError> {
        if !st.deferred.is_empty() && (all || (st.heal_at != 0 && now >= st.heal_at)) {
            st.heal_at = 0;
            while let Some(m) = st.deferred.pop_front() {
                self.inner.send(m)?;
            }
        }
        if !st.held.is_empty() && (all || st.held.iter().any(|(due, _, _)| *due <= now)) {
            let mut due_now: Vec<(u64, u64, Message)> = Vec::new();
            st.held.retain_mut(|entry| {
                if all || entry.0 <= now {
                    due_now.push((entry.0, entry.1, entry.2.clone()));
                    false
                } else {
                    true
                }
            });
            due_now.sort_by_key(|(due, seq, _)| (*due, *seq));
            for (_, _, m) in due_now {
                self.inner.send(m)?;
            }
        }
        Ok(())
    }
}

impl Endpoint for AdversaryEndpoint {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        let now = self.tick()?;
        let mut st = self.st.lock().unwrap_or_else(|e| e.into_inner());
        let class = class_of(&msg);
        match class {
            MsgClass::Control => {
                // Barrier: everything pending goes first, then the
                // control message itself — exactly once, unperturbed.
                self.flush_due(&mut st, now, true)?;
                self.inner.send(msg)
            }
            MsgClass::Handshake => {
                self.flush_due(&mut st, now, false)?;
                let drop_it = st.rng.bool(self.spec.drop_handshake);
                let dup_it = st.rng.bool(self.spec.dup_handshake);
                if drop_it {
                    st.stats.dropped += 1;
                    return Ok(());
                }
                self.inner.send(msg.clone())?;
                if dup_it {
                    st.stats.duplicated += 1;
                    self.inner.send(msg)?;
                }
                Ok(())
            }
            MsgClass::Data | MsgClass::Ack => {
                self.flush_due(&mut st, now, false)?;
                let (p_dup, p_delay) = match class {
                    MsgClass::Data => (self.spec.dup_data, self.spec.delay_data),
                    _ => (self.spec.dup_ack, self.spec.delay_ack),
                };
                // Draw every verdict up front so the decision stream
                // stays positionally aligned across code paths.
                let dup_it = st.rng.bool(p_dup);
                let delay_it = st.rng.bool(p_delay);
                let delay_by = 1 + st.rng.below(self.spec.reorder_window.max(1)) as u64;
                if st.heal_at != 0 {
                    // Mid-partition: defer in order.
                    st.deferred.push_back(msg);
                    return Ok(());
                }
                if self.spec.partition_every > 0 {
                    st.sends_since_partition += 1;
                    if st.sends_since_partition >= self.spec.partition_every {
                        st.sends_since_partition = 0;
                        st.heal_at = now + self.spec.partition_len.max(1);
                        st.stats.partitions += 1;
                        st.deferred.push_back(msg);
                        return Ok(());
                    }
                }
                if delay_it {
                    st.stats.delayed += 1;
                    let seq = st.held_seq;
                    st.held_seq += 1;
                    st.held.push((now + delay_by, seq, msg));
                    return Ok(());
                }
                self.inner.send(msg.clone())?;
                if dup_it {
                    st.stats.duplicated += 1;
                    self.inner.send(msg)?;
                }
                Ok(())
            }
        }
    }

    fn recv(&self) -> Result<Message, NetError> {
        let now = self.tick()?;
        {
            let mut st = self.st.lock().unwrap_or_else(|e| e.into_inner());
            self.flush_due(&mut st, now, false)?;
        }
        // The lock is NOT held across the blocking receive: senders on
        // other threads must stay free to make progress.
        self.inner.recv()
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        let now = self.tick()?;
        {
            let mut st = self.st.lock().unwrap_or_else(|e| e.into_inner());
            self.flush_due(&mut st, now, false)?;
        }
        self.inner.recv_timeout(timeout)
    }

    fn payload_sent(&self) -> u64 {
        self.inner.payload_sent()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{channel, FaultController, WireModel};

    fn torture_pair(
        spec: &TortureSpec,
        stream: Option<u32>,
    ) -> (AdversaryEndpoint, Arc<dyn Endpoint>) {
        let (a, b) = channel::pair(WireModel::none(), FaultController::unarmed());
        let src = AdversaryEndpoint::new(Arc::new(a), spec.clone(), Side::Source, stream);
        (src, Arc::new(b) as Arc<dyn Endpoint>)
    }

    fn block(n: u32) -> Message {
        Message::NewBlock {
            file_idx: 0,
            block_idx: n,
            offset: 0,
            digest: 0,
            data: vec![n as u8; 4].into(),
        }
    }

    fn drain(ep: &Arc<dyn Endpoint>) -> Vec<Message> {
        let mut out = Vec::new();
        while let Ok(m) = ep.recv_timeout(Duration::from_millis(20)) {
            out.push(m);
        }
        out
    }

    #[test]
    fn quiet_spec_is_passthrough() {
        let (src, sink) = torture_pair(&TortureSpec::quiet(1), None);
        for i in 0..10 {
            src.send(block(i)).unwrap();
        }
        let got = drain(&sink);
        assert_eq!(got.len(), 10);
        for (i, m) in got.iter().enumerate() {
            match m {
                Message::NewBlock { block_idx, .. } => assert_eq!(*block_idx, i as u32),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(src.stats(), AdversaryStats::default());
    }

    #[test]
    fn dup_profile_duplicates_deterministically() {
        let spec = TortureSpec::profile("dup", 42).unwrap().unwrap();
        let run = |spec: &TortureSpec| {
            let (src, sink) = torture_pair(spec, None);
            for i in 0..64 {
                src.send(block(i)).unwrap();
            }
            let frames: Vec<u32> = drain(&sink)
                .into_iter()
                .map(|m| match m {
                    Message::NewBlock { block_idx, .. } => block_idx,
                    other => panic!("unexpected {other:?}"),
                })
                .collect();
            (frames, src.stats())
        };
        let (frames_a, stats_a) = run(&spec);
        let (frames_b, stats_b) = run(&spec);
        // Same seed, same schedule — byte-for-byte.
        assert_eq!(frames_a, frames_b);
        assert_eq!(stats_a, stats_b);
        assert!(stats_a.duplicated > 0, "64 sends at p=0.3 must dup some");
        assert_eq!(frames_a.len() as u64, 64 + stats_a.duplicated);
        // A different seed produces a different schedule.
        let mut other = spec.clone();
        other.seed = 43;
        let (frames_c, _) = run(&other);
        assert_ne!(frames_a, frames_c);
    }

    #[test]
    fn control_message_flushes_held_traffic_first() {
        let mut spec = TortureSpec::quiet(7);
        spec.delay_data = 1.0; // hold every block
        spec.reorder_window = 1000; // far future: only the barrier flushes
        let (src, sink) = torture_pair(&spec, None);
        src.send(block(0)).unwrap();
        src.send(block(1)).unwrap();
        assert!(
            sink.recv_timeout(Duration::from_millis(20)).is_err(),
            "both blocks are held"
        );
        src.send(Message::FileClose { file_idx: 0 }).unwrap();
        let got = drain(&sink);
        assert_eq!(got.len(), 3);
        // Held traffic drains before the barrier, in order.
        assert!(matches!(got[0], Message::NewBlock { block_idx: 0, .. }));
        assert!(matches!(got[1], Message::NewBlock { block_idx: 1, .. }));
        assert!(matches!(got[2], Message::FileClose { file_idx: 0 }));
    }

    #[test]
    fn delayed_traffic_drains_on_receive_polls() {
        let mut spec = TortureSpec::quiet(7);
        spec.delay_data = 1.0;
        spec.reorder_window = 2;
        let (src, sink) = torture_pair(&spec, None);
        src.send(block(0)).unwrap();
        // The sender's own receive polling advances the clock past the
        // reorder window and flushes the held block.
        for _ in 0..4 {
            let _ = src.recv_timeout(Duration::from_millis(1));
        }
        let got = drain(&sink);
        assert_eq!(got.len(), 1, "held block must drain via polls: {got:?}");
    }

    #[test]
    fn partition_defers_then_heals_in_order() {
        let mut spec = TortureSpec::quiet(5);
        spec.partition_every = 3;
        spec.partition_len = 2;
        let (src, sink) = torture_pair(&spec, None);
        for i in 0..6 {
            src.send(block(i)).unwrap();
        }
        // Sends 0,1 pass; send 2 starts the partition (deferred); the
        // heal tick passes during sends 3/4 (also deferred until the
        // flush check), so everything arrives, in order, with no loss.
        for _ in 0..4 {
            let _ = src.recv_timeout(Duration::from_millis(1));
        }
        let got: Vec<u32> = drain(&sink)
            .into_iter()
            .map(|m| match m {
                Message::NewBlock { block_idx, .. } => block_idx,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
        assert!(src.stats().partitions >= 1);
    }

    #[test]
    fn handshake_drops_only_handshake_class() {
        let mut spec = TortureSpec::quiet(11);
        spec.drop_handshake = 1.0;
        let (src, sink) = torture_pair(&spec, None);
        src.send(Message::StreamHello { stream_id: 0, job: 0 }).unwrap();
        src.send(block(0)).unwrap();
        src.send(Message::Bye).unwrap();
        let got = drain(&sink);
        assert_eq!(got.len(), 2, "hello dropped, data+control delivered: {got:?}");
        assert!(matches!(got[0], Message::NewBlock { .. }));
        assert!(matches!(got[1], Message::Bye));
        assert_eq!(src.stats().dropped, 1);
    }

    #[test]
    fn cut_stream_severs_matching_stream_only() {
        let mut spec = TortureSpec::quiet(13);
        spec.cut_stream = Some(1);
        spec.cut_after_ops = 3;
        // Stream 1: cut after 3 ops, then permanently Closed.
        let (src, _sink) = torture_pair(&spec, Some(1));
        src.send(block(0)).unwrap();
        src.send(block(1)).unwrap();
        assert_eq!(src.send(block(2)), Err(NetError::Closed));
        assert_eq!(src.recv_timeout(Duration::from_millis(1)), Err(NetError::Closed));
        // Stream 0 and the control connection never cut.
        let (src0, sink0) = torture_pair(&spec, Some(0));
        let (ctrl, csink) = torture_pair(&spec, None);
        for i in 0..8 {
            src0.send(block(i)).unwrap();
            ctrl.send(block(i)).unwrap();
        }
        assert_eq!(drain(&sink0).len(), 8);
        assert_eq!(drain(&csink).len(), 8);
    }
}
