//! CCI-like transport layer.
//!
//! The paper's LADS communicates over CCI (Common Communication Interface)
//! with the Verbs transport on InfiniBand; bbcp uses IPoIB sockets. We
//! reproduce the *interface* LADS programs against — connect handshake,
//! active messages, RMA-read data movement, connection loss — behind the
//! [`Endpoint`] trait, with two backends:
//!
//! - [`channel::ChannelEndpoint`] — in-process, zero-copy handoff with a
//!   modeled wire (latency + bandwidth); the Verbs-like path.
//! - [`tcp::TcpEndpoint`] — real sockets over loopback with full
//!   serialization; the IPoIB-like path (used for the bbcp baseline so the
//!   baseline pays socket costs, as it does in the paper).
//!
//! Fault injection lives here too: a [`FaultController`] trips the
//! connection once a configured number of payload bytes has crossed the
//! wire — the simulation environment of paper §6 ("we generate faults
//! after transferring 20 %, 40 %, 60 %, 80 % of total data size") — and
//! an [`adversary::AdversaryEndpoint`] can wrap either backend with a
//! seeded deterministic torture policy (delay, duplicate, handshake
//! drop, partition/heal, stream cut) for protocol-hardening tests.

pub mod adversary;
pub mod channel;
pub mod message;
pub mod rma;
pub mod tcp;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

pub use message::Message;
pub use rma::{RmaPool, RmaSlot};

/// Transport errors.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum NetError {
    /// Orderly close (peer finished and went away after BYE).
    #[error("connection closed")]
    Closed,
    /// Abrupt connection loss — the injected (or real) fault.
    #[error("connection fault: {0}")]
    Fault(String),
    /// recv_timeout expired with no message.
    #[error("receive timeout")]
    Timeout,
}

/// One side of an established connection. Send is thread-safe; recv is
/// intended for the single comm thread that owns the endpoint.
pub trait Endpoint: Send + Sync {
    fn send(&self, msg: Message) -> Result<(), NetError>;
    fn recv(&self) -> Result<Message, NetError>;
    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError>;

    /// Payload bytes sent so far by THIS endpoint (NEW_BLOCK data only).
    fn payload_sent(&self) -> u64;
}

/// Which end of the transfer a component belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    Source,
    Sink,
}

impl std::fmt::Display for Side {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Side::Source => write!(f, "source"),
            Side::Sink => write!(f, "sink"),
        }
    }
}

/// Shared fault state for one connection: trips when cumulative payload
/// bytes cross `threshold`, after which every send/recv on either side
/// fails with `NetError::Fault`.
pub struct FaultController {
    threshold: AtomicU64,
    payload: AtomicU64,
    tripped: AtomicBool,
    /// Which side the fault is attributed to (reporting only — a severed
    /// link kills both directions either way).
    pub side: Side,
}

impl FaultController {
    pub fn unarmed() -> Arc<Self> {
        Arc::new(FaultController {
            threshold: AtomicU64::new(u64::MAX),
            payload: AtomicU64::new(0),
            tripped: AtomicBool::new(false),
            side: Side::Source,
        })
    }

    pub fn armed(threshold_bytes: u64, side: Side) -> Arc<Self> {
        Arc::new(FaultController {
            threshold: AtomicU64::new(threshold_bytes),
            payload: AtomicU64::new(0),
            tripped: AtomicBool::new(false),
            side,
        })
    }

    /// Account `bytes` of payload; returns true if the connection just
    /// tripped (or already was tripped).
    pub fn account(&self, bytes: u64) -> bool {
        if self.tripped.load(Ordering::SeqCst) {
            return true;
        }
        let total = self.payload.fetch_add(bytes, Ordering::SeqCst) + bytes;
        if total >= self.threshold.load(Ordering::SeqCst) {
            self.tripped.store(true, Ordering::SeqCst);
            return true;
        }
        false
    }

    pub fn is_tripped(&self) -> bool {
        self.tripped.load(Ordering::SeqCst)
    }

    /// Manually sever the connection (tests / CLI abort).
    pub fn trip(&self) {
        self.tripped.store(true, Ordering::SeqCst);
    }

    pub fn payload_so_far(&self) -> u64 {
        self.payload.load(Ordering::SeqCst)
    }
}

/// Wire model shared by both transports: a per-message latency plus a
/// bandwidth-proportional serialization delay, scaled by `time_scale`
/// (0 = no sleeping, pure logic).
#[derive(Debug, Clone)]
pub struct WireModel {
    pub latency: Duration,
    /// Bytes per second of the link (IB EDR-ish when scaled).
    pub bandwidth: f64,
    pub time_scale: f64,
}

impl Default for WireModel {
    fn default() -> Self {
        // Scaled stand-in for the paper's IB link: fast enough that the
        // storage (OST model) is the bottleneck, per §6.1 "the network
        // would not be the bottleneck".
        WireModel { latency: Duration::from_micros(15), bandwidth: 6.0e9, time_scale: 1.0 }
    }
}

impl WireModel {
    pub fn none() -> Self {
        WireModel { latency: Duration::ZERO, bandwidth: f64::INFINITY, time_scale: 0.0 }
    }

    pub fn delay_for(&self, payload: usize) -> Duration {
        if self.time_scale == 0.0 {
            return Duration::ZERO;
        }
        let secs = (self.latency.as_secs_f64() + payload as f64 / self.bandwidth)
            * self.time_scale;
        Duration::from_secs_f64(secs.max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_controller_trips_at_threshold() {
        let f = FaultController::armed(100, Side::Source);
        assert!(!f.account(40));
        assert!(!f.account(40));
        assert!(!f.is_tripped());
        assert!(f.account(40)); // 120 >= 100
        assert!(f.is_tripped());
        assert!(f.account(0), "stays tripped");
        assert_eq!(f.payload_so_far(), 120);
    }

    #[test]
    fn unarmed_never_trips() {
        let f = FaultController::unarmed();
        for _ in 0..1000 {
            assert!(!f.account(1 << 30));
        }
        assert!(!f.is_tripped());
    }

    #[test]
    fn manual_trip() {
        let f = FaultController::unarmed();
        f.trip();
        assert!(f.is_tripped());
        assert!(f.account(1));
    }

    #[test]
    fn wire_model_delay() {
        let m = WireModel { latency: Duration::from_millis(1), bandwidth: 1e6, time_scale: 1.0 };
        let d = m.delay_for(1_000_000);
        assert!((d.as_secs_f64() - 1.001).abs() < 1e-9);
        assert_eq!(WireModel::none().delay_for(1 << 20), Duration::ZERO);
    }
}
