//! TCP transport: real sockets, real serialization.
//!
//! The IPoIB-like path — every message is length-framed and byte-encoded
//! through the codec in [`super::message`]. Used for the bbcp baseline
//! (which in the paper runs over IPoIB sockets rather than Verbs) and for
//! the two-process deployment mode of the `ftlads` CLI.
//!
//! The [`FaultController`] hook severs the socket (shutdown both ways)
//! when the payload threshold trips, so connection loss manifests as real
//! I/O errors on both ends — the same observable the paper's simulated
//! hardware faults produce.
//!
//! Zero-copy framing: the send side encodes the length prefix + message
//! header into one scratch buffer reused per connection and puts the
//! payload on the wire with `write_vectored` straight from its
//! refcounted buffer — no per-message frame allocation, no payload
//! memcpy. The receive side reads each frame once and decodes it with
//! [`Message::decode_frame`], slicing the payload out refcounted. Wire
//! bytes are identical to the old contiguous-frame path.

use std::io::{IoSlice, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::message::Message;
use super::{Endpoint, FaultController, NetError, Side, WireModel};
use crate::util::bytes::Bytes;

/// The connection's write half plus its reusable header scratch buffer
/// (length prefix + encoded header; payloads never enter it).
struct WriteHalf {
    stream: TcpStream,
    scratch: Vec<u8>,
}

pub struct TcpEndpoint {
    side: Side,
    reader: Mutex<TcpStream>,
    writer: Mutex<WriteHalf>,
    stream: TcpStream, // kept for shutdown
    wire: WireModel,
    fault: Arc<FaultController>,
    sent_payload: AtomicU64,
}

/// Listen on `addr` (use port 0 for ephemeral) and return the bound
/// listener; `accept` completes the sink side.
pub fn listen(addr: &str) -> Result<TcpListener> {
    Ok(TcpListener::bind(addr)?)
}

pub fn accept(
    listener: &TcpListener,
    wire: WireModel,
    fault: Arc<FaultController>,
) -> Result<TcpEndpoint> {
    let (stream, _) = listener.accept()?;
    TcpEndpoint::new(Side::Sink, stream, wire, fault)
}

pub fn connect(
    addr: SocketAddr,
    wire: WireModel,
    fault: Arc<FaultController>,
) -> Result<TcpEndpoint> {
    let stream = TcpStream::connect(addr)?;
    TcpEndpoint::new(Side::Source, stream, wire, fault)
}

/// Convenience: a connected loopback pair (sink listener + source dial),
/// mirroring `channel::pair`.
pub fn loopback_pair(
    wire: WireModel,
    fault: Arc<FaultController>,
) -> Result<(TcpEndpoint, TcpEndpoint)> {
    let listener = listen("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let wire2 = wire.clone();
    let fault2 = fault.clone();
    let sink_thread = std::thread::spawn(move || accept(&listener, wire2, fault2));
    let source = connect(addr, wire, fault)?;
    let sink = sink_thread.join().expect("accept thread panicked")?;
    Ok((source, sink))
}

impl TcpEndpoint {
    fn new(
        side: Side,
        stream: TcpStream,
        wire: WireModel,
        fault: Arc<FaultController>,
    ) -> Result<Self> {
        stream.set_nodelay(true)?;
        let reader = stream.try_clone()?;
        let writer = stream.try_clone()?;
        Ok(TcpEndpoint {
            side,
            reader: Mutex::new(reader),
            writer: Mutex::new(WriteHalf { stream: writer, scratch: Vec::with_capacity(64) }),
            stream,
            wire,
            fault,
            sent_payload: AtomicU64::new(0),
        })
    }

    fn fault_error(&self) -> NetError {
        NetError::Fault(format!(
            "injected fault ({} side) after {} payload bytes",
            self.fault.side,
            self.fault.payload_so_far()
        ))
    }

    fn sever(&self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }

    fn check_fault(&self) -> Result<(), NetError> {
        if self.fault.is_tripped() {
            self.sever();
            Err(self.fault_error())
        } else {
            Ok(())
        }
    }
}

impl Endpoint for TcpEndpoint {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        self.check_fault()?;
        let payload = msg.payload_len();
        let delay = self.wire.delay_for(payload);
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        if payload > 0 {
            self.sent_payload.fetch_add(payload as u64, Ordering::Relaxed);
            if self.side == Side::Source && self.fault.account(payload as u64) {
                self.sever();
                return Err(self.fault_error());
            }
        }
        // Length prefix + header into the per-connection scratch, payload
        // gathered from its own buffer: one vectored write, zero frame
        // allocation, zero payload copy — same bytes on the wire as the
        // old contiguous frame.
        let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        let WriteHalf { stream, scratch } = &mut *w;
        scratch.clear();
        scratch.extend_from_slice(&0u32.to_le_bytes()); // placeholder
        let body = msg.encode_header(scratch);
        let body: &[u8] = body.map(Bytes::as_slice).unwrap_or(&[]);
        let body_len = (scratch.len() - 4 + body.len()) as u32;
        scratch[..4].copy_from_slice(&body_len.to_le_bytes());
        write_all_vectored(stream, scratch, body).map_err(|e| {
            if self.fault.is_tripped() {
                self.fault_error()
            } else {
                NetError::Fault(format!("tcp write: {e}"))
            }
        })
    }

    fn recv(&self) -> Result<Message, NetError> {
        self.recv_inner(None)
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        self.recv_inner(Some(timeout))
    }

    fn payload_sent(&self) -> u64 {
        self.sent_payload.load(Ordering::Relaxed)
    }
}

impl TcpEndpoint {
    fn recv_inner(&self, timeout: Option<Duration>) -> Result<Message, NetError> {
        self.check_fault()?;
        let mut r = self.reader.lock().unwrap_or_else(|e| e.into_inner());
        r.set_read_timeout(timeout).ok();
        let mut len_buf = [0u8; 4];
        if let Err(e) = r.read_exact(&mut len_buf) {
            return Err(self.classify_read_err(e));
        }
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > 512 * 1024 * 1024 {
            return Err(NetError::Fault(format!("frame of {len} bytes exceeds cap")));
        }
        let mut body = vec![0u8; len];
        if let Err(e) = r.read_exact(&mut body) {
            return Err(self.classify_read_err(e));
        }
        // Decode from the owned frame: the payload is sliced out
        // refcounted (the frame buffer lives on behind it) and `pwrite`
        // at the sink runs straight from it — the socket read above is
        // the only time these bytes move.
        Message::decode_frame(&Bytes::from_vec(body))
            .map_err(|e| NetError::Fault(format!("decode: {e}")))
    }

    fn classify_read_err(&self, e: std::io::Error) -> NetError {
        if self.fault.is_tripped() {
            return self.fault_error();
        }
        match e.kind() {
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => NetError::Timeout,
            std::io::ErrorKind::UnexpectedEof => NetError::Closed,
            _ => NetError::Fault(format!("tcp read: {e}")),
        }
    }
}

/// `write_all` over a (header, payload) pair with scatter/gather IO,
/// handling short writes across the two buffers. Control messages (empty
/// payload) take the plain `write_all` path.
fn write_all_vectored(
    stream: &mut TcpStream,
    header: &[u8],
    payload: &[u8],
) -> std::io::Result<()> {
    if payload.is_empty() {
        return stream.write_all(header);
    }
    let mut bufs = [IoSlice::new(header), IoSlice::new(payload)];
    let mut slices: &mut [IoSlice<'_>] = &mut bufs;
    while !slices.is_empty() {
        match stream.write_vectored(slices) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "tcp wrote zero bytes",
                ))
            }
            Ok(n) => IoSlice::advance_slices(&mut slices, n),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip() {
        let (src, sink) = loopback_pair(WireModel::none(), FaultController::unarmed()).unwrap();
        src.send(Message::NewFile {
            file_idx: 1,
            name: "x.bin".into(),
            size: 10,
            start_ost: 2,
        })
        .unwrap();
        match sink.recv().unwrap() {
            Message::NewFile { file_idx, name, size, start_ost } => {
                assert_eq!((file_idx, name.as_str(), size, start_ost), (1, "x.bin", 10, 2));
            }
            m => panic!("unexpected {m:?}"),
        }
        sink.send(Message::FileId { file_idx: 1, sink_fd: 5, skip: false }).unwrap();
        assert_eq!(src.recv().unwrap().type_name(), "FILE_ID");
    }

    #[test]
    fn block_data_survives_serialization() {
        let (src, sink) = loopback_pair(WireModel::none(), FaultController::unarmed()).unwrap();
        let data: Vec<u8> = (0..100_000u32).map(|i| (i * 31) as u8).collect();
        src.send(Message::NewBlock {
            file_idx: 0,
            block_idx: 7,
            offset: 7 << 18,
            digest: 42,
            data: data.clone().into(),
        })
        .unwrap();
        match sink.recv().unwrap() {
            Message::NewBlock { data: got, digest, .. } => {
                assert_eq!(got, data);
                assert_eq!(digest, 42);
            }
            m => panic!("unexpected {m:?}"),
        }
    }

    #[test]
    fn sliced_payload_serializes_like_owned() {
        // A refcounted slice of a larger buffer must land at the sink
        // byte-for-byte equal to an owned payload — the vectored write
        // path sees only the logical view.
        let (src, sink) = loopback_pair(WireModel::none(), FaultController::unarmed()).unwrap();
        let backing: Vec<u8> = (0..4096u32).map(|i| (i * 7) as u8).collect();
        let sliced = Bytes::from_vec(backing.clone()).slice(1024..3072);
        src.send(Message::NewBlock {
            file_idx: 1,
            block_idx: 2,
            offset: 0,
            digest: 9,
            data: sliced,
        })
        .unwrap();
        match sink.recv().unwrap() {
            Message::NewBlock { data, .. } => assert_eq!(data, backing[1024..3072].to_vec()),
            m => panic!("unexpected {m:?}"),
        }
    }

    #[test]
    fn recv_timeout_expires() {
        let (src, _sink) = loopback_pair(WireModel::none(), FaultController::unarmed()).unwrap();
        assert_eq!(
            src.recv_timeout(Duration::from_millis(30)),
            Err(NetError::Timeout)
        );
    }

    #[test]
    fn fault_severs_socket_both_ways() {
        let fault = FaultController::armed(1000, Side::Source);
        let (src, sink) = loopback_pair(WireModel::none(), fault.clone()).unwrap();
        let block = Message::NewBlock {
            file_idx: 0,
            block_idx: 0,
            offset: 0,
            digest: 0,
            data: vec![0; 1500].into(),
        };
        assert!(matches!(src.send(block), Err(NetError::Fault(_))));
        // The sink sees the fault as a failed read.
        assert!(matches!(
            sink.recv_timeout(Duration::from_millis(200)),
            Err(NetError::Fault(_) | NetError::Closed)
        ));
    }

    #[test]
    fn orderly_close_reports_closed() {
        let (src, sink) = loopback_pair(WireModel::none(), FaultController::unarmed()).unwrap();
        drop(src);
        assert_eq!(sink.recv(), Err(NetError::Closed));
    }
}
