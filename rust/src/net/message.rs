//! The FT-LADS wire protocol (paper Listing 1 + Figure 4).
//!
//! Message types mirror `msg_type_t`: CONNECT, NEW_FILE, FILE_ID,
//! NEW_BLOCK, BLOCK_SYNC, FILE_CLOSE, BYE. The paper's change from stock
//! LADS is BLOCK_DONE → BLOCK_SYNC: the sink acknowledges only after the
//! object is *written to the PFS* (and, here, digest-verified), so a
//! logged object is durably at rest on the sink file system.
//!
//! A hand-rolled binary codec (offline env has no serde): little-endian
//! fixed-width fields, u32-length-prefixed strings/blobs, one type byte.
//! The codec is exercised by round-trip property tests.
//!
//! Zero-copy payloads: NEW_BLOCK's object data is a refcounted
//! [`Bytes`], so moving a message between threads or endpoints never
//! copies the payload. [`Message::encode_header`] emits everything *up
//! to* the payload and hands the payload back by reference, letting
//! scatter/gather transports put it on the wire straight from the RMA
//! buffer; [`Message::decode_frame`] slices the payload out of a
//! received frame refcounted. `encode`/`decode` remain the contiguous
//! forms (identical wire bytes — the split is representation only).

use anyhow::{bail, Result};

use crate::util::bytes::Bytes;

/// Digest carried in NEW_BLOCK headers, packed `[A | B<<32]`.
pub type WireDigest = u64;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Message {
    /// Connection handshake: the source advertises its RMA geometry
    /// (paper §3.1: "sends its maximum object size, number of objects in
    /// the RMA buffer, and the memory handle") plus the largest
    /// BLOCK_SYNC batch it is willing to consume (`ack_batch`; 1 = the
    /// paper's per-object acknowledgements) and the NEW_BLOCK send window
    /// it would like to run (`send_window`; 1 = the lockstep
    /// issue-and-wait path). Both fields are optional on the wire — and
    /// `send_window` is only encoded when it is not 1 — so a field-less
    /// legacy CONNECT decodes as `ack_batch = 1` / `send_window = 1`, and
    /// a default-configured handshake stays byte-identical to the PR 2
    /// shape. Note the asymmetry (same as `ack_batch` had): an *old*
    /// decoder rejects trailing bytes, so asking a pre-`send_window` peer
    /// for a window > 1 fails the handshake rather than degrading —
    /// non-default windows assume both ends speak this revision.
    /// `data_streams` (1 = today's single fused connection) asks for a
    /// parallel data plane: the source proposes how many OST-sharded data
    /// connections it wants to dial alongside the control connection, the
    /// sink answers with the min of both sides, and each data connection
    /// then identifies itself with a [`StreamHello`]. Optional trailing
    /// field after `send_window`; a field-less legacy/PR 5-era peer
    /// decodes as 1 and keeps the fused path. Because the trailing fields
    /// are positional, encoding a non-default `data_streams` forces the
    /// preceding `send_window` onto the wire even when it is 1.
    /// `job` (0 = standalone transfer, the default) tags the connection
    /// with a daemon job id so one `ftlads serve` listener can demux many
    /// concurrent transfers to their job-scoped sessions; it is the last
    /// trailing field, only encoded when non-zero (forcing the earlier
    /// optionals onto the wire), so a standalone handshake stays
    /// byte-identical to every prior revision.
    Connect {
        max_object_size: u64,
        rma_slots: u32,
        resume: bool,
        ack_batch: u32,
        send_window: u32,
        data_streams: u32,
        job: u64,
    },
    /// Sink accepts; advertises its own RMA slot count, the ack batch
    /// size it will actually use (min of both sides' `ack_batch`), the
    /// negotiated NEW_BLOCK send window the source must honor (min of
    /// both sides' `send_window`), and the negotiated data-stream count
    /// (min of both sides' `data_streams`). All trailing fields are
    /// optional on the wire, defaulting to 1 for legacy peers, and each
    /// is only encoded when it (or a later field) is not 1.
    ConnectAck { rma_slots: u32, ack_batch: u32, send_window: u32, data_streams: u32 },
    /// Source → sink: begin file `file_idx` (§5.2.1). Carries the
    /// metadata the sink uses for the resume match (§5.2.2).
    NewFile { file_idx: u32, name: String, size: u64, start_ost: u32 },
    /// Sink → source: file opened, here is the sink fd; or `skip` when the
    /// resume metadata matched a committed file.
    FileId { file_idx: u32, sink_fd: u64, skip: bool },
    /// Source → sink: one object. Data rides along refcounted (the
    /// RMA-read emulation hands the receiver a view of the sender's
    /// registered buffer — no copy); `digest` is the source-side
    /// integrity digest (0 when integrity is off).
    NewBlock {
        file_idx: u32,
        block_idx: u32,
        offset: u64,
        digest: WireDigest,
        data: Bytes,
    },
    /// Sink → source: object written (and verified) at the sink PFS.
    /// `ok = false` reports a failed/corrupted write; the source must
    /// reschedule the object and must NOT log it.
    BlockSync { file_idx: u32, block_idx: u32, ok: bool },
    /// Sink → source: several objects of one file acknowledged at once —
    /// the coalesced form of `BlockSync`, sent only when the CONNECT
    /// handshake negotiated `ack_batch > 1`. Semantically identical to
    /// the same `BlockSync`s in sequence; amortizes one wire message (and
    /// one group-committed logger write at the source) over the batch.
    BlockSyncBatch { file_idx: u32, blocks: Vec<(u32, bool)> },
    /// Source → sink: all objects of the file synced; close + commit it.
    FileClose { file_idx: u32 },
    /// Sink → source: file committed (lets the source delete its FT log).
    FileCloseAck { file_idx: u32 },
    /// Source → sink: transfer complete, disconnect.
    Bye,
    /// First (and only handshake) message on each *data* connection of a
    /// multi-stream transfer: identifies which stream id the connection
    /// carries, so accepts arriving in any order still bind to the right
    /// OST shard. Never sent when the negotiated `data_streams` is 1 —
    /// the default wire is untouched. `job` carries the same daemon job
    /// id as the CONNECT (optional trailing field, encoded only when
    /// non-zero) so a serve listener can bind late-arriving data
    /// connections to the right job's session.
    StreamHello { stream_id: u32, job: u64 },
}

const T_CONNECT: u8 = 0;
const T_CONNECT_ACK: u8 = 1;
const T_NEW_FILE: u8 = 2;
const T_FILE_ID: u8 = 3;
const T_NEW_BLOCK: u8 = 4;
const T_BLOCK_SYNC: u8 = 5;
const T_FILE_CLOSE: u8 = 6;
const T_FILE_CLOSE_ACK: u8 = 7;
const T_BYE: u8 = 8;
const T_BLOCK_SYNC_BATCH: u8 = 9;
const T_STREAM_HELLO: u8 = 10;

impl Message {
    /// Payload bytes for accounting/bandwidth purposes (object data only —
    /// control headers are noise at MTU scale).
    pub fn payload_len(&self) -> usize {
        match self {
            Message::NewBlock { data, .. } => data.len(),
            _ => 0,
        }
    }

    pub fn type_name(&self) -> &'static str {
        match self {
            Message::Connect { .. } => "CONNECT",
            Message::ConnectAck { .. } => "CONNECT_ACK",
            Message::NewFile { .. } => "NEW_FILE",
            Message::FileId { .. } => "FILE_ID",
            Message::NewBlock { .. } => "NEW_BLOCK",
            Message::BlockSync { .. } => "BLOCK_SYNC",
            Message::BlockSyncBatch { .. } => "BLOCK_SYNC_BATCH",
            Message::FileClose { .. } => "FILE_CLOSE",
            Message::FileCloseAck { .. } => "FILE_CLOSE_ACK",
            Message::Bye => "BYE",
            Message::StreamHello { .. } => "STREAM_HELLO",
        }
    }

    /// The payload riding this message, if any (NEW_BLOCK's object
    /// data) — a refcounted view, never a copy.
    pub fn payload(&self) -> Option<&Bytes> {
        match self {
            Message::NewBlock { data, .. } => Some(data),
            _ => None,
        }
    }

    /// Encode into `out` (appends; does not clear). Contiguous form:
    /// header followed by the payload bytes — byte-identical to
    /// [`encode_header`](Message::encode_header) + payload.
    pub fn encode(&self, out: &mut Vec<u8>) {
        let payload = self.encode_header(out);
        if let Some(p) = payload {
            out.extend_from_slice(p);
        }
    }

    /// Encode everything *up to* the payload into `out` and return the
    /// payload (if any) that must follow it on the wire. Scatter/gather
    /// transports reuse one header scratch buffer per connection and
    /// write the payload from its own (RMA) buffer — zero per-message
    /// frame allocation, zero payload copies. Wire bytes are identical
    /// to [`encode`](Message::encode).
    pub fn encode_header<'a>(&'a self, out: &mut Vec<u8>) -> Option<&'a Bytes> {
        match self {
            Message::Connect {
                max_object_size,
                rma_slots,
                resume,
                ack_batch,
                send_window,
                data_streams,
                job,
            } => {
                out.push(T_CONNECT);
                put_u64(out, *max_object_size);
                put_u32(out, *rma_slots);
                out.push(*resume as u8);
                put_u32(out, *ack_batch);
                // Optional trailing fields, omitted at the defaults so the
                // PR 2-era wire bytes are reproduced exactly. The decode is
                // positional, so a non-default `data_streams` forces
                // `send_window` onto the wire even at its default, and a
                // non-zero `job` forces both earlier optionals.
                if *send_window != 1 || *data_streams != 1 || *job != 0 {
                    put_u32(out, *send_window);
                }
                if *data_streams != 1 || *job != 0 {
                    put_u32(out, *data_streams);
                }
                if *job != 0 {
                    put_u64(out, *job);
                }
            }
            Message::ConnectAck { rma_slots, ack_batch, send_window, data_streams } => {
                out.push(T_CONNECT_ACK);
                put_u32(out, *rma_slots);
                put_u32(out, *ack_batch);
                if *send_window != 1 || *data_streams != 1 {
                    put_u32(out, *send_window);
                }
                if *data_streams != 1 {
                    put_u32(out, *data_streams);
                }
            }
            Message::NewFile { file_idx, name, size, start_ost } => {
                out.push(T_NEW_FILE);
                put_u32(out, *file_idx);
                put_str(out, name);
                put_u64(out, *size);
                put_u32(out, *start_ost);
            }
            Message::FileId { file_idx, sink_fd, skip } => {
                out.push(T_FILE_ID);
                put_u32(out, *file_idx);
                put_u64(out, *sink_fd);
                out.push(*skip as u8);
            }
            Message::NewBlock { file_idx, block_idx, offset, digest, data } => {
                out.push(T_NEW_BLOCK);
                put_u32(out, *file_idx);
                put_u32(out, *block_idx);
                put_u64(out, *offset);
                put_u64(out, *digest);
                put_u32(out, data.len() as u32);
                return Some(data);
            }
            Message::BlockSync { file_idx, block_idx, ok } => {
                out.push(T_BLOCK_SYNC);
                put_u32(out, *file_idx);
                put_u32(out, *block_idx);
                out.push(*ok as u8);
            }
            Message::BlockSyncBatch { file_idx, blocks } => {
                out.push(T_BLOCK_SYNC_BATCH);
                put_u32(out, *file_idx);
                put_u32(out, blocks.len() as u32);
                for (block_idx, ok) in blocks {
                    put_u32(out, *block_idx);
                    out.push(*ok as u8);
                }
            }
            Message::FileClose { file_idx } => {
                out.push(T_FILE_CLOSE);
                put_u32(out, *file_idx);
            }
            Message::FileCloseAck { file_idx } => {
                out.push(T_FILE_CLOSE_ACK);
                put_u32(out, *file_idx);
            }
            Message::Bye => out.push(T_BYE),
            Message::StreamHello { stream_id, job } => {
                out.push(T_STREAM_HELLO);
                put_u32(out, *stream_id);
                if *job != 0 {
                    put_u64(out, *job);
                }
            }
        }
        None
    }

    /// Decode one message from `buf` (must contain exactly one message).
    /// The payload, if any, is copied out of `buf`; receive paths that
    /// own their frame use [`decode_frame`](Message::decode_frame) to
    /// slice it refcounted instead.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        Self::decode_inner(buf, None)
    }

    /// Decode one message from an owned `frame`, slicing the payload out
    /// refcounted — the frame's buffer stays alive behind the payload
    /// view and no payload bytes are copied.
    pub fn decode_frame(frame: &Bytes) -> Result<Message> {
        Self::decode_inner(frame.as_slice(), Some(frame))
    }

    fn decode_inner(buf: &[u8], frame: Option<&Bytes>) -> Result<Message> {
        let mut r = Reader { buf, frame, pos: 0 };
        let msg = r.message()?;
        if r.pos != buf.len() {
            bail!("trailing bytes after message ({} of {})", r.pos, buf.len());
        }
        Ok(msg)
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    /// When decoding an owned frame, the refcounted whole-frame view —
    /// payloads are sliced out of it instead of copied. Invariant:
    /// `frame.as_slice()` and `buf` are the same region.
    frame: Option<&'a Bytes>,
    pos: usize,
}

impl<'a> Reader<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("message truncated at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        if len > 64 * 1024 {
            bail!("string of {len} bytes exceeds sanity cap");
        }
        Ok(std::str::from_utf8(self.take(len)?)?.to_string())
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("bad bool byte {b}"),
        }
    }

    /// Consume `len` payload bytes: a refcounted slice of the frame when
    /// one backs this reader, a copy otherwise.
    fn payload(&mut self, len: usize) -> Result<Bytes> {
        let start = self.pos;
        let raw = self.take(len)?;
        Ok(match self.frame {
            Some(f) => f.slice(start..start + len),
            None => Bytes::copy_from_slice(raw),
        })
    }

    fn message(&mut self) -> Result<Message> {
        Ok(match self.u8()? {
            T_CONNECT => Message::Connect {
                max_object_size: self.u64()?,
                rma_slots: self.u32()?,
                resume: self.bool()?,
                // Optional trailing fields: a legacy peer's CONNECT stops
                // here and means "one BLOCK_SYNC per object", and a PR 2-
                // era peer stops after `ack_batch` and means "lockstep
                // NEW_BLOCK issue" (`send_window = 1`). This covers the
                // old-to-new direction only; an old decoder rejects the
                // extra field (see the `Connect` doc).
                ack_batch: if self.remaining() > 0 { self.u32()? } else { 1 },
                send_window: if self.remaining() > 0 { self.u32()? } else { 1 },
                data_streams: if self.remaining() > 0 { self.u32()? } else { 1 },
                job: if self.remaining() > 0 { self.u64()? } else { 0 },
            },
            T_CONNECT_ACK => Message::ConnectAck {
                rma_slots: self.u32()?,
                ack_batch: if self.remaining() > 0 { self.u32()? } else { 1 },
                send_window: if self.remaining() > 0 { self.u32()? } else { 1 },
                data_streams: if self.remaining() > 0 { self.u32()? } else { 1 },
            },
            T_NEW_FILE => Message::NewFile {
                file_idx: self.u32()?,
                name: self.string()?,
                size: self.u64()?,
                start_ost: self.u32()?,
            },
            T_FILE_ID => Message::FileId {
                file_idx: self.u32()?,
                sink_fd: self.u64()?,
                skip: self.bool()?,
            },
            T_NEW_BLOCK => {
                let file_idx = self.u32()?;
                let block_idx = self.u32()?;
                let offset = self.u64()?;
                let digest = self.u64()?;
                let len = self.u32()? as usize;
                if len > 256 * 1024 * 1024 {
                    bail!("block of {len} bytes exceeds sanity cap");
                }
                let data = self.payload(len)?;
                Message::NewBlock { file_idx, block_idx, offset, digest, data }
            }
            T_BLOCK_SYNC => Message::BlockSync {
                file_idx: self.u32()?,
                block_idx: self.u32()?,
                ok: self.bool()?,
            },
            T_BLOCK_SYNC_BATCH => {
                let file_idx = self.u32()?;
                let count = self.u32()? as usize;
                if count > 1 << 20 {
                    bail!("ack batch of {count} entries exceeds sanity cap");
                }
                let mut blocks = Vec::with_capacity(count);
                for _ in 0..count {
                    blocks.push((self.u32()?, self.bool()?));
                }
                Message::BlockSyncBatch { file_idx, blocks }
            }
            T_FILE_CLOSE => Message::FileClose { file_idx: self.u32()? },
            T_FILE_CLOSE_ACK => Message::FileCloseAck { file_idx: self.u32()? },
            T_BYE => Message::Bye,
            T_STREAM_HELLO => Message::StreamHello {
                stream_id: self.u32()?,
                job: if self.remaining() > 0 { self.u64()? } else { 0 },
            },
            t => bail!("unknown message type byte {t}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        let back = Message::decode(&buf).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn roundtrip_all_variants() {
        roundtrip(Message::Connect {
            max_object_size: 1 << 20,
            rma_slots: 64,
            resume: true,
            ack_batch: 8,
            send_window: 1,
            data_streams: 1,
            job: 0,
        });
        roundtrip(Message::Connect {
            max_object_size: 1 << 20,
            rma_slots: 64,
            resume: false,
            ack_batch: 8,
            send_window: 32,
            data_streams: 4,
            job: 0,
        });
        // The forced-encode corner: data_streams != 1 with the default
        // send_window — positional decode must still land every field.
        roundtrip(Message::Connect {
            max_object_size: 1 << 20,
            rma_slots: 64,
            resume: false,
            ack_batch: 1,
            send_window: 1,
            data_streams: 8,
            job: 0,
        });
        // The serve corner: a non-zero job tag with every earlier
        // optional at its default — all three must land positionally.
        roundtrip(Message::Connect {
            max_object_size: 1 << 20,
            rma_slots: 64,
            resume: false,
            ack_batch: 1,
            send_window: 1,
            data_streams: 1,
            job: u64::MAX,
        });
        roundtrip(Message::ConnectAck {
            rma_slots: 8,
            ack_batch: 1,
            send_window: 1,
            data_streams: 1,
        });
        roundtrip(Message::ConnectAck {
            rma_slots: 8,
            ack_batch: 4,
            send_window: 16,
            data_streams: 2,
        });
        roundtrip(Message::ConnectAck {
            rma_slots: 8,
            ack_batch: 1,
            send_window: 1,
            data_streams: 64,
        });
        roundtrip(Message::StreamHello { stream_id: 0, job: 0 });
        roundtrip(Message::StreamHello { stream_id: 63, job: 0 });
        roundtrip(Message::StreamHello { stream_id: 2, job: 41 });
        roundtrip(Message::NewFile {
            file_idx: 3,
            name: "dir/file-α.bin".into(),
            size: u64::MAX,
            start_ost: 10,
        });
        roundtrip(Message::FileId { file_idx: 3, sink_fd: 77, skip: false });
        roundtrip(Message::NewBlock {
            file_idx: 1,
            block_idx: 9,
            offset: 9 << 20,
            digest: 0xdead_beef_1234_5678,
            data: (0..=255u8).collect(),
        });
        roundtrip(Message::BlockSync { file_idx: 1, block_idx: 9, ok: true });
        roundtrip(Message::BlockSync { file_idx: 1, block_idx: 9, ok: false });
        roundtrip(Message::BlockSyncBatch { file_idx: 1, blocks: vec![] });
        roundtrip(Message::BlockSyncBatch {
            file_idx: 7,
            blocks: vec![(0, true), (9, false), (u32::MAX, true)],
        });
        roundtrip(Message::FileClose { file_idx: 2 });
        roundtrip(Message::FileCloseAck { file_idx: 2 });
        roundtrip(Message::Bye);
    }

    #[test]
    fn empty_block_roundtrips() {
        roundtrip(Message::NewBlock {
            file_idx: 0,
            block_idx: 0,
            offset: 0,
            digest: 0,
            data: Bytes::new(),
        });
    }

    #[test]
    fn payload_len_counts_data_only() {
        let m = Message::NewBlock {
            file_idx: 0,
            block_idx: 0,
            offset: 0,
            digest: 0,
            data: vec![0; 100].into(),
        };
        assert_eq!(m.payload_len(), 100);
        assert_eq!(m.payload().unwrap().len(), 100);
        assert_eq!(Message::Bye.payload_len(), 0);
        assert!(Message::Bye.payload().is_none());
    }

    /// Reference encoding of a NEW_BLOCK, built by hand field by field —
    /// the layout pin the zero-copy representation change must not move.
    fn reference_new_block_bytes(
        file_idx: u32,
        block_idx: u32,
        offset: u64,
        digest: u64,
        payload: &[u8],
    ) -> Vec<u8> {
        let mut buf = vec![T_NEW_BLOCK];
        buf.extend_from_slice(&file_idx.to_le_bytes());
        buf.extend_from_slice(&block_idx.to_le_bytes());
        buf.extend_from_slice(&offset.to_le_bytes());
        buf.extend_from_slice(&digest.to_le_bytes());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(payload);
        buf
    }

    #[test]
    fn new_block_wire_bytes_are_pinned() {
        let payload: Vec<u8> = (0..200u32).map(|i| (i * 13) as u8).collect();
        let expect = reference_new_block_bytes(7, 42, 42 << 16, 0xfeed_f00d, &payload);

        // Owned-vec payload.
        let mut buf = Vec::new();
        Message::NewBlock {
            file_idx: 7,
            block_idx: 42,
            offset: 42 << 16,
            digest: 0xfeed_f00d,
            data: payload.clone().into(),
        }
        .encode(&mut buf);
        assert_eq!(buf, expect);

        // A refcounted *slice* of a larger buffer encodes identically:
        // the wire depends only on the logical view.
        let mut big = vec![0xAAu8; 64];
        big.extend_from_slice(&payload);
        big.extend_from_slice(&[0xBB; 64]);
        let sliced = Bytes::from_vec(big).slice(64..64 + payload.len());
        let mut buf2 = Vec::new();
        Message::NewBlock {
            file_idx: 7,
            block_idx: 42,
            offset: 42 << 16,
            digest: 0xfeed_f00d,
            data: sliced,
        }
        .encode(&mut buf2);
        assert_eq!(buf2, expect);
    }

    #[test]
    fn encode_header_plus_payload_equals_encode() {
        let msg = Message::NewBlock {
            file_idx: 1,
            block_idx: 2,
            offset: 3,
            digest: 4,
            data: (0..64u8).collect(),
        };
        let mut whole = Vec::new();
        msg.encode(&mut whole);
        let mut header = Vec::new();
        let payload = msg.encode_header(&mut header).expect("NEW_BLOCK has a payload");
        header.extend_from_slice(payload);
        assert_eq!(header, whole);

        // Control messages: header IS the whole message.
        let mut header = Vec::new();
        assert!(Message::Bye.encode_header(&mut header).is_none());
        let mut whole = Vec::new();
        Message::Bye.encode(&mut whole);
        assert_eq!(header, whole);
    }

    #[test]
    fn decode_frame_slices_payload_zero_copy() {
        let msg = Message::NewBlock {
            file_idx: 9,
            block_idx: 1,
            offset: 1 << 20,
            digest: 5,
            data: (0..128u8).collect(),
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        let frame = Bytes::from_vec(buf);
        let frame_ptr = frame.as_slice().as_ptr() as usize;
        let back = Message::decode_frame(&frame).unwrap();
        assert_eq!(back, msg);
        let Message::NewBlock { data, .. } = back else { panic!("wrong variant") };
        // The decoded payload points INTO the frame buffer: header is
        // 1 + 4 + 4 + 8 + 8 + 4 = 29 bytes, payload starts right after.
        assert_eq!(data.as_slice().as_ptr() as usize, frame_ptr + 29);
        // The frame stays alive behind the payload even after we drop
        // our handle on it.
        drop(frame);
        assert_eq!(data, (0..128u8).collect::<Vec<_>>());

        // decode_frame matches decode on every other variant too.
        let mut buf = Vec::new();
        Message::FileClose { file_idx: 3 }.encode(&mut buf);
        assert_eq!(
            Message::decode_frame(&Bytes::from_vec(buf.clone())).unwrap(),
            Message::decode(&buf).unwrap()
        );
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        assert!(Message::decode(&[T_CONNECT, 1, 2]).is_err()); // truncated
        // trailing bytes rejected
        let mut buf = Vec::new();
        Message::Bye.encode(&mut buf);
        buf.push(0);
        assert!(Message::decode(&buf).is_err());
        // bad bool byte
        let mut buf = Vec::new();
        Message::FileId { file_idx: 0, sink_fd: 0, skip: false }.encode(&mut buf);
        *buf.last_mut().unwrap() = 7;
        assert!(Message::decode(&buf).is_err());
    }

    #[test]
    fn legacy_handshake_without_ack_batch_decodes_as_one() {
        // A pre-batching peer's CONNECT: type byte + u64 + u32 + bool,
        // no trailing ack_batch field.
        let mut buf = vec![T_CONNECT];
        buf.extend_from_slice(&(1u64 << 20).to_le_bytes());
        buf.extend_from_slice(&64u32.to_le_bytes());
        buf.push(1);
        assert_eq!(
            Message::decode(&buf).unwrap(),
            Message::Connect {
                max_object_size: 1 << 20,
                rma_slots: 64,
                resume: true,
                ack_batch: 1,
                send_window: 1,
                data_streams: 1,
                job: 0,
            }
        );
        let mut buf = vec![T_CONNECT_ACK];
        buf.extend_from_slice(&8u32.to_le_bytes());
        assert_eq!(
            Message::decode(&buf).unwrap(),
            Message::ConnectAck { rma_slots: 8, ack_batch: 1, send_window: 1, data_streams: 1 }
        );
    }

    #[test]
    fn pr2_handshake_without_send_window_decodes_as_one() {
        // A PR 2-era peer's CONNECT: the ack_batch field present, no
        // trailing send_window — the lockstep issue path.
        let mut buf = vec![T_CONNECT];
        buf.extend_from_slice(&(1u64 << 20).to_le_bytes());
        buf.extend_from_slice(&64u32.to_le_bytes());
        buf.push(0);
        buf.extend_from_slice(&8u32.to_le_bytes());
        assert_eq!(
            Message::decode(&buf).unwrap(),
            Message::Connect {
                max_object_size: 1 << 20,
                rma_slots: 64,
                resume: false,
                ack_batch: 8,
                send_window: 1,
                data_streams: 1,
                job: 0,
            }
        );
        let mut buf = vec![T_CONNECT_ACK];
        buf.extend_from_slice(&8u32.to_le_bytes());
        buf.extend_from_slice(&4u32.to_le_bytes());
        assert_eq!(
            Message::decode(&buf).unwrap(),
            Message::ConnectAck { rma_slots: 8, ack_batch: 4, send_window: 1, data_streams: 1 }
        );
    }

    #[test]
    fn pr5_handshake_without_data_streams_decodes_as_one() {
        // A PR 5-era peer's CONNECT: ack_batch and send_window present,
        // no trailing data_streams — the single fused connection path.
        let mut buf = vec![T_CONNECT];
        buf.extend_from_slice(&(1u64 << 20).to_le_bytes());
        buf.extend_from_slice(&64u32.to_le_bytes());
        buf.push(0);
        buf.extend_from_slice(&8u32.to_le_bytes());
        buf.extend_from_slice(&16u32.to_le_bytes());
        assert_eq!(
            Message::decode(&buf).unwrap(),
            Message::Connect {
                max_object_size: 1 << 20,
                rma_slots: 64,
                resume: false,
                ack_batch: 8,
                send_window: 16,
                data_streams: 1,
                job: 0,
            }
        );
        let mut buf = vec![T_CONNECT_ACK];
        buf.extend_from_slice(&8u32.to_le_bytes());
        buf.extend_from_slice(&4u32.to_le_bytes());
        buf.extend_from_slice(&16u32.to_le_bytes());
        assert_eq!(
            Message::decode(&buf).unwrap(),
            Message::ConnectAck { rma_slots: 8, ack_batch: 4, send_window: 16, data_streams: 1 }
        );
    }

    #[test]
    fn default_send_window_keeps_pr2_wire_bytes() {
        // The equivalence pin at the codec layer: `send_window = 1` must
        // encode to exactly the PR 2 byte shape (no trailing field), so a
        // default-configured handshake is byte-identical on the wire.
        let mut buf = Vec::new();
        Message::Connect {
            max_object_size: 1 << 20,
            rma_slots: 64,
            resume: false,
            ack_batch: 1,
            send_window: 1,
            data_streams: 1,
            job: 0,
        }
        .encode(&mut buf);
        assert_eq!(buf.len(), 1 + 8 + 4 + 1 + 4, "CONNECT grew beyond the PR 2 shape");
        let mut buf = Vec::new();
        Message::ConnectAck { rma_slots: 8, ack_batch: 1, send_window: 1, data_streams: 1 }
            .encode(&mut buf);
        assert_eq!(buf.len(), 1 + 4 + 4, "CONNECT_ACK grew beyond the PR 2 shape");
    }

    #[test]
    fn multi_stream_handshake_forces_send_window_onto_the_wire() {
        // data_streams != 1 with the default window: both trailing u32s
        // must be present (positional decode) — 5 extra bytes over PR 2
        // on CONNECT (4 + 4 minus nothing; window was already omitted).
        let mut buf = Vec::new();
        Message::Connect {
            max_object_size: 1 << 20,
            rma_slots: 64,
            resume: false,
            ack_batch: 1,
            send_window: 1,
            data_streams: 4,
            job: 0,
        }
        .encode(&mut buf);
        assert_eq!(buf.len(), 1 + 8 + 4 + 1 + 4 + 4 + 4);
        let mut buf = Vec::new();
        Message::ConnectAck { rma_slots: 8, ack_batch: 1, send_window: 1, data_streams: 4 }
            .encode(&mut buf);
        assert_eq!(buf.len(), 1 + 4 + 4 + 4 + 4);
        // And an untagged STREAM_HELLO is a fixed 5-byte frame.
        let mut buf = Vec::new();
        Message::StreamHello { stream_id: 3, job: 0 }.encode(&mut buf);
        assert_eq!(buf, {
            let mut b = vec![T_STREAM_HELLO];
            b.extend_from_slice(&3u32.to_le_bytes());
            b
        });
    }

    #[test]
    fn job_tag_forces_trailing_fields_and_legacy_decodes_as_zero() {
        // A tagged CONNECT carries every positional optional: ack_batch +
        // send_window + data_streams + the u64 job id.
        let mut buf = Vec::new();
        Message::Connect {
            max_object_size: 1 << 20,
            rma_slots: 64,
            resume: false,
            ack_batch: 1,
            send_window: 1,
            data_streams: 1,
            job: 3,
        }
        .encode(&mut buf);
        assert_eq!(buf.len(), 1 + 8 + 4 + 1 + 4 + 4 + 4 + 8);
        // A tagged STREAM_HELLO appends the u64 job id.
        let mut buf = Vec::new();
        Message::StreamHello { stream_id: 1, job: 3 }.encode(&mut buf);
        assert_eq!(buf.len(), 1 + 4 + 8);
        // PR 7-era frames (no job field) decode as job = 0 — a standalone
        // peer connecting to a serve daemon lands in the default job.
        let mut buf = vec![T_STREAM_HELLO];
        buf.extend_from_slice(&5u32.to_le_bytes());
        assert_eq!(
            Message::decode(&buf).unwrap(),
            Message::StreamHello { stream_id: 5, job: 0 }
        );
    }

    #[test]
    fn decode_rejects_oversized_ack_batch() {
        let mut buf = vec![T_BLOCK_SYNC_BATCH];
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&(1u32 << 30).to_le_bytes()); // absurd count
        assert!(Message::decode(&buf).is_err());
    }

    #[test]
    fn decode_rejects_oversized_string() {
        let mut buf = vec![T_NEW_FILE];
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&(1u32 << 30).to_le_bytes()); // absurd name len
        assert!(Message::decode(&buf).is_err());
    }
}
