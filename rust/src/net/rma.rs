//! RMA buffer pool.
//!
//! CCI registers a fixed DRAM region for RMA; LADS carves it into
//! object-sized slots. A sink comm thread must *reserve* a slot before it
//! can RMA-read an incoming object; if none is free it parks the request
//! and the master thread sleeps on the pool's wait queue until an IO
//! thread releases a slot after `pwrite` (paper §3.1). The paper's
//! evaluation uses max 256 MB of RMA DRAM per side.
//!
//! The pool hands out real reusable `Vec<u8>` buffers (so the data path
//! exercises actual memory traffic) and tracks reservation stalls — the
//! back-pressure signal the figures' CPU/memory analysis cares about.
//!
//! Zero-copy handoff: [`RmaSlot::freeze`] turns a filled slot into a
//! refcounted [`Bytes`] without copying. The buffer stays out of the
//! pool for as long as any view of it is alive (it is "registered" for
//! the duration of the transfer, like a real RMA region) and returns
//! automatically when the last reference drops — so slot-hold accounting
//! in the issue loop is decoupled from payload lifetime on the wire.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::bytes::{Bytes, BytesOwner};

/// A reserved slot; returns its buffer to the pool on drop.
pub struct RmaSlot {
    pool: Arc<RmaPoolInner>,
    buf: Option<Vec<u8>>,
    pub slot_bytes: usize,
}

impl RmaSlot {
    pub fn buf(&mut self) -> &mut Vec<u8> {
        self.buf.as_mut().expect("slot buffer present until drop")
    }

    pub fn data(&self) -> &[u8] {
        self.buf.as_ref().expect("slot buffer present until drop")
    }

    /// Freeze the slot's filled buffer into refcounted [`Bytes`] without
    /// copying. The slot handle is consumed; the buffer returns to the
    /// pool (cleared, reusable) when the last `Bytes` view drops — on the
    /// send path that is after the payload has left the wire and the
    /// peer released it, exactly like an RMA-registered region.
    pub fn freeze(mut self) -> Bytes {
        let buf = self.buf.take().expect("slot buffer present until drop");
        Bytes::from_owner(Arc::new(PooledBuf {
            pool: self.pool.clone(),
            buf: Some(buf),
        }))
    }
}

/// A frozen slot buffer: the [`BytesOwner`] behind [`RmaSlot::freeze`],
/// whose `Drop` gives the buffer back to its pool.
struct PooledBuf {
    pool: Arc<RmaPoolInner>,
    buf: Option<Vec<u8>>,
}

impl BytesOwner for PooledBuf {
    fn as_slice(&self) -> &[u8] {
        self.buf.as_ref().expect("pooled buffer present until drop")
    }

    fn as_mut_slice(&mut self) -> Option<&mut [u8]> {
        self.buf.as_mut().map(|b| &mut b[..])
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if let Some(mut b) = self.buf.take() {
            b.clear();
            self.pool.release(b);
        }
    }
}

impl Drop for RmaSlot {
    fn drop(&mut self) {
        if let Some(mut b) = self.buf.take() {
            b.clear();
            self.pool.release(b);
        }
    }
}

struct RmaPoolInner {
    free: Mutex<Vec<Vec<u8>>>,
    available: Condvar,
    slot_bytes: usize,
    /// Total registered slots. Atomic because the CONNECT-time autosizer
    /// may grow the pool after IO threads already hold a handle.
    slots: AtomicUsize,
    stalls: AtomicU64,
    stall_ns: AtomicU64,
}

impl RmaPoolInner {
    fn release(&self, buf: Vec<u8>) {
        let mut free = self.free.lock().unwrap_or_else(|e| e.into_inner());
        free.push(buf);
        drop(free);
        self.available.notify_one();
    }
}

/// Fixed-size pool of object-sized RMA buffers.
#[derive(Clone)]
pub struct RmaPool {
    inner: std::sync::Arc<RmaPoolInner>,
}

impl RmaPool {
    /// `total_bytes` of RMA DRAM carved into `slot_bytes` slots (at least 1).
    pub fn new(total_bytes: usize, slot_bytes: usize) -> Self {
        assert!(slot_bytes > 0);
        let slots = (total_bytes / slot_bytes).max(1);
        let free = (0..slots)
            .map(|_| Vec::with_capacity(slot_bytes))
            .collect();
        RmaPool {
            inner: std::sync::Arc::new(RmaPoolInner {
                free: Mutex::new(free),
                available: Condvar::new(),
                slot_bytes,
                slots: AtomicUsize::new(slots),
                stalls: AtomicU64::new(0),
                stall_ns: AtomicU64::new(0),
            }),
        }
    }

    pub fn slots(&self) -> usize {
        self.inner.slots.load(Ordering::SeqCst)
    }

    pub fn slot_bytes(&self) -> usize {
        self.inner.slot_bytes
    }

    /// Total registered RMA DRAM — `slots × slot_bytes` (grows with the
    /// autosizer, never shrinks).
    pub fn total_bytes(&self) -> u64 {
        (self.slots() * self.slot_bytes()) as u64
    }

    /// Autosizer: grow the pool to at least `min_slots` slots (register
    /// more DRAM), waking every blocked reservation. A pool already that
    /// large is untouched — the pool only ever grows, so outstanding
    /// slot handles stay valid. Returns the new slot count.
    pub fn grow_to(&self, min_slots: usize) -> usize {
        let mut free = self.inner.free.lock().unwrap_or_else(|e| e.into_inner());
        let cur = self.inner.slots.load(Ordering::SeqCst);
        if min_slots > cur {
            for _ in cur..min_slots {
                free.push(Vec::with_capacity(self.inner.slot_bytes));
            }
            self.inner.slots.store(min_slots, Ordering::SeqCst);
            drop(free);
            self.inner.available.notify_all();
            min_slots
        } else {
            cur
        }
    }

    pub fn free_slots(&self) -> usize {
        self.inner
            .free
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .len()
    }

    /// Non-blocking reserve (the comm thread's first attempt).
    pub fn try_reserve(&self) -> Option<RmaSlot> {
        let mut free = self.inner.free.lock().unwrap_or_else(|e| e.into_inner());
        free.pop().map(|buf| RmaSlot {
            pool: self.inner.clone(),
            buf: Some(buf),
            slot_bytes: self.inner.slot_bytes,
        })
    }

    /// Blocking reserve (the master-thread path when the pool is dry).
    pub fn reserve(&self) -> RmaSlot {
        let start = Instant::now();
        let mut free = self.inner.free.lock().unwrap_or_else(|e| e.into_inner());
        let mut stalled = false;
        while free.is_empty() {
            stalled = true;
            free = self
                .inner
                .available
                .wait(free)
                .unwrap_or_else(|e| e.into_inner());
        }
        let buf = free.pop().unwrap();
        drop(free);
        if stalled {
            self.inner.stalls.fetch_add(1, Ordering::Relaxed);
            self.inner
                .stall_ns
                .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        RmaSlot {
            pool: self.inner.clone(),
            buf: Some(buf),
            slot_bytes: self.inner.slot_bytes,
        }
    }

    /// Blocking reserve with timeout (used on shutdown paths and by the
    /// sink master's abort-aware wait loop).
    pub fn reserve_timeout(&self, timeout: Duration) -> Option<RmaSlot> {
        let start = Instant::now();
        let deadline = start + timeout;
        let mut stalled = false;
        let mut free = self.inner.free.lock().unwrap_or_else(|e| e.into_inner());
        while free.is_empty() {
            stalled = true;
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) = self
                .inner
                .available
                .wait_timeout(free, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            free = guard;
            if res.timed_out() && free.is_empty() {
                return None;
            }
        }
        let buf = free.pop().unwrap();
        drop(free);
        if stalled {
            self.inner.stalls.fetch_add(1, Ordering::Relaxed);
            self.inner
                .stall_ns
                .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        Some(RmaSlot {
            pool: self.inner.clone(),
            buf: Some(buf),
            slot_bytes: self.inner.slot_bytes,
        })
    }

    /// (count, total ns) of blocking reservations that had to wait.
    pub fn stall_stats(&self) -> (u64, u64) {
        (
            self.inner.stalls.load(Ordering::Relaxed),
            self.inner.stall_ns.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_capacity() {
        let p = RmaPool::new(1 << 20, 1 << 18);
        assert_eq!(p.slots(), 4);
        assert_eq!(p.free_slots(), 4);
        assert_eq!(p.slot_bytes(), 1 << 18);
        // Degenerate: smaller total than slot still yields one slot.
        assert_eq!(RmaPool::new(10, 100).slots(), 1);
    }

    #[test]
    fn grow_to_adds_slots_and_wakes_waiters() {
        let p = RmaPool::new(1024, 1024);
        assert_eq!(p.slots(), 1);
        assert_eq!(p.total_bytes(), 1024);
        let _hold = p.reserve();
        let p2 = p.clone();
        let h = std::thread::spawn(move || p2.reserve()); // blocks: pool dry
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(p.grow_to(4), 4);
        let _s = h.join().unwrap(); // grow satisfied the blocked reserve
        assert_eq!(p.slots(), 4);
        assert_eq!(p.total_bytes(), 4096);
        // Growing to a smaller/equal size is a no-op.
        assert_eq!(p.grow_to(2), 4);
        assert_eq!(p.slots(), 4);
    }

    #[test]
    fn reserve_release_cycle() {
        let p = RmaPool::new(4096, 1024);
        let s1 = p.try_reserve().unwrap();
        let _s2 = p.try_reserve().unwrap();
        assert_eq!(p.free_slots(), 2);
        drop(s1);
        assert_eq!(p.free_slots(), 3);
    }

    #[test]
    fn try_reserve_exhausts() {
        let p = RmaPool::new(2048, 1024);
        let _a = p.try_reserve().unwrap();
        let _b = p.try_reserve().unwrap();
        assert!(p.try_reserve().is_none());
    }

    #[test]
    fn blocking_reserve_wakes_on_release() {
        let p = RmaPool::new(1024, 1024);
        let slot = p.reserve();
        let p2 = p.clone();
        let h = std::thread::spawn(move || {
            let _s = p2.reserve(); // blocks until main drops
            p2.stall_stats().0
        });
        std::thread::sleep(Duration::from_millis(30));
        drop(slot);
        let stalls = h.join().unwrap();
        assert_eq!(stalls, 1);
        assert!(p.stall_stats().1 > 0);
    }

    #[test]
    fn reserve_timeout_expires() {
        let p = RmaPool::new(1024, 1024);
        let _hold = p.reserve();
        let t0 = Instant::now();
        assert!(p.reserve_timeout(Duration::from_millis(50)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(45));
    }

    #[test]
    fn slot_buffer_reusable() {
        let p = RmaPool::new(1024, 1024);
        {
            let mut s = p.reserve();
            s.buf().extend_from_slice(&[1, 2, 3]);
            assert_eq!(s.data(), &[1, 2, 3]);
        }
        let mut s = p.reserve();
        assert!(s.buf().is_empty(), "returned buffer must be cleared");
    }

    #[test]
    fn freeze_pins_buffer_until_last_ref_drops() {
        let p = RmaPool::new(2048, 1024);
        let mut slot = p.try_reserve().unwrap();
        slot.buf().extend_from_slice(&[7, 8, 9]);
        let frozen = slot.freeze();
        // The slot handle is gone but the buffer is still out of the pool.
        assert_eq!(p.free_slots(), 1);
        assert_eq!(frozen, vec![7, 8, 9]);
        let view = frozen.slice(1..3);
        drop(frozen);
        assert_eq!(p.free_slots(), 1, "live view keeps the buffer registered");
        assert_eq!(view, vec![8, 9]);
        drop(view);
        assert_eq!(p.free_slots(), 2, "last ref returns the buffer");
        // And it comes back cleared, like a plain slot release.
        let mut s = p.try_reserve().unwrap();
        let _ = p.try_reserve().unwrap();
        assert!(s.buf().is_empty(), "frozen buffer must return cleared");
    }

    #[test]
    fn freeze_is_zero_copy() {
        let p = RmaPool::new(1024, 1024);
        let mut slot = p.reserve();
        slot.buf().extend_from_slice(&[1; 64]);
        let before = slot.data().as_ptr() as usize;
        let frozen = slot.freeze();
        assert_eq!(frozen.as_slice().as_ptr() as usize, before);
    }
}
