//! Pluggable OST scheduling: the policy layer behind the per-OST work
//! queues ([`crate::coordinator::queues::OstQueues`]).
//!
//! LADS's core idea (§2.1) is that *which OST queue an IO thread drains
//! next* is a policy decision, and a good policy routes around congested
//! storage targets. The seed hardcoded one policy; this module turns the
//! choice into the system's primary experimentation surface. A policy is
//! anything implementing [`Scheduler`]; IO threads call
//! `OstQueues::pop_next(&*sched, osts)` and the queue layer consults the
//! policy under its lock.
//!
//! Policies read congestion through an [`OstCongestion`] view rather than
//! the raw [`OstModel`]: the view folds the session's own in-service
//! depth together with *foreign* load other jobs of the same daemon have
//! in flight on each OST (the shared [`crate::pfs::OstRegistry`] minted
//! per job as a [`JobOstHandle`] — the `ftlads serve` tentpole). A
//! standalone transfer uses [`OstCongestion::local`], where
//! `depth == OstModel::queue_depth` and every pick is bit-identical to
//! the registry-less behavior.
//!
//! A multi-stream source (`data_streams = K ≥ 2`) shares ONE policy
//! instance across its K per-stream queue sets: `pick` is consulted under
//! each queue set's own lock, so implementations must stay safe under
//! concurrent picks (the built-ins use atomics / internal locking — unit
//! policies trivially so), and stateful signals like the straggler EWMA
//! deliberately aggregate across streams, since OST service time is a
//! property of the storage target, not of the wire stream observing it.
//!
//! ## Built-in policies and the paper sections they model
//!
//! | policy | config name | models |
//! |---|---|---|
//! | [`CongestionAware`] | `congestion` | LADS §2.1/§5.1 layout- and congestion-aware dequeue — the seed behavior, extracted verbatim |
//! | [`RoundRobin`] | `round_robin` | uniform spread across OSTs; the ablation control with no congestion signal |
//! | [`FifoFile`] | `fifo_file` | bbcp-like logical-order drain (§2.1's "files in order" baseline) |
//! | [`StragglerAware`] | `straggler` | EWMA of per-OST service time with a slow-OST penalty, after Tavakoli et al. 2018 (client-side straggler-aware scheduling for object-based PFS) |
//!
//! ## Ordering contract (reproducibility)
//!
//! Every policy must be deterministic: given the same [`QueueView`], the
//! same [`OstCongestion`] readings, and the same internal state, `pick`
//! must return the same OST. Whenever a policy's primary score ties, it
//! must break the tie with the shared chain implemented by [`pick_min_by`]:
//! lower combined congestion depth first, then the *deeper* backlog
//! (drain pressure), then the lowest [`OstId`]. This is exactly the seed
//! scheduler's ordering, so `CongestionAware` (whose primary score *is*
//! the congestion depth) reproduces the seed's pick sequence bit for bit.
//!
//! ## Adding a policy
//!
//! 1. Add a unit (or stateful, with interior mutability — `pick` runs
//!    under the queue lock, hooks run outside it) struct implementing
//!    [`Scheduler`]. Use [`pick_min_by`] for the tie-break chain.
//! 2. Add a variant to [`SchedPolicy`], wire `parse`/`as_str`/`build`,
//!    and append it to [`SchedPolicy::ALL`] so the config/CLI layers, the
//!    `benches/ablation.rs` policy axis, and the integration tests pick
//!    it up automatically.
//! 3. Document which paper (section) the policy models in the table
//!    above.

pub mod congestion;
pub mod fifo_file;
pub mod round_robin;
pub mod straggler;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::Result;

use crate::pfs::ost::{OstId, OstModel};
use crate::pfs::registry::JobOstHandle;

pub use congestion::CongestionAware;
pub use fifo_file::FifoFile;
pub use round_robin::RoundRobin;
pub use straggler::StragglerAware;

/// A read-only snapshot of the per-OST queues, taken under the queue lock
/// right before `pick` is consulted. Indices are OST ids.
pub struct QueueView<'a> {
    /// `len[i]` — requests queued on OST `i`.
    pub len: &'a [usize],
    /// `head_seq[i]` — global arrival sequence number of OST `i`'s head
    /// request (`u64::MAX` when the queue is empty). Sequence numbers are
    /// assigned at enqueue time and strictly increase, so comparing heads
    /// recovers the global FIFO order.
    pub head_seq: &'a [u64],
}

impl QueueView<'_> {
    pub fn ost_count(&self) -> u32 {
        self.len.len() as u32
    }

    pub fn is_empty(&self, ost: OstId) -> bool {
        self.len
            .get(ost.0 as usize)
            .map(|&l| l == 0)
            .unwrap_or(true)
    }

    /// OSTs with at least one queued request, in id order.
    pub fn non_empty(&self) -> impl Iterator<Item = OstId> + '_ {
        self.len
            .iter()
            .enumerate()
            .filter(|(_, &l)| l > 0)
            .map(|(i, _)| OstId(i as u32))
    }
}

/// The congestion signal a [`Scheduler`] reads: the session's own
/// in-service depth per OST ([`OstModel::queue_depth`]) plus, when the
/// session runs under an `ftlads serve` daemon, the *foreign* in-flight
/// load other jobs currently have on that OST (their charges in the
/// shared [`crate::pfs::OstRegistry`], read through this job's
/// [`JobOstHandle`]).
///
/// With `shared == None` (every standalone transfer), `depth` is exactly
/// `queue_depth` and `foreign` is zero everywhere — policies behave
/// bit-identically to the pre-registry code.
#[derive(Clone, Copy)]
pub struct OstCongestion<'a> {
    osts: &'a OstModel,
    shared: Option<&'a JobOstHandle>,
}

impl<'a> OstCongestion<'a> {
    /// A session-local view: own service depth only, no cross-job signal.
    pub fn local(osts: &'a OstModel) -> OstCongestion<'a> {
        OstCongestion { osts, shared: None }
    }

    /// A daemon view folding in the job's shared-registry handle.
    pub fn with_shared(osts: &'a OstModel, shared: Option<&'a JobOstHandle>) -> OstCongestion<'a> {
        OstCongestion { osts, shared }
    }

    pub fn osts(&self) -> &'a OstModel {
        self.osts
    }

    pub fn has_shared(&self) -> bool {
        self.shared.is_some()
    }

    /// Combined congestion depth of `ost`: own in-service requests plus
    /// other jobs' in-flight requests. The score [`CongestionAware`] and
    /// the tie-break chain minimize.
    pub fn depth(&self, ost: OstId) -> usize {
        self.osts.queue_depth(ost) + self.foreign(ost)
    }

    /// Other jobs' in-flight requests on `ost` (zero without a registry).
    pub fn foreign(&self, ost: OstId) -> usize {
        self.shared.map_or(0, |h| h.foreign(ost))
    }
}

/// An OST dequeue policy. See the module docs for the ordering contract.
pub trait Scheduler: Send + Sync {
    /// Canonical policy name (matches [`SchedPolicy::as_str`]).
    fn name(&self) -> &'static str;

    /// Choose the OST whose queue the calling IO thread should drain
    /// next. Called under the queue lock with at least one non-empty
    /// queue; returning `None` or an empty/out-of-range OST makes the
    /// queue layer fall back to the lowest-id non-empty queue (progress
    /// is guaranteed regardless of the policy).
    fn pick(&self, view: &QueueView<'_>, cong: &OstCongestion<'_>) -> Option<OstId>;

    /// Hook: a request was handed to `ost`'s queue. Called outside the
    /// queue lock by the enqueuing thread; stateful policies may update
    /// arrival accounting here.
    fn on_enqueue(&self, _ost: OstId) {}

    /// Hook: a request dequeued from `ost` finished its storage service,
    /// taking `service` wall time. Called by IO threads after the
    /// pread/pwrite; stateful policies (e.g. [`StragglerAware`]) update
    /// their per-OST service-time estimates here.
    fn on_complete(&self, _ost: OstId, _service: Duration) {}
}

/// Shared deterministic selection: the non-empty OST minimizing
/// `(key(ost), congestion depth, deeper-backlog-first, OstId)`.
///
/// Every built-in policy routes its primary score through this helper so
/// ties resolve identically across policies and runs (the module-level
/// ordering contract).
pub fn pick_min_by<K: Ord>(
    view: &QueueView<'_>,
    cong: &OstCongestion<'_>,
    mut key: impl FnMut(OstId) -> K,
) -> Option<OstId> {
    view.non_empty().min_by_key(|&o| {
        (
            key(o),
            cong.depth(o),
            usize::MAX - view.len[o.0 as usize],
            o.0,
        )
    })
}

/// Per-side scheduling counters: how often the policy was consulted, how
/// long each `pick` took, and the storage service times it was fed back.
/// One instance lives in each coordinator side's shared state; IO threads
/// update it through [`crate::coordinator::queues::OstQueues::pop_next_timed`]
/// and their `on_complete` call sites, and the snapshot lands in
/// `TransferOutcome` / the CLI summary.
#[derive(Debug, Default)]
pub struct SchedStats {
    pub picks: AtomicU64,
    /// Picks where the policy returned `None`/an invalid OST and the
    /// queue layer fell back to the lowest-id non-empty queue.
    pub fallback_picks: AtomicU64,
    /// Total nanoseconds spent inside `Scheduler::pick` (under the queue
    /// lock — the policy's direct hot-path cost).
    pub pick_ns: AtomicU64,
    pub completes: AtomicU64,
    /// Total nanoseconds of storage service time reported to
    /// `on_complete`.
    pub service_ns: AtomicU64,
    /// Picks made while the shared [`crate::pfs::OstRegistry`] showed
    /// foreign (other-job) load on at least one non-empty candidate OST —
    /// i.e. picks where cross-job steering was possible at all.
    pub shared_picks: AtomicU64,
    /// The subset of `shared_picks` where the chosen OST itself carried
    /// no foreign load: the scheduler steered *around* the other jobs'
    /// hot OSTs. `shared_avoids / shared_picks` is the §A13 steering
    /// rate; both stay zero without a registry.
    pub shared_avoids: AtomicU64,
}

impl SchedStats {
    pub fn record_pick(&self, elapsed: Duration, fallback: bool) {
        self.picks.fetch_add(1, Ordering::Relaxed);
        self.pick_ns
            .fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        if fallback {
            self.fallback_picks.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_complete(&self, service: Duration) {
        self.completes.fetch_add(1, Ordering::Relaxed);
        self.service_ns
            .fetch_add(service.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record one pick's cross-job steering outcome (registry runs only).
    pub fn record_shared(&self, avoided: bool) {
        self.shared_picks.fetch_add(1, Ordering::Relaxed);
        if avoided {
            self.shared_avoids.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> SchedSnapshot {
        SchedSnapshot {
            picks: self.picks.load(Ordering::Relaxed),
            fallback_picks: self.fallback_picks.load(Ordering::Relaxed),
            pick_ns: self.pick_ns.load(Ordering::Relaxed),
            completes: self.completes.load(Ordering::Relaxed),
            service_ns: self.service_ns.load(Ordering::Relaxed),
            shared_picks: self.shared_picks.load(Ordering::Relaxed),
            shared_avoids: self.shared_avoids.load(Ordering::Relaxed),
        }
    }
}

/// Copyable summary of one side's [`SchedStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedSnapshot {
    pub picks: u64,
    pub fallback_picks: u64,
    pub pick_ns: u64,
    pub completes: u64,
    pub service_ns: u64,
    /// Picks where the shared registry showed foreign load on a
    /// candidate (zero for standalone transfers).
    pub shared_picks: u64,
    /// Foreign-load picks that steered to an OST with no foreign load.
    pub shared_avoids: u64,
}

impl SchedSnapshot {
    /// Mean time spent inside `pick`, nanoseconds.
    pub fn avg_pick_ns(&self) -> f64 {
        if self.picks == 0 {
            0.0
        } else {
            self.pick_ns as f64 / self.picks as f64
        }
    }

    /// Mean storage service time per completed request, microseconds.
    pub fn avg_service_us(&self) -> f64 {
        if self.completes == 0 {
            0.0
        } else {
            self.service_ns as f64 / self.completes as f64 / 1_000.0
        }
    }
}

/// The policy selector threaded through `Config`, the `--scheduler` CLI
/// flag, and the bench axes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    CongestionAware,
    RoundRobin,
    FifoFile,
    StragglerAware,
}

impl SchedPolicy {
    /// Every built-in policy — the sweep axis for `benches/ablation.rs`
    /// and the integration tests.
    pub const ALL: [SchedPolicy; 4] = [
        SchedPolicy::CongestionAware,
        SchedPolicy::RoundRobin,
        SchedPolicy::FifoFile,
        SchedPolicy::StragglerAware,
    ];

    pub fn parse(s: &str) -> Result<SchedPolicy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "congestion" | "congestion_aware" | "lads" => SchedPolicy::CongestionAware,
            "round_robin" | "rr" => SchedPolicy::RoundRobin,
            "fifo_file" | "fifo" | "bbcp" => SchedPolicy::FifoFile,
            "straggler" | "straggler_aware" | "ewma" => SchedPolicy::StragglerAware,
            _ => anyhow::bail!(
                "unknown scheduler '{s}' (congestion|round_robin|fifo_file|straggler)"
            ),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SchedPolicy::CongestionAware => "congestion",
            SchedPolicy::RoundRobin => "round_robin",
            SchedPolicy::FifoFile => "fifo_file",
            SchedPolicy::StragglerAware => "straggler",
        }
    }

    /// Instantiate the policy for a fleet of `ost_count` OSTs.
    pub fn build(&self, ost_count: u32) -> Box<dyn Scheduler> {
        match self {
            SchedPolicy::CongestionAware => Box::new(CongestionAware),
            SchedPolicy::RoundRobin => Box::new(RoundRobin::new()),
            SchedPolicy::FifoFile => Box::new(FifoFile),
            SchedPolicy::StragglerAware => Box::new(StragglerAware::new(ost_count)),
        }
    }
}

impl std::fmt::Display for SchedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::ost::OstConfig;

    fn idle_model(n: u32) -> OstModel {
        OstModel::new(n, OstConfig { time_scale: 0.0, ..Default::default() })
    }

    fn view<'a>(len: &'a [usize], head_seq: &'a [u64]) -> QueueView<'a> {
        QueueView { len, head_seq }
    }

    #[test]
    fn parse_roundtrips_and_aliases() {
        for p in SchedPolicy::ALL {
            assert_eq!(SchedPolicy::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(SchedPolicy::parse("LADS").unwrap(), SchedPolicy::CongestionAware);
        assert_eq!(SchedPolicy::parse("rr").unwrap(), SchedPolicy::RoundRobin);
        assert_eq!(SchedPolicy::parse("bbcp").unwrap(), SchedPolicy::FifoFile);
        assert_eq!(SchedPolicy::parse("ewma").unwrap(), SchedPolicy::StragglerAware);
        let err = SchedPolicy::parse("fastest").unwrap_err().to_string();
        for name in ["congestion", "round_robin", "fifo_file", "straggler"] {
            assert!(err.contains(name), "error should list '{name}': {err}");
        }
    }

    #[test]
    fn sched_stats_snapshot_and_averages() {
        let s = SchedStats::default();
        assert_eq!(s.snapshot(), SchedSnapshot::default());
        assert_eq!(s.snapshot().avg_pick_ns(), 0.0);
        assert_eq!(s.snapshot().avg_service_us(), 0.0);
        s.record_pick(Duration::from_nanos(100), false);
        s.record_pick(Duration::from_nanos(300), true);
        s.record_complete(Duration::from_micros(5));
        s.record_shared(true);
        s.record_shared(false);
        let snap = s.snapshot();
        assert_eq!(snap.picks, 2);
        assert_eq!(snap.fallback_picks, 1);
        assert_eq!(snap.pick_ns, 400);
        assert_eq!(snap.avg_pick_ns(), 200.0);
        assert_eq!(snap.completes, 1);
        assert_eq!(snap.avg_service_us(), 5.0);
        assert_eq!(snap.shared_picks, 2);
        assert_eq!(snap.shared_avoids, 1);
    }

    #[test]
    fn build_names_match_policy() {
        for p in SchedPolicy::ALL {
            assert_eq!(p.build(4).name(), p.as_str());
        }
    }

    #[test]
    fn pick_min_by_tie_break_chain() {
        let m = idle_model(4);
        let c = OstCongestion::local(&m);
        // Equal key everywhere: deeper backlog wins, then lowest id.
        let len = [1usize, 3, 3, 0];
        let seq = [0u64, 1, 2, u64::MAX];
        let v = view(&len, &seq);
        assert_eq!(pick_min_by(&v, &c, |_| 0u64), Some(OstId(1)));
        // Empty view picks nothing.
        let len = [0usize; 4];
        let seq = [u64::MAX; 4];
        let v = view(&len, &seq);
        assert_eq!(pick_min_by(&v, &c, |_| 0u64), None);
    }

    #[test]
    fn congestion_aware_orders_like_seed() {
        // Idle model: (depth, MAX-len, id) collapses to deeper backlog
        // first, ties by lowest id — the seed scheduler's exact order.
        let m = idle_model(5);
        let c = OstCongestion::local(&m);
        let len = [2usize, 1, 3, 0, 3];
        let seq = [0u64, 4, 1, u64::MAX, 3];
        let v = view(&len, &seq);
        assert_eq!(CongestionAware.pick(&v, &c), Some(OstId(2)));
    }

    #[test]
    fn fifo_file_drains_global_arrival_order() {
        let m = idle_model(3);
        let c = OstCongestion::local(&m);
        let len = [1usize, 2, 1];
        let seq = [7u64, 3, 5];
        let v = view(&len, &seq);
        assert_eq!(FifoFile.pick(&v, &c), Some(OstId(1)));
    }

    #[test]
    fn round_robin_cycles_non_empty_queues() {
        let m = idle_model(4);
        let c = OstCongestion::local(&m);
        let rr = RoundRobin::new();
        let len = [1usize, 0, 1, 1];
        let seq = [0u64, u64::MAX, 1, 2];
        let v = view(&len, &seq);
        assert_eq!(rr.pick(&v, &c), Some(OstId(0)));
        assert_eq!(rr.pick(&v, &c), Some(OstId(2)));
        assert_eq!(rr.pick(&v, &c), Some(OstId(3)));
        assert_eq!(rr.pick(&v, &c), Some(OstId(0)));
    }

    #[test]
    fn straggler_penalizes_slow_ost() {
        let m = idle_model(2);
        let c = OstCongestion::local(&m);
        let s = StragglerAware::new(2);
        // OST 0 is 10x slower than OST 1.
        for _ in 0..8 {
            s.on_complete(OstId(0), Duration::from_millis(10));
            s.on_complete(OstId(1), Duration::from_millis(1));
        }
        let len = [4usize, 1];
        let seq = [0u64, 1];
        let v = view(&len, &seq);
        // Despite OST 0's deeper backlog, the slow-OST penalty steers the
        // thread to OST 1.
        assert_eq!(s.pick(&v, &c), Some(OstId(1)));
    }

    #[test]
    fn straggler_with_no_samples_matches_congestion_order() {
        let m = idle_model(3);
        let c = OstCongestion::local(&m);
        let s = StragglerAware::new(3);
        let len = [1usize, 2, 1];
        let seq = [0u64, 1, 2];
        let v = view(&len, &seq);
        // No service history: every estimate ties, the shared tie-break
        // chain decides (deepest backlog, OST 1) — same as CongestionAware.
        assert_eq!(s.pick(&v, &c), CongestionAware.pick(&v, &c));
        assert_eq!(s.pick(&v, &c), Some(OstId(1)));
    }

    #[test]
    fn foreign_load_steers_congestion_pick_away() {
        use crate::pfs::registry::OstRegistry;
        let m = idle_model(3);
        let reg = OstRegistry::new(3);
        let me = reg.handle();
        let other = reg.handle();
        // Another job has 5 requests in flight on OST 0.
        for _ in 0..5 {
            other.begin(OstId(0));
        }
        let len = [3usize, 1, 0];
        let seq = [0u64, 1, u64::MAX];
        let v = view(&len, &seq);
        // Registry-blind: deeper backlog on an idle model wins → OST 0.
        let blind = OstCongestion::local(&m);
        assert_eq!(CongestionAware.pick(&v, &blind), Some(OstId(0)));
        // Registry-informed: OST 0 carries foreign depth 5 → steer to 1.
        let informed = OstCongestion::with_shared(&m, Some(&me));
        assert_eq!(informed.foreign(OstId(0)), 5);
        assert_eq!(informed.depth(OstId(0)), 5);
        assert_eq!(CongestionAware.pick(&v, &informed), Some(OstId(1)));
        // Own charges are not foreign: charging via `me` changes nothing.
        me.begin(OstId(1));
        assert_eq!(informed.foreign(OstId(1)), 0);
        me.end(OstId(1));
    }
}
