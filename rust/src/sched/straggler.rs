//! Straggler-aware dequeue: EWMA of per-OST service time with a slow-OST
//! penalty, after Tavakoli et al. 2018 (client-side straggler-aware
//! scheduling for object-based parallel file systems).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::pfs::ost::OstId;

use super::{pick_min_by, OstCongestion, QueueView, Scheduler};

/// EWMA weight: `new = (3*old + sample) / 4` (α = 1/4).
const EWMA_OLD_WEIGHT: u64 = 3;
const EWMA_DIV: u64 = 4;
/// An OST whose estimate exceeds twice the fleet's fastest estimate is a
/// straggler; its score is multiplied by this penalty so IO threads only
/// feed it when everything else is drained or deeply congested.
const STRAGGLER_FACTOR: u64 = 2;
const STRAGGLER_PENALTY: u64 = 4;

/// Score each OST by its expected wait — `(combined congestion depth
/// + 1) × EWMA(service time)`, where the depth folds in other jobs'
/// in-flight load under a serve daemon — and penalize stragglers. OSTs with no service
/// history yet borrow the fleet's fastest estimate so they are tried
/// early. With no history anywhere, every score ties and the shared
/// tie-break chain reduces this policy to [`super::CongestionAware`].
///
/// State updates ([`Scheduler::on_complete`]) use relaxed atomics: IO
/// threads race on the estimate, and a lost update only skews the EWMA by
/// one sample — acceptable for a scheduling heuristic, and the pick
/// itself stays deterministic for any given state.
#[derive(Debug)]
pub struct StragglerAware {
    /// Per-OST EWMA of service wall time, nanoseconds. 0 = no sample yet.
    ewma_ns: Vec<AtomicU64>,
}

impl StragglerAware {
    pub fn new(ost_count: u32) -> StragglerAware {
        StragglerAware {
            ewma_ns: (0..ost_count).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Current estimate for `ost` (0 = no sample yet). Exposed for tests
    /// and debugging.
    pub fn estimate_ns(&self, ost: OstId) -> u64 {
        self.ewma_ns
            .get(ost.0 as usize)
            .map(|a| a.load(Ordering::Relaxed))
            .unwrap_or(0)
    }
}

impl Scheduler for StragglerAware {
    fn name(&self) -> &'static str {
        "straggler"
    }

    fn pick(&self, view: &QueueView<'_>, cong: &OstCongestion<'_>) -> Option<OstId> {
        // Fastest known estimate — the baseline for both unknown OSTs and
        // the straggler threshold.
        let min_ewma = self
            .ewma_ns
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .filter(|&e| e > 0)
            .min()
            .unwrap_or(0);
        pick_min_by(view, cong, |o| {
            let e = self.estimate_ns(o);
            let est = if e == 0 { min_ewma } else { e };
            let mut score = (cong.depth(o) as u64 + 1).saturating_mul(est.max(1));
            if min_ewma > 0 && est > STRAGGLER_FACTOR * min_ewma {
                score = score.saturating_mul(STRAGGLER_PENALTY);
            }
            score
        })
    }

    fn on_complete(&self, ost: OstId, service: Duration) {
        let Some(cell) = self.ewma_ns.get(ost.0 as usize) else { return };
        let sample = (service.as_nanos() as u64).max(1);
        let old = cell.load(Ordering::Relaxed);
        let new = if old == 0 {
            sample // first sample seeds the estimate directly
        } else {
            (EWMA_OLD_WEIGHT * old + sample) / EWMA_DIV
        };
        cell.store(new, Ordering::Relaxed);
    }
}
