//! The seed scheduler, extracted verbatim: layout- and congestion-aware
//! dequeue (LADS §2.1/§5.1).

use crate::pfs::ost::OstId;

use super::{pick_min_by, OstCongestion, QueueView, Scheduler};

/// Dequeue from the least-congested non-empty OST. The congestion signal
/// is the combined [`OstCongestion::depth`]: the OST model's in-service
/// depth (requests queued or in service on the storage target itself)
/// plus any foreign load other jobs of the same daemon have in flight
/// there. Ties resolve by the shared chain — deeper backlog first, then
/// lowest id — which, for a standalone transfer (no foreign load), makes
/// this policy's pick order identical to the pre-refactor hardcoded
/// `pop_least_congested`.
///
/// If one OST is slow (external load, deep device queue, another job's
/// burst), IO threads naturally drain the others — "the N−1 threads are
/// free to issue new requests to other OSTs" (§2.1).
#[derive(Debug, Default, Clone, Copy)]
pub struct CongestionAware;

impl Scheduler for CongestionAware {
    fn name(&self) -> &'static str {
        "congestion"
    }

    fn pick(&self, view: &QueueView<'_>, cong: &OstCongestion<'_>) -> Option<OstId> {
        pick_min_by(view, cong, |o| cong.depth(o))
    }
}
