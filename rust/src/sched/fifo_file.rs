//! Global-FIFO dequeue: drain requests in arrival order, which — because
//! the source master enqueues files front to back — drains *files in
//! order*, the bbcp-like logical-order baseline LADS argues against
//! (§2.1: logical order ignores the physical layout).

use crate::pfs::ost::OstId;

use super::{pick_min_by, OstCongestion, QueueView, Scheduler};

/// Pick the OST whose head request arrived earliest (lowest global
/// sequence number). Empty queues report `u64::MAX` heads and are never
/// chosen; ties (impossible between distinct live sequence numbers, but
/// the contract demands it) fall back to the shared chain.
#[derive(Debug, Default, Clone, Copy)]
pub struct FifoFile;

impl Scheduler for FifoFile {
    fn name(&self) -> &'static str {
        "fifo_file"
    }

    fn pick(&self, view: &QueueView<'_>, cong: &OstCongestion<'_>) -> Option<OstId> {
        pick_min_by(view, cong, |o| view.head_seq[o.0 as usize])
    }
}
