//! Round-robin dequeue: uniform spread with no congestion signal — the
//! ablation control for the layout-aware policies.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::pfs::ost::OstId;

use super::{OstCongestion, QueueView, Scheduler};

/// Cycle through the OSTs, draining the next non-empty queue after the
/// previously picked one. Deterministic: the pick sequence is a pure
/// function of the enqueue history (the cursor advances only on picks).
///
/// Stateful: the cursor lives behind an atomic because `pick` takes
/// `&self`; calls are serialized by the queue lock, so plain
/// load/store ordering suffices.
#[derive(Debug)]
pub struct RoundRobin {
    /// Last picked OST id; `u32::MAX` before the first pick so the scan
    /// starts at OST 0.
    cursor: AtomicU32,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { cursor: AtomicU32::new(u32::MAX) }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn pick(&self, view: &QueueView<'_>, _cong: &OstCongestion<'_>) -> Option<OstId> {
        let n = view.ost_count();
        if n == 0 {
            return None;
        }
        let start = self.cursor.load(Ordering::Relaxed).wrapping_add(1);
        for k in 0..n {
            let i = start.wrapping_add(k) % n;
            if view.len[i as usize] > 0 {
                self.cursor.store(i, Ordering::Relaxed);
                return Some(OstId(i));
            }
        }
        None
    }
}
