//! Real-file-backed PFS: files live under `root/`, the OST service model
//! still charges simulated per-OST time on top of the real I/O.
//!
//! Used by the end-to-end example (`examples/quickstart.rs` with
//! `--backend disk`) so at least one driver moves *real bytes on a real
//! file system*. Layout metadata (start OST, committed flag) is kept in a
//! sidecar `.ftmeta` file per data file, mirroring what Lustre keeps in
//! the MDS.

use std::fs;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::layout::StripeLayout;
use super::ost::{OstConfig, OstModel};
use super::{FileId, FileMeta, Pfs};

pub struct DiskPfs {
    root: PathBuf,
    layout: StripeLayout,
    osts: OstModel,
    ids: Mutex<std::collections::BTreeMap<u64, String>>,
    next_id: AtomicU64,
}

impl DiskPfs {
    pub fn new(root: &Path, layout: StripeLayout, ost_cfg: OstConfig) -> Result<Self> {
        fs::create_dir_all(root)
            .with_context(|| format!("creating PFS root {}", root.display()))?;
        let osts = OstModel::new(layout.ost_count, ost_cfg);
        Ok(DiskPfs {
            root: root.to_path_buf(),
            layout,
            osts,
            ids: Mutex::new(std::collections::BTreeMap::new()),
            next_id: AtomicU64::new(1),
        })
    }

    fn data_path(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    fn meta_path(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.ftmeta"))
    }

    fn read_meta(&self, name: &str) -> Option<FileMeta> {
        let text = fs::read_to_string(self.meta_path(name)).ok()?;
        let mut size = None;
        let mut committed = false;
        let mut start_ost = 0;
        for line in text.lines() {
            let (k, v) = line.split_once('=')?;
            match k {
                "size" => size = v.parse().ok(),
                "committed" => committed = v == "1",
                "start_ost" => start_ost = v.parse().ok()?,
                _ => {}
            }
        }
        Some(FileMeta { name: name.to_string(), size: size?, committed, start_ost })
    }

    fn write_meta(&self, meta: &FileMeta) -> Result<()> {
        let text = format!(
            "size={}\ncommitted={}\nstart_ost={}\n",
            meta.size,
            if meta.committed { 1 } else { 0 },
            meta.start_ost
        );
        fs::write(self.meta_path(&meta.name), text).context("writing .ftmeta")
    }

    fn name_of(&self, id: FileId) -> Result<String> {
        self.ids
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&id.0)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no open file id {}", id.0))
    }

    /// Register an existing file (e.g. created by a previous process) so it
    /// gets an id in this process.
    fn register(&self, name: &str) -> FileId {
        let mut ids = self.ids.lock().unwrap_or_else(|e| e.into_inner());
        if let Some((id, _)) = ids.iter().find(|(_, n)| n.as_str() == name) {
            return FileId(*id);
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        ids.insert(id, name.to_string());
        FileId(id)
    }

    /// Import a directory of plain files as a committed dataset (source
    /// pre-population from real data).
    pub fn import_dir(&self, dir: &Path) -> Result<usize> {
        let mut count = 0usize;
        let mut entries: Vec<_> = fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().is_file())
            .collect();
        entries.sort_by_key(|e| e.file_name());
        for entry in entries {
            let name = entry.file_name().to_string_lossy().to_string();
            if name.ends_with(".ftmeta") {
                continue;
            }
            let size = entry.metadata()?.len();
            let start = self.layout.round_robin_start(count as u64);
            fs::copy(entry.path(), self.data_path(&name))?;
            self.write_meta(&FileMeta {
                name: name.clone(),
                size,
                committed: true,
                start_ost: start,
            })?;
            self.register(&name);
            count += 1;
        }
        Ok(count)
    }
}

/// Write every byte of `iovs` at `offset` with gathered positional I/O:
/// `libc::pwritev` on unix, advancing the iov cursor across short writes
/// so a partial write never silently drops bytes. Non-unix targets fall
/// back to one seek + `write_all` of a scratch join — still a single
/// write submission, just without the zero-copy gather.
#[cfg(unix)]
fn pwritev_all(f: &fs::File, offset: u64, iovs: &[&[u8]]) -> std::io::Result<()> {
    use std::os::unix::io::AsRawFd;
    // POSIX caps iovcnt at IOV_MAX; a longer run is submitted as
    // ceil(n / IOV_MAX) gathered syscalls instead of failing EINVAL
    // (which would demote every run to per-block writes). The sink caps
    // runs at the same shared constant, so splitting never actually
    // fires there and `write_syscalls` stays exact.
    const MAX_IOVS: usize = super::IOV_MAX_GATHER;
    let fd = f.as_raw_fd();
    let total: u64 = iovs.iter().map(|v| v.len() as u64).sum();
    let mut written = 0u64;
    while written < total {
        // Rebuild the iovec list past what has already landed.
        let mut skip = written;
        let mut vecs: Vec<libc::iovec> = Vec::with_capacity(iovs.len().min(MAX_IOVS));
        for iov in iovs {
            if vecs.len() == MAX_IOVS {
                break;
            }
            let len = iov.len() as u64;
            if skip >= len {
                skip -= len;
                continue;
            }
            vecs.push(libc::iovec {
                iov_base: unsafe { iov.as_ptr().add(skip as usize) } as *mut libc::c_void,
                iov_len: (len - skip) as usize,
            });
            skip = 0;
        }
        // off_t is i32 on some 32-bit targets: reject rather than wrap
        // to a negative offset (the caller then degrades to per-block
        // writes, whose u64 seek path is offset-safe).
        let pos = libc::off_t::try_from(offset + written).map_err(|_| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "write offset exceeds off_t on this target",
            )
        })?;
        let n = unsafe { libc::pwritev(fd, vecs.as_ptr(), vecs.len() as libc::c_int, pos) };
        if n < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            return Err(err);
        }
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                "pwritev wrote 0 bytes",
            ));
        }
        written += n as u64;
    }
    Ok(())
}

#[cfg(not(unix))]
fn pwritev_all(f: &fs::File, offset: u64, iovs: &[&[u8]]) -> std::io::Result<()> {
    let mut f = f;
    let mut scratch = Vec::with_capacity(iovs.iter().map(|v| v.len()).sum());
    for iov in iovs {
        scratch.extend_from_slice(iov);
    }
    f.seek(SeekFrom::Start(offset))?;
    f.write_all(&scratch)
}

/// Fill `iovs` from `offset` with scattered positional I/O:
/// `libc::preadv` on unix, advancing the iov cursor across short reads
/// (posix permits them) and stopping at EOF. Returns the total bytes
/// read. Non-unix targets fall back to one seek + read into a scratch
/// buffer scattered out afterwards — still a single read submission.
#[cfg(unix)]
fn preadv_all(f: &fs::File, offset: u64, iovs: &mut [&mut [u8]]) -> std::io::Result<usize> {
    use std::os::unix::io::AsRawFd;
    // Same IOV_MAX discipline as `pwritev_all`: the source caps gathered
    // runs at the shared constant, so the split never actually fires and
    // `read_syscalls` stays exact.
    const MAX_IOVS: usize = super::IOV_MAX_GATHER;
    let fd = f.as_raw_fd();
    let total: u64 = iovs.iter().map(|v| v.len() as u64).sum();
    let mut read = 0u64;
    while read < total {
        // Rebuild the iovec list past what has already arrived.
        let mut skip = read;
        let mut vecs: Vec<libc::iovec> = Vec::with_capacity(iovs.len().min(MAX_IOVS));
        for iov in iovs.iter_mut() {
            if vecs.len() == MAX_IOVS {
                break;
            }
            let len = iov.len() as u64;
            if skip >= len {
                skip -= len;
                continue;
            }
            vecs.push(libc::iovec {
                iov_base: unsafe { iov.as_mut_ptr().add(skip as usize) } as *mut libc::c_void,
                iov_len: (len - skip) as usize,
            });
            skip = 0;
        }
        let pos = libc::off_t::try_from(offset + read).map_err(|_| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "read offset exceeds off_t on this target",
            )
        })?;
        let n = unsafe { libc::preadv(fd, vecs.as_ptr(), vecs.len() as libc::c_int, pos) };
        if n < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            return Err(err);
        }
        if n == 0 {
            break; // EOF inside the run: short total, like read_at
        }
        read += n as u64;
    }
    Ok(read as usize)
}

#[cfg(not(unix))]
fn preadv_all(f: &fs::File, offset: u64, iovs: &mut [&mut [u8]]) -> std::io::Result<usize> {
    let mut f = f;
    let total: usize = iovs.iter().map(|v| v.len()).sum();
    let mut scratch = vec![0u8; total];
    f.seek(SeekFrom::Start(offset))?;
    let mut got = 0usize;
    while got < total {
        let n = f.read(&mut scratch[got..])?;
        if n == 0 {
            break;
        }
        got += n;
    }
    let mut off = 0usize;
    for iov in iovs.iter_mut() {
        if off >= got {
            break;
        }
        let n = iov.len().min(got - off);
        iov[..n].copy_from_slice(&scratch[off..off + n]);
        off += n;
    }
    Ok(got)
}

impl Pfs for DiskPfs {
    fn layout(&self) -> &StripeLayout {
        &self.layout
    }

    fn ost_model(&self) -> &OstModel {
        &self.osts
    }

    fn lookup(&self, name: &str) -> Option<(FileId, FileMeta)> {
        let meta = self.read_meta(name)?;
        if !self.data_path(name).exists() {
            return None;
        }
        Some((self.register(name), meta))
    }

    fn list(&self) -> Vec<String> {
        let mut names: Vec<String> = fs::read_dir(&self.root)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .map(|e| e.file_name().to_string_lossy().to_string())
                    .filter(|n| !n.ends_with(".ftmeta"))
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }

    fn create(&self, name: &str, size: u64, start_ost: u32) -> Result<FileId> {
        let f = fs::File::create(self.data_path(name))
            .with_context(|| format!("creating {}", name))?;
        f.set_len(size)?;
        self.write_meta(&FileMeta {
            name: name.to_string(),
            size,
            committed: false,
            start_ost: start_ost % self.layout.ost_count,
        })?;
        Ok(self.register(name))
    }

    fn read_at(&self, file: FileId, offset: u64, buf: &mut [u8]) -> Result<usize> {
        let name = self.name_of(file)?;
        let meta = self
            .read_meta(&name)
            .ok_or_else(|| anyhow::anyhow!("no metadata for '{name}'"))?;
        let ost = self.layout.ost_for(meta.start_ost, offset);
        let mut f = fs::File::open(self.data_path(&name))?;
        f.seek(SeekFrom::Start(offset))?;
        let mut total = 0usize;
        while total < buf.len() {
            let n = f.read(&mut buf[total..])?;
            if n == 0 {
                break;
            }
            total += n;
        }
        self.osts.service(ost, total as u64, false);
        Ok(total)
    }

    fn write_at(&self, file: FileId, offset: u64, data: &[u8]) -> Result<bool> {
        let name = self.name_of(file)?;
        let meta = self
            .read_meta(&name)
            .ok_or_else(|| anyhow::anyhow!("no metadata for '{name}'"))?;
        let ost = self.layout.ost_for(meta.start_ost, offset);
        let mut f = fs::OpenOptions::new().write(true).open(self.data_path(&name))?;
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(data)?;
        self.osts.service(ost, data.len() as u64, true);
        // Real storage persists what it was given.
        Ok(true)
    }

    /// Gathered write: ONE `pwritev` syscall for the whole run on unix
    /// (looping only on short writes, which posix permits), a single
    /// `write_all` of a scratch join elsewhere. Either way the OST model
    /// is charged one service round for the run — the coalescing win.
    fn write_at_vectored(&self, file: FileId, offset: u64, iovs: &[&[u8]]) -> Result<Vec<usize>> {
        let name = self.name_of(file)?;
        let meta = self
            .read_meta(&name)
            .ok_or_else(|| anyhow::anyhow!("no metadata for '{name}'"))?;
        let ost = self.layout.ost_for(meta.start_ost, offset);
        let total: u64 = iovs.iter().map(|v| v.len() as u64).sum();
        let f = fs::OpenOptions::new().write(true).open(self.data_path(&name))?;
        pwritev_all(&f, offset, iovs)?;
        self.osts.service(ost, total, true);
        Ok(Vec::new())
    }

    /// Scattered read: ONE `preadv` syscall for the whole run on unix
    /// (looping only on short reads), a single scratch read elsewhere.
    /// Either way the OST model is charged one service round for the
    /// run — the gather win, mirroring `write_at_vectored`.
    fn read_at_vectored(
        &self,
        file: FileId,
        offset: u64,
        iovs: &mut [&mut [u8]],
    ) -> Result<usize> {
        let name = self.name_of(file)?;
        let meta = self
            .read_meta(&name)
            .ok_or_else(|| anyhow::anyhow!("no metadata for '{name}'"))?;
        let ost = self.layout.ost_for(meta.start_ost, offset);
        let f = fs::File::open(self.data_path(&name))?;
        let n = preadv_all(&f, offset, iovs)?;
        self.osts.service(ost, n as u64, false);
        Ok(n)
    }

    fn commit_file(&self, file: FileId) -> Result<()> {
        let name = self.name_of(file)?;
        let mut meta = self
            .read_meta(&name)
            .ok_or_else(|| anyhow::anyhow!("no metadata for '{name}'"))?;
        meta.committed = true;
        self.write_meta(&meta)
    }

    fn remove(&self, name: &str) -> Result<()> {
        fs::remove_file(self.data_path(name))
            .with_context(|| format!("removing {name}"))?;
        let _ = fs::remove_file(self.meta_path(name));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> OstConfig {
        OstConfig { time_scale: 0.0, ..Default::default() }
    }

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ftlads-diskpfs-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn create_write_read_roundtrip() {
        let root = tmp_root("rw");
        let pfs = DiskPfs::new(&root, StripeLayout::paper(), fast_cfg()).unwrap();
        let id = pfs.create("a.bin", 64, 3).unwrap();
        assert!(pfs.write_at(id, 16, &[9u8; 8]).unwrap());
        let mut buf = [0u8; 8];
        assert_eq!(pfs.read_at(id, 16, &mut buf).unwrap(), 8);
        assert_eq!(buf, [9u8; 8]);
        // Holes read back as zeros (set_len preallocates sparse).
        assert_eq!(pfs.read_at(id, 0, &mut buf).unwrap(), 8);
        assert_eq!(buf, [0u8; 8]);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn metadata_roundtrip_and_commit() {
        let root = tmp_root("meta");
        let pfs = DiskPfs::new(&root, StripeLayout::paper(), fast_cfg()).unwrap();
        let id = pfs.create("f", 100, 7).unwrap();
        let (_, meta) = pfs.lookup("f").unwrap();
        assert_eq!(meta.size, 100);
        assert_eq!(meta.start_ost, 7);
        assert!(!meta.committed);
        pfs.commit_file(id).unwrap();
        assert!(pfs.lookup("f").unwrap().1.committed);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn metadata_survives_new_instance() {
        let root = tmp_root("persist");
        {
            let pfs = DiskPfs::new(&root, StripeLayout::paper(), fast_cfg()).unwrap();
            let id = pfs.create("p", 10, 2).unwrap();
            pfs.write_at(id, 0, &[1u8; 10]).unwrap();
            pfs.commit_file(id).unwrap();
        }
        let pfs2 = DiskPfs::new(&root, StripeLayout::paper(), fast_cfg()).unwrap();
        let (id, meta) = pfs2.lookup("p").unwrap();
        assert!(meta.committed);
        let mut buf = [0u8; 10];
        assert_eq!(pfs2.read_at(id, 0, &mut buf).unwrap(), 10);
        assert_eq!(buf, [1u8; 10]);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn vectored_write_gathers_one_run() {
        let root = tmp_root("vec");
        let pfs = DiskPfs::new(&root, StripeLayout::paper(), fast_cfg()).unwrap();
        let id = pfs.create("v.bin", 64, 0).unwrap();
        let (a, b, c): (&[u8], &[u8], &[u8]) = (&[1; 8], &[2; 4], &[3; 12]);
        let corrupted = pfs.write_at_vectored(id, 8, &[a, b, c]).unwrap();
        assert!(corrupted.is_empty(), "real storage is always faithful");
        let mut buf = [0u8; 24];
        assert_eq!(pfs.read_at(id, 8, &mut buf).unwrap(), 24);
        let mut want = Vec::new();
        want.extend_from_slice(a);
        want.extend_from_slice(b);
        want.extend_from_slice(c);
        assert_eq!(&buf[..], &want[..]);
        // One OST write round charged for the whole run.
        let stats = pfs.ost_model().total_stats();
        assert_eq!(stats.writes, 1);
        assert_eq!(stats.bytes_written, 24);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn vectored_write_longer_than_iov_max_lands_fully() {
        // 1500 one-byte iovs: more than POSIX's IOV_MAX (1024), so the
        // gather must be split across pwritev calls without losing bytes.
        let root = tmp_root("iovmax");
        let pfs = DiskPfs::new(&root, StripeLayout::paper(), fast_cfg()).unwrap();
        let n = 1500usize;
        let id = pfs.create("big.bin", n as u64, 0).unwrap();
        let bytes: Vec<[u8; 1]> = (0..n).map(|i| [(i % 251) as u8]).collect();
        let iovs: Vec<&[u8]> = bytes.iter().map(|b| &b[..]).collect();
        assert!(pfs.write_at_vectored(id, 0, &iovs).unwrap().is_empty());
        let mut buf = vec![0u8; n];
        assert_eq!(pfs.read_at(id, 0, &mut buf).unwrap(), n);
        for (i, b) in buf.iter().enumerate() {
            assert_eq!(*b, (i % 251) as u8, "byte {i}");
        }
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn vectored_read_scatters_one_run() {
        let root = tmp_root("vread");
        let pfs = DiskPfs::new(&root, StripeLayout::paper(), fast_cfg()).unwrap();
        let id = pfs.create("r.bin", 64, 0).unwrap();
        let data: Vec<u8> = (0..24u8).collect();
        assert!(pfs.write_at(id, 8, &data).unwrap());
        let (mut a, mut b, mut c) = ([0u8; 8], [0u8; 4], [0u8; 12]);
        let reads_before = pfs.ost_model().total_stats().reads;
        let n = pfs
            .read_at_vectored(id, 8, &mut [&mut a[..], &mut b[..], &mut c[..]])
            .unwrap();
        assert_eq!(n, 24);
        let mut got = Vec::new();
        got.extend_from_slice(&a);
        got.extend_from_slice(&b);
        got.extend_from_slice(&c);
        assert_eq!(got, data);
        // One OST read round charged for the whole run.
        assert_eq!(pfs.ost_model().total_stats().reads, reads_before + 1);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn vectored_read_short_at_eof() {
        let root = tmp_root("vreadeof");
        let pfs = DiskPfs::new(&root, StripeLayout::paper(), fast_cfg()).unwrap();
        let id = pfs.create("s.bin", 10, 0).unwrap();
        pfs.write_at(id, 0, &[7u8; 10]).unwrap();
        let (mut a, mut b) = ([0u8; 8], [0u8; 8]);
        let n = pfs
            .read_at_vectored(id, 0, &mut [&mut a[..], &mut b[..]])
            .unwrap();
        assert_eq!(n, 10, "EOF inside the run returns the short total");
        assert_eq!(a, [7u8; 8]);
        assert_eq!(&b[..2], &[7u8; 2]);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn list_excludes_sidecars() {
        let root = tmp_root("list");
        let pfs = DiskPfs::new(&root, StripeLayout::paper(), fast_cfg()).unwrap();
        pfs.create("b", 1, 0).unwrap();
        pfs.create("a", 1, 0).unwrap();
        assert_eq!(pfs.list(), vec!["a".to_string(), "b".to_string()]);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn import_dir_registers_committed_files() {
        let root = tmp_root("imp");
        let src = tmp_root("impsrc");
        fs::create_dir_all(&src).unwrap();
        fs::write(src.join("x.dat"), b"hello world").unwrap();
        fs::write(src.join("y.dat"), b"abc").unwrap();
        let pfs = DiskPfs::new(&root, StripeLayout::paper(), fast_cfg()).unwrap();
        assert_eq!(pfs.import_dir(&src).unwrap(), 2);
        let (_, meta) = pfs.lookup("x.dat").unwrap();
        assert_eq!(meta.size, 11);
        assert!(meta.committed);
        // Round-robin starts: x is file 0, y is file 1.
        assert_eq!(pfs.lookup("y.dat").unwrap().1.start_ost, 1);
        let _ = fs::remove_dir_all(&root);
        let _ = fs::remove_dir_all(&src);
    }
}
