//! In-memory simulated PFS with deterministic synthetic data.
//!
//! Source side: files are declared with a size; `read_at` synthesizes
//! their bytes deterministically from `(seed, file, word index)` — O(1)
//! random access, no RAM proportional to the dataset, and the *same*
//! function regenerates the bytes anywhere (which is how tests verify
//! end-to-end integrity without a second copy of the data).
//!
//! Sink side: `write_at`/`write_at_vectored` record a digest ledger entry
//! per written range (plus optionally the raw bytes), so tests can check
//! every object landed exactly once with exactly the right content.
//! Write-corruption hooks flip a byte of the *stored* copy on the way
//! down — reported back through the write's fidelity return value — to
//! exercise the §3.2 failure mode that motivates BLOCK_SYNC + integrity
//! verification.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

use super::layout::StripeLayout;
use super::ost::{OstConfig, OstModel};
use super::{FileId, FileMeta, Pfs};
use crate::integrity::native::{digest_bytes, Digest};

/// Deterministic lane generator: splitmix64 of (seed, file, 8-byte lane).
/// One mix produces a full 8-byte lane (§Perf: the 4-byte-per-mix version
/// made data *generation* the dominant cost of time_scale=0 transfers).
#[inline]
pub fn synth_lane(seed: u64, file: u64, lane_idx: u64) -> u64 {
    let mut z = seed
        .wrapping_add(file.wrapping_mul(0x9e37_79b9_7f4a_7c15))
        .wrapping_add(lane_idx.wrapping_mul(0xbf58_476d_1ce4_e5b9));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Deterministic word view (u32 half of a lane) — kept for tests.
#[inline]
pub fn synth_word(seed: u64, file: u64, word_idx: u64) -> u32 {
    let lane = synth_lane(seed, file, word_idx / 2);
    (lane >> (32 * (word_idx & 1))) as u32
}

/// Fill `buf` with the synthetic content of `file` starting at `offset`.
pub fn synth_fill(seed: u64, file: u64, offset: u64, buf: &mut [u8]) {
    let mut pos = 0usize;
    let mut off = offset;
    // Unaligned head up to an 8-byte lane boundary.
    while pos < buf.len() && off % 8 != 0 {
        let lane = synth_lane(seed, file, off / 8).to_le_bytes();
        let within = (off % 8) as usize;
        let take = (8 - within).min(buf.len() - pos);
        buf[pos..pos + take].copy_from_slice(&lane[within..within + take]);
        pos += take;
        off += take as u64;
    }
    // Bulk: one mix per 8 bytes.
    let mut lane_idx = off / 8;
    let mut chunks = buf[pos..].chunks_exact_mut(8);
    for c in &mut chunks {
        c.copy_from_slice(&synth_lane(seed, file, lane_idx).to_le_bytes());
        lane_idx += 1;
    }
    let rem = chunks.into_remainder();
    if !rem.is_empty() {
        let lane = synth_lane(seed, file, lane_idx).to_le_bytes();
        rem.copy_from_slice(&lane[..rem.len()]);
    }
}

struct SimFile {
    id: u64,
    meta: FileMeta,
    /// Sink ledger: offset -> (digest, len) of the last write there.
    writes: BTreeMap<u64, (Digest, u32)>,
    /// Raw stored bytes (only when `store_data`).
    data: Option<Vec<u8>>,
}

/// One (file, offset) write to corrupt (single shot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CorruptionTarget {
    pub file_name_hash: u64,
    pub offset: u64,
}

pub struct SimPfs {
    layout: StripeLayout,
    osts: OstModel,
    seed: u64,
    files: Mutex<BTreeMap<String, SimFile>>,
    ids: Mutex<BTreeMap<u64, String>>,
    next_id: AtomicU64,
    store_data: bool,
    /// Pending single-shot write corruptions (§3.2 failure injection).
    corruptions: Mutex<Vec<CorruptionTarget>>,
    pub corrupted_writes: AtomicU64,
}

impl SimPfs {
    pub fn new(layout: StripeLayout, ost_cfg: OstConfig, seed: u64) -> Self {
        let osts = OstModel::new(layout.ost_count, ost_cfg);
        SimPfs {
            layout,
            osts,
            seed,
            files: Mutex::new(BTreeMap::new()),
            ids: Mutex::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            store_data: false,
            corruptions: Mutex::new(Vec::new()),
            corrupted_writes: AtomicU64::new(0),
        }
    }

    /// Keep raw written bytes (small tests only — memory grows with data).
    pub fn with_stored_data(mut self) -> Self {
        self.store_data = true;
        self
    }

    /// Source-side pre-population: declare `(name, size)` files, start OSTs
    /// assigned round-robin like a quiet Lustre allocator.
    pub fn populate(&self, files: &[(String, u64)]) {
        for (i, (name, size)) in files.iter().enumerate() {
            let start = self.layout.round_robin_start(i as u64);
            self.create(name, *size, start).expect("populate create");
            // Pre-populated source files are complete by definition.
            let (id, _) = self.lookup(name).unwrap();
            self.commit_file(id).unwrap();
        }
    }

    /// Arrange for the next write covering `(file_name, offset)` to be
    /// corrupted (one byte of the stored copy flipped) before it lands;
    /// the write reports the infidelity through its return value.
    pub fn inject_write_corruption(&self, file_name: &str, offset: u64) {
        self.corruptions
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(CorruptionTarget { file_name_hash: name_hash(file_name), offset });
    }

    /// Sink ledger: digest of the last write at exactly `offset`, if any.
    pub fn written_digest(&self, name: &str, offset: u64) -> Option<(Digest, u32)> {
        let files = self.files.lock().unwrap_or_else(|e| e.into_inner());
        files.get(name)?.writes.get(&offset).copied()
    }

    /// Total distinct offsets written for `name`.
    pub fn written_ranges(&self, name: &str) -> usize {
        let files = self.files.lock().unwrap_or_else(|e| e.into_inner());
        files.get(name).map(|f| f.writes.len()).unwrap_or(0)
    }

    /// Raw stored bytes (requires `with_stored_data`).
    pub fn stored_data(&self, name: &str) -> Option<Vec<u8>> {
        let files = self.files.lock().unwrap_or_else(|e| e.into_inner());
        files.get(name)?.data.clone()
    }

    /// The digest an honest source would compute for `(file, offset, len)`
    /// of this PFS's synthetic content.
    pub fn expected_digest(&self, name: &str, offset: u64, len: usize) -> Digest {
        let fid_hash = name_hash(name);
        let mut buf = vec![0u8; len];
        synth_fill(self.seed, fid_hash, offset, &mut buf);
        digest_bytes(&buf)
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

fn name_hash(name: &str) -> u64 {
    // FNV-1a 64.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl Pfs for SimPfs {
    fn layout(&self) -> &StripeLayout {
        &self.layout
    }

    fn ost_model(&self) -> &OstModel {
        &self.osts
    }

    fn lookup(&self, name: &str) -> Option<(FileId, FileMeta)> {
        let files = self.files.lock().unwrap_or_else(|e| e.into_inner());
        let f = files.get(name)?;
        Some((FileId(f.id), f.meta.clone()))
    }

    fn list(&self) -> Vec<String> {
        self.files
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .cloned()
            .collect()
    }

    fn create(&self, name: &str, size: u64, start_ost: u32) -> Result<FileId> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let mut files = self.files.lock().unwrap_or_else(|e| e.into_inner());
        files.insert(
            name.to_string(),
            SimFile {
                id,
                meta: FileMeta {
                    name: name.to_string(),
                    size,
                    committed: false,
                    start_ost: start_ost % self.layout.ost_count,
                },
                writes: BTreeMap::new(),
                data: self.store_data.then(|| vec![0u8; size as usize]),
            },
        );
        self.ids
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(id, name.to_string());
        Ok(FileId(id))
    }

    fn read_at(&self, file: FileId, offset: u64, buf: &mut [u8]) -> Result<usize> {
        let (name, size, start_ost) = {
            let ids = self.ids.lock().unwrap_or_else(|e| e.into_inner());
            let name = ids
                .get(&file.0)
                .ok_or_else(|| anyhow::anyhow!("read_at: no file id {}", file.0))?
                .clone();
            let files = self.files.lock().unwrap_or_else(|e| e.into_inner());
            let f = &files[&name];
            (name, f.meta.size, f.meta.start_ost)
        };
        if offset >= size {
            return Ok(0);
        }
        let n = buf.len().min((size - offset) as usize);
        // Charge the serving OST before producing data (pread semantics).
        let ost = self.layout.ost_for(start_ost, offset);
        self.osts.service(ost, n as u64, false);
        synth_fill(self.seed, name_hash(&name), offset, &mut buf[..n]);
        Ok(n)
    }

    fn write_at(&self, file: FileId, offset: u64, data: &[u8]) -> Result<bool> {
        Ok(self.write_at_vectored(file, offset, &[data])?.is_empty())
    }

    /// One charged OST service op for the whole scattered run — the
    /// gather win `read_at` pays per object. Fill semantics match
    /// `read_at` exactly (same synthetic bytes, short total at EOF).
    fn read_at_vectored(
        &self,
        file: FileId,
        offset: u64,
        iovs: &mut [&mut [u8]],
    ) -> Result<usize> {
        let (name, size, start_ost) = {
            let ids = self.ids.lock().unwrap_or_else(|e| e.into_inner());
            let name = ids
                .get(&file.0)
                .ok_or_else(|| anyhow::anyhow!("read_at: no file id {}", file.0))?
                .clone();
            let files = self.files.lock().unwrap_or_else(|e| e.into_inner());
            let f = &files[&name];
            (name, f.meta.size, f.meta.start_ost)
        };
        if offset >= size {
            return Ok(0);
        }
        let want: u64 = iovs.iter().map(|v| v.len() as u64).sum();
        let n = want.min(size - offset) as usize;
        // ONE service round for the gathered run, charged before the data
        // is produced (pread semantics), on the OST serving the head.
        let ost = self.layout.ost_for(start_ost, offset);
        self.osts.service(ost, n as u64, false);
        let h = name_hash(&name);
        let mut remaining = n;
        let mut off = offset;
        for iov in iovs.iter_mut() {
            if remaining == 0 {
                break;
            }
            let take = iov.len().min(remaining);
            synth_fill(self.seed, h, off, &mut iov[..take]);
            off += take as u64;
            remaining -= take;
        }
        Ok(n)
    }

    /// One charged OST service op for the whole gathered run; per-iov
    /// ledger entries so every constituent object keeps its own digest.
    /// Pending single-shot corruptions whose `(file, offset)` matches an
    /// iov flip one byte of the *stored* copy — the caller's buffer is
    /// untouched, and the corrupted iov indices come back in the return
    /// value, exactly what a read-back verification would observe.
    fn write_at_vectored(&self, file: FileId, offset: u64, iovs: &[&[u8]]) -> Result<Vec<usize>> {
        let name = {
            let ids = self.ids.lock().unwrap_or_else(|e| e.into_inner());
            ids.get(&file.0)
                .ok_or_else(|| anyhow::anyhow!("write_at: no file id {}", file.0))?
                .clone()
        };
        let total: u64 = iovs.iter().map(|v| v.len() as u64).sum();

        let mut corrupted = Vec::new();
        let mut files = self.files.lock().unwrap_or_else(|e| e.into_inner());
        let f = files
            .get_mut(&name)
            .ok_or_else(|| anyhow::anyhow!("write_at: file '{name}' removed"))?;
        if offset + total > f.meta.size {
            bail!(
                "write_at: [{offset}, +{total}) beyond declared size {} of '{name}'",
                f.meta.size
            );
        }
        let ost = self.layout.ost_for(f.meta.start_ost, offset);
        let h = name_hash(&name);
        let mut iov_offset = offset;
        for (i, &iov) in iovs.iter().enumerate() {
            // Single-shot corruption for this (file, iov offset): bit rot
            // between the caller's memory and the platters, applied to the
            // stored copy only.
            let corrupt = {
                let mut corr = self.corruptions.lock().unwrap_or_else(|e| e.into_inner());
                match corr
                    .iter()
                    .position(|c| c.file_name_hash == h && c.offset == iov_offset)
                {
                    Some(pos) => {
                        corr.remove(pos);
                        true
                    }
                    None => false,
                }
            };
            let mut stored_copy: Vec<u8>;
            let stored: &[u8] = if corrupt && !iov.is_empty() {
                stored_copy = iov.to_vec();
                let mid = stored_copy.len() / 2;
                stored_copy[mid] ^= 0x40;
                self.corrupted_writes.fetch_add(1, Ordering::SeqCst);
                corrupted.push(i);
                &stored_copy
            } else {
                iov
            };
            f.writes
                .insert(iov_offset, (digest_bytes(stored), stored.len() as u32));
            if let Some(d) = f.data.as_mut() {
                d[iov_offset as usize..iov_offset as usize + stored.len()]
                    .copy_from_slice(stored);
            }
            iov_offset += iov.len() as u64;
        }
        drop(files);
        // ONE service round for the gathered run (the coalescing win the
        // OST model is meant to expose).
        self.osts.service(ost, total, true);
        Ok(corrupted)
    }

    fn commit_file(&self, file: FileId) -> Result<()> {
        let ids = self.ids.lock().unwrap_or_else(|e| e.into_inner());
        let name = ids
            .get(&file.0)
            .ok_or_else(|| anyhow::anyhow!("commit: no file id {}", file.0))?
            .clone();
        drop(ids);
        let mut files = self.files.lock().unwrap_or_else(|e| e.into_inner());
        files
            .get_mut(&name)
            .ok_or_else(|| anyhow::anyhow!("commit: file '{name}' removed"))?
            .meta
            .committed = true;
        Ok(())
    }

    fn remove(&self, name: &str) -> Result<()> {
        let mut files = self.files.lock().unwrap_or_else(|e| e.into_inner());
        files
            .remove(name)
            .ok_or_else(|| anyhow::anyhow!("remove: no file '{name}'"))?;
        let mut ids = self.ids.lock().unwrap_or_else(|e| e.into_inner());
        ids.retain(|_, n| n != name);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_pfs() -> SimPfs {
        SimPfs::new(
            StripeLayout::paper(),
            OstConfig { time_scale: 0.0, ..Default::default() },
            42,
        )
    }

    #[test]
    fn synth_is_deterministic_and_offset_consistent() {
        let mut a = vec![0u8; 64];
        synth_fill(1, 2, 0, &mut a);
        let mut b = vec![0u8; 32];
        synth_fill(1, 2, 32, &mut b);
        assert_eq!(&a[32..], &b[..]);
        // Unaligned reads agree with aligned ones.
        let mut c = vec![0u8; 10];
        synth_fill(1, 2, 3, &mut c);
        assert_eq!(&a[3..13], &c[..]);
    }

    #[test]
    fn synth_differs_by_file_and_seed() {
        let mut a = vec![0u8; 32];
        let mut b = vec![0u8; 32];
        synth_fill(1, 2, 0, &mut a);
        synth_fill(1, 3, 0, &mut b);
        assert_ne!(a, b);
        synth_fill(9, 2, 0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn populate_and_read() {
        let pfs = fast_pfs();
        pfs.populate(&[("f0".into(), 100), ("f1".into(), 50)]);
        let (id, meta) = pfs.lookup("f0").unwrap();
        assert_eq!(meta.size, 100);
        assert!(meta.committed);
        let mut buf = vec![0u8; 64];
        assert_eq!(pfs.read_at(id, 0, &mut buf).unwrap(), 64);
        // Short read at EOF.
        assert_eq!(pfs.read_at(id, 96, &mut buf).unwrap(), 4);
        assert_eq!(pfs.read_at(id, 100, &mut buf).unwrap(), 0);
        // Round-robin start OSTs.
        assert_eq!(pfs.lookup("f0").unwrap().1.start_ost, 0);
        assert_eq!(pfs.lookup("f1").unwrap().1.start_ost, 1);
    }

    #[test]
    fn write_ledger_records_digests() {
        let pfs = fast_pfs();
        let id = pfs.create("out", 100, 0).unwrap();
        assert!(pfs.write_at(id, 0, &[1, 2, 3, 4]).unwrap());
        assert!(pfs.write_at(id, 50, &[5; 10]).unwrap());
        let (d, len) = pfs.written_digest("out", 0).unwrap();
        assert_eq!(len, 4);
        assert_eq!(d, digest_bytes(&[1, 2, 3, 4]));
        assert_eq!(pfs.written_ranges("out"), 2);
        assert!(pfs.written_digest("out", 1).is_none());
    }

    #[test]
    fn write_beyond_size_rejected() {
        let pfs = fast_pfs();
        let id = pfs.create("out", 10, 0).unwrap();
        assert!(pfs.write_at(id, 8, &[0; 4]).is_err());
        // Vectored totals are bounds-checked the same way.
        assert!(pfs.write_at_vectored(id, 4, &[&[0; 4], &[0; 4]]).is_err());
    }

    #[test]
    fn vectored_write_is_one_service_op_with_per_iov_ledger() {
        let pfs = fast_pfs();
        let id = pfs.create("out", 100, 0).unwrap();
        let (a, b, c): (&[u8], &[u8], &[u8]) = (&[1; 8], &[2; 8], &[3; 4]);
        let corrupted = pfs.write_at_vectored(id, 10, &[a, b, c]).unwrap();
        assert!(corrupted.is_empty());
        // One OST service round for the whole gathered run...
        let stats = pfs.ost_model().total_stats();
        assert_eq!(stats.writes, 1);
        assert_eq!(stats.bytes_written, 20);
        // ...but every constituent range keeps its own ledger digest.
        assert_eq!(pfs.written_digest("out", 10).unwrap(), (digest_bytes(a), 8));
        assert_eq!(pfs.written_digest("out", 18).unwrap(), (digest_bytes(b), 8));
        assert_eq!(pfs.written_digest("out", 26).unwrap(), (digest_bytes(c), 4));
        assert_eq!(pfs.written_ranges("out"), 3);
    }

    #[test]
    fn vectored_write_reports_corrupted_iov_indices() {
        let pfs = fast_pfs();
        let id = pfs.create("out", 100, 0).unwrap();
        // Corrupt the middle iov of a 3-iov run (it starts at offset 18).
        pfs.inject_write_corruption("out", 18);
        let (a, b): (&[u8], &[u8]) = (&[7; 8], &[9; 8]);
        let corrupted = pfs.write_at_vectored(id, 10, &[a, b, a]).unwrap();
        assert_eq!(corrupted, vec![1]);
        assert_eq!(pfs.corrupted_writes.load(Ordering::SeqCst), 1);
        // The caller's view of the run is untouched; the stored copy of
        // the corrupted iov differs, its neighbors are faithful.
        assert_eq!(pfs.written_digest("out", 10).unwrap().0, digest_bytes(a));
        assert_ne!(pfs.written_digest("out", 18).unwrap().0, digest_bytes(b));
        assert_eq!(pfs.written_digest("out", 26).unwrap().0, digest_bytes(a));
    }

    #[test]
    fn vectored_read_is_one_service_op_matching_read_at() {
        let pfs = fast_pfs();
        pfs.populate(&[("f".into(), 1000)]);
        let (id, _) = pfs.lookup("f").unwrap();
        let mut plain = vec![0u8; 96];
        pfs.read_at(id, 40, &mut plain).unwrap();
        let reads_before = pfs.ost_model().total_stats().reads;
        let (mut a, mut b, mut c) = ([0u8; 32], [0u8; 32], [0u8; 32]);
        let n = pfs
            .read_at_vectored(id, 40, &mut [&mut a[..], &mut b[..], &mut c[..]])
            .unwrap();
        assert_eq!(n, 96);
        // One OST service round for the whole run...
        assert_eq!(pfs.ost_model().total_stats().reads, reads_before + 1);
        // ...and byte-identical content to three plain reads.
        let mut got = Vec::new();
        got.extend_from_slice(&a);
        got.extend_from_slice(&b);
        got.extend_from_slice(&c);
        assert_eq!(got, plain);
    }

    #[test]
    fn vectored_read_short_at_eof() {
        let pfs = fast_pfs();
        pfs.populate(&[("f".into(), 50)]);
        let (id, _) = pfs.lookup("f").unwrap();
        let (mut a, mut b) = ([0u8; 32], [0u8; 32]);
        let n = pfs
            .read_at_vectored(id, 0, &mut [&mut a[..], &mut b[..]])
            .unwrap();
        assert_eq!(n, 50, "EOF inside the run returns the short total");
        let mut plain = vec![0u8; 50];
        pfs.read_at(id, 0, &mut plain).unwrap();
        let mut got = Vec::new();
        got.extend_from_slice(&a);
        got.extend_from_slice(&b[..18]);
        assert_eq!(got, plain);
        // Fully past EOF is an empty read.
        assert_eq!(pfs.read_at_vectored(id, 50, &mut [&mut a[..]]).unwrap(), 0);
    }

    #[test]
    fn commit_sets_metadata() {
        let pfs = fast_pfs();
        let id = pfs.create("out", 10, 0).unwrap();
        assert!(!pfs.lookup("out").unwrap().1.committed);
        pfs.commit_file(id).unwrap();
        assert!(pfs.lookup("out").unwrap().1.committed);
    }

    #[test]
    fn corruption_hook_flips_exactly_once() {
        let pfs = fast_pfs();
        let id = pfs.create("out", 100, 0).unwrap();
        pfs.inject_write_corruption("out", 10);
        let data = [7u8; 20];
        assert!(
            !pfs.write_at(id, 10, &data).unwrap(),
            "corrupted write must report infidelity"
        );
        let (d, _) = pfs.written_digest("out", 10).unwrap();
        assert_ne!(d, digest_bytes(&data), "write should have been corrupted");
        assert_eq!(pfs.corrupted_writes.load(Ordering::SeqCst), 1);
        // Re-write is clean (single shot).
        assert!(pfs.write_at(id, 10, &data).unwrap());
        let (d2, _) = pfs.written_digest("out", 10).unwrap();
        assert_eq!(d2, digest_bytes(&data));
    }

    #[test]
    fn expected_digest_matches_read() {
        let pfs = fast_pfs();
        pfs.populate(&[("f".into(), 1000)]);
        let (id, _) = pfs.lookup("f").unwrap();
        let mut buf = vec![0u8; 256];
        pfs.read_at(id, 128, &mut buf).unwrap();
        assert_eq!(digest_bytes(&buf), pfs.expected_digest("f", 128, 256));
    }

    #[test]
    fn remove_then_lookup_fails() {
        let pfs = fast_pfs();
        pfs.create("x", 1, 0).unwrap();
        pfs.remove("x").unwrap();
        assert!(pfs.lookup("x").is_none());
        assert!(pfs.remove("x").is_err());
    }
}
