//! Cross-job OST load registry (the `ftlads serve` tentpole).
//!
//! One transfer session only ever sees its *own* queue depths, so the
//! congestion/straggler policies (paper §2.1) are blind to every other
//! job hammering the same Lustre OSTs — exactly the shared-storage
//! situation layout-aware scheduling exists for. An [`OstRegistry`] is
//! the daemon-wide fix: a per-OST table of refcounted in-flight request
//! counts, shared (`Arc`) by every job of one daemon. Each job holds a
//! [`JobOstHandle`] and charges it at enqueue / discharges it at service
//! completion; a scheduler then reads `foreign = total − own` through
//! [`crate::sched::OstCongestion`] and steers around OSTs *other* jobs
//! are saturating.
//!
//! The handle is the ownership boundary: dropping it (job done, job
//! killed mid-transfer, session thread panicked) drains whatever the job
//! still had charged, so a dead job can never pin phantom load onto the
//! registry other jobs keep scheduling against.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::ost::OstId;

/// Daemon-wide per-OST in-flight request totals, summed across every
/// job's [`JobOstHandle`]. Keyed by OST id (dense vector — OST ids are
/// `0..ost_count` everywhere in this crate).
#[derive(Debug)]
pub struct OstRegistry {
    total: Vec<AtomicU64>,
}

impl OstRegistry {
    pub fn new(ost_count: u32) -> Arc<OstRegistry> {
        assert!(ost_count > 0);
        Arc::new(OstRegistry {
            total: (0..ost_count).map(|_| AtomicU64::new(0)).collect(),
        })
    }

    pub fn ost_count(&self) -> u32 {
        self.total.len() as u32
    }

    /// In-flight requests on `ost` across ALL jobs of the daemon.
    pub fn load(&self, ost: OstId) -> u64 {
        self.total[ost.0 as usize].load(Ordering::SeqCst)
    }

    /// In-flight requests across all OSTs and all jobs.
    pub fn total_load(&self) -> u64 {
        self.total.iter().map(|t| t.load(Ordering::SeqCst)).sum()
    }

    /// Mint one job's view of the registry. The handle's own charges are
    /// tracked separately so `foreign()` can subtract them back out.
    pub fn handle(self: &Arc<Self>) -> JobOstHandle {
        JobOstHandle {
            registry: self.clone(),
            own: (0..self.total.len()).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

/// One job's refcounted charge against a shared [`OstRegistry`].
///
/// `begin`/`end` bracket a request's life on an OST (enqueue → service
/// complete). `foreign(ost)` is the congestion signal the schedulers
/// read: the registry total minus this job's own charges — i.e. what
/// *other* jobs currently have in flight there. Dropping the handle
/// drains every remaining own charge from the registry (the killed-job
/// release path).
#[derive(Debug)]
pub struct JobOstHandle {
    registry: Arc<OstRegistry>,
    own: Vec<AtomicU64>,
}

impl JobOstHandle {
    /// Charge one in-flight request against `ost`.
    pub fn begin(&self, ost: OstId) {
        let o = ost.0 as usize;
        self.own[o].fetch_add(1, Ordering::SeqCst);
        self.registry.total[o].fetch_add(1, Ordering::SeqCst);
    }

    /// Discharge one request from `ost`. Floored at zero on both sides:
    /// a stray double-end (e.g. a retransmit acked twice after a resume)
    /// must never underflow another job's charges out of the registry.
    pub fn end(&self, ost: OstId) {
        let o = ost.0 as usize;
        if self.own[o]
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
            .is_ok()
        {
            let _ = self.registry.total[o]
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1));
        }
    }

    /// This job's own in-flight requests on `ost`.
    pub fn own(&self, ost: OstId) -> u64 {
        self.own[ost.0 as usize].load(Ordering::SeqCst)
    }

    /// In-flight requests OTHER jobs have on `ost` — the cross-job
    /// congestion signal. Saturating: the unlocked two-load race can
    /// transiently read `total < own`, which means "no foreign load",
    /// never a wrap to u64::MAX.
    pub fn foreign(&self, ost: OstId) -> usize {
        let o = ost.0 as usize;
        let total = self.registry.total[o].load(Ordering::SeqCst);
        let own = self.own[o].load(Ordering::SeqCst);
        total.saturating_sub(own).min(usize::MAX as u64) as usize
    }

    pub fn registry(&self) -> &Arc<OstRegistry> {
        &self.registry
    }
}

impl Drop for JobOstHandle {
    /// Drain whatever this job still had charged — a job that dies
    /// mid-transfer (fault injection, panic, kill) must not leave
    /// phantom load for surviving jobs to schedule around forever.
    fn drop(&mut self) {
        for (o, own) in self.own.iter().enumerate() {
            let n = own.swap(0, Ordering::SeqCst);
            if n > 0 {
                let _ = self.registry.total[o]
                    .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                        Some(v.saturating_sub(n))
                    });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn begin_end_roundtrip() {
        let reg = OstRegistry::new(4);
        let h = reg.handle();
        h.begin(OstId(1));
        h.begin(OstId(1));
        h.begin(OstId(3));
        assert_eq!(reg.load(OstId(1)), 2);
        assert_eq!(reg.load(OstId(3)), 1);
        assert_eq!(reg.total_load(), 3);
        assert_eq!(h.own(OstId(1)), 2);
        // A job never sees its own charges as foreign.
        assert_eq!(h.foreign(OstId(1)), 0);
        h.end(OstId(1));
        assert_eq!(reg.load(OstId(1)), 1);
        h.end(OstId(1));
        h.end(OstId(3));
        assert_eq!(reg.total_load(), 0);
    }

    #[test]
    fn foreign_is_other_jobs_load_only() {
        let reg = OstRegistry::new(4);
        let a = reg.handle();
        let b = reg.handle();
        a.begin(OstId(2));
        b.begin(OstId(2));
        b.begin(OstId(2));
        assert_eq!(a.foreign(OstId(2)), 2);
        assert_eq!(b.foreign(OstId(2)), 1);
        assert_eq!(a.foreign(OstId(0)), 0);
        b.end(OstId(2));
        b.end(OstId(2));
        assert_eq!(a.foreign(OstId(2)), 0);
        a.end(OstId(2));
    }

    #[test]
    fn double_end_never_underflows() {
        let reg = OstRegistry::new(2);
        let a = reg.handle();
        let b = reg.handle();
        b.begin(OstId(0));
        a.begin(OstId(0));
        a.end(OstId(0));
        a.end(OstId(0)); // stray: must not eat b's charge
        assert_eq!(reg.load(OstId(0)), 1);
        assert_eq!(b.own(OstId(0)), 1);
        b.end(OstId(0));
        assert_eq!(reg.load(OstId(0)), 0);
    }

    #[test]
    fn drop_drains_remaining_charges() {
        let reg = OstRegistry::new(3);
        let survivor = reg.handle();
        survivor.begin(OstId(0));
        {
            let killed = reg.handle();
            killed.begin(OstId(0));
            killed.begin(OstId(1));
            killed.begin(OstId(1));
            assert_eq!(survivor.foreign(OstId(0)), 1);
            assert_eq!(survivor.foreign(OstId(1)), 2);
            // `killed` dropped here mid-"transfer".
        }
        assert_eq!(survivor.foreign(OstId(0)), 0);
        assert_eq!(survivor.foreign(OstId(1)), 0);
        assert_eq!(reg.load(OstId(0)), 1, "the survivor's own charge stays");
        survivor.end(OstId(0));
    }
}
