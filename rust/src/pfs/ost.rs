//! Per-OST service model: queueing, service times, congestion.
//!
//! Each OST is a serial(ish) device: a bounded number of in-flight
//! requests (disk heads), a service time proportional to request size,
//! and an *external load factor* that models other tenants hammering the
//! shared file system (the situation LADS's congestion-aware scheduling
//! exists for). Threads that issue I/O against a busy OST queue up; the
//! queue depth is exported as the congestion signal the scheduler reads.
//!
//! Times are scaled by `time_scale` so the figure benches can run the
//! paper's experiment *shapes* in seconds instead of hours; `time_scale =
//! 0` disables sleeping entirely (pure logic tests).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Index of an object storage target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OstId(pub u32);

impl std::fmt::Display for OstId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ost{}", self.0)
    }
}

/// Service-model parameters (defaults roughly match a single SATA-class
/// OST scaled for fast experiments; see DESIGN.md §Substitutions).
#[derive(Debug, Clone)]
pub struct OstConfig {
    /// Sustained per-OST bandwidth, bytes/second.
    pub bandwidth: f64,
    /// Fixed per-request overhead.
    pub base_latency: Duration,
    /// Concurrent requests an OST services (1 = strictly serial device).
    pub max_concurrent: usize,
    /// Global multiplier on all service times (0.0 = never sleep).
    pub time_scale: f64,
}

impl Default for OstConfig {
    fn default() -> Self {
        OstConfig {
            bandwidth: 1.5e9,                      // 1.5 GB/s per OST (scaled testbed)
            base_latency: Duration::from_micros(80),
            max_concurrent: 1,
            time_scale: 1.0,
        }
    }
}

/// Cumulative per-OST counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OstStats {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Nanoseconds requests spent waiting for a service slot.
    pub wait_ns: u64,
    /// Nanoseconds of charged service time.
    pub service_ns: u64,
}

struct OstState {
    /// Service slots: (in_use, capacity) guarded by mutex + condvar.
    slots: Mutex<usize>,
    available: Condvar,
    /// Requests queued or in service — the congestion signal.
    depth: AtomicUsize,
    /// External load multiplier ×1000 (1000 = idle, 5000 = 5× slower).
    load_milli: AtomicU64,
    stats: Mutex<OstStats>,
}

/// The OST fleet of one file system.
pub struct OstModel {
    cfg: OstConfig,
    osts: Vec<OstState>,
}

impl OstModel {
    pub fn new(ost_count: u32, cfg: OstConfig) -> Self {
        assert!(ost_count > 0);
        assert!(cfg.max_concurrent > 0);
        let osts = (0..ost_count)
            .map(|_| OstState {
                slots: Mutex::new(0),
                available: Condvar::new(),
                depth: AtomicUsize::new(0),
                load_milli: AtomicU64::new(1000),
                stats: Mutex::new(OstStats::default()),
            })
            .collect();
        OstModel { cfg, osts }
    }

    pub fn ost_count(&self) -> u32 {
        self.osts.len() as u32
    }

    pub fn config(&self) -> &OstConfig {
        &self.cfg
    }

    /// Charge one request of `bytes` against `ost`: wait for a service
    /// slot, then hold it for the modeled service time.
    pub fn service(&self, ost: OstId, bytes: u64, is_write: bool) {
        let st = &self.osts[ost.0 as usize];
        st.depth.fetch_add(1, Ordering::SeqCst);
        let wait_start = Instant::now();

        // Acquire a slot.
        {
            let mut in_use = st.slots.lock().unwrap_or_else(|e| e.into_inner());
            while *in_use >= self.cfg.max_concurrent {
                in_use = st
                    .available
                    .wait(in_use)
                    .unwrap_or_else(|e| e.into_inner());
            }
            *in_use += 1;
        }
        let waited = wait_start.elapsed();

        // Modeled service time.
        let load = st.load_milli.load(Ordering::Relaxed) as f64 / 1000.0;
        let secs = (self.cfg.base_latency.as_secs_f64() + bytes as f64 / self.cfg.bandwidth)
            * load
            * self.cfg.time_scale;
        let service = Duration::from_secs_f64(secs.max(0.0));
        if !service.is_zero() {
            std::thread::sleep(service);
        }

        // Release.
        {
            let mut in_use = st.slots.lock().unwrap_or_else(|e| e.into_inner());
            *in_use -= 1;
        }
        st.available.notify_one();
        st.depth.fetch_sub(1, Ordering::SeqCst);

        let mut s = st.stats.lock().unwrap_or_else(|e| e.into_inner());
        if is_write {
            s.writes += 1;
            s.bytes_written += bytes;
        } else {
            s.reads += 1;
            s.bytes_read += bytes;
        }
        s.wait_ns += waited.as_nanos() as u64;
        s.service_ns += service.as_nanos() as u64;
    }

    /// Congestion signal: requests queued or in service on `ost`.
    pub fn queue_depth(&self, ost: OstId) -> usize {
        self.osts[ost.0 as usize].depth.load(Ordering::SeqCst)
    }

    /// Model other tenants on a shared OST: all its service times are
    /// multiplied by `factor` until reset (factor 1.0).
    pub fn set_external_load(&self, ost: OstId, factor: f64) {
        assert!(factor > 0.0);
        self.osts[ost.0 as usize]
            .load_milli
            .store((factor * 1000.0) as u64, Ordering::Relaxed);
    }

    pub fn external_load(&self, ost: OstId) -> f64 {
        self.osts[ost.0 as usize].load_milli.load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// The least-congested OST among `candidates` (ties → lowest id).
    pub fn least_loaded(&self, candidates: &[OstId]) -> Option<OstId> {
        candidates
            .iter()
            .copied()
            .min_by_key(|&o| (self.queue_depth(o), o.0))
    }

    pub fn stats(&self, ost: OstId) -> OstStats {
        *self.osts[ost.0 as usize]
            .stats
            .lock()
            .unwrap_or_else(|e| e.into_inner())
    }

    pub fn total_stats(&self) -> OstStats {
        let mut t = OstStats::default();
        for i in 0..self.ost_count() {
            let s = self.stats(OstId(i));
            t.reads += s.reads;
            t.writes += s.writes;
            t.bytes_read += s.bytes_read;
            t.bytes_written += s.bytes_written;
            t.wait_ns += s.wait_ns;
            t.service_ns += s.service_ns;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> OstConfig {
        OstConfig { time_scale: 0.0, ..Default::default() }
    }

    #[test]
    fn stats_accumulate() {
        let m = OstModel::new(3, fast_cfg());
        m.service(OstId(0), 1024, false);
        m.service(OstId(0), 2048, true);
        m.service(OstId(1), 10, false);
        let s0 = m.stats(OstId(0));
        assert_eq!(s0.reads, 1);
        assert_eq!(s0.writes, 1);
        assert_eq!(s0.bytes_read, 1024);
        assert_eq!(s0.bytes_written, 2048);
        assert_eq!(m.stats(OstId(2)), OstStats::default());
        let t = m.total_stats();
        assert_eq!(t.reads, 2);
        assert_eq!(t.bytes_read, 1034);
    }

    #[test]
    fn queue_depth_reflects_in_flight() {
        let m = std::sync::Arc::new(OstModel::new(
            1,
            OstConfig {
                bandwidth: 1e6,
                base_latency: Duration::from_millis(20),
                max_concurrent: 1,
                time_scale: 1.0,
            },
        ));
        let m2 = m.clone();
        let h1 = std::thread::spawn(move || m2.service(OstId(0), 1000, false));
        let m3 = m.clone();
        let h2 = std::thread::spawn(move || m3.service(OstId(0), 1000, false));
        // Within the first service window both requests are queued/in-service.
        std::thread::sleep(Duration::from_millis(8));
        assert!(m.queue_depth(OstId(0)) >= 1);
        h1.join().unwrap();
        h2.join().unwrap();
        assert_eq!(m.queue_depth(OstId(0)), 0);
        // Second request must have measurably waited for the slot.
        assert!(m.stats(OstId(0)).wait_ns > 0);
    }

    #[test]
    fn external_load_slows_service() {
        let cfg = OstConfig {
            bandwidth: 1e9,
            base_latency: Duration::from_millis(5),
            max_concurrent: 1,
            time_scale: 1.0,
        };
        let m = OstModel::new(2, cfg);
        let t0 = Instant::now();
        m.service(OstId(0), 0, false);
        let idle = t0.elapsed();
        m.set_external_load(OstId(0), 8.0);
        assert_eq!(m.external_load(OstId(0)), 8.0);
        let t1 = Instant::now();
        m.service(OstId(0), 0, false);
        let loaded = t1.elapsed();
        assert!(
            loaded > idle * 3,
            "loaded {loaded:?} should be much slower than idle {idle:?}"
        );
        m.set_external_load(OstId(0), 1.0);
        assert_eq!(m.external_load(OstId(0)), 1.0);
    }

    #[test]
    fn least_loaded_prefers_empty() {
        let m = std::sync::Arc::new(OstModel::new(
            2,
            OstConfig {
                base_latency: Duration::from_millis(30),
                max_concurrent: 1,
                time_scale: 1.0,
                ..Default::default()
            },
        ));
        let m2 = m.clone();
        let h = std::thread::spawn(move || m2.service(OstId(0), 0, false));
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(m.least_loaded(&[OstId(0), OstId(1)]), Some(OstId(1)));
        h.join().unwrap();
        // Idle: ties break to the lowest id.
        assert_eq!(m.least_loaded(&[OstId(1), OstId(0)]), Some(OstId(0)));
        assert_eq!(m.least_loaded(&[]), None);
    }

    #[test]
    fn time_scale_zero_never_sleeps() {
        let m = OstModel::new(1, fast_cfg());
        let t0 = Instant::now();
        for _ in 0..100 {
            m.service(OstId(0), 1 << 20, true);
        }
        assert!(t0.elapsed() < Duration::from_millis(200));
    }
}
