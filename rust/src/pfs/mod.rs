//! Parallel-file-system substrate.
//!
//! The paper's testbed is two Lustre 2.9 file systems (1 OSS, 11 OSTs,
//! 1 MB stripes). We reproduce the pieces LADS actually interacts with:
//!
//! - the **striping layout** — which OST serves which byte range of which
//!   file ([`layout::StripeLayout`]); this is what makes scheduling
//!   "layout-aware";
//! - the **per-OST service behaviour** — queueing, service times,
//!   congestion ([`ost::OstModel`]); this is what makes scheduling
//!   "congestion-aware";
//! - the **file namespace** — create/read/write/commit with metadata
//!   (size + committed flag), which the resume protocol's sink-side
//!   metadata match consults.
//!
//! Two implementations of the [`Pfs`] trait:
//! - [`sim::SimPfs`] — deterministic synthetic data, in-memory state, a
//!   per-object write ledger (digests) and fault hooks. Used by tests and
//!   the figure benches.
//! - [`disk::DiskPfs`] — real files under an OST-per-subdirectory root,
//!   for the end-to-end example on a real small dataset.

pub mod disk;
pub mod layout;
pub mod ost;
pub mod registry;
pub mod sim;

use anyhow::Result;

pub use layout::StripeLayout;
pub use ost::{OstId, OstModel, OstStats};
pub use registry::{JobOstHandle, OstRegistry};

/// Upper bound on the iovs of one gathered write — POSIX's IOV_MAX
/// (1024 on Linux). Load-bearing invariant: the sink caps coalesced
/// runs at this many blocks and [`disk::DiskPfs`] splits `pwritev`
/// calls at the same bound, so "one gathered run == one syscall" (and
/// therefore `write_syscalls` == real submissions) holds by
/// construction. Keep both sides on THIS constant.
pub const IOV_MAX_GATHER: usize = 1024;

/// Opaque per-PFS file handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FileId(pub u64);

/// File metadata, the unit of the sink-side resume check (§5.2.2: "the
/// sink checks if the file already exists and the file's metadata is
/// matching with the source file's metadata").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileMeta {
    pub name: String,
    pub size: u64,
    /// Set by `commit_file` (transfer fully completed + closed). A partial
    /// file left behind by a fault is not committed and must not match.
    pub committed: bool,
    /// First OST index of the file's stripe pattern.
    pub start_ost: u32,
}

/// The PFS interface the coordinator programs against.
pub trait Pfs: Send + Sync {
    /// Striping geometry (shared by layout-aware scheduling on both ends).
    fn layout(&self) -> &StripeLayout;

    /// The OST service model (congestion queries + service-time charging).
    fn ost_model(&self) -> &OstModel;

    /// Look up a file by name.
    fn lookup(&self, name: &str) -> Option<(FileId, FileMeta)>;

    /// List all file names (source-side dataset walk).
    fn list(&self) -> Vec<String>;

    /// Create (or truncate) a file of known final size; returns its id.
    fn create(&self, name: &str, size: u64, start_ost: u32) -> Result<FileId>;

    /// `pread`: read `buf.len()` bytes at `offset`, charging the serving
    /// OST's service time. Short reads at EOF return the short length.
    fn read_at(&self, file: FileId, offset: u64, buf: &mut [u8]) -> Result<usize>;

    /// `pwrite`: write at `offset`, charging the serving OST.
    ///
    /// Returns `true` when the storage persisted exactly `data` — the
    /// caller's read-back verification channel for the §3.2 failure mode
    /// stock LADS cannot detect. Real backends always persist faithfully
    /// and return `true`; [`sim::SimPfs`] returns `false` for a write its
    /// injected corruption flipped on the way down (the stored bytes, and
    /// the ledger digest, then differ from `data`).
    ///
    /// The payload is a shared `&[u8]` — no implementor mutates it, so
    /// refcounted `Bytes` views reach the platters without a
    /// copy-on-write detach.
    fn write_at(&self, file: FileId, offset: u64, data: &[u8]) -> Result<bool>;

    /// Vectored `pwrite`: persist the concatenation of `iovs` at `offset`
    /// as ONE storage request — one syscall / one OST service round where
    /// the backend supports gather I/O ([`disk::DiskPfs`] via `pwritev`,
    /// [`sim::SimPfs`] as a single charged service op). Returns the
    /// indices of iovs the storage corrupted on the way down (empty =
    /// every iov byte-faithful, the only possibility for real backends).
    ///
    /// The default implementation degrades to one [`write_at`] per iov:
    /// byte- and fidelity-equivalent, just without the coalescing win.
    ///
    /// [`write_at`]: Pfs::write_at
    fn write_at_vectored(&self, file: FileId, offset: u64, iovs: &[&[u8]]) -> Result<Vec<usize>> {
        let mut corrupted = Vec::new();
        let mut off = offset;
        for (i, iov) in iovs.iter().enumerate() {
            if !self.write_at(file, off, iov)? {
                corrupted.push(i);
            }
            off += iov.len() as u64;
        }
        Ok(corrupted)
    }

    /// Vectored `pread`: fill the concatenation of `iovs` from `offset`
    /// as ONE storage request — one syscall / one OST service round where
    /// the backend supports scatter I/O ([`disk::DiskPfs`] via `preadv`,
    /// [`sim::SimPfs`] as a single charged service op). Returns the total
    /// bytes read; a short count means EOF landed inside the run (the
    /// trailing iovs are partially or not at all filled).
    ///
    /// The default implementation degrades to one [`read_at`] per iov:
    /// byte-equivalent, just without the gather win.
    ///
    /// [`read_at`]: Pfs::read_at
    fn read_at_vectored(
        &self,
        file: FileId,
        offset: u64,
        iovs: &mut [&mut [u8]],
    ) -> Result<usize> {
        let mut total = 0usize;
        let mut off = offset;
        for iov in iovs.iter_mut() {
            let n = self.read_at(file, off, iov)?;
            total += n;
            if n < iov.len() {
                break; // EOF inside this iov
            }
            off += iov.len() as u64;
        }
        Ok(total)
    }

    /// Mark a file fully transferred (close + metadata barrier). After
    /// commit, `lookup().1.committed` is true.
    fn commit_file(&self, file: FileId) -> Result<()>;

    /// Remove a file (sink-side cleanup when restarting a mismatched file).
    fn remove(&self, name: &str) -> Result<()>;
}

/// Which OST serves byte `offset` of a file with the given start OST.
/// Convenience wrapper over the layout.
pub fn ost_of(layout: &StripeLayout, start_ost: u32, offset: u64) -> OstId {
    layout.ost_for(start_ost, offset)
}
