//! Lustre-style striping layout: which OST serves which byte of a file.
//!
//! A file with stripe count `c`, stripe size `s` and starting OST `o0`
//! places byte `x` on OST `(o0 + (x / s) % c) % ost_count`. The paper's
//! testbed uses stripe count 1 with 1 MB stripes (each file lives wholly
//! on one OST, files round-robin across the 11 OSTs); both that and wider
//! stripings are supported.

use super::OstId;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StripeLayout {
    /// Bytes per stripe (Lustre default and paper setting: 1 MiB).
    pub stripe_size: u64,
    /// OSTs a single file is striped over (paper setting: 1).
    pub stripe_count: u32,
    /// Total OSTs in the file system (paper setting: 11).
    pub ost_count: u32,
}

impl StripeLayout {
    pub fn new(stripe_size: u64, stripe_count: u32, ost_count: u32) -> Self {
        assert!(stripe_size > 0, "stripe_size must be positive");
        assert!(ost_count > 0, "ost_count must be positive");
        assert!(
            (1..=ost_count).contains(&stripe_count),
            "stripe_count must be in 1..=ost_count"
        );
        StripeLayout { stripe_size, stripe_count, ost_count }
    }

    /// Paper testbed: 1 MiB stripes, count 1, 11 OSTs.
    pub fn paper() -> Self {
        Self::new(1 << 20, 1, 11)
    }

    /// The OST serving byte `offset` of a file whose first stripe lives on
    /// `start_ost`.
    pub fn ost_for(&self, start_ost: u32, offset: u64) -> OstId {
        let stripe_idx = offset / self.stripe_size;
        let within = (stripe_idx % self.stripe_count as u64) as u32;
        OstId(((start_ost % self.ost_count) + within) % self.ost_count)
    }

    /// All OSTs a file of `size` bytes touches (deduplicated, ordered).
    pub fn osts_for_file(&self, start_ost: u32, size: u64) -> Vec<OstId> {
        let stripes = crate::util::div_ceil(size.max(1), self.stripe_size);
        let n = stripes.min(self.stripe_count as u64) as u32;
        (0..n)
            .map(|i| OstId(((start_ost % self.ost_count) + i) % self.ost_count))
            .collect()
    }

    /// Round-robin start OST assignment for the `idx`-th created file —
    /// what Lustre's allocator does on a quiet file system, and what makes
    /// stripe-count-1 datasets spread across OSTs.
    pub fn round_robin_start(&self, idx: u64) -> u32 {
        (idx % self.ost_count as u64) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripe_count_one_pins_file_to_one_ost() {
        let l = StripeLayout::paper();
        for off in [0u64, 1, 1 << 20, 37 << 20, (1 << 30) - 1] {
            assert_eq!(l.ost_for(4, off), OstId(4));
        }
    }

    #[test]
    fn round_robin_across_stripes() {
        let l = StripeLayout::new(1 << 20, 4, 11);
        assert_eq!(l.ost_for(2, 0), OstId(2));
        assert_eq!(l.ost_for(2, 1 << 20), OstId(3));
        assert_eq!(l.ost_for(2, 2 << 20), OstId(4));
        assert_eq!(l.ost_for(2, 3 << 20), OstId(5));
        // wraps back to the start of the stripe group
        assert_eq!(l.ost_for(2, 4 << 20), OstId(2));
    }

    #[test]
    fn stripe_group_wraps_around_ost_count() {
        let l = StripeLayout::new(1 << 20, 3, 4);
        assert_eq!(l.ost_for(3, 0), OstId(3));
        assert_eq!(l.ost_for(3, 1 << 20), OstId(0));
        assert_eq!(l.ost_for(3, 2 << 20), OstId(1));
    }

    #[test]
    fn osts_for_file_small_file_fewer_stripes() {
        let l = StripeLayout::new(1 << 20, 4, 11);
        // half-a-stripe file touches only its start OST
        assert_eq!(l.osts_for_file(5, 1 << 19), vec![OstId(5)]);
        // 2.5 stripes -> 3 OSTs
        assert_eq!(
            l.osts_for_file(5, (5 << 20) / 2),
            vec![OstId(5), OstId(6), OstId(7)]
        );
        // big file capped at stripe_count OSTs
        assert_eq!(l.osts_for_file(5, 100 << 20).len(), 4);
    }

    #[test]
    fn round_robin_start_covers_all_osts() {
        let l = StripeLayout::paper();
        let starts: Vec<u32> = (0..22).map(|i| l.round_robin_start(i)).collect();
        for ost in 0..11 {
            assert_eq!(starts.iter().filter(|&&s| s == ost).count(), 2);
        }
    }

    #[test]
    #[should_panic]
    fn zero_stripe_size_rejected() {
        StripeLayout::new(0, 1, 11);
    }

    #[test]
    #[should_panic]
    fn stripe_count_gt_ost_count_rejected() {
        StripeLayout::new(1 << 20, 12, 11);
    }
}
