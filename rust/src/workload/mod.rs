//! Workload generation.
//!
//! The paper evaluates two datasets (§6.1): "big" — 100 × 1 GB files —
//! and "small" — 10,000 × 1 MB files, motivated by the observation that
//! 86.76 % of files on the production Lustre system are < 1 MB while the
//! few large files hold most of the bytes. We generate both, plus the
//! mixed production-like distribution the intro describes, at a
//! configurable scale factor (the default figure benches run 1/64-scale;
//! EXPERIMENTS.md records the scaling).

use crate::testutil::Pcg32;

/// One file to be transferred.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileSpec {
    pub name: String,
    pub size: u64,
}

/// A named dataset.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub files: Vec<FileSpec>,
}

impl Workload {
    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.size).sum()
    }

    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    /// Objects at the given MTU (what LADS actually schedules).
    pub fn total_objects(&self, object_size: u64) -> u64 {
        self.files
            .iter()
            .map(|f| crate::util::div_ceil(f.size.max(1), object_size))
            .sum()
    }

    pub fn as_tuples(&self) -> Vec<(String, u64)> {
        self.files.iter().map(|f| (f.name.clone(), f.size)).collect()
    }
}

/// Paper's big workload: `count` files of `file_size` bytes
/// (paper: 100 × 1 GB; scaled default in benches: 100 × 16 MB).
pub fn big_workload(count: usize, file_size: u64) -> Workload {
    Workload {
        name: format!("big-{count}x{}", crate::util::fmt_bytes(file_size)),
        files: (0..count)
            .map(|i| FileSpec { name: format!("big/file_{i:05}.dat"), size: file_size })
            .collect(),
    }
}

/// Paper's small workload: `count` files of exactly one MTU
/// (paper: 10,000 × 1 MB with 1 MB MTU — file == one object, which is why
/// Fig 9's recovery overhead is flat; preserve that identity when scaling).
pub fn small_workload(count: usize, file_size: u64) -> Workload {
    Workload {
        name: format!("small-{count}x{}", crate::util::fmt_bytes(file_size)),
        files: (0..count)
            .map(|i| FileSpec { name: format!("small/file_{i:05}.dat"), size: file_size })
            .collect(),
    }
}

/// Production-like mixed distribution (intro §6.1: 86.76 % < 1 MB,
/// 90.35 % < 4 MB, the rest large): sizes drawn deterministically from
/// `seed`. `unit` scales the whole distribution (unit = 1 MiB gives the
/// paper's absolute sizes).
pub fn mixed_workload(count: usize, unit: u64, seed: u64) -> Workload {
    let mut rng = Pcg32::new(seed);
    let files = (0..count)
        .map(|i| {
            let p = rng.f64();
            let size = if p < 0.8676 {
                // < 1 unit: 4 KiB-grained sizes
                rng.range(unit / 256, unit.max(2) - 1)
            } else if p < 0.9035 {
                // 1..4 units
                rng.range(unit, 4 * unit - 1)
            } else {
                // heavy tail: 4..64 units
                rng.range(4 * unit, 64 * unit)
            };
            FileSpec { name: format!("mixed/file_{i:05}.dat"), size: size.max(1) }
        })
        .collect();
    Workload { name: format!("mixed-{count}"), files }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn big_workload_shape() {
        let w = big_workload(100, 16 << 20);
        assert_eq!(w.file_count(), 100);
        assert_eq!(w.total_bytes(), 100 * (16 << 20));
        assert_eq!(w.total_objects(256 << 10), 100 * 64);
        assert_ne!(w.files[0].name, w.files[1].name);
    }

    #[test]
    fn small_workload_one_object_per_file() {
        let w = small_workload(2000, 256 << 10);
        assert_eq!(w.total_objects(256 << 10), 2000);
    }

    #[test]
    fn odd_sizes_round_up_objects() {
        let w = Workload {
            name: "t".into(),
            files: vec![
                FileSpec { name: "a".into(), size: 1 },
                FileSpec { name: "b".into(), size: 100 },
                FileSpec { name: "c".into(), size: 101 },
            ],
        };
        assert_eq!(w.total_objects(100), 1 + 1 + 2);
    }

    #[test]
    fn mixed_distribution_matches_paper_fractions() {
        let unit = 1 << 20;
        let w = mixed_workload(20_000, unit, 7);
        let small = w.files.iter().filter(|f| f.size < unit).count() as f64;
        let under4 = w.files.iter().filter(|f| f.size < 4 * unit).count() as f64;
        let n = w.file_count() as f64;
        assert!((small / n - 0.8676).abs() < 0.01, "got {}", small / n);
        assert!((under4 / n - 0.9035).abs() < 0.01, "got {}", under4 / n);
        // Large files dominate the bytes (the paper's second observation).
        let big_bytes: u64 = w.files.iter().filter(|f| f.size >= 4 * unit).map(|f| f.size).sum();
        assert!(big_bytes as f64 / w.total_bytes() as f64 > 0.5);
    }

    #[test]
    fn mixed_is_deterministic() {
        let a = mixed_workload(100, 1 << 20, 3);
        let b = mixed_workload(100, 1 << 20, 3);
        assert_eq!(a.files, b.files);
        let c = mixed_workload(100, 1 << 20, 4);
        assert_ne!(a.files, c.files);
    }
}
