//! Property-testing support.
//!
//! The offline vendor set has no `proptest`, so this module provides the
//! pieces the test suite needs: a small, fast, *deterministic* PCG32 RNG,
//! value generators, and a `forall` driver that reports the seed and a
//! shrunk-ish (first-failing) case on failure. Deliberately tiny — no
//! macro magic, just functions.

/// PCG32 (O'Neill): 64-bit state, 32-bit output. Deterministic, seedable,
/// passes practical statistical tests; plenty for property tests and
/// workload generation.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire-ish rejection; exact).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    pub fn below_u64(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below_u64(hi - lo + 1)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a byte buffer.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(4);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u32().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u32().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u32) as usize]
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            items.swap(i, j);
        }
    }
}

/// Run `prop(seed_rng)` for `cases` deterministic cases. On the first
/// failure, re-runs once more to confirm, then panics with the case seed so
/// the failure is reproducible with `forall_seeded`.
pub fn forall<F: FnMut(&mut Pcg32) -> Result<(), String>>(name: &str, cases: u32, mut prop: F) {
    for case in 0..cases {
        let seed = 0x5eed_0000u64 + case as u64;
        let mut rng = Pcg32::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn forall_seeded<F: FnMut(&mut Pcg32) -> Result<(), String>>(
    name: &str,
    seed: u64,
    mut prop: F,
) {
    let mut rng = Pcg32::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}");
    }
}

/// assert_eq-style helper returning Result for use inside `forall`.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

/// assert-style helper returning Result for use inside `forall`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {{
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    }};
    ($cond:expr, $($fmt:tt)+) => {{
        if !$cond {
            return Err(format!($($fmt)+));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn below_is_in_range() {
        let mut rng = Pcg32::new(7);
        for _ in 0..1000 {
            assert!(rng.below(17) < 17);
            let v = rng.range(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg32::new(3);
        for _ in 0..1000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut rng = Pcg32::new(13);
        let mut buf = [0u8; 7];
        rng.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn forall_passes() {
        forall("trivial", 50, |rng| {
            let x = rng.below(100);
            prop_assert!(x < 100);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn forall_reports_failure() {
        forall("fails", 10, |rng| {
            let x = rng.below(4);
            prop_assert!(x < 3, "got {x}");
            Ok(())
        });
    }
}
