//! Run statistics: mean / stddev / confidence intervals.
//!
//! The paper reports multi-iteration averages with 99 % confidence
//! intervals as error bars (Figs 5, 6, 10); this module is the shared
//! implementation used by the figure benches and the metrics reports.

/// Summary of a sample of measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    /// Half-width of the 99 % confidence interval for the mean.
    pub ci99: f64,
    pub min: f64,
    pub max: f64,
}

/// Two-sided 99 % Student-t critical values for small samples (df = n-1);
/// beyond the table we use the normal-approximation 2.576.
const T99: [f64; 30] = [
    63.657, 9.925, 5.841, 4.604, 4.032, 3.707, 3.499, 3.355, 3.250, 3.169, 3.106, 3.055, 3.012,
    2.977, 2.947, 2.921, 2.898, 2.878, 2.861, 2.845, 2.831, 2.819, 2.807, 2.797, 2.787, 2.779,
    2.771, 2.763, 2.756, 2.750,
];

pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "summarize: empty sample");
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let stddev = var.sqrt();
    let t = if n > 1 {
        *T99.get(n - 2).unwrap_or(&2.576)
    } else {
        0.0
    };
    let ci99 = if n > 1 { t * stddev / (n as f64).sqrt() } else { 0.0 };
    let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &s in samples {
        min = min.min(s);
        max = max.max(s);
    }
    Summary { n, mean, stddev, ci99, min, max }
}

/// Accumulating helper for streaming measurements.
#[derive(Debug, Default, Clone)]
pub struct Series {
    samples: Vec<f64>,
}

impl Series {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn summary(&self) -> Summary {
        summarize(&self.samples)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Bench loop: run `f` for `warmup + iters` iterations, timing the last
/// `iters`; returns the per-iteration wall-clock summary in seconds.
pub fn bench_seconds<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut series = Series::new();
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        series.push(t0.elapsed().as_secs_f64());
    }
    series.summary()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_sample() {
        let s = summarize(&[2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.ci99, 0.0);
        assert_eq!((s.min, s.max), (2.0, 2.0));
    }

    #[test]
    fn known_values() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
        // t(df=4) = 4.604; ci = 4.604 * sqrt(2.5)/sqrt(5)
        let expect = 4.604 * (2.5f64).sqrt() / (5f64).sqrt();
        assert!((s.ci99 - expect).abs() < 1e-9);
        assert_eq!((s.min, s.max), (1.0, 5.0));
    }

    #[test]
    fn constant_series_zero_ci() {
        let s = summarize(&[7.0; 10]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.ci99, 0.0);
    }

    #[test]
    fn large_n_uses_normal_approx() {
        let samples: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = summarize(&samples);
        assert_eq!(s.n, 100);
        assert!(s.ci99 > 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        summarize(&[]);
    }
}
