//! Recovery: parse whatever logger state a fault left on disk back into
//! per-file completed sets (§5.2.2's source-side half).
//!
//! The three mechanisms leave different artifacts:
//! - File logger: `*.flog` files (header + records/bitmap), one per
//!   in-flight file. Record streams are *unsorted* — recovery pays the
//!   parse+dedup cost the paper measures as file logger's recovery
//!   overhead (Fig 8).
//! - Transaction/Universal: `index.tidx` + region logs. Regions are
//!   count-prefixed and sorted; a `DONE` tombstone hides completed files.
//!
//! For the bitmap methods the popcounts (completed counts per file) can
//! be computed through the compiled PJRT recovery artifact — see
//! [`recovered_counts_pjrt`] — which is the L1/L2 path the resume flow
//! uses when a runtime is available; [`recover_all`] itself is pure rust
//! so recovery never *requires* the artifacts.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{Context, Result};

use super::codec::{CompletedSet, Method};
use super::file_logger;
use super::region::INDEX_NAME;
use super::{unescape_name, FtConfig, Mechanism};

/// Parse all recoverable per-file completed sets under `cfg.dir`.
/// Keys are the original (unescaped) transferred-file names.
pub fn recover_all(cfg: &FtConfig) -> Result<BTreeMap<String, CompletedSet>> {
    if cfg.mechanism == Mechanism::None {
        return Ok(BTreeMap::new());
    }
    if !cfg.dir.exists() {
        return Ok(BTreeMap::new());
    }
    if cfg.dir.join(INDEX_NAME).exists() {
        recover_region(&cfg.dir, cfg.method)
    } else {
        recover_file_logs(&cfg.dir)
    }
}

/// File-logger recovery: scan `*.flog`, parse header + body.
fn recover_file_logs(dir: &Path) -> Result<BTreeMap<String, CompletedSet>> {
    let mut out = BTreeMap::new();
    for entry in std::fs::read_dir(dir).context("reading FT log dir")? {
        let entry = entry?;
        let path = entry.path();
        if path.extension().map(|e| e != "flog").unwrap_or(true) {
            continue;
        }
        let mut buf = Vec::new();
        std::fs::File::open(&path)?.read_to_end(&mut buf)?;
        let Some((method, total, name, header_len)) = file_logger::decode_header(&buf) else {
            // Torn header (crash during creation): nothing was logged for
            // this file that the sink could have durably written *and*
            // acked, so skipping it is safe (blocks get retransmitted).
            continue;
        };
        let body = &buf[header_len..];
        let set = if method.is_bitmap() {
            CompletedSet::from_bitmap_bytes(total, body)
        } else {
            CompletedSet::from_stream(total, &method.decode_stream(body))
        };
        out.insert(name, set);
    }
    Ok(out)
}

/// Index line for a live file region.
#[derive(Debug, Clone, PartialEq, Eq)]
struct IndexEntry {
    log_name: String,
    total_blocks: u32,
    offset: u64,
    region_len: usize,
}

/// Parse `index.tidx`: later LOG lines override earlier ones (a reused
/// region re-registers the file); DONE removes the entry.
fn parse_index(text: &str) -> BTreeMap<String, IndexEntry> {
    let mut live: BTreeMap<String, IndexEntry> = BTreeMap::new();
    for line in text.lines() {
        let mut parts = line.split(' ');
        match parts.next() {
            Some("LOG") => {
                let Some(log_name) = parts.next() else { continue };
                let Some(escname) = parts.next() else { continue };
                let Some(name) = unescape_name(escname) else { continue };
                let (Some(total), Some(offset), Some(len)) = (
                    parts.next().and_then(|s| s.parse::<u32>().ok()),
                    parts.next().and_then(|s| s.parse::<u64>().ok()),
                    parts.next().and_then(|s| s.parse::<usize>().ok()),
                ) else {
                    continue; // torn tail line
                };
                live.insert(
                    name,
                    IndexEntry {
                        log_name: log_name.to_string(),
                        total_blocks: total,
                        offset,
                        region_len: len,
                    },
                );
            }
            Some("DONE") => {
                if let Some(name) = parts.next().and_then(unescape_name_opt) {
                    live.remove(&name);
                }
            }
            _ => continue,
        }
    }
    live
}

fn unescape_name_opt(s: &str) -> Option<String> {
    unescape_name(s)
}

/// Transaction/universal recovery: index + region decode. `method` is the
/// session's configured method (a resume runs with the same FT flags as
/// the interrupted transfer, §5.2) — regions do not self-describe.
fn recover_region(dir: &Path, method: Method) -> Result<BTreeMap<String, CompletedSet>> {
    let text = std::fs::read_to_string(dir.join(INDEX_NAME)).context("reading index")?;
    let live = parse_index(&text);

    // Read each log file once.
    let mut logs: BTreeMap<String, Vec<u8>> = BTreeMap::new();
    let mut out = BTreeMap::new();
    for (name, e) in live {
        let log = match logs.entry(e.log_name.clone()) {
            std::collections::btree_map::Entry::Occupied(o) => o.into_mut(),
            std::collections::btree_map::Entry::Vacant(v) => {
                let path = dir.join(&e.log_name);
                let mut buf = Vec::new();
                if let Ok(mut f) = std::fs::File::open(&path) {
                    f.read_to_end(&mut buf)?;
                }
                v.insert(buf)
            }
        };
        let start = e.offset as usize;
        if start >= log.len() {
            // Region beyond the (possibly truncated) log: nothing durable.
            out.insert(name, CompletedSet::new(e.total_blocks));
            continue;
        }
        let end = (start + e.region_len).min(log.len());
        let region = &log[start..end];
        let set = decode_region(region, e.total_blocks, method);
        out.insert(name, set);
    }
    Ok(out)
}

/// Decode one region with the session method. Bitmap regions are raw
/// bitmaps; record regions carry a little-endian u32 count followed by
/// sorted records. A torn/garbled region decodes to as many prefix
/// records as are consistent (lost completions are just retransmitted).
fn decode_region(region: &[u8], total_blocks: u32, method: Method) -> CompletedSet {
    if method.is_bitmap() {
        return CompletedSet::from_bitmap_bytes(total_blocks, region);
    }
    if region.len() < 4 {
        return CompletedSet::new(total_blocks);
    }
    let count = u32::from_le_bytes(region[..4].try_into().unwrap());
    if count <= total_blocks {
        if let Some(set) = try_counted(region, total_blocks, count, method) {
            return set;
        }
    }
    // Count/record mismatch (torn write): take the valid sorted prefix.
    let stream = method.decode_stream(&region[4..]);
    let mut prefix = Vec::new();
    for &b in &stream {
        if b >= total_blocks || prefix.last().map(|&p| b <= p).unwrap_or(false) {
            break;
        }
        prefix.push(b);
    }
    CompletedSet::from_stream(total_blocks, &prefix)
}

fn try_counted(
    region: &[u8],
    total_blocks: u32,
    count: u32,
    method: Method,
) -> Option<CompletedSet> {
    let body = &region[4..];
    let stream = method.decode_stream(body);
    if stream.len() < count as usize {
        return None;
    }
    let taken = &stream[..count as usize];
    // Sorted, strictly increasing, in range — the invariant the region
    // writer maintains. Reject otherwise so we do not misdecode.
    if taken.windows(2).any(|w| w[0] >= w[1]) {
        return None;
    }
    if taken.iter().any(|&b| b >= total_blocks) {
        return None;
    }
    Some(CompletedSet::from_stream(total_blocks, taken))
}

/// Bit8/Bit64 resume acceleration: batch the recovered bitmap sets
/// through the PJRT recovery artifact, returning (completed, pending)
/// counts per file in the iteration order of `sets`.
pub fn recovered_counts_pjrt(
    handle: &crate::runtime::RuntimeHandle,
    sets: &BTreeMap<String, CompletedSet>,
) -> Result<BTreeMap<String, (u32, u32)>> {
    let max_words = handle.manifest.bitmap_words;
    let mut names = Vec::new();
    let mut bitmaps = Vec::new();
    let mut totals = Vec::new();
    for (name, set) in sets {
        let mut words = set.to_u32_words();
        anyhow::ensure!(
            words.len() <= max_words,
            "file '{name}' needs {} bitmap words, artifact supports {max_words}",
            words.len()
        );
        words.resize(max_words, 0);
        names.push(name.clone());
        bitmaps.push(words);
        totals.push(set.total());
    }
    let (completed, pending) =
        crate::integrity::pjrt_recovery_summary(handle, &bitmaps, &totals)?;
    Ok(names
        .into_iter()
        .zip(completed.into_iter().zip(pending))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_index_basic() {
        let text = "LOG u.ulog a.dat 10 0 44\nLOG u.ulog b.dat 5 44 24\nDONE a.dat\n";
        let live = parse_index(text);
        assert_eq!(live.len(), 1);
        let e = &live["b.dat"];
        assert_eq!(e.total_blocks, 5);
        assert_eq!(e.offset, 44);
        assert_eq!(e.region_len, 24);
    }

    #[test]
    fn parse_index_reregistration_overrides() {
        let text = "LOG u.ulog f 10 0 44\nDONE f\nLOG u.ulog f 10 100 44\n";
        let live = parse_index(text);
        assert_eq!(live["f"].offset, 100);
    }

    #[test]
    fn parse_index_tolerates_torn_tail() {
        let text = "LOG u.ulog a 10 0 44\nLOG u.ulog b 5 4";
        let live = parse_index(text);
        assert_eq!(live.len(), 1);
        assert!(live.contains_key("a"));
    }

    #[test]
    fn parse_index_escaped_names() {
        let esc = crate::ftlog::escape_name("dir/with space.dat");
        let text = format!("LOG u.ulog {esc} 3 0 16\n");
        let live = parse_index(&text);
        assert!(live.contains_key("dir/with space.dat"));
    }

    #[test]
    fn counted_decode_rejects_unsorted() {
        let mut region = 2u32.to_le_bytes().to_vec();
        Method::Int.encode_record(5, &mut region);
        Method::Int.encode_record(3, &mut region); // unsorted
        assert!(try_counted(&region, 10, 2, Method::Int).is_none());
    }

    #[test]
    fn counted_decode_accepts_sorted() {
        let mut region = 3u32.to_le_bytes().to_vec();
        for b in [1u32, 4, 9] {
            Method::Enc.encode_record(b, &mut region);
        }
        let set = try_counted(&region, 10, 3, Method::Enc).unwrap();
        assert_eq!(set.iter_completed().collect::<Vec<_>>(), vec![1, 4, 9]);
    }

    #[test]
    fn empty_dir_recovers_nothing() {
        let dir = std::env::temp_dir().join(format!(
            "ftlads-recover-empty-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = FtConfig::new(Mechanism::File, Method::Int, &dir);
        assert!(recover_all(&cfg).unwrap().is_empty());
        std::fs::create_dir_all(&dir).unwrap();
        assert!(recover_all(&cfg).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mechanism_none_recovers_nothing() {
        let cfg = FtConfig::new(Mechanism::None, Method::Int, "/nonexistent-xyz");
        assert!(recover_all(&cfg).unwrap().is_empty());
    }
}
