//! Object-based FT logging — the paper's core contribution (§4, §5).
//!
//! Because LADS transfers objects of a file *out of order*, offset
//! checkpoints (bbcp/GridFTP restart markers) cannot describe progress.
//! Instead the source logs every object whose BLOCK_SYNC arrived — i.e.
//! every object durably written at the sink PFS — and on resume schedules
//! only the complement.
//!
//! Three **mechanisms** (how many logger files per dataset):
//! - [`Mechanism::File`] — one log per transferred file, created lazily on
//!   the first completed object ("light-weight logging") and deleted when
//!   the file completes. Appends records in completion order; no
//!   in-memory state (lowest memory, slower recovery parse).
//! - [`Mechanism::Transaction`] — one log per `txn_size` files plus a
//!   dataset-wide index (`[LogFileName, FileName, TotalBlocks, Offset,
//!   Data_Length]`); keeps per-file completed sets in memory and writes
//!   regions *sorted* (higher memory, faster recovery — §6.2/§6.4).
//! - [`Mechanism::Universal`] — one log for the whole dataset plus the
//!   index (`[FileName, TotalBlocks, Offset, Data_Length]`); otherwise
//!   like Transaction. Freed regions are reused, keeping the single log
//!   small.
//!
//! Six **methods** (how a completed block id is encoded) live in
//! [`codec::Method`]: Char, Int, Enc (VLD varint), Binary, Bit8, Bit64.
//!
//! Recovery ([`recover`]) parses whatever the fault left on disk back
//! into per-file [`CompletedSet`]s.

pub mod async_logger;
pub mod codec;
pub mod file_logger;
pub mod manifest;
pub mod recover;
pub mod region;
pub mod vld;

use std::path::PathBuf;

use anyhow::Result;

pub use codec::{CompletedSet, Method};

/// The paper's three logger mechanisms (+ None = stock LADS).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mechanism {
    /// No FT logging (stock LADS; restart retransmits everything).
    None,
    File,
    Transaction,
    Universal,
}

impl Mechanism {
    pub const ALL_FT: [Mechanism; 3] =
        [Mechanism::File, Mechanism::Transaction, Mechanism::Universal];

    pub fn parse(s: &str) -> Result<Mechanism> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Mechanism::None,
            "file" => Mechanism::File,
            "transaction" | "txn" => Mechanism::Transaction,
            "universal" | "univ" => Mechanism::Universal,
            _ => anyhow::bail!("unknown FT mechanism '{s}' (none|file|transaction|universal)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Mechanism::None => "none",
            Mechanism::File => "file",
            Mechanism::Transaction => "transaction",
            Mechanism::Universal => "universal",
        }
    }
}

/// FT logging configuration for one transfer session.
#[derive(Debug, Clone)]
pub struct FtConfig {
    pub mechanism: Mechanism,
    pub method: Method,
    /// Logger directory — the paper's `~/ftlads` subdirectory (§5.2),
    /// created automatically when FT is enabled.
    pub dir: PathBuf,
    /// Files per transaction (paper evaluates 4; 1 degenerates to the
    /// file logger's granularity, ∞ to universal — §6.1).
    pub txn_size: usize,
}

impl FtConfig {
    pub fn new(mechanism: Mechanism, method: Method, dir: impl Into<PathBuf>) -> Self {
        FtConfig { mechanism, method, dir: dir.into(), txn_size: 4 }
    }
}

/// Handle to a registered in-flight file.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FileKey(pub u32);

/// Space/I-O accounting for Fig 7.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpaceStats {
    /// Live logger bytes on disk right now (logs + index).
    pub current_bytes: u64,
    /// High-water mark of `current_bytes` over the session.
    pub peak_bytes: u64,
    /// Total bytes ever written to logger files.
    pub bytes_written: u64,
    /// Logical appends: blocks recorded via `log_block`/`log_blocks`.
    pub appends: u64,
    /// Physical logger write invocations: one per `log_block`, one per
    /// group-committed `log_blocks` batch — the denominator the batched
    /// ack path shrinks.
    pub write_ops: u64,
    /// Live logger bytes measured in allocated 4 KiB file-system blocks
    /// (what `du` would report — each live log file costs at least one
    /// block). This is the measure under which the paper's "universal has
    /// minimal space overhead" holds: one shared log + one index vs one
    /// block-rounded log per in-flight file.
    pub current_alloc_bytes: u64,
    /// High-water mark of `current_alloc_bytes`.
    pub peak_alloc_bytes: u64,
}

/// Round a file size up to allocated 4 KiB blocks (min one block for a
/// non-empty file).
pub fn alloc_rounded(size: u64) -> u64 {
    if size == 0 {
        0
    } else {
        size.div_ceil(4096) * 4096
    }
}

/// The logging interface the source comm thread drives (synchronous
/// logging, §5.1: the completed-block information is written "in the
/// context of the comm thread").
pub trait FtLogger: Send {
    /// Declare a file before its first `log_block`. Light-weight logging:
    /// no file system activity happens here.
    fn register_file(&mut self, name: &str, total_blocks: u32) -> Result<FileKey>;

    /// Record that `block` of `key` was synced at the sink PFS.
    fn log_block(&mut self, key: FileKey, block: u32) -> Result<()>;

    /// Record several synced blocks of `key` at once — the group-commit
    /// entry point the batched BLOCK_SYNC path drives. Implementations
    /// SHOULD perform one seek+write for the whole batch; the default
    /// falls back to per-block `log_block` appends so custom loggers stay
    /// correct without changes. Must be equivalent to calling `log_block`
    /// for each entry in order (and, for a one-element batch, exactly
    /// that).
    fn log_blocks(&mut self, key: FileKey, blocks: &[u32]) -> Result<()> {
        for &b in blocks {
            self.log_block(key, b)?;
        }
        Ok(())
    }

    /// All blocks synced: delete the file's log entry (§5.2.1 "if all the
    /// objects are successfully transferred, then the FT log entry
    /// corresponding to that file is deleted").
    fn complete_file(&mut self, key: FileKey) -> Result<()>;

    /// Dataset complete: remove any remaining logger state.
    fn finish_dataset(&mut self) -> Result<()>;

    fn space(&self) -> SpaceStats;

    fn mechanism(&self) -> Mechanism;
}

/// No-op logger for `Mechanism::None` (stock LADS).
pub struct NullLogger;

impl FtLogger for NullLogger {
    fn register_file(&mut self, _name: &str, _total_blocks: u32) -> Result<FileKey> {
        Ok(FileKey(0))
    }

    fn log_block(&mut self, _key: FileKey, _block: u32) -> Result<()> {
        Ok(())
    }

    fn complete_file(&mut self, _key: FileKey) -> Result<()> {
        Ok(())
    }

    fn finish_dataset(&mut self) -> Result<()> {
        Ok(())
    }

    fn space(&self) -> SpaceStats {
        SpaceStats::default()
    }

    fn mechanism(&self) -> Mechanism {
        Mechanism::None
    }
}

/// Synchronous vs asynchronous logging (paper §5.1; the paper measured
/// no performance difference — the ablation bench reproduces that).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoggingMode {
    Sync,
    Async,
}

impl LoggingMode {
    pub fn parse(s: &str) -> Result<LoggingMode> {
        match s {
            "sync" => Ok(LoggingMode::Sync),
            "async" => Ok(LoggingMode::Async),
            _ => anyhow::bail!("logging mode must be sync|async, got '{s}'"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            LoggingMode::Sync => "sync",
            LoggingMode::Async => "async",
        }
    }
}

/// Build the logger for a session with the given logging mode.
pub fn create_logger_with_mode(
    cfg: &FtConfig,
    mode: LoggingMode,
) -> Result<Box<dyn FtLogger>> {
    let inner = create_logger(cfg)?;
    match (mode, cfg.mechanism) {
        (_, Mechanism::None) | (LoggingMode::Sync, _) => Ok(inner),
        (LoggingMode::Async, _) => Ok(Box::new(async_logger::AsyncLogger::wrap(inner)?)),
    }
}

/// Build the logger for a session.
pub fn create_logger(cfg: &FtConfig) -> Result<Box<dyn FtLogger>> {
    match cfg.mechanism {
        Mechanism::None => Ok(Box::new(NullLogger)),
        Mechanism::File => Ok(Box::new(file_logger::FileLogger::new(cfg)?)),
        Mechanism::Transaction => Ok(Box::new(region::RegionLogger::transaction(cfg)?)),
        Mechanism::Universal => Ok(Box::new(region::RegionLogger::universal(cfg)?)),
    }
}

/// Total bytes currently occupied by logger files under `dir` (on-disk
/// ground truth for the space figures; loggers also track this
/// incrementally in [`SpaceStats`]).
pub fn dir_bytes(dir: &std::path::Path) -> u64 {
    let mut total = 0;
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            if let Ok(md) = e.metadata() {
                if md.is_file() {
                    total += md.len();
                }
            }
        }
    }
    total
}

/// Escape a file name for use inside index lines / log file names: every
/// byte outside `[A-Za-z0-9._-]` becomes `%xx` (so escaped names are
/// always safe as single space-separated index tokens AND as flat file
/// names, including non-ASCII input).
pub fn escape_name(name: &str) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(name.len());
    for b in name.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'.' | b'_' | b'-' => {
                out.push(b as char)
            }
            // Direct nibble pushes: this runs per index line, so no
            // per-byte format! allocation on the hot path.
            _ => {
                out.push('%');
                out.push(HEX[(b >> 4) as usize] as char);
                out.push(HEX[(b & 0x0f) as usize] as char);
            }
        }
    }
    out
}

pub fn unescape_name(esc: &str) -> Option<String> {
    let bytes = esc.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            if i + 3 > bytes.len() {
                return None;
            }
            let hex = std::str::from_utf8(&bytes[i + 1..i + 3]).ok()?;
            out.push(u8::from_str_radix(hex, 16).ok()?);
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mechanism_parse() {
        assert_eq!(Mechanism::parse("file").unwrap(), Mechanism::File);
        assert_eq!(Mechanism::parse("txn").unwrap(), Mechanism::Transaction);
        assert_eq!(Mechanism::parse("universal").unwrap(), Mechanism::Universal);
        assert_eq!(Mechanism::parse("none").unwrap(), Mechanism::None);
        assert!(Mechanism::parse("quantum").is_err());
        for m in Mechanism::ALL_FT {
            assert_eq!(Mechanism::parse(m.as_str()).unwrap(), m);
        }
    }

    #[test]
    fn null_logger_is_inert() {
        let mut l = NullLogger;
        let k = l.register_file("x", 10).unwrap();
        l.log_block(k, 3).unwrap();
        l.complete_file(k).unwrap();
        l.finish_dataset().unwrap();
        assert_eq!(l.space(), SpaceStats::default());
        assert_eq!(l.mechanism(), Mechanism::None);
    }

    #[test]
    fn escape_roundtrip() {
        for name in [
            "plain.dat",
            "dir/sub/file.bin",
            "with space.dat",
            "100%.log",
            "multi\nline",
            "unicode-α.dat",
        ] {
            let esc = escape_name(name);
            assert!(!esc.contains(' ') && !esc.contains('\n') && !esc.contains('/'));
            assert_eq!(unescape_name(&esc).unwrap(), name, "escaped: {esc}");
        }
    }

    #[test]
    fn escape_emits_lowercase_two_digit_hex() {
        // Pin the exact encoding the old format!("%{b:02x}") produced so
        // logs written before the hot-path rewrite still unescape.
        assert_eq!(escape_name("a b"), "a%20b");
        assert_eq!(escape_name("100%"), "100%25");
        assert_eq!(escape_name("α"), "%ce%b1");
        assert_eq!(escape_name("x/y"), "x%2fy");
        assert_eq!(escape_name("\n"), "%0a");
    }

    #[test]
    fn unescape_rejects_truncated() {
        assert!(unescape_name("abc%2").is_none());
        assert!(unescape_name("%").is_none());
        assert!(unescape_name("%zz").is_none());
    }
}
