//! Variable-Length Datatype (VLD) codec — the paper's `Enc` method.
//!
//! The paper describes `Enc` as "successful block information with the
//! char type … encoded using a Variable Length Datatype (VLD) library
//! written by one of the authors". The library itself is not published;
//! we use LEB128 (the canonical varint): 7 data bits per byte, high bit =
//! continuation. Block ids < 128 take 1 byte, < 16384 take 2, etc. —
//! strictly smaller than both the `Char` (ASCII decimal) and `Int`
//! (fixed 4-byte) encodings for realistic block counts, which is the
//! property the paper's Fig 7 relies on.

/// Append the varint encoding of `v` to `out`; returns bytes written.
pub fn encode_u32(v: u32, out: &mut Vec<u8>) -> usize {
    let mut v = v;
    let mut n = 0;
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return n + 1;
        }
        out.push(byte | 0x80);
        n += 1;
    }
}

/// Decode one varint from `buf`; returns `(value, bytes_consumed)` or
/// `None` on truncation/overflow.
pub fn decode_u32(buf: &[u8]) -> Option<(u32, usize)> {
    let mut v: u64 = 0;
    for (i, &b) in buf.iter().enumerate().take(5) {
        v |= ((b & 0x7f) as u64) << (7 * i);
        if b & 0x80 == 0 {
            if v > u32::MAX as u64 {
                return None;
            }
            return Some((v as u32, i + 1));
        }
    }
    None // truncated or > 5 bytes
}

/// Encoded size of `v` without materializing it.
pub fn encoded_len(v: u32) -> usize {
    match v {
        0..=0x7f => 1,
        0x80..=0x3fff => 2,
        0x4000..=0x1f_ffff => 3,
        0x20_0000..=0xfff_ffff => 4,
        _ => 5,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_boundaries() {
        for v in [
            0u32,
            1,
            0x7f,
            0x80,
            0x3fff,
            0x4000,
            0x1f_ffff,
            0x20_0000,
            0xfff_ffff,
            0x1000_0000,
            u32::MAX,
        ] {
            let mut buf = Vec::new();
            let n = encode_u32(v, &mut buf);
            assert_eq!(n, buf.len());
            assert_eq!(n, encoded_len(v), "len mismatch for {v}");
            let (back, used) = decode_u32(&buf).unwrap();
            assert_eq!(back, v);
            assert_eq!(used, n);
        }
    }

    #[test]
    fn decode_stream() {
        let mut buf = Vec::new();
        for v in [3u32, 300, 70_000, 5] {
            encode_u32(v, &mut buf);
        }
        let mut pos = 0;
        let mut out = Vec::new();
        while pos < buf.len() {
            let (v, n) = decode_u32(&buf[pos..]).unwrap();
            out.push(v);
            pos += n;
        }
        assert_eq!(out, vec![3, 300, 70_000, 5]);
    }

    #[test]
    fn truncated_returns_none() {
        let mut buf = Vec::new();
        encode_u32(300, &mut buf); // 2 bytes
        assert!(decode_u32(&buf[..1]).is_none());
        assert!(decode_u32(&[]).is_none());
    }

    #[test]
    fn overlong_rejected() {
        // 6 continuation bytes: invalid for u32.
        assert!(decode_u32(&[0x80, 0x80, 0x80, 0x80, 0x80, 0x01]).is_none());
        // 5 bytes encoding > u32::MAX.
        assert!(decode_u32(&[0xff, 0xff, 0xff, 0xff, 0x7f]).is_none());
    }

    #[test]
    fn smaller_than_char_and_int() {
        // The Fig 7 property: enc <= int (4B) and enc <= char for ids
        // that fit in 3 decimal digits or fewer bytes.
        for v in 0..100_000u32 {
            let char_len = v.to_string().len() + 1; // + '\n'
            assert!(encoded_len(v) <= 4);
            assert!(encoded_len(v) <= char_len);
        }
    }
}
