//! Transaction and Universal loggers (§4.1.2, §4.1.3).
//!
//! Both share one implementation: completed-block information for several
//! files multiplexed into shared log files, with an *index file*
//! describing where each file's region lives:
//!
//! ```text
//! LOG  <LogFileName> <FileName> <TotalBlocks> <Offset> <Data_Length>
//! DONE <FileName>
//! ```
//!
//! (the paper's `[LogFileName, FileName, TotalBlocks, Offset,
//! Data_Length]` line; the universal logger's lines simply always name the
//! single log file). The index is append-only; `DONE` tombstones a file's
//! entry when its transfer completes.
//!
//! In contrast to the file logger, these mechanisms keep each in-flight
//! file's completed set *in memory* and write its region **sorted by
//! object index** (§6.2: "completed objects information of all files are
//! maintained internally as a list … sorted based on object index", §6.4:
//! that is why their recovery is faster). This is also exactly the memory
//! overhead Fig 5(c)/6(c) attributes to them.
//!
//! Freed regions go on a per-log free list and are reused by later files;
//! a freed tail region shrinks the log. A transaction log whose
//! `txn_size` files have all completed is deleted outright.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::PathBuf;

use anyhow::{Context, Result};

use super::codec::{CompletedSet, Method};
use super::{alloc_rounded, escape_name, FileKey, FtConfig, FtLogger, Mechanism, SpaceStats};

pub const INDEX_NAME: &str = "index.tidx";
pub const UNIVERSAL_LOG: &str = "universal.ulog";

struct RegState {
    name: String,
    total_blocks: u32,
    set: CompletedSet,
    /// Region allocation, present once the first block was logged.
    region: Option<Region>,
    done: bool,
}

#[derive(Debug, Clone)]
struct Region {
    log_name: String,
    offset: u64,
    len: usize,
}

struct LogState {
    path: PathBuf,
    file: File,
    /// End-of-allocations cursor (regions are allocated below this).
    cursor: u64,
    /// Freed regions available for reuse: (offset, len).
    free: Vec<(u64, usize)>,
    /// Files with live (allocated, not-done) regions.
    live: usize,
    /// Files ever assigned to this log.
    assigned: usize,
}

pub struct RegionLogger {
    mechanism: Mechanism,
    dir: PathBuf,
    method: Method,
    /// Files per transaction log (usize::MAX for universal).
    txn_size: usize,
    files: Vec<RegState>,
    logs: BTreeMap<String, LogState>,
    index: File,
    index_bytes: u64,
    stats: SpaceStats,
    scratch: Vec<u8>,
}

impl RegionLogger {
    pub fn transaction(cfg: &FtConfig) -> Result<RegionLogger> {
        anyhow::ensure!(cfg.txn_size >= 1, "txn_size must be >= 1");
        Self::new(cfg, Mechanism::Transaction, cfg.txn_size)
    }

    pub fn universal(cfg: &FtConfig) -> Result<RegionLogger> {
        Self::new(cfg, Mechanism::Universal, usize::MAX)
    }

    fn new(cfg: &FtConfig, mechanism: Mechanism, txn_size: usize) -> Result<RegionLogger> {
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating FT log dir {}", cfg.dir.display()))?;
        let index_path = cfg.dir.join(INDEX_NAME);
        let index = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&index_path)
            .with_context(|| format!("creating index {}", index_path.display()))?;
        let index_bytes = index.metadata()?.len();
        Ok(RegionLogger {
            mechanism,
            dir: cfg.dir.clone(),
            method: cfg.method,
            txn_size,
            files: Vec::new(),
            logs: BTreeMap::new(),
            index,
            index_bytes,
            stats: SpaceStats {
                current_bytes: index_bytes,
                peak_bytes: index_bytes,
                ..Default::default()
            },
            scratch: Vec::with_capacity(4096),
        })
    }

    fn log_name_for(&self, key: FileKey) -> String {
        if self.txn_size == usize::MAX {
            UNIVERSAL_LOG.to_string()
        } else {
            format!("txn_{:05}.tlog", key.0 as usize / self.txn_size)
        }
    }

    fn charge(&mut self, grow: i64, written: u64) {
        self.stats.bytes_written += written;
        if grow >= 0 {
            self.stats.current_bytes += grow as u64;
        } else {
            self.stats.current_bytes = self.stats.current_bytes.saturating_sub((-grow) as u64);
        }
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.stats.current_bytes);
        self.recompute_alloc();
    }

    /// Recompute the allocated-block gauge from live log cursors + index.
    /// (Logs per dataset are few — one per active transaction or one
    /// total — so the walk is O(active txns), not O(files).)
    fn recompute_alloc(&mut self) {
        let mut alloc = alloc_rounded(self.index_bytes);
        for log in self.logs.values() {
            alloc += alloc_rounded(log.cursor);
        }
        self.stats.current_alloc_bytes = alloc;
        self.stats.peak_alloc_bytes = self.stats.peak_alloc_bytes.max(alloc);
    }

    fn append_index_line(&mut self, line: &str) -> Result<()> {
        self.index.write_all(line.as_bytes())?;
        self.index_bytes += line.len() as u64;
        self.charge(line.len() as i64, line.len() as u64);
        Ok(())
    }

    /// Ensure the file has a region allocated (lazy, on first completion).
    fn ensure_region(&mut self, key: FileKey) -> Result<()> {
        if self.files[key.0 as usize].region.is_some() {
            return Ok(());
        }
        let log_name = self.log_name_for(key);
        let (total_blocks, name) = {
            let st = &self.files[key.0 as usize];
            (st.total_blocks, st.name.clone())
        };
        let region_len = self.method.region_bytes(total_blocks);

        // Open/create the shared log lazily.
        if !self.logs.contains_key(&log_name) {
            let path = self.dir.join(&log_name);
            let file = OpenOptions::new()
                .create(true)
                .read(true)
                .write(true)
                .open(&path)
                .with_context(|| format!("creating log {}", path.display()))?;
            let cursor = file.metadata()?.len();
            self.logs.insert(
                log_name.clone(),
                LogState { path, file, cursor, free: Vec::new(), live: 0, assigned: 0 },
            );
        }

        let (offset, grow) = {
            let log = self.logs.get_mut(&log_name).unwrap();
            log.live += 1;
            log.assigned += 1;
            // Reuse a freed region if one is big enough (best fit).
            let slot = log
                .free
                .iter()
                .enumerate()
                .filter(|(_, (_, len))| *len >= region_len)
                .min_by_key(|(_, (_, len))| *len)
                .map(|(i, _)| i);
            match slot {
                Some(i) => {
                    let (off, _) = log.free.remove(i);
                    (off, 0i64)
                }
                None => {
                    let off = log.cursor;
                    log.cursor += region_len as u64;
                    log.file.set_len(log.cursor)?;
                    (off, region_len as i64)
                }
            }
        };
        self.charge(grow, 0);

        // Zero the region if reused (stale bits from the previous tenant
        // would corrupt bitmap decodes).
        {
            let log = self.logs.get_mut(&log_name).unwrap();
            let zeros = vec![0u8; region_len];
            log.file.seek(SeekFrom::Start(offset))?;
            log.file.write_all(&zeros)?;
        }
        self.charge(0, region_len as u64);

        self.files[key.0 as usize].region =
            Some(Region { log_name: log_name.clone(), offset, len: region_len });

        // Paper's index line: [LogFileName, FileName, TotalBlocks, Offset,
        // Data_Length]. (Universal's line nominally omits LogFileName; we
        // keep the column with the constant name for a single parser.)
        let line = format!(
            "LOG {} {} {} {} {}\n",
            log_name,
            escape_name(&name),
            total_blocks,
            offset,
            region_len
        );
        self.append_index_line(&line)
    }

    /// Rewrite the (sorted) region contents for a record-stream method,
    /// or the affected word for a bitmap method.
    fn write_region(&mut self, key: FileKey, new_block: u32) -> Result<()> {
        let (region, word_io) = {
            let st = &self.files[key.0 as usize];
            let region = st.region.clone().expect("region allocated");
            (region, self.method.is_bitmap())
        };
        if word_io {
            // Bitmap: write only the word containing the new bit, straight
            // from the in-memory set (no file read needed — the set is
            // authoritative).
            let range = self.method.word_range(new_block);
            let st = &self.files[key.0 as usize];
            let mut word = vec![0u8; range.len()];
            for (i, byte) in word.iter_mut().enumerate() {
                let base = ((range.start + i) * 8) as u32;
                for bit in 0..8u32 {
                    let b = base + bit;
                    if b < st.total_blocks && st.set.contains(b) {
                        *byte |= 1 << bit;
                    }
                }
            }
            let log = self.logs.get_mut(&region.log_name).unwrap();
            log.file.seek(SeekFrom::Start(region.offset + range.start as u64))?;
            log.file.write_all(&word)?;
            self.charge(0, word.len() as u64);
        } else {
            // Record stream: count-prefixed, sorted rewrite (§6.2).
            self.scratch.clear();
            let st = &self.files[key.0 as usize];
            self.scratch.extend_from_slice(&st.set.count().to_le_bytes());
            for b in st.set.iter_completed() {
                self.method.encode_record(b, &mut self.scratch);
            }
            anyhow::ensure!(
                self.scratch.len() <= region.len,
                "region overflow for '{}': {} > {}",
                st.name,
                self.scratch.len(),
                region.len
            );
            let written = self.scratch.len() as u64;
            let log = self.logs.get_mut(&region.log_name).unwrap();
            log.file.seek(SeekFrom::Start(region.offset))?;
            log.file.write_all(&self.scratch)?;
            self.charge(0, written);
        }
        Ok(())
    }

    /// Rewrite the file's region for a whole batch in ONE write — the
    /// group-commit path for multi-block batches. Bitmaps write only the
    /// word span covering the batch's blocks (from the in-memory set, no
    /// file read needed); stream regions are count-prefixed sorted
    /// rewrites, which are whole-region by format.
    fn write_region_batch(&mut self, key: FileKey, blocks: &[u32]) -> Result<()> {
        let region = self.files[key.0 as usize]
            .region
            .clone()
            .expect("region allocated");
        if self.method.is_bitmap() {
            let mut lo = usize::MAX;
            let mut hi = 0usize;
            for &b in blocks {
                let r = self.method.word_range(b);
                lo = lo.min(r.start);
                hi = hi.max(r.end);
            }
            let st = &self.files[key.0 as usize];
            let mut span = vec![0u8; hi - lo];
            for (i, byte) in span.iter_mut().enumerate() {
                let base = ((lo + i) * 8) as u32;
                for bit in 0..8u32 {
                    let b = base + bit;
                    if b < st.total_blocks && st.set.contains(b) {
                        *byte |= 1 << bit;
                    }
                }
            }
            let log = self.logs.get_mut(&region.log_name).unwrap();
            log.file.seek(SeekFrom::Start(region.offset + lo as u64))?;
            log.file.write_all(&span)?;
            self.charge(0, span.len() as u64);
        } else {
            // Count-prefixed, sorted rewrite (§6.2) — same bytes the
            // per-block path produces after its last append.
            self.scratch.clear();
            let st = &self.files[key.0 as usize];
            self.scratch.extend_from_slice(&st.set.count().to_le_bytes());
            for b in st.set.iter_completed() {
                self.method.encode_record(b, &mut self.scratch);
            }
            anyhow::ensure!(
                self.scratch.len() <= region.len,
                "region overflow for '{}': {} > {}",
                st.name,
                self.scratch.len(),
                region.len
            );
            let written = self.scratch.len() as u64;
            let log = self.logs.get_mut(&region.log_name).unwrap();
            log.file.seek(SeekFrom::Start(region.offset))?;
            log.file.write_all(&self.scratch)?;
            self.charge(0, written);
        }
        Ok(())
    }
}

impl FtLogger for RegionLogger {
    fn register_file(&mut self, name: &str, total_blocks: u32) -> Result<FileKey> {
        let key = FileKey(self.files.len() as u32);
        self.files.push(RegState {
            name: name.to_string(),
            total_blocks,
            set: CompletedSet::new(total_blocks),
            region: None,
            done: false,
        });
        Ok(key)
    }

    fn log_block(&mut self, key: FileKey, block: u32) -> Result<()> {
        {
            let st = &mut self.files[key.0 as usize];
            anyhow::ensure!(
                block < st.total_blocks,
                "block {block} out of range for '{}' ({} blocks)",
                st.name,
                st.total_blocks
            );
            if !st.set.insert(block) {
                return Ok(()); // duplicate sync (retransmit) — already durable
            }
        }
        self.ensure_region(key)?;
        self.write_region(key, block)?;
        self.stats.appends += 1;
        self.stats.write_ops += 1;
        Ok(())
    }

    fn log_blocks(&mut self, key: FileKey, blocks: &[u32]) -> Result<()> {
        match blocks {
            [] => return Ok(()),
            [b] => return self.log_block(key, *b),
            _ => {}
        }
        let fresh = {
            let st = &mut self.files[key.0 as usize];
            for &b in blocks {
                anyhow::ensure!(
                    b < st.total_blocks,
                    "block {b} out of range for '{}' ({} blocks)",
                    st.name,
                    st.total_blocks
                );
            }
            let mut fresh = 0u64;
            for &b in blocks {
                if st.set.insert(b) {
                    fresh += 1;
                }
            }
            fresh
        };
        if fresh == 0 {
            return Ok(()); // whole batch was duplicate retransmits
        }
        self.ensure_region(key)?;
        self.write_region_batch(key, blocks)?;
        self.stats.appends += fresh;
        self.stats.write_ops += 1;
        Ok(())
    }

    fn complete_file(&mut self, key: FileKey) -> Result<()> {
        let (name, region) = {
            let st = &mut self.files[key.0 as usize];
            if st.done {
                return Ok(());
            }
            st.done = true;
            (st.name.clone(), st.region.take())
        };
        let Some(region) = region else {
            return Ok(()); // zero logged blocks (file skipped at resume)
        };

        // Tombstone the index entry (§5.2.1 "the FT log entry
        // corresponding to that file is deleted").
        let line = format!("DONE {}\n", escape_name(&name));
        self.append_index_line(&line)?;

        let mut delete_log: Option<String> = None;
        let mut shrink: i64 = 0;
        {
            let log = self.logs.get_mut(&region.log_name).unwrap();
            log.live -= 1;
            if region.offset + region.len as u64 == log.cursor {
                // Tail region: reclaim the space physically.
                log.cursor = region.offset;
                // Also swallow any adjacent freed tail regions.
                loop {
                    let tail = log
                        .free
                        .iter()
                        .position(|(off, len)| off + *len as u64 == log.cursor);
                    match tail {
                        Some(i) => {
                            let (off, len) = log.free.remove(i);
                            log.cursor = off;
                            shrink += len as i64;
                        }
                        None => break,
                    }
                }
                log.file.set_len(log.cursor)?;
                shrink += region.len as i64;
            } else {
                log.free.push((region.offset, region.len));
            }
            // A full transaction whose files all completed is deleted
            // outright (the file-logger deletion semantics at transaction
            // granularity). Universal logs persist until finish_dataset.
            if self.txn_size != usize::MAX && log.assigned == self.txn_size && log.live == 0 {
                delete_log = Some(region.log_name.clone());
            }
        }
        if shrink > 0 {
            self.charge(-shrink, 0);
        }
        if let Some(name) = delete_log {
            let log = self.logs.remove(&name).unwrap();
            let size = log.file.metadata().map(|m| m.len()).unwrap_or(0);
            drop(log.file);
            std::fs::remove_file(&log.path)
                .with_context(|| format!("removing log {}", log.path.display()))?;
            self.charge(-(size as i64), 0);
        }
        Ok(())
    }

    fn finish_dataset(&mut self) -> Result<()> {
        for (_, log) in std::mem::take(&mut self.logs) {
            let size = log.file.metadata().map(|m| m.len()).unwrap_or(0);
            drop(log.file);
            let _ = std::fs::remove_file(&log.path);
            self.charge(-(size as i64), 0);
        }
        let index_path = self.dir.join(INDEX_NAME);
        let _ = std::fs::remove_file(&index_path);
        self.charge(-(self.index_bytes as i64), 0);
        self.index_bytes = 0;
        Ok(())
    }

    fn space(&self) -> SpaceStats {
        self.stats
    }

    fn mechanism(&self) -> Mechanism {
        self.mechanism
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftlog::recover;
    use std::path::Path;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ftlads-region-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn cfg(dir: &Path, mechanism: Mechanism, method: Method, txn: usize) -> FtConfig {
        FtConfig { mechanism, method, dir: dir.to_path_buf(), txn_size: txn }
    }

    #[test]
    fn transaction_groups_files_into_logs() {
        let dir = tmp_dir("txn-group");
        let c = cfg(&dir, Mechanism::Transaction, Method::Int, 2);
        let mut l = RegionLogger::transaction(&c).unwrap();
        let keys: Vec<FileKey> =
            (0..5).map(|i| l.register_file(&format!("f{i}"), 8).unwrap()).collect();
        for &k in &keys {
            l.log_block(k, 0).unwrap();
        }
        // 5 files, txn size 2 -> logs txn_00000..txn_00002 + index.
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .collect();
        names.sort();
        assert_eq!(
            names,
            vec!["index.tidx", "txn_00000.tlog", "txn_00001.tlog", "txn_00002.tlog"]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn universal_uses_single_log() {
        let dir = tmp_dir("univ-single");
        let c = cfg(&dir, Mechanism::Universal, Method::Bit8, 4);
        let mut l = RegionLogger::universal(&c).unwrap();
        for i in 0..10 {
            let k = l.register_file(&format!("f{i}"), 64).unwrap();
            l.log_block(k, (i % 64) as u32).unwrap();
        }
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .collect();
        names.sort();
        assert_eq!(names, vec!["index.tidx", UNIVERSAL_LOG]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn all_methods_roundtrip_through_recovery() {
        for mech in [Mechanism::Transaction, Mechanism::Universal] {
            for method in Method::ALL {
                let dir = tmp_dir(&format!("rt-{}-{}", mech.as_str(), method.as_str()));
                let c = cfg(&dir, mech, method, 3);
                let mut l = match mech {
                    Mechanism::Transaction => RegionLogger::transaction(&c).unwrap(),
                    _ => RegionLogger::universal(&c).unwrap(),
                };
                let ka = l.register_file("a", 50).unwrap();
                let kb = l.register_file("b", 7).unwrap();
                for b in [9u32, 0, 49, 20, 21, 9] {
                    l.log_block(ka, b).unwrap();
                }
                for b in [6u32, 1] {
                    l.log_block(kb, b).unwrap();
                }
                let rec = recover::recover_all(&c).unwrap();
                assert_eq!(rec.len(), 2, "{mech:?}/{method:?}");
                let sa = &rec["a"];
                assert_eq!(sa.count(), 5);
                for b in [9, 0, 49, 20, 21] {
                    assert!(sa.contains(b), "{mech:?}/{method:?} missing {b}");
                }
                let sb = &rec["b"];
                assert_eq!(sb.iter_completed().collect::<Vec<_>>(), vec![1, 6]);
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }

    #[test]
    fn log_blocks_group_commit_equals_sequential() {
        for mech in [Mechanism::Transaction, Mechanism::Universal] {
            for method in Method::ALL {
                let dir = tmp_dir(&format!("grp-{}-{}", mech.as_str(), method.as_str()));
                let c = cfg(&dir, mech, method, 3);
                let mut l = match mech {
                    Mechanism::Transaction => RegionLogger::transaction(&c).unwrap(),
                    _ => RegionLogger::universal(&c).unwrap(),
                };
                let k = l.register_file("g", 64).unwrap();
                l.log_blocks(k, &[9u32, 0, 63, 20, 9 /* dup */]).unwrap();
                l.log_blocks(k, &[1u32, 2]).unwrap();
                let s = l.space();
                assert_eq!(s.write_ops, 2, "{mech:?}/{method:?}");
                assert_eq!(s.appends, 6, "{mech:?}/{method:?}");
                // An all-duplicate batch writes nothing.
                l.log_blocks(k, &[0u32, 1]).unwrap();
                assert_eq!(l.space().write_ops, 2, "{mech:?}/{method:?}");
                let rec = recover::recover_all(&c).unwrap();
                assert_eq!(
                    rec["g"].iter_completed().collect::<Vec<_>>(),
                    vec![0, 1, 2, 9, 20, 63],
                    "{mech:?}/{method:?}"
                );
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }

    #[test]
    fn done_tombstone_removes_from_recovery() {
        let dir = tmp_dir("done");
        let c = cfg(&dir, Mechanism::Universal, Method::Enc, 4);
        let mut l = RegionLogger::universal(&c).unwrap();
        let ka = l.register_file("done.dat", 4).unwrap();
        let kb = l.register_file("live.dat", 4).unwrap();
        for b in 0..4 {
            l.log_block(ka, b).unwrap();
        }
        l.log_block(kb, 2).unwrap();
        l.complete_file(ka).unwrap();
        let rec = recover::recover_all(&c).unwrap();
        assert!(!rec.contains_key("done.dat"));
        assert!(rec.contains_key("live.dat"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn completed_transaction_log_is_deleted() {
        let dir = tmp_dir("txn-del");
        let c = cfg(&dir, Mechanism::Transaction, Method::Int, 2);
        let mut l = RegionLogger::transaction(&c).unwrap();
        let k0 = l.register_file("f0", 4).unwrap();
        let k1 = l.register_file("f1", 4).unwrap();
        let k2 = l.register_file("f2", 4).unwrap();
        for k in [k0, k1, k2] {
            for b in 0..4 {
                l.log_block(k, b).unwrap();
            }
        }
        assert!(dir.join("txn_00000.tlog").exists());
        l.complete_file(k0).unwrap();
        assert!(dir.join("txn_00000.tlog").exists(), "half-done txn stays");
        l.complete_file(k1).unwrap();
        assert!(!dir.join("txn_00000.tlog").exists(), "full txn deleted");
        assert!(dir.join("txn_00001.tlog").exists(), "other txn unaffected");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn universal_reuses_freed_regions() {
        let dir = tmp_dir("reuse");
        let c = cfg(&dir, Mechanism::Universal, Method::Int, 4);
        let mut l = RegionLogger::universal(&c).unwrap();
        // Register + complete files one at a time: the log should stay at
        // ~one region's size rather than growing linearly.
        let region = Method::Int.region_bytes(16) as u64;
        for i in 0..20 {
            let k = l.register_file(&format!("f{i}"), 16).unwrap();
            for b in 0..16 {
                l.log_block(k, b).unwrap();
            }
            l.complete_file(k).unwrap();
        }
        let log_size = std::fs::metadata(dir.join(UNIVERSAL_LOG)).unwrap().len();
        assert!(
            log_size <= 2 * region,
            "universal log should reuse regions: {log_size} vs region {region}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn finish_dataset_cleans_everything() {
        let dir = tmp_dir("finish");
        let c = cfg(&dir, Mechanism::Universal, Method::Bit64, 4);
        let mut l = RegionLogger::universal(&c).unwrap();
        let k = l.register_file("f", 8).unwrap();
        l.log_block(k, 3).unwrap();
        l.complete_file(k).unwrap();
        l.finish_dataset().unwrap();
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
        assert_eq!(l.space().current_bytes, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_sync_is_idempotent() {
        let dir = tmp_dir("dup");
        let c = cfg(&dir, Mechanism::Universal, Method::Char, 4);
        let mut l = RegionLogger::universal(&c).unwrap();
        let k = l.register_file("f", 8).unwrap();
        l.log_block(k, 5).unwrap();
        let w1 = l.space().bytes_written;
        l.log_block(k, 5).unwrap();
        assert_eq!(l.space().bytes_written, w1, "duplicate write skipped");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn space_tracking_matches_disk() {
        let dir = tmp_dir("space");
        let c = cfg(&dir, Mechanism::Transaction, Method::Bit8, 2);
        let mut l = RegionLogger::transaction(&c).unwrap();
        for i in 0..6 {
            let k = l.register_file(&format!("f{i}"), 100).unwrap();
            l.log_block(k, 50).unwrap();
        }
        let disk = crate::ftlog::dir_bytes(&dir);
        assert_eq!(l.space().current_bytes, disk);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
