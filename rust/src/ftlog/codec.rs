//! Per-method encodings of completed-block information (paper §4.2).
//!
//! Six methods, two families:
//!
//! **Record streams** (Char, Int, Enc, Binary): each completed block id is
//! one record. The file logger appends records in completion order (out
//! of order); the transaction/universal loggers write a sorted,
//! count-prefixed region. Decoders tolerate torn tails (a crash can land
//! mid-record — the lost suffix is simply retransmitted).
//!
//! **Bitmaps** (Bit8, Bit64): one bit per block, Algorithm 1's
//! read-modify-write on N-bit words. Word size is the only difference
//! between the two (and the rounding of region size it implies).

use super::vld;

/// The paper's six logging methods.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Char,
    Int,
    Enc,
    Binary,
    Bit8,
    Bit64,
}

impl Method {
    pub const ALL: [Method; 6] = [
        Method::Char,
        Method::Int,
        Method::Enc,
        Method::Binary,
        Method::Bit8,
        Method::Bit64,
    ];

    pub fn parse(s: &str) -> anyhow::Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "char" => Method::Char,
            "int" => Method::Int,
            "enc" => Method::Enc,
            "binary" => Method::Binary,
            "bit8" => Method::Bit8,
            "bit64" => Method::Bit64,
            _ => anyhow::bail!("unknown FT method '{s}' (char|int|enc|binary|bit8|bit64)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Method::Char => "char",
            Method::Int => "int",
            Method::Enc => "enc",
            Method::Binary => "binary",
            Method::Bit8 => "bit8",
            Method::Bit64 => "bit64",
        }
    }

    pub fn is_bitmap(&self) -> bool {
        matches!(self, Method::Bit8 | Method::Bit64)
    }

    /// Bitmap word size in bytes (Algorithm 1's N/8).
    pub fn word_bytes(&self) -> usize {
        match self {
            Method::Bit8 => 1,
            Method::Bit64 => 8,
            _ => panic!("word_bytes on non-bitmap method"),
        }
    }

    /// Worst-case bytes to record all of `total_blocks` completions —
    /// the region size the transaction/universal loggers reserve.
    pub fn region_bytes(&self, total_blocks: u32) -> usize {
        match self {
            // count prefix + records
            Method::Char => 4 + total_blocks as usize * 11, // "4294967295\n"
            Method::Int | Method::Binary => 4 + total_blocks as usize * 4,
            Method::Enc => 4 + total_blocks as usize * 5,
            Method::Bit8 => {
                (total_blocks as usize).div_ceil(8)
            }
            Method::Bit64 => {
                (total_blocks as usize).div_ceil(64) * 8
            }
        }
    }

    /// Append one record (record-stream methods only).
    pub fn encode_record(&self, block: u32, out: &mut Vec<u8>) {
        match self {
            Method::Char => {
                out.extend_from_slice(block.to_string().as_bytes());
                out.push(b'\n');
            }
            Method::Int => out.extend_from_slice(&block.to_le_bytes()),
            Method::Enc => {
                vld::encode_u32(block, out);
            }
            Method::Binary => {
                // "converted to binary format … 32-bit binary
                // representation": big-endian bit-string, byte-packed.
                out.extend_from_slice(&block.to_be_bytes());
            }
            Method::Bit8 | Method::Bit64 => panic!("encode_record on bitmap method"),
        }
    }

    /// Decode a record stream, tolerating a torn tail. Returns block ids
    /// in stream order (may contain duplicates if a block was re-sent).
    pub fn decode_stream(&self, buf: &[u8]) -> Vec<u32> {
        let mut out = Vec::new();
        match self {
            Method::Char => {
                for line in buf.split(|&b| b == b'\n') {
                    if line.is_empty() {
                        continue;
                    }
                    if let Ok(s) = std::str::from_utf8(line) {
                        if let Ok(v) = s.trim().parse::<u32>() {
                            out.push(v);
                        }
                    }
                }
                // A torn tail (no trailing newline) was still parsed above;
                // drop it only if the buffer does not end with '\n' AND the
                // tail parsed — we cannot distinguish "complete but
                // unterminated" from torn, so be conservative and keep it:
                // a duplicate retransmit is harmless, a lost record is not.
            }
            Method::Int => {
                for c in buf.chunks_exact(4) {
                    out.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
            }
            Method::Binary => {
                for c in buf.chunks_exact(4) {
                    out.push(u32::from_be_bytes([c[0], c[1], c[2], c[3]]));
                }
            }
            Method::Enc => {
                let mut pos = 0;
                while pos < buf.len() {
                    match vld::decode_u32(&buf[pos..]) {
                        Some((v, n)) => {
                            out.push(v);
                            pos += n;
                        }
                        None => break, // torn tail
                    }
                }
            }
            Method::Bit8 | Method::Bit64 => panic!("decode_stream on bitmap method"),
        }
        out
    }

    /// Bitmap byte + bit position for `block` (Algorithm 1: index = A/N,
    /// bit = A%N — expressed byte-wise; word size only affects I/O width
    /// and region rounding).
    pub fn bit_position(&self, block: u32) -> (usize, u8) {
        ((block / 8) as usize, (block % 8) as u8)
    }

    /// The word-aligned byte range Algorithm 1 reads+writes for `block`.
    pub fn word_range(&self, block: u32) -> std::ops::Range<usize> {
        let wb = self.word_bytes();
        let word = (block as usize / 8) / wb;
        word * wb..(word + 1) * wb
    }
}

/// A set of completed blocks, the output of recovery decoding and the
/// in-memory state of the transaction/universal loggers.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CompletedSet {
    bits: Vec<u64>,
    count: u32,
    total: u32,
}

impl CompletedSet {
    pub fn new(total_blocks: u32) -> Self {
        CompletedSet {
            bits: vec![0u64; (total_blocks as usize).div_ceil(64)],
            count: 0,
            total: total_blocks,
        }
    }

    pub fn insert(&mut self, block: u32) -> bool {
        assert!(block < self.total, "block {block} >= total {}", self.total);
        let w = (block / 64) as usize;
        let m = 1u64 << (block % 64);
        if self.bits[w] & m == 0 {
            self.bits[w] |= m;
            self.count += 1;
            true
        } else {
            false
        }
    }

    pub fn contains(&self, block: u32) -> bool {
        if block >= self.total {
            return false;
        }
        self.bits[(block / 64) as usize] & (1u64 << (block % 64)) != 0
    }

    pub fn count(&self) -> u32 {
        self.count
    }

    pub fn total(&self) -> u32 {
        self.total
    }

    pub fn is_complete(&self) -> bool {
        self.count == self.total
    }

    /// Blocks NOT in the set — the pending list the resume path schedules.
    pub fn pending(&self) -> Vec<u32> {
        (0..self.total).filter(|&b| !self.contains(b)).collect()
    }

    /// Completed blocks in ascending order.
    pub fn iter_completed(&self) -> impl Iterator<Item = u32> + '_ {
        (0..self.total).filter(move |&b| self.contains(b))
    }

    /// Build from a decoded record stream (ignores out-of-range ids from
    /// corrupt logs and duplicates from retransmits).
    pub fn from_stream(total_blocks: u32, stream: &[u32]) -> Self {
        let mut set = CompletedSet::new(total_blocks);
        for &b in stream {
            if b < total_blocks {
                set.insert(b);
            }
        }
        set
    }

    /// Build from bitmap bytes (little-endian bit order within bytes).
    pub fn from_bitmap_bytes(total_blocks: u32, bytes: &[u8]) -> Self {
        let mut set = CompletedSet::new(total_blocks);
        for b in 0..total_blocks {
            let (byte, bit) = ((b / 8) as usize, b % 8);
            if byte < bytes.len() && bytes[byte] & (1 << bit) != 0 {
                set.insert(b);
            }
        }
        set
    }

    /// The bitmap as u32 words — the layout the PJRT recovery artifact
    /// consumes (little-endian within words, same bit order as bytes).
    pub fn to_u32_words(&self) -> Vec<u32> {
        let mut words = Vec::with_capacity(self.bits.len() * 2);
        for &w in &self.bits {
            words.push(w as u32);
            words.push((w >> 32) as u32);
        }
        words.truncate((self.total as usize).div_ceil(32).max(1));
        words
    }
}

/// Append one length-prefixed frame: a little-endian u32 payload length
/// followed by the payload bytes. The manifest store (and any other
/// append-only ftlog consumer that needs self-delimiting records over a
/// plain file) shares this framing so a crash mid-append tears at most
/// the final frame.
pub fn encode_frame(payload: &[u8], out: &mut Vec<u8>) {
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Split a buffer of [`encode_frame`] frames back into payload slices,
/// stopping cleanly at a torn tail: a truncated length prefix or a
/// payload shorter than its prefix ends the scan (the lost suffix is the
/// record that was mid-append at the crash — the writer re-appends it).
pub fn decode_frames(buf: &[u8]) -> Vec<&[u8]> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while buf.len() - pos >= 4 {
        let len =
            u32::from_le_bytes([buf[pos], buf[pos + 1], buf[pos + 2], buf[pos + 3]]) as usize;
        pos += 4;
        if buf.len() - pos < len {
            break; // torn payload
        }
        out.push(&buf[pos..pos + len]);
        pos += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_methods() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.as_str()).unwrap(), m);
        }
        assert!(Method::parse("xor").is_err());
    }

    #[test]
    fn record_roundtrip_all_stream_methods() {
        let blocks = [0u32, 1, 9, 127, 128, 300, 65_535, 1_000_000, u32::MAX];
        for m in [Method::Char, Method::Int, Method::Enc, Method::Binary] {
            let mut buf = Vec::new();
            for &b in &blocks {
                m.encode_record(b, &mut buf);
            }
            assert_eq!(m.decode_stream(&buf), blocks, "method {m:?}");
        }
    }

    #[test]
    fn torn_tail_tolerated() {
        for m in [Method::Int, Method::Enc, Method::Binary] {
            let mut buf = Vec::new();
            m.encode_record(1000, &mut buf);
            m.encode_record(2000, &mut buf);
            buf.pop(); // tear the last record
            let got = m.decode_stream(&buf);
            assert_eq!(got[0], 1000, "method {m:?}");
            assert!(got.len() <= 2);
            if got.len() == 2 {
                assert_ne!(got[1], 2000, "torn record must not decode to 2000");
            }
        }
        // Char: torn digits parse as a different (prefix) number or are kept;
        // either way the first record survives.
        let m = Method::Char;
        let mut buf = Vec::new();
        m.encode_record(1234, &mut buf);
        m.encode_record(5678, &mut buf);
        buf.truncate(buf.len() - 3); // "1234\n56"
        let got = m.decode_stream(&buf);
        assert_eq!(got[0], 1234);
    }

    #[test]
    fn region_bytes_ordering_matches_fig7() {
        // Per-method space for the same file: bit < enc <= int/binary < char.
        let n = 1024;
        let char_b = Method::Char.region_bytes(n);
        let int_b = Method::Int.region_bytes(n);
        let enc_b = Method::Enc.region_bytes(n);
        let bin_b = Method::Binary.region_bytes(n);
        let b8 = Method::Bit8.region_bytes(n);
        let b64 = Method::Bit64.region_bytes(n);
        assert!(b8 <= b64);
        assert!(b64 < enc_b);
        assert!(enc_b <= int_b + n as usize); // enc worst case 5B vs 4B
        assert_eq!(int_b, bin_b);
        assert!(int_b < char_b);
        assert_eq!(b8, 128);
        assert_eq!(b64, 128);
    }

    #[test]
    fn bitmap_positions() {
        let m = Method::Bit8;
        assert_eq!(m.bit_position(0), (0, 0));
        assert_eq!(m.bit_position(7), (0, 7));
        assert_eq!(m.bit_position(8), (1, 0));
        assert_eq!(m.word_range(0), 0..1);
        assert_eq!(m.word_range(15), 1..2);
        let m64 = Method::Bit64;
        assert_eq!(m64.word_range(0), 0..8);
        assert_eq!(m64.word_range(63), 0..8);
        assert_eq!(m64.word_range(64), 8..16);
    }

    #[test]
    fn completed_set_basics() {
        let mut s = CompletedSet::new(10);
        assert!(s.insert(3));
        assert!(!s.insert(3), "duplicate insert reports false");
        assert!(s.insert(9));
        assert!(s.contains(3));
        assert!(!s.contains(4));
        assert_eq!(s.count(), 2);
        assert!(!s.is_complete());
        assert_eq!(s.pending(), vec![0, 1, 2, 4, 5, 6, 7, 8]);
        assert_eq!(s.iter_completed().collect::<Vec<_>>(), vec![3, 9]);
        for b in 0..10 {
            s.insert(b);
        }
        assert!(s.is_complete());
        assert!(s.pending().is_empty());
    }

    #[test]
    fn completed_set_from_stream_ignores_junk() {
        let s = CompletedSet::from_stream(5, &[0, 2, 2, 99, 4]);
        assert_eq!(s.count(), 3);
        assert!(s.contains(4));
        assert!(!s.contains(3));
    }

    #[test]
    fn bitmap_bytes_roundtrip() {
        let mut s = CompletedSet::new(20);
        for b in [0, 7, 8, 19] {
            s.insert(b);
        }
        // bytes: bit0+bit7 -> 0x81, bit8 -> 0x01, bit19 -> byte2 bit3 = 0x08
        let bytes = [0x81u8, 0x01, 0x08];
        let back = CompletedSet::from_bitmap_bytes(20, &bytes);
        assert_eq!(back, s);
    }

    #[test]
    fn u32_words_match_popcount() {
        let mut s = CompletedSet::new(100);
        for b in (0..100).step_by(3) {
            s.insert(b);
        }
        let words = s.to_u32_words();
        assert_eq!(words.len(), 4); // ceil(100/32)
        let pop: u32 = words.iter().map(|w| w.count_ones()).sum();
        assert_eq!(pop, s.count());
    }

    #[test]
    #[should_panic]
    fn insert_out_of_range_panics() {
        CompletedSet::new(4).insert(4);
    }

    #[test]
    fn frames_roundtrip() {
        let mut buf = Vec::new();
        encode_frame(b"", &mut buf);
        encode_frame(b"one", &mut buf);
        encode_frame(&[0u8; 300], &mut buf);
        let frames = decode_frames(&buf);
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0], b"");
        assert_eq!(frames[1], b"one");
        assert_eq!(frames[2], &[0u8; 300][..]);
    }

    #[test]
    fn frames_tolerate_torn_tail() {
        let mut buf = Vec::new();
        encode_frame(b"intact", &mut buf);
        encode_frame(b"torn-record", &mut buf);
        for cut in 1..=b"torn-record".len() + 3 {
            let torn = &buf[..buf.len() - cut];
            let frames = decode_frames(torn);
            assert_eq!(frames, vec![&b"intact"[..]], "cut {cut}");
        }
        assert!(decode_frames(&buf[..2]).is_empty(), "torn length prefix");
        assert!(decode_frames(&[]).is_empty());
    }
}
