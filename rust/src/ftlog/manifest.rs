//! Crash-consistent daemon job manifest (the serve-mode hardening leg
//! of the paper's fault-tolerance story): every job the daemon accepts
//! leaves a durable record under `<ft_dir>/manifest/`, so a killed
//! daemon can be restarted with `--recover` and re-admit every
//! incomplete job from its own per-job `job-<id>` object log instead of
//! forgetting the job ever existed.
//!
//! The store is a single append-only file using the same discipline as
//! the object loggers: length-prefixed frames ([`codec::encode_frame`])
//! appended and fsynced one record at a time, torn-tail tolerant on
//! replay ([`codec::decode_frames`] stops at a frame the crash tore).
//! Records are last-writer-wins per job id, so a job's lifecycle is the
//! record sequence SUBMITTED → ADMITTED → COMPLETED | FAULTED. Only
//! COMPLETED is terminal: a FAULTED job (including one the
//! `job_deadline_ms` watchdog shot) is re-admitted by recovery — its FT
//! log bounds the retransmit, exactly like §5.2.2 resume.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::codec::{decode_frames, encode_frame};
use super::{escape_name, unescape_name};

/// Subdirectory of the daemon's `ft_dir` holding the store.
pub const MANIFEST_DIR: &str = "manifest";
/// The append-only record file inside [`MANIFEST_DIR`].
pub const MANIFEST_FILE: &str = "jobs.mlog";
/// File magic. A file that is shorter than the magic was torn during
/// creation (nothing durable was recorded — replay treats it as empty);
/// a file with *different* leading bytes is not ours and is an error.
const MAGIC: &[u8; 4] = b"FTM1";

/// Lifecycle state carried by each manifest record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Submitted,
    Admitted,
    Completed,
    Faulted,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Submitted => "SUBMITTED",
            JobState::Admitted => "ADMITTED",
            JobState::Completed => "COMPLETED",
            JobState::Faulted => "FAULTED",
        }
    }

    pub fn parse(s: &str) -> Option<JobState> {
        Some(match s {
            "SUBMITTED" => JobState::Submitted,
            "ADMITTED" => JobState::Admitted,
            "COMPLETED" => JobState::Completed,
            "FAULTED" => JobState::Faulted,
            _ => return None,
        })
    }

    /// Only COMPLETED ends a job's story — FAULTED jobs are re-admitted
    /// on recovery and resume from their FT logs.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Completed)
    }
}

/// One durable record. `spec_digest`/`knobs_digest` fingerprint what
/// was submitted (file list) and how (FT mechanism/method, object and
/// txn sizes) so recovery can refuse a provider that hands back a
/// different transfer under a recycled job id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestRecord {
    pub job: u64,
    pub state: JobState,
    pub tenant: String,
    pub weight: u32,
    pub spec_digest: u64,
    pub knobs_digest: u64,
}

impl ManifestRecord {
    /// Frame payload: a single space-separated text line (tenant %xx
    /// escaped like log file names), human-greppable on disk.
    fn encode(&self) -> Vec<u8> {
        format!(
            "JOB {} {} {} {} {:016x} {:016x}",
            self.job,
            self.state.as_str(),
            escape_name(&self.tenant),
            self.weight,
            self.spec_digest,
            self.knobs_digest
        )
        .into_bytes()
    }

    /// Decode one frame payload; `None` for anything malformed (a
    /// corrupt or foreign frame is skipped, not fatal — the frames
    /// before and after it still replay).
    fn decode(payload: &[u8]) -> Option<ManifestRecord> {
        let text = std::str::from_utf8(payload).ok()?;
        let mut parts = text.split(' ');
        if parts.next()? != "JOB" {
            return None;
        }
        let job = parts.next()?.parse::<u64>().ok()?;
        let state = JobState::parse(parts.next()?)?;
        let tenant = unescape_name(parts.next()?)?;
        let weight = parts.next()?.parse::<u32>().ok()?;
        let spec_digest = u64::from_str_radix(parts.next()?, 16).ok()?;
        let knobs_digest = u64::from_str_radix(parts.next()?, 16).ok()?;
        Some(ManifestRecord { job, state, tenant, weight, spec_digest, knobs_digest })
    }
}

/// Append handle on the store. Opening creates `<ft_dir>/manifest/` and
/// the record file (magic written+fsynced first) if absent; an existing
/// file is appended to, never rewritten.
pub struct ManifestStore {
    file: File,
    path: PathBuf,
}

impl ManifestStore {
    pub fn open(ft_dir: &Path) -> Result<ManifestStore> {
        let dir = ft_dir.join(MANIFEST_DIR);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating manifest dir {}", dir.display()))?;
        let path = dir.join(MANIFEST_FILE);
        let mut file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening manifest {}", path.display()))?;
        if file.metadata()?.len() == 0 {
            file.write_all(MAGIC)?;
            file.sync_data()?;
        }
        Ok(ManifestStore { file, path })
    }

    /// Append one record durably: the frame is written and fsynced
    /// before this returns, so a daemon crash at ANY later point still
    /// replays the record.
    pub fn append(&mut self, rec: &ManifestRecord) -> Result<()> {
        let mut buf = Vec::new();
        encode_frame(&rec.encode(), &mut buf);
        self.file
            .write_all(&buf)
            .with_context(|| format!("appending manifest {}", self.path.display()))?;
        self.file.sync_data()?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// What a replay found: the latest record per job id, plus the raw
/// record count (the `DaemonSnapshot::manifest_records` figure).
#[derive(Debug, Default)]
pub struct ManifestReplay {
    pub jobs: BTreeMap<u64, ManifestRecord>,
    pub records: u64,
}

impl ManifestReplay {
    /// Jobs whose latest state is not terminal — the recovery set, in
    /// ascending job-id order.
    pub fn incomplete(&self) -> impl Iterator<Item = &ManifestRecord> {
        self.jobs.values().filter(|r| !r.state.is_terminal())
    }

    /// Highest job id on record (0 when empty) — restart seeds its id
    /// counter above this so recovered and fresh jobs never collide.
    pub fn max_job(&self) -> u64 {
        self.jobs.keys().next_back().copied().unwrap_or(0)
    }
}

/// Replay the store under `ft_dir`. Missing dir/file (or a file torn
/// inside the magic) replays as empty; frames the crash tore are
/// dropped by [`decode_frames`]; malformed frame payloads are skipped.
pub fn replay(ft_dir: &Path) -> Result<ManifestReplay> {
    let path = ft_dir.join(MANIFEST_DIR).join(MANIFEST_FILE);
    let mut buf = Vec::new();
    match File::open(&path) {
        Ok(mut f) => {
            f.read_to_end(&mut buf)
                .with_context(|| format!("reading manifest {}", path.display()))?;
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(ManifestReplay::default());
        }
        Err(e) => {
            return Err(e)
                .with_context(|| format!("opening manifest {}", path.display()));
        }
    }
    if buf.len() < MAGIC.len() {
        return Ok(ManifestReplay::default()); // torn during creation
    }
    anyhow::ensure!(
        &buf[..MAGIC.len()] == MAGIC,
        "{} is not a job manifest (bad magic)",
        path.display()
    );
    let mut out = ManifestReplay::default();
    for frame in decode_frames(&buf[MAGIC.len()..]) {
        let Some(rec) = ManifestRecord::decode(frame) else { continue };
        out.records += 1;
        out.jobs.insert(rec.job, rec);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("ftlads-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn rec(job: u64, state: JobState) -> ManifestRecord {
        ManifestRecord {
            job,
            state,
            tenant: "tenant a".to_string(), // space exercises escaping
            weight: 2,
            spec_digest: 0xdead_beef_0123_4567,
            knobs_digest: 0x89ab_cdef_0000_0001,
        }
    }

    #[test]
    fn append_replay_roundtrip_last_record_wins() {
        let dir = tmp("roundtrip");
        let mut store = ManifestStore::open(&dir).unwrap();
        store.append(&rec(1, JobState::Submitted)).unwrap();
        store.append(&rec(2, JobState::Submitted)).unwrap();
        store.append(&rec(1, JobState::Admitted)).unwrap();
        store.append(&rec(1, JobState::Completed)).unwrap();
        store.append(&rec(2, JobState::Faulted)).unwrap();
        drop(store);

        let replay = replay(&dir).unwrap();
        assert_eq!(replay.records, 5);
        assert_eq!(replay.jobs.len(), 2);
        assert_eq!(replay.jobs[&1].state, JobState::Completed);
        assert_eq!(replay.jobs[&2].state, JobState::Faulted);
        assert_eq!(replay.jobs[&2].tenant, "tenant a");
        assert_eq!(replay.jobs[&2], rec(2, JobState::Faulted));
        // COMPLETED is terminal, FAULTED is the recovery set.
        let inc: Vec<u64> = replay.incomplete().map(|r| r.job).collect();
        assert_eq!(inc, vec![2]);
        assert_eq!(replay.max_job(), 2);

        // Reopening appends — records survive.
        let mut store = ManifestStore::open(&dir).unwrap();
        store.append(&rec(3, JobState::Submitted)).unwrap();
        drop(store);
        let replay = replay(&dir).unwrap();
        assert_eq!(replay.records, 6);
        assert_eq!(replay.max_job(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replay_tolerates_torn_tail_and_junk_frames() {
        let dir = tmp("torn");
        let mut store = ManifestStore::open(&dir).unwrap();
        store.append(&rec(1, JobState::Submitted)).unwrap();
        store.append(&rec(2, JobState::Submitted)).unwrap();
        drop(store);
        let path = dir.join(MANIFEST_DIR).join(MANIFEST_FILE);

        // Tear mid-way through the last frame, crash-style.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let r = replay(&dir).unwrap();
        assert_eq!(r.records, 1, "torn record must be dropped");
        assert!(r.jobs.contains_key(&1));

        // A junk (undecodable) frame between valid ones is skipped.
        let mut buf = std::fs::read(&path).unwrap();
        encode_frame(b"not a JOB line", &mut buf);
        encode_frame(&rec(7, JobState::Admitted).encode(), &mut buf);
        std::fs::write(&path, &buf).unwrap();
        let r = replay(&dir).unwrap();
        assert_eq!(r.records, 2);
        assert!(r.jobs.contains_key(&7));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replay_missing_or_torn_header_is_empty_wrong_magic_errors() {
        let dir = tmp("magic");
        let r = replay(&dir).unwrap();
        assert_eq!(r.records, 0);
        assert_eq!(r.max_job(), 0);

        let mdir = dir.join(MANIFEST_DIR);
        std::fs::create_dir_all(&mdir).unwrap();
        let path = mdir.join(MANIFEST_FILE);
        std::fs::write(&path, b"FT").unwrap(); // torn inside the magic
        assert_eq!(replay(&dir).unwrap().records, 0);
        std::fs::write(&path, b"WRONG MAGIC").unwrap();
        assert!(replay(&dir).is_err(), "foreign file must not replay");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn states_parse_and_terminality() {
        for s in
            [JobState::Submitted, JobState::Admitted, JobState::Completed, JobState::Faulted]
        {
            assert_eq!(JobState::parse(s.as_str()), Some(s));
        }
        assert_eq!(JobState::parse("RUNNING"), None);
        assert!(JobState::Completed.is_terminal());
        assert!(!JobState::Faulted.is_terminal());
        assert!(!JobState::Submitted.is_terminal());
        assert!(!JobState::Admitted.is_terminal());
    }
}
