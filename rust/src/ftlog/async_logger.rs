//! Asynchronous logging (paper §5.1).
//!
//! "Upon receiving the BLOCK_SYNC message, based on the synchronous or
//! asynchronous logging method, the source comm thread either writes the
//! completed block information to the FT logger file directly or
//! enqueues the request on the wait queue in the logger thread. … we
//! implemented and evaluated the performance and found no difference
//! between the two methods."
//!
//! This wrapper gives any [`FtLogger`] the asynchronous flavour: a
//! dedicated *logger thread* owns the inner logger; `log_block` and
//! `complete_file` become queue pushes, and the lifecycle calls
//! (`register_file`, `finish_dataset`) act as barriers so ordering
//! guarantees are preserved:
//!
//! * a file's registration happens-before any of its block logs;
//! * `finish_dataset` flushes the queue before cleanup;
//! * dropping the wrapper flushes and joins the thread — nothing logged
//!   before a clean shutdown can be lost. (A *crash* can lose the queued
//!   tail — exactly the durability trade the paper's async variant makes;
//!   lost records are simply retransmitted after resume.)

use std::sync::mpsc;

use anyhow::Result;

use super::{FileKey, FtLogger, Mechanism, SpaceStats};

enum Op {
    Register { name: String, total_blocks: u32, reply: mpsc::Sender<Result<FileKey>> },
    Log { key: FileKey, block: u32 },
    LogBatch { key: FileKey, blocks: Vec<u32> },
    Complete { key: FileKey },
    Finish { reply: mpsc::Sender<Result<()>> },
    Space { reply: mpsc::Sender<SpaceStats> },
    Shutdown,
}

pub struct AsyncLogger {
    tx: mpsc::Sender<Op>,
    join: Option<std::thread::JoinHandle<()>>,
    mechanism: Mechanism,
    /// First error the logger thread hit (surfaced on the next call).
    errors: std::sync::Arc<std::sync::Mutex<Option<String>>>,
}

impl AsyncLogger {
    pub fn wrap(mut inner: Box<dyn FtLogger>) -> Result<AsyncLogger> {
        let mechanism = inner.mechanism();
        let (tx, rx) = mpsc::channel::<Op>();
        let errors = std::sync::Arc::new(std::sync::Mutex::new(None::<String>));
        let errors2 = errors.clone();
        let join = std::thread::Builder::new()
            .name("ft-logger".into())
            .spawn(move || {
                let record_err = |e: anyhow::Error| {
                    let mut g = errors2.lock().unwrap_or_else(|p| p.into_inner());
                    if g.is_none() {
                        *g = Some(e.to_string());
                    }
                };
                while let Ok(op) = rx.recv() {
                    match op {
                        Op::Register { name, total_blocks, reply } => {
                            let _ = reply.send(inner.register_file(&name, total_blocks));
                        }
                        Op::Log { key, block } => {
                            if let Err(e) = inner.log_block(key, block) {
                                record_err(e);
                            }
                        }
                        Op::LogBatch { key, blocks } => {
                            // Whole batch in one queue op AND one inner
                            // group commit — the async flavour of the
                            // batched ack path.
                            if let Err(e) = inner.log_blocks(key, &blocks) {
                                record_err(e);
                            }
                        }
                        Op::Complete { key } => {
                            if let Err(e) = inner.complete_file(key) {
                                record_err(e);
                            }
                        }
                        Op::Finish { reply } => {
                            let _ = reply.send(inner.finish_dataset());
                        }
                        Op::Space { reply } => {
                            let _ = reply.send(inner.space());
                        }
                        Op::Shutdown => break,
                    }
                }
            })?;
        Ok(AsyncLogger { tx, join: Some(join), mechanism, errors })
    }

    fn check_deferred_error(&self) -> Result<()> {
        let g = self.errors.lock().unwrap_or_else(|p| p.into_inner());
        match &*g {
            Some(e) => anyhow::bail!("async FT logging failed earlier: {e}"),
            None => Ok(()),
        }
    }
}

impl FtLogger for AsyncLogger {
    fn register_file(&mut self, name: &str, total_blocks: u32) -> Result<FileKey> {
        self.check_deferred_error()?;
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Op::Register { name: name.to_string(), total_blocks, reply })
            .map_err(|_| anyhow::anyhow!("logger thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("logger thread gone"))?
    }

    fn log_block(&mut self, key: FileKey, block: u32) -> Result<()> {
        self.check_deferred_error()?;
        self.tx
            .send(Op::Log { key, block })
            .map_err(|_| anyhow::anyhow!("logger thread gone"))
    }

    fn log_blocks(&mut self, key: FileKey, blocks: &[u32]) -> Result<()> {
        self.check_deferred_error()?;
        self.tx
            .send(Op::LogBatch { key, blocks: blocks.to_vec() })
            .map_err(|_| anyhow::anyhow!("logger thread gone"))
    }

    fn complete_file(&mut self, key: FileKey) -> Result<()> {
        self.check_deferred_error()?;
        self.tx
            .send(Op::Complete { key })
            .map_err(|_| anyhow::anyhow!("logger thread gone"))
    }

    fn finish_dataset(&mut self) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Op::Finish { reply })
            .map_err(|_| anyhow::anyhow!("logger thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("logger thread gone"))??;
        self.check_deferred_error()
    }

    fn space(&self) -> SpaceStats {
        let (reply, rx) = mpsc::channel();
        if self.tx.send(Op::Space { reply }).is_err() {
            return SpaceStats::default();
        }
        rx.recv().unwrap_or_default()
    }

    fn mechanism(&self) -> Mechanism {
        self.mechanism
    }
}

impl Drop for AsyncLogger {
    fn drop(&mut self) {
        let _ = self.tx.send(Op::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftlog::{codec::Method, create_logger, recover, FtConfig};

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ftlads-async-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn async_wrapper_equals_sync_result() {
        for mech in Mechanism::ALL_FT {
            let dir = tmp_dir(&format!("eq-{}", mech.as_str()));
            let cfg = FtConfig {
                mechanism: mech,
                method: Method::Int,
                dir: dir.clone(),
                txn_size: 2,
            };
            let inner = create_logger(&cfg).unwrap();
            let mut logger = AsyncLogger::wrap(inner).unwrap();
            let ka = logger.register_file("a", 16).unwrap();
            let kb = logger.register_file("b", 16).unwrap();
            for b in [3u32, 1, 9, 15] {
                logger.log_block(ka, b).unwrap();
            }
            logger.log_block(kb, 0).unwrap();
            logger.complete_file(kb).unwrap();
            // space() acts as a flush barrier (FIFO queue).
            let space = logger.space();
            assert!(space.appends >= 5);
            drop(logger); // clean shutdown flushes

            let rec = recover::recover_all(&cfg).unwrap();
            assert_eq!(rec.len(), 1, "{mech:?}");
            assert_eq!(
                rec["a"].iter_completed().collect::<Vec<_>>(),
                vec![1, 3, 9, 15]
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn batched_log_blocks_flow_through_the_queue() {
        let dir = tmp_dir("batch");
        let cfg = FtConfig::new(Mechanism::Universal, Method::Int, &dir);
        let mut logger = AsyncLogger::wrap(create_logger(&cfg).unwrap()).unwrap();
        let k = logger.register_file("a", 32).unwrap();
        logger.log_blocks(k, &[5, 1, 9]).unwrap();
        logger.log_blocks(k, &[2]).unwrap();
        let space = logger.space(); // flush barrier
        assert_eq!(space.appends, 4);
        assert_eq!(space.write_ops, 2, "one group commit per batch");
        drop(logger);
        let rec = recover::recover_all(&cfg).unwrap();
        assert_eq!(rec["a"].iter_completed().collect::<Vec<_>>(), vec![1, 2, 5, 9]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn register_is_a_barrier() {
        let dir = tmp_dir("barrier");
        let cfg = FtConfig::new(Mechanism::File, Method::Bit8, &dir);
        let mut logger = AsyncLogger::wrap(create_logger(&cfg).unwrap()).unwrap();
        // Interleave: register, burst of logs, register again (barrier),
        // more logs — keys must stay valid.
        let k0 = logger.register_file("x", 64).unwrap();
        for b in 0..32 {
            logger.log_block(k0, b).unwrap();
        }
        let k1 = logger.register_file("y", 8).unwrap();
        logger.log_block(k1, 7).unwrap();
        logger.finish_dataset().unwrap();
        drop(logger);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn deferred_errors_surface() {
        let dir = tmp_dir("err");
        let cfg = FtConfig::new(Mechanism::File, Method::Int, &dir);
        let mut logger = AsyncLogger::wrap(create_logger(&cfg).unwrap()).unwrap();
        let k = logger.register_file("f", 4).unwrap();
        logger.log_block(k, 99).unwrap(); // out of range: fails in thread
        logger.space(); // flush
        let err = logger.log_block(k, 0);
        assert!(err.is_err(), "deferred error must surface");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
