//! File logger: one log file per transferred file (§4.1.1).
//!
//! Light-weight semantics: the log is created only when the first object
//! of the file completes, and unlinked as soon as the file completes —
//! so at any instant only in-flight files occupy logger space, and the
//! amount of log parsed at recovery is independent of the fault point
//! (§6.4: "the amount of logs to be parsed … will not depend on the
//! fault point").
//!
//! Record-stream methods append completion records *in arrival order* —
//! the paper notes this costs an extra search/sort at recovery (Fig 8:
//! file logger ≈ 2× bbcp recovery) but zero in-memory state during the
//! transfer (Fig 5c/6c: memory indistinguishable from stock LADS).
//! Bitmap methods implement Algorithm 1 literally: read the word, OR the
//! bit, write the word back — against the *file*, not a cached copy.
//!
//! On-disk format: `FTL1` magic, method byte, total_blocks u32,
//! name_len u32, name bytes, then the body (records or bitmap).

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::codec::Method;
use super::{alloc_rounded, escape_name, FileKey, FtConfig, FtLogger, Mechanism, SpaceStats};

pub(crate) const MAGIC: &[u8; 4] = b"FTL1";

struct FileState {
    name: String,
    total_blocks: u32,
    path: PathBuf,
    /// Open handle once the log exists (lazy creation).
    log: Option<File>,
    header_len: u64,
    /// Current on-disk size of this log (for allocated-block accounting).
    size: u64,
    logged: u32,
    record_buf: Vec<u8>,
}

pub struct FileLogger {
    dir: PathBuf,
    method: Method,
    files: Vec<FileState>,
    stats: SpaceStats,
}

impl FileLogger {
    pub fn new(cfg: &FtConfig) -> Result<FileLogger> {
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating FT log dir {}", cfg.dir.display()))?;
        Ok(FileLogger {
            dir: cfg.dir.clone(),
            method: cfg.method,
            files: Vec::new(),
            stats: SpaceStats::default(),
        })
    }

    fn charge_write(&mut self, bytes: u64) {
        self.stats.bytes_written += bytes;
        self.stats.current_bytes += bytes;
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.stats.current_bytes);
    }

    /// Adjust the allocated-block gauge when a log grows from `old` to
    /// `new` bytes (or is created/deleted).
    fn charge_alloc(&mut self, old: u64, new: u64) {
        let (oa, na) = (alloc_rounded(old), alloc_rounded(new));
        if na >= oa {
            self.stats.current_alloc_bytes += na - oa;
        } else {
            self.stats.current_alloc_bytes =
                self.stats.current_alloc_bytes.saturating_sub(oa - na);
        }
        self.stats.peak_alloc_bytes =
            self.stats.peak_alloc_bytes.max(self.stats.current_alloc_bytes);
    }
}

/// Log file path for a transferred file (deterministic so recovery can
/// find it from the file name alone).
pub fn log_path(dir: &Path, method: Method, name: &str) -> PathBuf {
    dir.join(format!("{}.{}.flog", escape_name(name), method.as_str()))
}

/// Serialized header for a log file.
pub(crate) fn encode_header(method: Method, total_blocks: u32, name: &str) -> Vec<u8> {
    let mut h = Vec::with_capacity(13 + name.len());
    h.extend_from_slice(MAGIC);
    h.push(method_byte(method));
    h.extend_from_slice(&total_blocks.to_le_bytes());
    h.extend_from_slice(&(name.len() as u32).to_le_bytes());
    h.extend_from_slice(name.as_bytes());
    h
}

pub(crate) fn method_byte(m: Method) -> u8 {
    match m {
        Method::Char => 0,
        Method::Int => 1,
        Method::Enc => 2,
        Method::Binary => 3,
        Method::Bit8 => 4,
        Method::Bit64 => 5,
    }
}

pub(crate) fn method_from_byte(b: u8) -> Option<Method> {
    Some(match b {
        0 => Method::Char,
        1 => Method::Int,
        2 => Method::Enc,
        3 => Method::Binary,
        4 => Method::Bit8,
        5 => Method::Bit64,
        _ => return None,
    })
}

/// Parse a log file header; returns (method, total_blocks, name, header_len).
pub(crate) fn decode_header(buf: &[u8]) -> Option<(Method, u32, String, usize)> {
    if buf.len() < 13 || &buf[..4] != MAGIC {
        return None;
    }
    let method = method_from_byte(buf[4])?;
    let total = u32::from_le_bytes(buf[5..9].try_into().ok()?);
    let name_len = u32::from_le_bytes(buf[9..13].try_into().ok()?) as usize;
    if buf.len() < 13 + name_len {
        return None;
    }
    let name = std::str::from_utf8(&buf[13..13 + name_len]).ok()?.to_string();
    Some((method, total, name, 13 + name_len))
}

impl FtLogger for FileLogger {
    fn register_file(&mut self, name: &str, total_blocks: u32) -> Result<FileKey> {
        let key = FileKey(self.files.len() as u32);
        self.files.push(FileState {
            name: name.to_string(),
            total_blocks,
            path: log_path(&self.dir, self.method, name),
            log: None,
            header_len: 0,
            size: 0,
            logged: 0,
            record_buf: Vec::with_capacity(16),
        });
        Ok(key)
    }

    fn log_block(&mut self, key: FileKey, block: u32) -> Result<()> {
        let method = self.method;
        let st = &mut self.files[key.0 as usize];
        anyhow::ensure!(
            block < st.total_blocks,
            "block {block} out of range for '{}' ({} blocks)",
            st.name,
            st.total_blocks
        );
        let mut charged = 0u64;

        // Light-weight logging: create the log on first completion.
        if st.log.is_none() {
            let header = encode_header(method, st.total_blocks, &st.name);
            let mut f = OpenOptions::new()
                .create(true)
                .read(true)
                .write(true)
                .truncate(true)
                .open(&st.path)
                .with_context(|| format!("creating log {}", st.path.display()))?;
            f.write_all(&header)?;
            charged += header.len() as u64;
            st.header_len = header.len() as u64;
            if method.is_bitmap() {
                // Preallocate the (zeroed) bitmap region.
                let region = method.region_bytes(st.total_blocks);
                f.set_len(st.header_len + region as u64)?;
                charged += region as u64;
            }
            st.log = Some(f);
        }

        let f = st.log.as_mut().unwrap();
        if method.is_bitmap() {
            // Algorithm 1: buff <- ReadFromFile; buff[i] |= 1 << pos;
            // WritetoFile <- buff — performed on the word containing the
            // block's bit, via pread/pwrite at the word offset.
            let range = method.word_range(block);
            let mut word = vec![0u8; range.len()];
            f.seek(SeekFrom::Start(st.header_len + range.start as u64))?;
            f.read_exact(&mut word)?;
            let (byte_pos, bit) = method.bit_position(block);
            word[byte_pos - range.start] |= 1 << bit;
            f.seek(SeekFrom::Start(st.header_len + range.start as u64))?;
            f.write_all(&word)?;
            self.stats.bytes_written += word.len() as u64; // rewrite, not growth
        } else {
            // Append the record in completion (possibly out-of-order) order.
            st.record_buf.clear();
            method.encode_record(block, &mut st.record_buf);
            f.seek(SeekFrom::End(0))?;
            f.write_all(&st.record_buf)?;
            charged += st.record_buf.len() as u64;
        }
        st.logged += 1;
        let old_size = st.size;
        st.size += charged;
        let new_size = st.size;
        self.stats.appends += 1;
        self.stats.write_ops += 1;
        self.charge_write(charged);
        self.charge_alloc(old_size, new_size);
        Ok(())
    }

    fn log_blocks(&mut self, key: FileKey, blocks: &[u32]) -> Result<()> {
        match blocks {
            [] => return Ok(()),
            [b] => return self.log_block(key, *b),
            _ => {}
        }
        let method = self.method;
        let st = &mut self.files[key.0 as usize];
        for &b in blocks {
            anyhow::ensure!(
                b < st.total_blocks,
                "block {b} out of range for '{}' ({} blocks)",
                st.name,
                st.total_blocks
            );
        }
        let mut charged = 0u64;

        // Light-weight logging: create the log on first completion.
        if st.log.is_none() {
            let header = encode_header(method, st.total_blocks, &st.name);
            let mut f = OpenOptions::new()
                .create(true)
                .read(true)
                .write(true)
                .truncate(true)
                .open(&st.path)
                .with_context(|| format!("creating log {}", st.path.display()))?;
            f.write_all(&header)?;
            charged += header.len() as u64;
            st.header_len = header.len() as u64;
            if method.is_bitmap() {
                let region = method.region_bytes(st.total_blocks);
                f.set_len(st.header_len + region as u64)?;
                charged += region as u64;
            }
            st.log = Some(f);
        }

        let f = st.log.as_mut().unwrap();
        if method.is_bitmap() {
            // Group commit: one read-modify-write over the word span that
            // covers every block in the batch (Algorithm 1, amortized —
            // one seek+write instead of one per block).
            let mut lo = usize::MAX;
            let mut hi = 0usize;
            for &b in blocks {
                let r = method.word_range(b);
                lo = lo.min(r.start);
                hi = hi.max(r.end);
            }
            let mut span = vec![0u8; hi - lo];
            f.seek(SeekFrom::Start(st.header_len + lo as u64))?;
            f.read_exact(&mut span)?;
            for &b in blocks {
                let (byte_pos, bit) = method.bit_position(b);
                span[byte_pos - lo] |= 1 << bit;
            }
            f.seek(SeekFrom::Start(st.header_len + lo as u64))?;
            f.write_all(&span)?;
            self.stats.bytes_written += span.len() as u64; // rewrite, not growth
        } else {
            // All records of the batch in one appended write (completion
            // order within the batch is preserved).
            st.record_buf.clear();
            for &b in blocks {
                method.encode_record(b, &mut st.record_buf);
            }
            f.seek(SeekFrom::End(0))?;
            f.write_all(&st.record_buf)?;
            charged += st.record_buf.len() as u64;
        }
        st.logged += blocks.len() as u32;
        let old_size = st.size;
        st.size += charged;
        let new_size = st.size;
        self.stats.appends += blocks.len() as u64;
        self.stats.write_ops += 1;
        self.charge_write(charged);
        self.charge_alloc(old_size, new_size);
        Ok(())
    }

    fn complete_file(&mut self, key: FileKey) -> Result<()> {
        let st = &mut self.files[key.0 as usize];
        if st.log.take().is_some() {
            // Unlink the log: the committed sink file is the durable record.
            let size = std::fs::metadata(&st.path).map(|m| m.len()).unwrap_or(0);
            std::fs::remove_file(&st.path)
                .with_context(|| format!("removing log {}", st.path.display()))?;
            self.stats.current_bytes = self.stats.current_bytes.saturating_sub(size);
            let old = self.files[key.0 as usize].size;
            self.files[key.0 as usize].size = 0;
            self.charge_alloc(old, 0);
        }
        Ok(())
    }

    fn finish_dataset(&mut self) -> Result<()> {
        // Every per-file log should already be gone; sweep leftovers from
        // aborted files defensively (they belong to an interrupted run).
        for st in &self.files {
            if st.log.is_some() && st.path.exists() {
                let _ = std::fs::remove_file(&st.path);
            }
        }
        Ok(())
    }

    fn space(&self) -> SpaceStats {
        self.stats
    }

    fn mechanism(&self) -> Mechanism {
        Mechanism::File
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ftlog::codec::CompletedSet;
    use crate::ftlog::recover;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ftlads-flog-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn cfg(dir: &Path, method: Method) -> FtConfig {
        FtConfig { mechanism: Mechanism::File, method, dir: dir.to_path_buf(), txn_size: 4 }
    }

    #[test]
    fn lazy_creation_and_deletion() {
        let dir = tmp_dir("lazy");
        let c = cfg(&dir, Method::Int);
        let mut l = FileLogger::new(&c).unwrap();
        let k = l.register_file("a.dat", 4).unwrap();
        // Light-weight: registration creates nothing.
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
        l.log_block(k, 2).unwrap();
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 1);
        l.complete_file(k).unwrap();
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
        assert_eq!(l.space().current_bytes, 0);
        assert!(l.space().peak_bytes > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn header_roundtrip() {
        let h = encode_header(Method::Bit64, 1234, "dir/file α.bin");
        let (m, total, name, len) = decode_header(&h).unwrap();
        assert_eq!(m, Method::Bit64);
        assert_eq!(total, 1234);
        assert_eq!(name, "dir/file α.bin");
        assert_eq!(len, h.len());
        assert!(decode_header(&h[..8]).is_none());
        let mut bad = h.clone();
        bad[0] = b'X';
        assert!(decode_header(&bad).is_none());
    }

    #[test]
    fn all_methods_roundtrip_through_recovery() {
        for method in Method::ALL {
            let dir = tmp_dir(&format!("rt-{}", method.as_str()));
            let c = cfg(&dir, method);
            let mut l = FileLogger::new(&c).unwrap();
            let k = l.register_file("f.dat", 100).unwrap();
            // Out-of-order completions, as LADS produces them.
            for b in [7u32, 3, 99, 0, 42, 43, 44, 7 /* dup retransmit */] {
                l.log_block(k, b).unwrap();
            }
            let recovered = recover::recover_all(&c).unwrap();
            let set = &recovered["f.dat"];
            let mut expect = CompletedSet::new(100);
            for b in [7, 3, 99, 0, 42, 43, 44] {
                expect.insert(b);
            }
            assert_eq!(set, &expect, "method {method:?}");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn log_blocks_group_commit_equals_sequential() {
        for method in Method::ALL {
            let dir = tmp_dir(&format!("grp-{}", method.as_str()));
            let c = cfg(&dir, method);
            let mut l = FileLogger::new(&c).unwrap();
            let k = l.register_file("f.dat", 200).unwrap();
            l.log_blocks(k, &[7u32, 3, 199, 0, 42]).unwrap();
            l.log_blocks(k, &[100u32, 101]).unwrap();
            l.log_blocks(k, &[]).unwrap();
            let s = l.space();
            // One physical write per non-empty batch, one logical append
            // per block.
            assert_eq!(s.write_ops, 2, "method {method:?}");
            assert_eq!(s.appends, 7, "method {method:?}");
            let recovered = recover::recover_all(&c).unwrap();
            let set = &recovered["f.dat"];
            let mut expect = CompletedSet::new(200);
            for b in [7, 3, 199, 0, 42, 100, 101] {
                expect.insert(b);
            }
            assert_eq!(set, &expect, "method {method:?}");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn log_blocks_out_of_range_rejected_before_writing() {
        let dir = tmp_dir("grp-oor");
        let c = cfg(&dir, Method::Int);
        let mut l = FileLogger::new(&c).unwrap();
        let k = l.register_file("f", 10).unwrap();
        assert!(l.log_blocks(k, &[1, 99]).is_err());
        // Nothing was created: validation runs before the lazy open.
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bitmap_is_fixed_size() {
        let dir = tmp_dir("bmsize");
        let c = cfg(&dir, Method::Bit8);
        let mut l = FileLogger::new(&c).unwrap();
        let k = l.register_file("f", 80).unwrap();
        l.log_block(k, 0).unwrap();
        let path = log_path(&dir, Method::Bit8, "f");
        let size1 = std::fs::metadata(&path).unwrap().len();
        for b in 1..80 {
            l.log_block(k, b).unwrap();
        }
        assert_eq!(std::fs::metadata(&path).unwrap().len(), size1, "bitmap never grows");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stream_methods_grow_per_record() {
        let dir = tmp_dir("grow");
        let c = cfg(&dir, Method::Char);
        let mut l = FileLogger::new(&c).unwrap();
        let k = l.register_file("f", 1000).unwrap();
        l.log_block(k, 5).unwrap();
        let path = log_path(&dir, Method::Char, "f");
        let s1 = std::fs::metadata(&path).unwrap().len();
        l.log_block(k, 987).unwrap();
        let s2 = std::fs::metadata(&path).unwrap().len();
        assert_eq!(s2 - s1, 4); // "987\n"
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn out_of_range_block_rejected() {
        let dir = tmp_dir("oor");
        let c = cfg(&dir, Method::Int);
        let mut l = FileLogger::new(&c).unwrap();
        let k = l.register_file("f", 10).unwrap();
        assert!(l.log_block(k, 10).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn many_files_independent_logs() {
        let dir = tmp_dir("many");
        let c = cfg(&dir, Method::Bit64);
        let mut l = FileLogger::new(&c).unwrap();
        let keys: Vec<FileKey> = (0..20)
            .map(|i| l.register_file(&format!("f{i}"), 16).unwrap())
            .collect();
        for (i, &k) in keys.iter().enumerate() {
            l.log_block(k, (i % 16) as u32).unwrap();
        }
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 20);
        for &k in &keys[..10] {
            l.complete_file(k).unwrap();
        }
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 10);
        let rec = recover::recover_all(&c).unwrap();
        assert_eq!(rec.len(), 10);
        assert!(rec.contains_key("f15"));
        assert!(!rec.contains_key("f5"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
