//! FT-LADS: Fault-Tolerant Object-Logging based Big Data Transfer System
//! using Layout-Aware Data Scheduling.
//!
//! Reproduction of Kasu et al., IEEE Access 2019 (CS.DC 2018),
//! DOI 10.1109/ACCESS.2019.2905158 — see DESIGN.md for the system
//! inventory and EXPERIMENTS.md for the paper-vs-measured results.
//!
//! Architecture (three layers, python never on the request path):
//!
//! - **L3 (this crate)** — the LADS coordinator (master/comm/IO threads at
//!   source and sink, per-OST work queues, congestion-aware scheduling),
//!   the FT object-logging subsystem (File/Transaction/Universal × six
//!   encodings), fault injection + resume, the bbcp baseline, and all
//!   substrates (PFS simulator, CCI-like transport, metrics, config).
//! - **L2/L1 (python/compile, build time)** — JAX integrity graphs calling
//!   Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//! - **runtime** — loads those artifacts via the PJRT C API (`xla` crate)
//!   and executes them from the sink's verify path and the source's
//!   recovery path.

pub mod baseline;
pub mod bench_support;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod ftlog;
pub mod integrity;
pub mod metrics;
pub mod net;
pub mod pfs;
pub mod runtime;
pub mod sched;
pub mod stats;
pub mod testutil;
pub mod tune;
pub mod util;
pub mod workload;
pub mod cli;
