//! Tiny argv parser (no clap in the offline vendor set).
//!
//! Grammar: `ftlads <subcommand> [--key value | --flag]...`
//! Values may also be attached as `--key=value`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
}

impl Args {
    /// Parse argv (excluding argv[0]). `flag_names` lists options that
    /// take no value.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.entry(k.to_string()).or_default().push(v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    i += 1;
                    let Some(v) = argv.get(i) else {
                        bail!("--{rest} expects a value");
                    };
                    out.opts
                        .entry(rest.to_string())
                        .or_default()
                        .push(v.clone());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                bail!("unexpected positional argument '{a}'");
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key)?.last().map(|s| s.as_str())
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.opts
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_subcommand_opts_flags() {
        let a = Args::parse(
            &argv(&["transfer", "--files", "10", "--resume", "--method=bit8"]),
            &["resume"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("transfer"));
        assert_eq!(a.get("files"), Some("10"));
        assert_eq!(a.get("method"), Some("bit8"));
        assert!(a.flag("resume"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn repeated_opts_collect() {
        let a = Args::parse(&argv(&["x", "--set", "a=1", "--set", "b=2"]), &[]).unwrap();
        assert_eq!(a.get_all("set"), vec!["a=1", "b=2"]);
        assert_eq!(a.get("set"), Some("b=2")); // last wins for single get
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&argv(&["x", "--key"]), &[]).is_err());
    }

    #[test]
    fn get_parse_typed() {
        let a = Args::parse(&argv(&["x", "--n", "7"]), &[]).unwrap();
        assert_eq!(a.get_parse::<u32>("n", 0).unwrap(), 7);
        assert_eq!(a.get_parse::<u32>("missing", 42).unwrap(), 42);
        let b = Args::parse(&argv(&["x", "--n", "zz"]), &[]).unwrap();
        assert!(b.get_parse::<u32>("n", 0).is_err());
    }

    #[test]
    fn double_positional_rejected() {
        assert!(Args::parse(&argv(&["a", "b"]), &[]).is_err());
    }

    #[test]
    fn scheduler_flag_roundtrips_into_config() {
        use crate::config::Config;
        use crate::sched::SchedPolicy;
        // Both flag spellings land in Config the way main.rs wires them.
        let a = Args::parse(
            &argv(&["transfer", "--scheduler", "fifo_file", "--sink-scheduler=rr"]),
            &[],
        )
        .unwrap();
        let mut cfg = Config::default();
        cfg.apply_kv("scheduler", a.get("scheduler").unwrap()).unwrap();
        cfg.apply_kv("sink_scheduler", a.get("sink-scheduler").unwrap())
            .unwrap();
        assert_eq!(cfg.scheduler, SchedPolicy::FifoFile);
        assert_eq!(cfg.sink_sched(), SchedPolicy::RoundRobin);
        assert_eq!(cfg.scheduler.as_str(), "fifo_file");
    }

    #[test]
    fn send_window_and_ack_adaptive_flags_roundtrip_into_config() {
        use crate::config::Config;
        // The way main.rs wires them: --send-window takes a value,
        // --ack-adaptive is a bare flag, and both exist as --set keys.
        let a = Args::parse(
            &argv(&["transfer", "--send-window", "8", "--ack-adaptive", "--ack-batch=16"]),
            &["ack-adaptive"],
        )
        .unwrap();
        let mut cfg = Config::default();
        cfg.send_window = a.get_parse("send-window", 1u32).unwrap();
        cfg.ack_batch = a.get_parse("ack-batch", 1u32).unwrap();
        cfg.ack_adaptive = a.flag("ack-adaptive");
        assert_eq!(cfg.send_window, 8);
        assert!(cfg.ack_adaptive);
        assert!(cfg.validate().is_ok());

        let mut cfg = Config::default();
        cfg.apply_kv("send_window", "32").unwrap();
        cfg.apply_kv("ack_adaptive", "true").unwrap();
        cfg.apply_kv("ack_batch", "8").unwrap();
        assert_eq!(cfg.send_window, 32);
        assert!(cfg.ack_adaptive);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn send_window_adaptive_flag_roundtrips_into_config() {
        use crate::config::Config;
        // The way main.rs wires it: --send-window-adaptive is a bare
        // flag, and the same knob exists as a --set key.
        let a = Args::parse(
            &argv(&["transfer", "--send-window", "8", "--send-window-adaptive"]),
            &["send-window-adaptive"],
        )
        .unwrap();
        let mut cfg = Config::default();
        cfg.send_window = a.get_parse("send-window", 1u32).unwrap();
        cfg.send_window_adaptive = a.flag("send-window-adaptive");
        assert!(cfg.send_window_adaptive);
        assert!(cfg.validate().is_ok());

        let mut cfg = Config::default();
        cfg.apply_kv("send_window_adaptive", "true").unwrap();
        cfg.apply_kv("send_window", "4").unwrap();
        assert!(cfg.send_window_adaptive);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn write_coalesce_and_rma_autosize_flags_roundtrip_into_config() {
        use crate::config::{parse_bytes, Config};
        // The way main.rs wires them: --write-coalesce-bytes takes a byte
        // value (with K/M/G units), --rma-autosize is a bare flag, and
        // both exist as --set keys.
        let a = Args::parse(
            &argv(&["transfer", "--write-coalesce-bytes", "4M", "--rma-autosize"]),
            &["rma-autosize"],
        )
        .unwrap();
        let mut cfg = Config::default();
        cfg.write_coalesce_bytes = parse_bytes(a.get("write-coalesce-bytes").unwrap()).unwrap();
        cfg.rma_autosize = a.flag("rma-autosize");
        assert_eq!(cfg.write_coalesce_bytes, 4 << 20);
        assert!(cfg.rma_autosize);
        assert!(cfg.validate().is_ok());

        let mut cfg = Config::default();
        cfg.apply_kv("write_coalesce_bytes", "16M").unwrap();
        cfg.apply_kv("rma_autosize", "true").unwrap();
        assert_eq!(cfg.write_coalesce_bytes, 16 << 20);
        assert!(cfg.rma_autosize);
        assert!(cfg.validate().is_ok());
        // 0 is the seed-exact off position.
        cfg.apply_kv("write_coalesce_bytes", "0").unwrap();
        assert_eq!(cfg.write_coalesce_bytes, 0);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn data_streams_and_read_gather_flags_roundtrip_into_config() {
        use crate::config::{parse_bytes, Config};
        // The way main.rs wires them: --data-streams takes a count,
        // --read-gather-bytes a byte value; both exist as --set keys.
        let a = Args::parse(
            &argv(&["transfer", "--data-streams", "4", "--read-gather-bytes", "8M"]),
            &[],
        )
        .unwrap();
        let mut cfg = Config::default();
        cfg.data_streams = a.get_parse("data-streams", 1u32).unwrap();
        cfg.read_gather_bytes = parse_bytes(a.get("read-gather-bytes").unwrap()).unwrap();
        assert_eq!(cfg.data_streams, 4);
        assert_eq!(cfg.read_gather_bytes, 8 << 20);
        assert!(cfg.validate().is_ok());

        let mut cfg = Config::default();
        cfg.apply_kv("data_streams", "8").unwrap();
        cfg.apply_kv("read_gather_bytes", "2M").unwrap();
        assert_eq!(cfg.data_streams, 8);
        assert_eq!(cfg.read_gather_bytes, 2 << 20);
        assert!(cfg.validate().is_ok());
        // 1 stream / 0 gather is the seed-exact off position; the stream
        // count is bounded.
        cfg.apply_kv("data_streams", "1").unwrap();
        cfg.apply_kv("read_gather_bytes", "0").unwrap();
        assert!(cfg.validate().is_ok());
        cfg.apply_kv("data_streams", "65").unwrap();
        assert!(cfg.validate().is_err());
        cfg.apply_kv("data_streams", "0").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn tune_flag_roundtrips_into_config_and_supersedes_adaptive() {
        use crate::config::Config;
        // The way main.rs wires them: --tune is a bare flag,
        // --tune-epoch-ms takes a value; both exist as --set keys.
        let a = Args::parse(
            &argv(&["transfer", "--tune", "--tune-epoch-ms", "50"]),
            &["tune"],
        )
        .unwrap();
        let mut cfg = Config::default();
        cfg.tune = a.flag("tune");
        cfg.tune_epoch_ms = a.get_parse("tune-epoch-ms", 100u64).unwrap();
        assert!(cfg.tune);
        assert_eq!(cfg.tune_epoch_ms, 50);
        assert!(cfg.validate().is_ok());

        let mut cfg = Config::default();
        cfg.apply_kv("tune", "true").unwrap();
        cfg.apply_kv("tune_epoch_ms", "25").unwrap();
        assert!(cfg.tune);
        assert_eq!(cfg.tune_epoch_ms, 25);
        assert!(cfg.validate().is_ok());

        // One controller per knob: the unified tuner rejects the
        // per-knob adaptive flags with an actionable message.
        let mut cfg = Config::default();
        cfg.tune = true;
        cfg.ack_adaptive = true;
        cfg.ack_batch = 16;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("supersedes"), "{err}");
        assert!(err.contains("ack-adaptive"), "{err}");
    }

    #[test]
    fn torture_and_hardening_flags_roundtrip_into_config() {
        use crate::config::Config;
        // The way main.rs wires them: every knob takes a value, and each
        // exists as a --set key too.
        let a = Args::parse(
            &argv(&[
                "transfer",
                "--torture-seed",
                "7",
                "--torture-profile=reorder",
                "--connect-timeout-ms",
                "50",
                "--connect-retries",
                "3",
                "--job-deadline-ms",
                "2000",
            ]),
            &[],
        )
        .unwrap();
        let mut cfg = Config::default();
        cfg.torture_seed = a.get_parse("torture-seed", 0u64).unwrap();
        cfg.torture_profile = a.get("torture-profile").unwrap().to_string();
        cfg.connect_timeout_ms = a.get_parse("connect-timeout-ms", 10_000u64).unwrap();
        cfg.connect_retries = a.get_parse("connect-retries", 0u32).unwrap();
        cfg.job_deadline_ms = a.get_parse("job-deadline-ms", 0u64).unwrap();
        assert!(cfg.validate().is_ok());
        let spec = cfg.torture().expect("seed + profile arm the adversary");
        assert_eq!(spec.seed, 7);
        assert_eq!(cfg.connect_retries, 3);
        assert_eq!(cfg.job_deadline_ms, 2000);

        let mut cfg = Config::default();
        cfg.apply_kv("torture_seed", "9").unwrap();
        cfg.apply_kv("torture_profile", "dup").unwrap();
        cfg.apply_kv("connect_retries", "2").unwrap();
        assert!(cfg.validate().is_ok());
        assert!(cfg.torture().is_some());
        // Seed 0 is the hard off switch: no profile ever arms without it.
        let mut cfg = Config::default();
        cfg.apply_kv("torture_profile", "reorder").unwrap();
        assert!(cfg.torture().is_none());
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn recover_and_quota_flags_roundtrip_into_config() {
        use crate::config::{parse_bytes, Config};
        // The way main.rs wires them: --recover is a bare flag,
        // --serve-quota-bytes a byte value; both exist as --set keys.
        let a = Args::parse(
            &argv(&["serve", "--recover", "--serve-quota-bytes", "64M"]),
            &["recover"],
        )
        .unwrap();
        let mut cfg = Config::default();
        cfg.serve_recover = a.flag("recover");
        cfg.serve_quota_bytes = parse_bytes(a.get("serve-quota-bytes").unwrap()).unwrap();
        assert!(cfg.serve_recover);
        assert_eq!(cfg.serve_quota_bytes, 64 << 20);
        assert!(cfg.validate().is_ok());

        let mut cfg = Config::default();
        cfg.apply_kv("serve_recover", "true").unwrap();
        cfg.apply_kv("serve_quota_bytes", "1G").unwrap();
        assert!(cfg.serve_recover);
        assert_eq!(cfg.serve_quota_bytes, 1 << 30);
        assert!(cfg.validate().is_ok());
        // Off / 0 is the seed-exact default position.
        let cfg = Config::default();
        assert!(!cfg.serve_recover);
        assert_eq!(cfg.serve_quota_bytes, 0);
    }

    #[test]
    fn scheduler_typo_error_lists_valid_policies() {
        use crate::sched::SchedPolicy;
        let a = Args::parse(&argv(&["transfer", "--scheduler", "speedy"]), &[]).unwrap();
        let err = SchedPolicy::parse(a.get("scheduler").unwrap())
            .unwrap_err()
            .to_string();
        for name in ["congestion", "round_robin", "fifo_file", "straggler"] {
            assert!(err.contains(name), "error should list '{name}': {err}");
        }
    }
}
