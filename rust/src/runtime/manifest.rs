//! Parser for `artifacts/manifest.json`, written by `python/compile/aot.py`.
//!
//! The manifest pins the static AOT shapes (object words, batch sizes) and
//! maps each entry name to its HLO text file and I/O signature. The rust
//! side validates every execute call against this signature — shape bugs
//! fail loudly here instead of deep inside PJRT.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Tensor signature: dtype (currently always u32) + dims.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSig {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT-compiled computation: file + I/O signature.
#[derive(Debug, Clone)]
pub struct EntrySig {
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// u32 words per object (the AOT `W`). Objects are zero-padded to this.
    pub object_words: usize,
    /// Bytes per object (`4 * object_words`) — must equal the configured MTU.
    pub object_bytes: usize,
    /// Objects per digest/verify batch (the AOT `B`).
    pub digest_batch: usize,
    /// Files per recovery batch (the AOT `F`).
    pub recovery_files: usize,
    /// u32 bitmap words per file in the recovery input (the AOT `WB`).
    pub bitmap_words: usize,
    pub entries: BTreeMap<String, EntrySig>,
    /// Directory the manifest was loaded from (entry files are relative).
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let v = Json::parse(text)?;
        let need_u64 = |key: &str| -> anyhow::Result<u64> {
            v.get(key)
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("manifest: missing/invalid '{key}'"))
        };
        let mut entries = BTreeMap::new();
        let eobj = v
            .get("entries")
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("manifest: missing 'entries'"))?;
        for (name, e) in eobj {
            let file = e
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("entry {name}: missing 'file'"))?;
            let sig_list = |key: &str| -> anyhow::Result<Vec<TensorSig>> {
                e.get(key)
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("entry {name}: missing '{key}'"))?
                    .iter()
                    .map(|t| {
                        let pair =
                            t.as_arr().ok_or_else(|| anyhow::anyhow!("bad tensor sig"))?;
                        let dtype = pair[0]
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("bad dtype"))?
                            .to_string();
                        let dims = pair[1]
                            .as_arr()
                            .ok_or_else(|| anyhow::anyhow!("bad dims"))?
                            .iter()
                            .map(|d| {
                                d.as_u64()
                                    .map(|x| x as usize)
                                    .ok_or_else(|| anyhow::anyhow!("bad dim"))
                            })
                            .collect::<anyhow::Result<Vec<_>>>()?;
                        Ok(TensorSig { dtype, dims })
                    })
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySig {
                    file: dir.join(file),
                    inputs: sig_list("inputs")?,
                    outputs: sig_list("outputs")?,
                },
            );
        }
        Ok(Manifest {
            object_words: need_u64("object_words")? as usize,
            object_bytes: need_u64("object_bytes")? as usize,
            digest_batch: need_u64("digest_batch")? as usize,
            recovery_files: need_u64("recovery_files")? as usize,
            bitmap_words: need_u64("bitmap_words")? as usize,
            entries,
            dir: dir.to_path_buf(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "object_words": 65536, "object_bytes": 262144, "digest_batch": 8,
      "recovery_files": 64, "bitmap_words": 128,
      "entries": {
        "digest": {"file": "digest.hlo.txt",
                   "inputs": [["u32", [8, 65536]]],
                   "outputs": [["u32", [8, 2]]]},
        "recovery": {"file": "recovery.hlo.txt",
                     "inputs": [["u32", [64, 128]], ["u32", [64]]],
                     "outputs": [["u32", [64]], ["u32", [64]]]}
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.object_words, 65536);
        assert_eq!(m.object_bytes, 262144);
        assert_eq!(m.digest_batch, 8);
        let d = &m.entries["digest"];
        assert_eq!(d.file, Path::new("/tmp/a/digest.hlo.txt"));
        assert_eq!(d.inputs[0].dims, vec![8, 65536]);
        assert_eq!(d.inputs[0].element_count(), 8 * 65536);
        let r = &m.entries["recovery"];
        assert_eq!(r.inputs.len(), 2);
        assert_eq!(r.outputs.len(), 2);
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"object_words": 1}"#, Path::new(".")).is_err());
    }
}
