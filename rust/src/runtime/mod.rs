//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them from
//! the rust hot path.
//!
//! Python runs once at build time (`make artifacts`); afterwards this module
//! is self-contained: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `client.compile` → `execute`. HLO *text* is the interchange format —
//! the image's xla_extension 0.5.1 rejects jax≥0.5's serialized protos
//! (64-bit instruction ids); the text parser reassigns ids.
//!
//! One `LoadedGraph` per model variant; executables are compiled once and
//! reused for the life of the process (compile is ~100 ms, execute is the
//! hot path).

pub mod manifest;
pub mod service;

use std::path::Path;

use anyhow::{bail, Context, Result};

pub use manifest::{EntrySig, Manifest, TensorSig};
pub use service::{RuntimeHandle, RuntimeService};

/// A PJRT client plus every artifact from the manifest, compiled.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    graphs: std::collections::BTreeMap<String, LoadedGraph>,
}

/// One compiled computation with its validated I/O signature.
pub struct LoadedGraph {
    exe: xla::PjRtLoadedExecutable,
    pub sig: EntrySig,
    pub name: String,
}

impl Runtime {
    /// Load every entry in `dir/manifest.json` and compile it.
    pub fn load_dir(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut graphs = std::collections::BTreeMap::new();
        for (name, sig) in &manifest.entries {
            let graph = LoadedGraph::compile(&client, name, sig)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            graphs.insert(name.clone(), graph);
        }
        Ok(Runtime { client, manifest, graphs })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn graph(&self, name: &str) -> Result<&LoadedGraph> {
        self.graphs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no artifact named '{name}' in manifest"))
    }

    pub fn graph_names(&self) -> Vec<&str> {
        self.graphs.keys().map(|s| s.as_str()).collect()
    }
}

impl LoadedGraph {
    fn compile(client: &xla::PjRtClient, name: &str, sig: &EntrySig) -> Result<LoadedGraph> {
        let path = sig
            .file
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        Ok(LoadedGraph { exe, sig: sig.clone(), name: name.to_string() })
    }

    /// Execute with u32 tensors. `inputs[i]` must have exactly
    /// `sig.inputs[i].element_count()` elements; shapes come from the
    /// signature. Returns the untupled outputs as flat u32 vectors.
    pub fn execute_u32(&self, inputs: &[&[u32]]) -> Result<Vec<Vec<u32>>> {
        if inputs.len() != self.sig.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                self.name,
                self.sig.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, tsig)) in inputs.iter().zip(&self.sig.inputs).enumerate() {
            if data.len() != tsig.element_count() {
                bail!(
                    "artifact '{}' input {i}: expected {} elements ({:?}), got {}",
                    self.name,
                    tsig.element_count(),
                    tsig.dims,
                    data.len()
                );
            }
            // Single-copy literal creation (vec1 + reshape would copy the
            // buffer twice — §Perf iteration 3).
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            literals.push(
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::U32,
                    &tsig.dims,
                    bytes,
                )
                .context("create input literal")?,
            );
        }
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = result.to_tuple().context("untuple result")?;
        if parts.len() != self.sig.outputs.len() {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.sig.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (part, tsig) in parts.into_iter().zip(&self.sig.outputs) {
            let v: Vec<u32> = part.to_vec().context("read output literal")?;
            if v.len() != tsig.element_count() {
                bail!(
                    "artifact '{}': output has {} elements, manifest says {}",
                    self.name,
                    v.len(),
                    tsig.element_count()
                );
            }
            out.push(v);
        }
        Ok(out)
    }
}

/// Smoke helper used by `ftlads doctor` and tests: is PJRT usable at all?
pub fn pjrt_available() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}
