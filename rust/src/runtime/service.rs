//! Thread-confined PJRT service.
//!
//! The `xla` crate's client/executable handles are `!Send` (`Rc` + raw
//! PJRT pointers), so all PJRT use is confined to one dedicated service
//! thread that owns the [`Runtime`]; the rest of the system talks to it
//! through a cloneable, thread-safe [`RuntimeHandle`]. One compile at
//! startup, then request/response over channels — the request path never
//! touches python OR re-compiles.

use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::manifest::Manifest;
use super::Runtime;

enum Request {
    Execute {
        graph: String,
        inputs: Vec<Vec<u32>>,
        reply: mpsc::Sender<Result<Vec<Vec<u32>>>>,
    },
    Shutdown,
}

/// Cloneable, Send+Sync handle to the PJRT service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Arc<Mutex<mpsc::Sender<Request>>>,
    pub manifest: Arc<Manifest>,
    pub platform: String,
}

/// Owns the service thread; dropping shuts it down.
pub struct RuntimeService {
    handle: RuntimeHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl RuntimeService {
    /// Load `dir/manifest.json`, compile every artifact on the service
    /// thread, and return once compilation succeeded (or failed).
    pub fn start(dir: &Path) -> Result<RuntimeService> {
        let dir = dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(Arc<Manifest>, String)>>();
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let runtime = match Runtime::load_dir(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok((
                            Arc::new(rt.manifest.clone()),
                            rt.platform_name(),
                        )));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Execute { graph, inputs, reply } => {
                            let result = runtime.graph(&graph).and_then(|g| {
                                let refs: Vec<&[u32]> =
                                    inputs.iter().map(|v| v.as_slice()).collect();
                                g.execute_u32(&refs)
                            });
                            let _ = reply.send(result);
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        let (manifest, platform) = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("PJRT service thread died during startup"))??;
        Ok(RuntimeService {
            handle: RuntimeHandle { tx: Arc::new(Mutex::new(tx)), manifest, platform },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        {
            let tx = self.handle.tx.lock().unwrap_or_else(|e| e.into_inner());
            let _ = tx.send(Request::Shutdown);
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl RuntimeHandle {
    /// Execute a compiled artifact; blocks until the service replies.
    pub fn execute_u32(&self, graph: &str, inputs: Vec<Vec<u32>>) -> Result<Vec<Vec<u32>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().unwrap_or_else(|e| e.into_inner());
            tx.send(Request::Execute {
                graph: graph.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("PJRT service thread gone"))?;
        }
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("PJRT service dropped reply"))?
    }
}
