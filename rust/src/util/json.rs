//! Minimal JSON parser + writer.
//!
//! The offline crate set has no serde, but the AOT pipeline hands the rust
//! runtime a `manifest.json` and the metrics subsystem wants to emit JSON
//! reports, so we carry a small, strict RFC-8259-subset implementation:
//! objects, arrays, strings (with escapes), integers/floats, bools, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.src.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    self.pos = end;
                    s.push_str(
                        std::str::from_utf8(&self.src[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{text}'") })
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Serialize a `Json` value (stable key order — `Obj` is a BTreeMap).
pub fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, it) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(it, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_escapes() {
        assert_eq!(
            Json::parse(r#""a\n\t\"\\A""#).unwrap(),
            Json::Str("a\n\t\"\\A".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"object_words": 65536, "entries": {"digest":
            {"file": "digest.hlo.txt", "inputs": [["u32", [8, 65536]]]}}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("object_words").as_u64(), Some(65536));
        let e = v.get("entries").get("digest");
        assert_eq!(e.get("file").as_str(), Some("digest.hlo.txt"));
        assert_eq!(
            e.get("inputs").as_arr().unwrap()[0].as_arr().unwrap()[1]
                .as_arr()
                .unwrap()
                .iter()
                .map(|d| d.as_u64().unwrap())
                .collect::<Vec<_>>(),
            vec![8, 65536]
        );
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\ny"],"b":{"c":true,"d":null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
