//! Small shared utilities: JSON parsing (offline environment has no serde),
//! the refcounted [`bytes::Bytes`] payload buffer, byte formatting, time
//! formatting.

pub mod bytes;
pub mod json;

/// Format a byte count human-readably (`12.3 MiB`).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format a duration in adaptive units.
pub fn fmt_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Integer ceiling division.
pub fn div_ceil(a: u64, b: u64) -> u64 {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(0), "0 B");
        assert_eq!(fmt_bytes(1023), "1023 B");
        assert_eq!(fmt_bytes(1024), "1.0 KiB");
        assert_eq!(fmt_bytes(1536), "1.5 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn fmt_duration_units() {
        use std::time::Duration;
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.0 µs");
    }

    #[test]
    fn div_ceil_cases() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }
}
