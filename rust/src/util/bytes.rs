//! Refcounted, cheaply sliceable byte buffer — the zero-copy payload
//! currency of the data path (the offline crate set has no `bytes`).
//!
//! A [`Bytes`] is a `(Arc<owner>, offset, len)` view into backing
//! storage. Cloning and slicing bump a refcount; no payload bytes move.
//! The backing is any [`BytesOwner`], which lets the RMA pool hand out
//! *poolable* buffers: `RmaSlot::freeze` wraps the slot's buffer in an
//! owner whose `Drop` returns it to the pool, so the buffer is pinned
//! exactly as long as any view of it is alive (slot accounting and
//! payload lifetime are decoupled) and never copied on the way to the
//! wire or the sink's `pwrite`.
//!
//! Mutation is copy-on-write: [`Bytes::to_mut`] hands out `&mut [u8]`
//! directly when the view is unique (the hot path — the sink is the sole
//! holder by the time it writes) and falls back to one counted copy when
//! shared.

use std::ops::{Bound, Deref, RangeBounds};
use std::sync::Arc;

/// Backing storage a [`Bytes`] views into. Implementors own a stable
/// byte region for the lifetime of the `Arc`; pooled buffers use their
/// `Drop` to return storage to the pool once the last view goes away.
pub trait BytesOwner: Send + Sync {
    fn as_slice(&self) -> &[u8];

    /// Mutable access for the copy-on-write path. Owners backed by plain
    /// writable memory return their full region; immutable owners (e.g.
    /// static data) return `None` and force the COW fallback.
    fn as_mut_slice(&mut self) -> Option<&mut [u8]> {
        None
    }
}

impl BytesOwner for Vec<u8> {
    fn as_slice(&self) -> &[u8] {
        self
    }

    fn as_mut_slice(&mut self) -> Option<&mut [u8]> {
        Some(self)
    }
}

impl BytesOwner for &'static [u8] {
    fn as_slice(&self) -> &[u8] {
        self
    }
}

/// A refcounted view into a [`BytesOwner`]. See the module docs.
#[derive(Clone)]
pub struct Bytes {
    owner: Arc<dyn BytesOwner>,
    off: usize,
    len: usize,
}

impl Bytes {
    /// The empty buffer (shared static — no allocation per call after
    /// the first).
    pub fn new() -> Bytes {
        static EMPTY: std::sync::OnceLock<Bytes> = std::sync::OnceLock::new();
        EMPTY.get_or_init(|| Bytes::from_static(&[])).clone()
    }

    /// Take ownership of `v` without copying.
    pub fn from_vec(v: Vec<u8>) -> Bytes {
        let len = v.len();
        Bytes { owner: Arc::new(v), off: 0, len }
    }

    /// View a static region without copying.
    pub fn from_static(s: &'static [u8]) -> Bytes {
        Bytes { owner: Arc::new(s), off: 0, len: s.len() }
    }

    /// Copy `s` into a fresh owned buffer.
    pub fn copy_from_slice(s: &[u8]) -> Bytes {
        Bytes::from_vec(s.to_vec())
    }

    /// View the whole region of an existing owner without copying.
    pub fn from_owner(owner: Arc<dyn BytesOwner>) -> Bytes {
        let len = owner.as_slice().len();
        Bytes { owner, off: 0, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.owner.as_slice()[self.off..self.off + self.len]
    }

    /// A refcounted subview — no bytes move. Panics when `range` falls
    /// outside `0..len` (same contract as slice indexing).
    pub fn slice(&self, range: impl RangeBounds<usize>) -> Bytes {
        let start = match range.start_bound() {
            Bound::Included(&s) => s,
            Bound::Excluded(&s) => s + 1,
            Bound::Unbounded => 0,
        };
        let end = match range.end_bound() {
            Bound::Included(&e) => e + 1,
            Bound::Excluded(&e) => e,
            Bound::Unbounded => self.len,
        };
        assert!(
            start <= end && end <= self.len,
            "slice {start}..{end} out of range for Bytes of {}",
            self.len
        );
        Bytes { owner: self.owner.clone(), off: self.off + start, len: end - start }
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self.as_slice().to_vec()
    }

    /// Mutable access iff this is the only view into a writable owner;
    /// `None` means [`to_mut`](Bytes::to_mut) would have to copy.
    pub fn try_unique_mut(&mut self) -> Option<&mut [u8]> {
        let (off, len) = (self.off, self.len);
        let region = Arc::get_mut(&mut self.owner)?.as_mut_slice()?;
        Some(&mut region[off..off + len])
    }

    /// Copy-on-write mutable access: unique writable views are handed
    /// out in place, shared (or immutable-backed) ones are detached into
    /// a fresh owned copy first.
    pub fn to_mut(&mut self) -> &mut [u8] {
        let in_place = Arc::get_mut(&mut self.owner).is_some_and(|o| o.as_mut_slice().is_some());
        if !in_place {
            let copy = self.as_slice().to_vec();
            self.owner = Arc::new(copy);
            self.off = 0;
        }
        let (off, len) = (self.off, self.len);
        let region = Arc::get_mut(&mut self.owner)
            .expect("unique after copy-on-write")
            .as_mut_slice()
            .expect("vec backing is writable");
        &mut region[off..off + len]
    }
}

impl Default for Bytes {
    fn default() -> Bytes {
        Bytes::new()
    }
}

impl Deref for Bytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Bytes {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Payloads run to megabytes; don't dump them into panic messages.
        const SHOWN: usize = 32;
        if self.len <= SHOWN {
            write!(f, "Bytes({:?})", self.as_slice())
        } else {
            write!(f, "Bytes(len={}, {:?}…)", self.len, &self.as_slice()[..SHOWN])
        }
    }
}

impl PartialEq for Bytes {
    fn eq(&self, other: &Bytes) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Bytes {}

impl PartialEq<[u8]> for Bytes {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[u8]> for Bytes {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}

impl PartialEq<Vec<u8>> for Bytes {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Bytes> for Vec<u8> {
    fn eq(&self, other: &Bytes) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl From<Vec<u8>> for Bytes {
    fn from(v: Vec<u8>) -> Bytes {
        Bytes::from_vec(v)
    }
}

impl From<&[u8]> for Bytes {
    fn from(s: &[u8]) -> Bytes {
        Bytes::copy_from_slice(s)
    }
}

impl FromIterator<u8> for Bytes {
    fn from_iter<I: IntoIterator<Item = u8>>(iter: I) -> Bytes {
        Bytes::from_vec(iter.into_iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn construction_and_views() {
        let b = Bytes::from_vec(vec![1, 2, 3, 4, 5]);
        assert_eq!(b.len(), 5);
        assert_eq!(&b[..], &[1, 2, 3, 4, 5]);
        assert_eq!(b, vec![1, 2, 3, 4, 5]);
        assert!(Bytes::new().is_empty());
        assert_eq!(Bytes::from_static(b"abc").as_slice(), b"abc");
        let collected: Bytes = (0u8..4).collect();
        assert_eq!(collected, vec![0, 1, 2, 3]);
    }

    #[test]
    fn slice_is_zero_copy() {
        let b = Bytes::from_vec((0u8..64).collect());
        let s = b.slice(10..20);
        assert_eq!(&s[..], &(10u8..20).collect::<Vec<_>>()[..]);
        // Same backing allocation: the slice's data pointer lands inside
        // the parent's region.
        let parent = b.as_slice().as_ptr() as usize;
        let child = s.as_slice().as_ptr() as usize;
        assert_eq!(child, parent + 10);
        // Nested slices compose offsets.
        let s2 = s.slice(2..4);
        assert_eq!(&s2[..], &[12, 13]);
        // Open-ended ranges.
        assert_eq!(b.slice(..).len(), 64);
        assert_eq!(b.slice(60..).len(), 4);
        assert_eq!(b.slice(..=1).len(), 2);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn slice_out_of_range_panics() {
        let b = Bytes::from_vec(vec![0; 4]);
        let _ = b.slice(2..6);
    }

    #[test]
    fn unique_mut_in_place_shared_copies() {
        let mut b = Bytes::from_vec(vec![1, 2, 3]);
        // Unique: mutation happens in the original allocation.
        let p0 = b.as_slice().as_ptr() as usize;
        b.to_mut()[0] = 9;
        assert_eq!(b.as_slice().as_ptr() as usize, p0);
        assert_eq!(b, vec![9, 2, 3]);

        // Shared: COW detaches, the clone is untouched.
        let clone = b.clone();
        assert!(b.try_unique_mut().is_none());
        b.to_mut()[0] = 7;
        assert_eq!(b, vec![7, 2, 3]);
        assert_eq!(clone, vec![9, 2, 3]);

        // Static backing is immutable: even a unique view must copy.
        let mut s = Bytes::from_static(b"xy");
        assert!(s.try_unique_mut().is_none());
        s.to_mut()[0] = b'z';
        assert_eq!(s, b"zy".to_vec());
    }

    #[test]
    fn slice_mut_stays_inside_view() {
        let mut b = Bytes::from_vec(vec![0u8; 8]).slice(2..6);
        b.to_mut().fill(7);
        assert_eq!(b, vec![7, 7, 7, 7]);
        assert_eq!(b.len(), 4);
    }

    struct DropOwner(Arc<AtomicUsize>);

    impl BytesOwner for DropOwner {
        fn as_slice(&self) -> &[u8] {
            &[1, 2, 3, 4]
        }
    }

    impl Drop for DropOwner {
        fn drop(&mut self) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }

    #[test]
    fn owner_dropped_with_last_view() {
        let drops = Arc::new(AtomicUsize::new(0));
        let b = Bytes::from_owner(Arc::new(DropOwner(drops.clone())));
        let s = b.slice(1..3);
        drop(b);
        assert_eq!(drops.load(Ordering::SeqCst), 0, "slice keeps the owner alive");
        assert_eq!(s, vec![2, 3]);
        drop(s);
        assert_eq!(drops.load(Ordering::SeqCst), 1);
    }
}
