//! `ftlads` — CLI launcher for the FT-LADS reproduction.
//!
//! Subcommands:
//!   transfer   run a transfer on a simulated PFS pair (one process)
//!   bbcp       same workload through the bbcp-model baseline
//!   serve      long-running daemon serving many concurrent transfer jobs
//!   sink       start a sink node listening on TCP (two-process mode)
//!   source     run a source node against a TCP sink
//!   recover    inspect FT logger state left by an interrupted run
//!   doctor     environment check: PJRT client, artifacts, manifest
//!
//! The list above mirrors [`SUBCOMMANDS`] — the one table that drives
//! the dispatcher and the usage text; a unit test keeps this doc in
//! sync with it.
//!
//! Examples:
//!   ftlads transfer --workload big --files 20 --file-size 4M \
//!       --mechanism universal --method bit64 --fault 0.4
//!   ftlads transfer --workload big --files 20 --file-size 4M --resume
//!   ftlads serve --role sink --root /data/sink --jobs 4
//!   ftlads doctor --artifacts artifacts
//!
//! Any `Config` field can be overridden with `--set key=value`.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use ftlads::baseline::bbcp::{run_bbcp, BbcpConfig};
use ftlads::cli::Args;
use ftlads::config::{parse_bytes, Config};
use ftlads::coordinator::{self, SimEnv, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{self, Mechanism, Method};
use ftlads::integrity::IntegrityMode;
use ftlads::net::{tcp, Endpoint, FaultController, Side};
use ftlads::pfs::disk::DiskPfs;
use ftlads::pfs::Pfs;
use ftlads::runtime::RuntimeService;
use ftlads::sched::SchedPolicy;
use ftlads::util::{fmt_bytes, fmt_duration};
use ftlads::workload::{self, Workload};

const FLAGS: [&str; 8] = [
    "resume",
    "verbose",
    "json",
    "ack-adaptive",
    "send-window-adaptive",
    "rma-autosize",
    "tune",
    "recover",
];

/// The subcommand table: name, one-line summary, handler. Single source
/// of truth for the dispatcher in [`run`], the usage text, and (guarded
/// by a unit test) the `//! Subcommands:` listing in the module doc.
const SUBCOMMANDS: [(&str, &str, fn(&Args) -> Result<i32>); 7] = [
    (
        "transfer",
        "run a transfer on a simulated PFS pair (one process)",
        cmd_transfer,
    ),
    (
        "bbcp",
        "same workload through the bbcp-model baseline",
        cmd_bbcp,
    ),
    (
        "serve",
        "long-running daemon serving many concurrent transfer jobs",
        cmd_serve,
    ),
    (
        "sink",
        "start a sink node listening on TCP (two-process mode)",
        cmd_sink,
    ),
    (
        "source",
        "run a source node against a TCP sink",
        cmd_source,
    ),
    (
        "recover",
        "inspect FT logger state left by an interrupted run",
        cmd_recover,
    ),
    (
        "doctor",
        "environment check: PJRT client, artifacts, manifest",
        cmd_doctor,
    ),
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("ftlads: error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(argv: &[String]) -> Result<i32> {
    let args = Args::parse(argv, &FLAGS)?;
    match args.subcommand.as_deref() {
        Some(name) => match SUBCOMMANDS.iter().find(|(n, _, _)| *n == name) {
            Some((_, _, handler)) => handler(&args),
            None => bail!("unknown subcommand '{name}' (run `ftlads` for usage)"),
        },
        None => {
            print_usage();
            Ok(0)
        }
    }
}

fn print_usage() {
    let names: Vec<&str> = SUBCOMMANDS.iter().map(|(n, _, _)| *n).collect();
    println!("ftlads — Fault-Tolerant Layout-Aware Data Scheduler (paper reproduction)");
    println!();
    println!("usage: ftlads <{}> [options]", names.join("|"));
    println!();
    println!("subcommands:");
    for (name, what, _) in SUBCOMMANDS {
        println!("  {name:<10} {what}");
    }
    println!();
    println!(
        "common options:\n\
           --mechanism none|file|transaction|universal   FT logger mechanism\n\
           --method char|int|enc|binary|bit8|bit64       FT logging method\n\
           --integrity off|native|pjrt                   digest verification\n\
           --scheduler congestion|round_robin|fifo_file|straggler\n\
                                                         OST dequeue policy\n\
           --sink-scheduler POLICY                       sink-side override\n\
           --ack-batch N                                 coalesce N BLOCK_SYNCs per\n\
                                                         wire msg / logger write (1 =\n\
                                                         paper's per-object path)\n\
           --ack-flush-us USEC                           partial-batch flush window\n\
           --ack-adaptive                                let the sink float the\n\
                                                         applied batch in 1..=ack_batch\n\
           --send-window N                               un-acked NEW_BLOCKs kept in\n\
                                                         flight per connection (1 =\n\
                                                         lockstep issue-and-wait)\n\
           --send-window-adaptive                        float the applied window in\n\
                                                         1..=send_window from stall/\n\
                                                         credit-wait feedback\n\
           --write-coalesce-bytes BYTES                  gather byte-contiguous sink\n\
                                                         writes into one vectored\n\
                                                         pwrite up to this budget\n\
                                                         (0 = one pwrite per object)\n\
           --read-gather-bytes BYTES                     gather byte-contiguous source\n\
                                                         reads into one preadv up to\n\
                                                         this budget (0 = one pread\n\
                                                         per object)\n\
           --data-streams K                              shard OSTs over K parallel\n\
                                                         data connections, each with\n\
                                                         its own credit window + RMA\n\
                                                         pool (negotiated down to the\n\
                                                         peer's K; 1 = single fused\n\
                                                         connection, the legacy wire)\n\
           --rma-autosize                                grow each RMA pool toward\n\
                                                         send_window x object_size at\n\
                                                         CONNECT\n\
           --tune                                        unified online autotuner: one\n\
                                                         goodput-driven hill-climb\n\
                                                         walks send window, ack batch,\n\
                                                         gather + coalesce budgets and\n\
                                                         the per-stream window split\n\
                                                         mid-transfer (supersedes the\n\
                                                         per-knob *-adaptive flags)\n\
           --tune-epoch-ms MS                            autotuner sampling epoch\n\
                                                         (default 100)\n\
           --role sink|source                            serve: which half this daemon\n\
                                                         runs (default sink)\n\
           --jobs N                                      serve: transfer jobs to run\n\
                                                         (sink: accept N tagged jobs on\n\
                                                         one listener; source: split the\n\
                                                         file set round-robin into N\n\
                                                         tagged jobs). Admission beyond\n\
                                                         --set serve_max_jobs=K queues\n\
                                                         in fair-share order; --set\n\
                                                         serve_registry=off disables the\n\
                                                         cross-job OST registry\n\
           --connect-timeout-ms MS                       handshake wait per attempt\n\
                                                         (exponential backoff per retry;\n\
                                                         default 10000)\n\
           --connect-retries N                           CONNECT/ACK retransmissions\n\
                                                         before faulting (default 0,\n\
                                                         the legacy single wait)\n\
           --job-deadline-ms MS                          serve: fault a job silent past\n\
                                                         this deadline and free its\n\
                                                         admission slot (0 = off)\n\
           --recover                                     serve: durable job manifest +\n\
                                                         crash recovery. Every job state\n\
                                                         change is fsynced under\n\
                                                         <ft_dir>/manifest/; a restarted\n\
                                                         daemon re-admits incomplete\n\
                                                         jobs, which resume from their\n\
                                                         per-job FT logs (sink role\n\
                                                         hands reconnecting clients\n\
                                                         their recovered session)\n\
           --serve-quota-bytes BYTES                     serve: reject a tenant's job\n\
                                                         once its cumulative source\n\
                                                         bytes would exceed this quota\n\
                                                         (0 = unlimited)\n\
           --torture-seed N                              arm the adversarial transport\n\
                                                         with this RNG seed (0 = off,\n\
                                                         byte-identical wire)\n\
           --torture-profile NAME                        off|reorder|dup|lossy-handshake|\n\
                                                         partition|cut-stream — the\n\
                                                         seeded deterministic delay/dup/\n\
                                                         drop/partition/cut policy\n\
           --workload big|small|mixed  --files N  --file-size BYTES\n\
           --fault FRAC [--fault-side source|sink]       inject fault at FRAC\n\
           --resume                                      resume per FT logs\n\
           --config FILE  --set key=value                config overrides\n\
         \n\
         See README.md for the full reference."
    );
}

/// Shared config assembly: defaults < --config file < --set overrides <
/// dedicated flags.
fn build_config(args: &Args) -> Result<Config> {
    let mut cfg = Config::default();
    if let Some(path) = args.get("config") {
        cfg.apply_file(std::path::Path::new(path))?;
    }
    for kv in args.get_all("set") {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--set expects key=value, got '{kv}'"))?;
        cfg.apply_kv(k.trim(), v.trim())?;
    }
    if let Some(m) = args.get("mechanism") {
        cfg.mechanism = Mechanism::parse(m)?;
    }
    if let Some(m) = args.get("method") {
        cfg.method = Method::parse(m)?;
    }
    if let Some(i) = args.get("integrity") {
        cfg.integrity = IntegrityMode::parse(i)?;
    }
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler = SchedPolicy::parse(s)?;
    }
    if let Some(s) = args.get("sink-scheduler") {
        cfg.sink_scheduler = Some(SchedPolicy::parse(s)?);
    }
    if let Some(d) = args.get("ft-dir") {
        cfg.ft_dir = d.into();
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.into();
    }
    if let Some(v) = args.get("io-threads") {
        cfg.io_threads = v.parse().context("--io-threads")?;
    }
    if let Some(v) = args.get("ack-batch") {
        cfg.ack_batch = v.parse().context("--ack-batch")?;
    }
    if let Some(v) = args.get("ack-flush-us") {
        cfg.ack_flush_us = v.parse().context("--ack-flush-us")?;
    }
    if args.flag("ack-adaptive") {
        cfg.ack_adaptive = true;
    }
    if let Some(v) = args.get("send-window") {
        cfg.send_window = v.parse().context("--send-window")?;
    }
    if args.flag("send-window-adaptive") {
        cfg.send_window_adaptive = true;
    }
    if let Some(v) = args.get("write-coalesce-bytes") {
        cfg.write_coalesce_bytes = parse_bytes(v)?;
    }
    if let Some(v) = args.get("read-gather-bytes") {
        cfg.read_gather_bytes = parse_bytes(v)?;
    }
    if let Some(v) = args.get("data-streams") {
        cfg.data_streams = v.parse().context("--data-streams")?;
    }
    if args.flag("rma-autosize") {
        cfg.rma_autosize = true;
    }
    if args.flag("tune") {
        cfg.tune = true;
    }
    if let Some(v) = args.get("tune-epoch-ms") {
        cfg.tune_epoch_ms = v.parse().context("--tune-epoch-ms")?;
    }
    if let Some(v) = args.get("connect-timeout-ms") {
        cfg.connect_timeout_ms = v.parse().context("--connect-timeout-ms")?;
    }
    if let Some(v) = args.get("connect-retries") {
        cfg.connect_retries = v.parse().context("--connect-retries")?;
    }
    if let Some(v) = args.get("job-deadline-ms") {
        cfg.job_deadline_ms = v.parse().context("--job-deadline-ms")?;
    }
    if args.flag("recover") {
        cfg.serve_recover = true;
    }
    if let Some(v) = args.get("serve-quota-bytes") {
        cfg.serve_quota_bytes = parse_bytes(v)?;
    }
    if let Some(v) = args.get("torture-seed") {
        cfg.torture_seed = v.parse().context("--torture-seed")?;
    }
    if let Some(v) = args.get("torture-profile") {
        cfg.torture_profile = v.to_string();
    }
    if let Some(v) = args.get("object-size") {
        cfg.object_size = parse_bytes(v)?;
    }
    if let Some(v) = args.get("time-scale") {
        cfg.time_scale = v.parse().context("--time-scale")?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn build_workload(args: &Args, cfg: &Config) -> Result<Workload> {
    let kind = args.get("workload").unwrap_or("big");
    let files: usize = args.get_parse("files", 16usize)?;
    let default_size = match kind {
        "small" => cfg.object_size,
        _ => 16 * cfg.object_size,
    };
    let file_size = match args.get("file-size") {
        Some(v) => parse_bytes(v)?,
        None => default_size,
    };
    Ok(match kind {
        "big" => workload::big_workload(files, file_size),
        "small" => workload::small_workload(files, file_size),
        "mixed" => workload::mixed_workload(files, file_size, cfg.seed),
        other => bail!("unknown workload '{other}' (big|small|mixed)"),
    })
}

fn build_fault(args: &Args) -> Result<FaultPlan> {
    match args.get("fault") {
        None => Ok(FaultPlan::none()),
        Some(v) => {
            let frac: f64 = v.parse().context("--fault")?;
            let side = match args.get("fault-side").unwrap_or("source") {
                "source" => Side::Source,
                "sink" => Side::Sink,
                other => bail!("--fault-side must be source|sink, got '{other}'"),
            };
            Ok(FaultPlan::at_fraction(frac, side))
        }
    }
}

fn maybe_runtime(
    cfg: &Config,
) -> Result<Option<(RuntimeService, ftlads::runtime::RuntimeHandle)>> {
    if cfg.integrity != IntegrityMode::Pjrt {
        return Ok(None);
    }
    let service = RuntimeService::start(&cfg.artifacts_dir).with_context(|| {
        format!(
            "starting PJRT runtime from {} (run `make artifacts`?)",
            cfg.artifacts_dir.display()
        )
    })?;
    let handle = service.handle();
    Ok(Some((service, handle)))
}

fn print_outcome(label: &str, out: &coordinator::TransferOutcome, json: bool) {
    if json {
        use ftlads::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("label".into(), Json::Str(label.into()));
        m.insert("completed".into(), Json::Bool(out.completed));
        m.insert(
            "fault".into(),
            out.fault.clone().map(Json::Str).unwrap_or(Json::Null),
        );
        m.insert("elapsed_s".into(), Json::Num(out.elapsed.as_secs_f64()));
        m.insert("payload_bytes".into(), Json::Num(out.payload_bytes as f64));
        m.insert(
            "throughput_mbps".into(),
            Json::Num(out.throughput_bytes_per_sec() / 1e6),
        );
        m.insert("objects_sent".into(), Json::Num(out.source.objects_sent as f64));
        m.insert(
            "objects_synced".into(),
            Json::Num(out.source.objects_synced as f64),
        );
        m.insert(
            "objects_skipped_resume".into(),
            Json::Num(out.source.objects_skipped_resume as f64),
        );
        m.insert(
            "failed_verify".into(),
            Json::Num(out.sink.objects_failed_verify as f64),
        );
        m.insert("cpu_percent".into(), Json::Num(out.resources.cpu_percent));
        m.insert(
            "peak_rss_bytes".into(),
            Json::Num(out.resources.peak_rss_bytes as f64),
        );
        m.insert(
            "log_peak_bytes".into(),
            Json::Num(out.log_space.peak_bytes as f64),
        );
        m.insert("ack_messages".into(), Json::Num(out.sink.ack_messages as f64));
        m.insert("log_writes".into(), Json::Num(out.source.log_writes as f64));
        m.insert("send_window".into(), Json::Num(out.send_window as f64));
        m.insert(
            "send_window_effective".into(),
            Json::Num(out.send_window_effective as f64),
        );
        m.insert("send_stalls".into(), Json::Num(out.source.send_stalls as f64));
        m.insert("credit_waits".into(), Json::Num(out.source.credit_waits as f64));
        m.insert(
            "ack_batch_effective".into(),
            Json::Num(out.ack_batch_effective as f64),
        );
        m.insert(
            "payload_copies".into(),
            Json::Num(out.payload_copies() as f64),
        );
        m.insert("bytes_copied".into(), Json::Num(out.bytes_copied() as f64));
        m.insert(
            "write_syscalls".into(),
            Json::Num(out.sink.write_syscalls as f64),
        );
        m.insert(
            "coalesced_runs".into(),
            Json::Num(out.sink.coalesced_runs as f64),
        );
        m.insert(
            "coalesce_bytes_max".into(),
            Json::Num(out.sink.coalesce_bytes_max as f64),
        );
        m.insert(
            "coalesce_continuations".into(),
            Json::Num(out.sink.coalesce_continuations as f64),
        );
        m.insert(
            "read_syscalls".into(),
            Json::Num(out.source.read_syscalls as f64),
        );
        m.insert(
            "gathered_runs".into(),
            Json::Num(out.source.gathered_runs as f64),
        );
        m.insert(
            "gather_bytes_max".into(),
            Json::Num(out.source.gather_bytes_max as f64),
        );
        m.insert("data_streams".into(), Json::Num(out.data_streams as f64));
        m.insert(
            "rma_bytes_effective".into(),
            Json::Num(out.rma_bytes_effective as f64),
        );
        m.insert(
            "rma_stalls_src".into(),
            Json::Num(out.rma_stalls_src.0 as f64),
        );
        m.insert(
            "rma_stalls_snk".into(),
            Json::Num(out.rma_stalls_snk.0 as f64),
        );
        m.insert(
            "sched_picks_source".into(),
            Json::Num(out.source_sched.picks as f64),
        );
        m.insert(
            "sched_avg_pick_ns_source".into(),
            Json::Num(out.source_sched.avg_pick_ns()),
        );
        m.insert(
            "sched_picks_sink".into(),
            Json::Num(out.sink_sched.picks as f64),
        );
        m.insert(
            "sched_avg_pick_ns_sink".into(),
            Json::Num(out.sink_sched.avg_pick_ns()),
        );
        m.insert("tune_epochs".into(), Json::Num(out.tune_epochs as f64));
        m.insert("tune_grows".into(), Json::Num(out.tune_grows as f64));
        m.insert("tune_shrinks".into(), Json::Num(out.tune_shrinks as f64));
        m.insert("tune_reverts".into(), Json::Num(out.tune_reverts as f64));
        m.insert(
            "goodput_final_mbps".into(),
            Json::Num(out.goodput_final / 1e6),
        );
        m.insert(
            "tune_trajectory".into(),
            Json::Arr(
                out.tune_trajectory
                    .iter()
                    .map(|t| Json::Str(t.clone()))
                    .collect(),
            ),
        );
        println!("{}", Json::Obj(m));
        return;
    }
    println!("== {label} ==");
    println!("  completed        : {}", out.completed);
    if let Some(f) = &out.fault {
        println!("  fault            : {f}");
    }
    println!("  elapsed          : {}", fmt_duration(out.elapsed));
    println!(
        "  payload          : {} ({:.1} MB/s)",
        fmt_bytes(out.payload_bytes),
        out.throughput_bytes_per_sec() / 1e6
    );
    println!(
        "  data plane       : {} stream{} (OST-sharded, per-stream window + rma pool)",
        out.data_streams,
        if out.data_streams == 1 { "" } else { "s" }
    );
    println!(
        "  objects          : sent {}  synced {}  skipped(resume) {}  failed-verify {}",
        out.source.objects_sent,
        out.source.objects_synced,
        out.source.objects_skipped_resume,
        out.sink.objects_failed_verify
    );
    println!(
        "  files            : completed {}  skipped(resume) {}",
        out.source.files_completed, out.source.files_skipped_resume
    );
    println!(
        "  cpu              : {:.1}% of one core   rss peak {}",
        out.resources.cpu_percent,
        fmt_bytes(out.resources.peak_rss_bytes)
    );
    println!(
        "  ft log space     : peak {}  written {}  appends {}  writes {}",
        fmt_bytes(out.log_space.peak_bytes),
        fmt_bytes(out.log_space.bytes_written),
        out.log_space.appends,
        out.log_space.write_ops
    );
    println!(
        "  ack path         : {} wire acks  {} logger writes (batched BLOCK_SYNC)",
        out.sink.ack_messages, out.source.log_writes
    );
    println!(
        "  send path        : window {} (eff {}, {}+ {}-)  {} slot stalls  \
         {} credit waits  eff ack batch {} ({}+ {}-)",
        out.send_window,
        out.send_window_effective,
        out.source.send_window_grows,
        out.source.send_window_shrinks,
        out.source.send_stalls,
        out.source.credit_waits,
        out.ack_batch_effective,
        out.sink.ack_batch_grows,
        out.sink.ack_batch_shrinks
    );
    if out.tune_epochs > 0 {
        println!(
            "  autotune         : {} epochs  {}+ {}-  {} reverts  best epoch {:.1} MB/s",
            out.tune_epochs,
            out.tune_grows,
            out.tune_shrinks,
            out.tune_reverts,
            out.goodput_final / 1e6
        );
        // The first few knob moves tell the convergence story; the full
        // trajectory is in the JSON output.
        for step in out.tune_trajectory.iter().take(6) {
            println!("                     {step}");
        }
        if out.tune_trajectory.len() > 6 {
            println!(
                "                     ... {} more steps (--json for all)",
                out.tune_trajectory.len() - 6
            );
        }
    }
    println!(
        "  zero-copy        : {} payload copies ({}) — pread-into-slot only \
         on the clean path",
        out.payload_copies(),
        fmt_bytes(out.bytes_copied())
    );
    println!(
        "  write path       : {} syscalls  {} coalesced runs ({} continued)  \
         max run {}  rma pool {}",
        out.sink.write_syscalls,
        out.sink.coalesced_runs,
        out.sink.coalesce_continuations,
        fmt_bytes(out.sink.coalesce_bytes_max),
        fmt_bytes(out.rma_bytes_effective)
    );
    println!(
        "  read path        : {} syscalls  {} gathered runs  max run {}",
        out.source.read_syscalls,
        out.source.gathered_runs,
        fmt_bytes(out.source.gather_bytes_max)
    );
    println!(
        "  sched (source)   : {} picks ({} fallback)  avg pick {:.0} ns  avg service {:.1} µs",
        out.source_sched.picks,
        out.source_sched.fallback_picks,
        out.source_sched.avg_pick_ns(),
        out.source_sched.avg_service_us()
    );
    println!(
        "  sched (sink)     : {} picks ({} fallback)  avg pick {:.0} ns  avg service {:.1} µs",
        out.sink_sched.picks,
        out.sink_sched.fallback_picks,
        out.sink_sched.avg_pick_ns(),
        out.sink_sched.avg_service_us()
    );
    println!(
        "  rma stalls       : src {} ({} ms waiting)  snk {} ({} ms waiting)",
        out.rma_stalls_src.0,
        out.rma_stalls_src.1 / 1_000_000,
        out.rma_stalls_snk.0,
        out.rma_stalls_snk.1 / 1_000_000
    );
}

fn cmd_transfer(args: &Args) -> Result<i32> {
    let cfg = build_config(args)?;
    let wl = build_workload(args, &cfg)?;
    let fault = build_fault(args)?;
    let runtime = maybe_runtime(&cfg)?;
    println!(
        "workload {}: {} files, {} total, {} objects @ {}",
        wl.name,
        wl.file_count(),
        fmt_bytes(wl.total_bytes()),
        wl.total_objects(cfg.object_size),
        fmt_bytes(cfg.object_size),
    );
    let env = SimEnv::new(cfg, &wl);
    let spec = TransferSpec {
        files: env.files.clone(),
        resume: args.flag("resume"),
        fault,
    };
    let out = env.run_with_runtime(&spec, runtime.as_ref().map(|(_, h)| h.clone()))?;
    print_outcome(
        &format!(
            "FT-LADS transfer [{} / {} / integrity={} / sched={}]",
            env.cfg.mechanism.as_str(),
            env.cfg.method.as_str(),
            env.cfg.integrity.as_str(),
            env.cfg.scheduler.as_str()
        ),
        &out,
        args.flag("json"),
    );
    if out.completed {
        env.verify_sink_complete()
            .context("post-transfer verification")?;
        println!("sink dataset verified: every object present and intact");
    }
    Ok(if out.completed { 0 } else { 2 })
}

fn cmd_bbcp(args: &Args) -> Result<i32> {
    let cfg = build_config(args)?;
    let wl = build_workload(args, &cfg)?;
    let fault = build_fault(args)?;
    let env = SimEnv::new(cfg, &wl);
    let bcfg = BbcpConfig {
        streams: args.get_parse("streams", 2usize)?,
        window_bytes: parse_bytes(args.get("window").unwrap_or("8M"))?,
        block_size: env.cfg.object_size,
        ckpt_dir: env.cfg.ft_dir.join("bbcp"),
    };
    let out = run_bbcp(
        &env.cfg,
        &bcfg,
        env.source.clone(),
        env.sink.clone(),
        &env.files,
        fault,
    )?;
    print_outcome("bbcp baseline", &out, args.flag("json"));
    Ok(if out.completed { 0 } else { 2 })
}

fn cmd_sink(args: &Args) -> Result<i32> {
    let cfg = build_config(args)?;
    let addr = args.get("listen").unwrap_or("127.0.0.1:7070");
    let root = args
        .get("root")
        .ok_or_else(|| anyhow::anyhow!("sink requires --root DIR"))?;
    let pfs: Arc<dyn Pfs> = Arc::new(DiskPfs::new(
        std::path::Path::new(root),
        cfg.layout(),
        cfg.ost_config(),
    )?);
    let runtime = maybe_runtime(&cfg)?;
    println!("sink: listening on {addr}, PFS root {root}");
    let listener = tcp::listen(addr)?;
    // The FIRST connection is always control (the source dials data
    // connections only after the CONNECT handshake negotiated a stream
    // count, so there is no accept-order race).
    let ep = tcp::accept(&listener, cfg.wire(), FaultController::unarmed())?;
    let ep: Arc<dyn Endpoint> = Arc::new(ep);
    let wire = cfg.wire();
    let plane = coordinator::DataPlane::Connector(Box::new(move |k| {
        let mut slots: Vec<Option<Arc<dyn Endpoint>>> = (0..k).map(|_| None).collect();
        for _ in 0..k {
            let dep = tcp::accept(&listener, wire.clone(), FaultController::unarmed())?;
            let dep: Arc<dyn Endpoint> = Arc::new(dep);
            // Each data connection introduces itself with STREAM_HELLO;
            // consume it here to place the connection at its stream
            // index (TCP accept order is not dial order).
            let hello = dep
                .recv_timeout(std::time::Duration::from_secs(30))
                .map_err(|e| anyhow::anyhow!("waiting for STREAM_HELLO: {e:?}"))?;
            let ftlads::net::Message::StreamHello { stream_id, .. } = hello else {
                bail!(
                    "expected STREAM_HELLO on data connection, got {}",
                    hello.type_name()
                );
            };
            let idx = stream_id as usize;
            anyhow::ensure!(
                idx < k as usize,
                "STREAM_HELLO stream {stream_id} out of range (k = {k})"
            );
            anyhow::ensure!(
                slots[idx].is_none(),
                "duplicate STREAM_HELLO for stream {stream_id}"
            );
            slots[idx] = Some(dep);
        }
        Ok(slots
            .into_iter()
            .map(|s| s.expect("k distinct in-range hellos fill every slot"))
            .collect())
    }));
    let node = coordinator::sink::SinkSession::new(&cfg, pfs, ep)
        .data_plane(plane)
        .runtime(runtime.as_ref().map(|(_, h)| h.clone()))
        .spawn()?;
    let report = node.join();
    match report.fault {
        None => {
            println!(
                "sink: transfer complete ({} files)",
                report.counters.files_completed
            );
            Ok(0)
        }
        Some(f) => {
            println!("sink: ended with fault: {f}");
            Ok(2)
        }
    }
}

fn cmd_source(args: &Args) -> Result<i32> {
    let cfg = build_config(args)?;
    let addr = args
        .get("connect")
        .unwrap_or("127.0.0.1:7070")
        .parse()
        .context("--connect address")?;
    let root = args
        .get("root")
        .ok_or_else(|| anyhow::anyhow!("source requires --root DIR"))?;
    let pfs = DiskPfs::new(std::path::Path::new(root), cfg.layout(), cfg.ost_config())?;
    let files = {
        let names = args.get_all("file");
        if names.is_empty() {
            pfs.list()
        } else {
            names.into_iter().map(|s| s.to_string()).collect()
        }
    };
    anyhow::ensure!(!files.is_empty(), "no files to transfer under {root}");
    let ep = tcp::connect(addr, cfg.wire(), FaultController::unarmed())?;
    let ep: Arc<dyn Endpoint> = Arc::new(ep);
    let wire = cfg.wire();
    // Dialed lazily, only when CONNECT negotiates K >= 2; the source
    // introduces each connection with STREAM_HELLO after materializing.
    let plane = coordinator::DataPlane::Connector(Box::new(move |k| {
        let mut eps: Vec<Arc<dyn Endpoint>> = Vec::with_capacity(k as usize);
        for _ in 0..k {
            let dep = tcp::connect(addr, wire.clone(), FaultController::unarmed())?;
            eps.push(Arc::new(dep));
        }
        Ok(eps)
    }));
    let spec = TransferSpec {
        files,
        resume: args.flag("resume"),
        fault: FaultPlan::none(),
    };
    let report = coordinator::source::SourceSession::new(&cfg, Arc::new(pfs), ep)
        .data_plane(plane)
        .run(&spec)?;
    match report.fault {
        None => {
            println!(
                "source: transfer complete ({} files, {} objects synced)",
                report.files_done, report.counters.objects_synced
            );
            Ok(0)
        }
        Some(f) => {
            println!("source: ended with fault: {f} — rerun with --resume");
            Ok(2)
        }
    }
}

/// `ftlads serve` — the multi-transfer service mode. One daemon process
/// runs many concurrent transfer jobs: as the sink role it accepts N
/// tagged jobs over ONE listener (control and data connections
/// demultiplexed by their wire-level job tag); as the source role it
/// splits the file set into N tagged jobs and drives them against a
/// serve sink. Jobs beyond `serve_max_jobs` queue for an admission
/// slot, and all of a daemon's jobs share one cross-job OST congestion
/// registry (disable with `--set serve_registry=off`).
///
/// `--recover` arms the crash-consistent job manifest: job lifecycles
/// are fsynced under `<ft_dir>/manifest/`, a restarted sink daemon
/// hands reconnecting clients their recovered sessions, and a
/// restarted source daemon re-runs its jobs with resume forced.
/// `--serve-quota-bytes` caps each tenant's cumulative source bytes.
fn cmd_serve(args: &Args) -> Result<i32> {
    let cfg = build_config(args)?;
    let jobs: usize = args.get_parse("jobs", 1usize)?;
    anyhow::ensure!(jobs >= 1, "--jobs must be at least 1");
    let root = args
        .get("root")
        .ok_or_else(|| anyhow::anyhow!("serve requires --root DIR"))?;
    let registry = if cfg.serve_registry { "shared" } else { "off" };
    match args.get("role").unwrap_or("sink") {
        "sink" => {
            let addr = args.get("listen").unwrap_or("127.0.0.1:7070");
            let pfs: Arc<dyn Pfs> = Arc::new(DiskPfs::new(
                std::path::Path::new(root),
                cfg.layout(),
                cfg.ost_config(),
            )?);
            let runtime = maybe_runtime(&cfg)?;
            println!(
                "serve(sink): listening on {addr}, {jobs} job(s), \
                 max {} concurrent, OST registry {registry}",
                cfg.serve_max_jobs
            );
            let listener = tcp::listen(addr)?;
            let (results, stats) = coordinator::serve::serve_sink(
                &cfg,
                &listener,
                pfs,
                runtime.as_ref().map(|(_, h)| h.clone()),
                jobs,
            )?;
            let mut code = 0;
            for (job, report) in &results {
                match report {
                    Ok(r) if r.fault.is_none() => println!(
                        "serve(sink): job {job} complete ({} files)",
                        r.counters.files_completed
                    ),
                    Ok(r) => {
                        println!(
                            "serve(sink): job {job} ended with fault: {}",
                            r.fault.as_deref().unwrap_or("?")
                        );
                        code = 2;
                    }
                    Err(e) => {
                        println!("serve(sink): job {job} failed to run: {e:#}");
                        code = 2;
                    }
                }
            }
            println!(
                "serve(sink): {} submitted, {} completed, {} faulted, \
                 peak {} concurrent",
                stats.jobs_submitted,
                stats.jobs_completed,
                stats.jobs_faulted,
                stats.peak_concurrent
            );
            if cfg.serve_recover {
                println!(
                    "serve(sink): manifest {} record(s), {} job(s) recovered",
                    stats.manifest_records, stats.jobs_recovered
                );
            }
            for (tenant, n) in &stats.rejected_by_tenant {
                println!("serve(sink): tenant '{tenant}': {n} job(s) rejected");
            }
            Ok(code)
        }
        "source" => {
            let addr = args
                .get("connect")
                .unwrap_or("127.0.0.1:7070")
                .parse()
                .context("--connect address")?;
            let pfs = DiskPfs::new(std::path::Path::new(root), cfg.layout(), cfg.ost_config())?;
            let files = {
                let names = args.get_all("file");
                if names.is_empty() {
                    pfs.list()
                } else {
                    names.into_iter().map(|s| s.to_string()).collect()
                }
            };
            anyhow::ensure!(!files.is_empty(), "no files to transfer under {root}");
            // Round-robin the file set into `jobs` tagged jobs.
            let mut specs: Vec<TransferSpec> = (0..jobs.min(files.len()))
                .map(|_| TransferSpec {
                    files: Vec::new(),
                    resume: args.flag("resume"),
                    fault: FaultPlan::none(),
                })
                .collect();
            for (i, f) in files.into_iter().enumerate() {
                let slot = i % specs.len();
                specs[slot].files.push(f);
            }
            println!(
                "serve(source): {} job(s) against {addr}, \
                 max {} concurrent, OST registry {registry}",
                specs.len(),
                cfg.serve_max_jobs
            );
            let results =
                coordinator::serve::serve_source(&cfg, addr, Arc::new(pfs), specs)?;
            let mut code = 0;
            for (job, report) in &results {
                match report {
                    Ok(r) if r.fault.is_none() => println!(
                        "serve(source): job {job} complete ({} files, {} objects synced)",
                        r.files_done, r.counters.objects_synced
                    ),
                    Ok(r) => {
                        println!(
                            "serve(source): job {job} ended with fault: {} — \
                             rerun with --resume",
                            r.fault.as_deref().unwrap_or("?")
                        );
                        code = 2;
                    }
                    Err(e) => {
                        println!("serve(source): job {job} failed to run: {e:#}");
                        code = 2;
                    }
                }
            }
            Ok(code)
        }
        other => bail!("--role must be sink|source, got '{other}'"),
    }
}

fn cmd_recover(args: &Args) -> Result<i32> {
    let cfg = build_config(args)?;
    let recovered = ftlog::recover::recover_all(&cfg.ft())?;
    if recovered.is_empty() {
        println!(
            "no recoverable FT state under {} (mechanism {})",
            cfg.ft_dir.display(),
            cfg.mechanism.as_str()
        );
        return Ok(0);
    }
    println!(
        "{} in-flight file(s) under {}:",
        recovered.len(),
        cfg.ft_dir.display()
    );
    for (name, set) in &recovered {
        println!(
            "  {name}: {}/{} objects durable, {} pending",
            set.count(),
            set.total(),
            set.total() - set.count()
        );
    }
    Ok(0)
}

fn cmd_doctor(args: &Args) -> Result<i32> {
    let cfg = build_config(args)?;
    println!("ftlads doctor");
    println!(
        "  config           : ok ({} OSTs, {} IO threads)",
        cfg.ost_count, cfg.io_threads
    );
    match ftlads::runtime::pjrt_available() {
        Ok(p) => println!("  PJRT client      : ok (platform {p})"),
        Err(e) => println!("  PJRT client      : FAILED ({e})"),
    }
    let dir = &cfg.artifacts_dir;
    match ftlads::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!(
                "  artifacts        : ok ({} entries, object {} x batch {})",
                m.entries.len(),
                fmt_bytes(m.object_bytes as u64),
                m.digest_batch
            );
            match RuntimeService::start(dir) {
                Ok(svc) => {
                    let h = svc.handle();
                    let graphs = h.manifest.entries.keys().cloned().collect::<Vec<_>>();
                    println!("  compile          : ok ({})", graphs.join(", "));
                    let b = h.manifest.digest_batch;
                    let w = h.manifest.object_words;
                    let out = h.execute_u32("digest", vec![vec![0u32; b * w]])?;
                    anyhow::ensure!(
                        out[0].iter().all(|&x| x == 0),
                        "zero-batch digest not zero"
                    );
                    println!("  execute          : ok (zero-batch digest verified)");
                }
                Err(e) => println!("  compile          : FAILED ({e})"),
            }
        }
        Err(e) => println!(
            "  artifacts        : missing under {} ({e}) — run `make artifacts`",
            dir.display()
        ),
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::SUBCOMMANDS;

    /// The `//! Subcommands:` listing at the top of this file is prose,
    /// so it cannot be generated from [`SUBCOMMANDS`] — instead this
    /// test pins each table row to a matching doc line.
    #[test]
    fn module_doc_lists_every_subcommand() {
        let src = include_str!("main.rs");
        let doc: Vec<&str> = src.lines().take_while(|l| l.starts_with("//!")).collect();
        for (name, what, _) in SUBCOMMANDS {
            assert!(
                doc.iter().any(|l| {
                    let l = l.trim_start_matches("//!").trim_start();
                    l.starts_with(name) && l.ends_with(what)
                }),
                "module doc is missing the `{name}` line — keep the \
                 `//! Subcommands:` listing in sync with SUBCOMMANDS"
            );
        }
    }
}
