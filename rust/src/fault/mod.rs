//! Fault injection plans.
//!
//! Paper §6: "we have created a simulation environment in which we
//! generate faults after transferring 20 %, 40 %, 60 %, 80 % of total
//! data size … for the purpose of our experiments, we have executed this
//! simulation in the source end."
//!
//! A [`FaultPlan`] describes *when* (fraction or absolute bytes of payload
//! across the wire) and *where* (source or sink attribution) the
//! connection dies; [`FaultPlan::arm`] turns it into the transport-level
//! [`FaultController`] that actually severs the link. PFS write-error
//! injection (the §3.2 corruption case) lives in `pfs::sim`.

use std::sync::Arc;

use crate::net::{FaultController, Side};

/// When a transfer should be killed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultPoint {
    /// Never fault (baseline runs).
    None,
    /// After this fraction of the dataset's payload bytes crossed the wire
    /// (paper uses 0.2 / 0.4 / 0.6 / 0.8).
    Fraction(f64),
    /// After an absolute number of payload bytes.
    Bytes(u64),
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    pub point: FaultPoint,
    /// End the fault is attributed to (paper simulates at the source).
    pub side: Side,
}

impl FaultPlan {
    pub fn none() -> Self {
        FaultPlan { point: FaultPoint::None, side: Side::Source }
    }

    pub fn at_fraction(frac: f64, side: Side) -> Self {
        Self::try_at_fraction(frac, side).expect("fault fraction must be in [0,1]")
    }

    /// Non-panicking [`at_fraction`](FaultPlan::at_fraction): matrix
    /// harnesses composing fault points with other knobs (e.g. torture
    /// profiles) validate generated sweeps instead of crashing them.
    pub fn try_at_fraction(frac: f64, side: Side) -> Option<Self> {
        if (0.0..=1.0).contains(&frac) {
            Some(FaultPlan { point: FaultPoint::Fraction(frac), side })
        } else {
            None
        }
    }

    pub fn at_bytes(bytes: u64, side: Side) -> Self {
        FaultPlan { point: FaultPoint::Bytes(bytes), side }
    }

    /// The paper's four fault points.
    pub fn paper_points() -> [f64; 4] {
        [0.2, 0.4, 0.6, 0.8]
    }

    /// Build the transport hook for a dataset of `total_bytes`.
    pub fn arm(&self, total_bytes: u64) -> Arc<FaultController> {
        match self.point {
            FaultPoint::None => FaultController::unarmed(),
            FaultPoint::Fraction(f) => {
                let thresh = (total_bytes as f64 * f).round() as u64;
                FaultController::armed(thresh.max(1), self.side)
            }
            FaultPoint::Bytes(b) => FaultController::armed(b.max(1), self.side),
        }
    }

    pub fn is_none(&self) -> bool {
        matches!(self.point, FaultPoint::None)
    }

    pub fn label(&self) -> String {
        match self.point {
            FaultPoint::None => "no-fault".to_string(),
            FaultPoint::Fraction(f) => format!("{}%@{}", (f * 100.0).round() as u32, self.side),
            FaultPoint::Bytes(b) => format!("{}B@{}", b, self.side),
        }
    }

    /// The plan's label composed with a torture-profile tag:
    /// `"60%@source+reorder"`. `None` or `"off"` yields the bare label,
    /// so fault-matrix rows without an adversary keep their names.
    pub fn label_with(&self, torture: Option<&str>) -> String {
        match torture {
            Some(p) if !p.is_empty() && p != "off" => format!("{}+{p}", self.label()),
            _ => self.label(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_never_trips() {
        let c = FaultPlan::none().arm(1_000_000);
        assert!(!c.account(u64::MAX / 2));
        assert!(!c.is_tripped());
    }

    #[test]
    fn fraction_plan_threshold() {
        let c = FaultPlan::at_fraction(0.4, Side::Source).arm(1000);
        assert!(!c.account(399));
        assert!(c.account(1)); // 400 == threshold
        assert!(c.is_tripped());
    }

    #[test]
    fn bytes_plan_threshold() {
        let c = FaultPlan::at_bytes(512, Side::Sink).arm(0);
        assert!(!c.account(511));
        assert!(c.account(1));
        assert_eq!(c.side, Side::Sink);
    }

    #[test]
    fn zero_fraction_trips_immediately() {
        let c = FaultPlan::at_fraction(0.0, Side::Source).arm(1000);
        assert!(c.account(1), "threshold clamps to 1 byte");
    }

    #[test]
    #[should_panic]
    fn fraction_out_of_range_rejected() {
        FaultPlan::at_fraction(1.5, Side::Source);
    }

    #[test]
    fn labels() {
        assert_eq!(FaultPlan::none().label(), "no-fault");
        assert_eq!(
            FaultPlan::at_fraction(0.6, Side::Source).label(),
            "60%@source"
        );
        assert_eq!(FaultPlan::at_bytes(7, Side::Sink).label(), "7B@sink");
        assert_eq!(FaultPlan::paper_points(), [0.2, 0.4, 0.6, 0.8]);
    }

    #[test]
    fn composed_labels() {
        let p = FaultPlan::at_fraction(0.6, Side::Source);
        assert_eq!(p.label_with(Some("reorder")), "60%@source+reorder");
        assert_eq!(p.label_with(Some("off")), "60%@source");
        assert_eq!(p.label_with(Some("")), "60%@source");
        assert_eq!(p.label_with(None), "60%@source");
        assert_eq!(FaultPlan::none().label_with(Some("dup")), "no-fault+dup");
    }

    #[test]
    fn try_at_fraction_rejects_out_of_range() {
        assert!(FaultPlan::try_at_fraction(1.5, Side::Source).is_none());
        assert!(FaultPlan::try_at_fraction(-0.1, Side::Sink).is_none());
        assert_eq!(
            FaultPlan::try_at_fraction(0.4, Side::Sink),
            Some(FaultPlan::at_fraction(0.4, Side::Sink))
        );
    }
}
