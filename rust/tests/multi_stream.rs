//! Multi-stream data plane (`data_streams = K`): the K = 1 default is
//! byte-identical to the fused single-connection wire (the acceptance
//! pin), CONNECT negotiates min(ours, theirs) with a legacy field-less
//! fallback to 1, every stream's un-acked NEW_BLOCKs stay within the
//! per-stream credit window, and FILE_CLOSE only leaves the source after
//! every stream's acknowledgements for that file are in (the close
//! barrier).

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use ftlads::config::Config;
use ftlads::coordinator::sink::{SinkReport, SinkSession};
use ftlads::coordinator::source::{SourceReport, SourceSession};
use ftlads::coordinator::{DataPlane, SimEnv, TransferSpec};
use ftlads::net::{channel, Endpoint, FaultController, Message, NetError};
use ftlads::workload;

/// Wire-level event, recorded by every tap into ONE shared log so the
/// cross-stream ordering (acks before FILE_CLOSE) is observable.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Event {
    /// NEW_BLOCK sent on `stream`.
    NewBlock { stream: usize, file_idx: u32 },
    /// `n` acknowledgements for `file_idx` received on `stream`.
    Ack { stream: usize, file_idx: u32, n: usize },
    /// FILE_CLOSE sent (control stream).
    FileClose { file_idx: u32 },
}

const CONTROL: usize = usize::MAX;

/// Endpoint wrapper for the SOURCE side of one connection: records the
/// encoded bytes of every send, the per-connection NEW_BLOCK in-flight
/// high-water mark, and the shared event log.
struct Tap {
    inner: channel::ChannelEndpoint,
    stream: usize,
    events: Arc<Mutex<Vec<Event>>>,
    sent: Arc<Mutex<Vec<Vec<u8>>>>,
    inflight: AtomicI64,
    max_inflight: Arc<AtomicI64>,
}

impl Tap {
    fn new(
        inner: channel::ChannelEndpoint,
        stream: usize,
        events: Arc<Mutex<Vec<Event>>>,
    ) -> (Tap, Arc<Mutex<Vec<Vec<u8>>>>, Arc<AtomicI64>) {
        let sent = Arc::new(Mutex::new(Vec::new()));
        let max_inflight = Arc::new(AtomicI64::new(0));
        let tap = Tap {
            inner,
            stream,
            events,
            sent: sent.clone(),
            inflight: AtomicI64::new(0),
            max_inflight: max_inflight.clone(),
        };
        (tap, sent, max_inflight)
    }

    fn log(&self, ev: Event) {
        self.events.lock().unwrap_or_else(|e| e.into_inner()).push(ev);
    }

    fn track(&self, delta: i64) {
        let now = self.inflight.fetch_add(delta, Ordering::SeqCst) + delta;
        self.max_inflight.fetch_max(now, Ordering::SeqCst);
    }

    fn on_recv(&self, msg: &Message) {
        match msg {
            Message::BlockSync { file_idx, .. } => {
                self.track(-1);
                self.log(Event::Ack { stream: self.stream, file_idx: *file_idx, n: 1 });
            }
            Message::BlockSyncBatch { file_idx, blocks } => {
                self.track(-(blocks.len() as i64));
                self.log(Event::Ack {
                    stream: self.stream,
                    file_idx: *file_idx,
                    n: blocks.len(),
                });
            }
            _ => {}
        }
    }
}

impl Endpoint for Tap {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        let mut bytes = Vec::new();
        msg.encode(&mut bytes);
        self.sent.lock().unwrap_or_else(|e| e.into_inner()).push(bytes);
        match &msg {
            Message::NewBlock { file_idx, .. } => {
                self.track(1);
                self.log(Event::NewBlock { stream: self.stream, file_idx: *file_idx });
            }
            Message::FileClose { file_idx } => {
                self.log(Event::FileClose { file_idx: *file_idx });
            }
            _ => {}
        }
        self.inner.send(msg)
    }

    fn recv(&self) -> Result<Message, NetError> {
        let msg = self.inner.recv()?;
        self.on_recv(&msg);
        Ok(msg)
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        let msg = self.inner.recv_timeout(timeout)?;
        self.on_recv(&msg);
        Ok(msg)
    }

    fn payload_sent(&self) -> u64 {
        self.inner.payload_sent()
    }
}

struct MultiRun {
    src: SourceReport,
    snk: SinkReport,
    events: Vec<Event>,
    /// Per-data-stream NEW_BLOCK in-flight high-water marks, index = id.
    max_inflight: Vec<i64>,
    /// Encoded bytes of every control-connection source send.
    ctrl_sent: Vec<Vec<u8>>,
}

/// Wire a K-stream source/sink pair over in-process channels, tapping
/// every source-side endpoint, and run one fresh transfer.
fn run_multi(cfg: &Config, env: &SimEnv) -> MultiRun {
    let k = cfg.data_streams.max(1) as usize;
    let events = Arc::new(Mutex::new(Vec::new()));

    let (src_ctrl, snk_ctrl) = channel::pair(cfg.wire(), FaultController::unarmed());
    let (ctrl_tap, ctrl_sent, _) = Tap::new(src_ctrl, CONTROL, events.clone());

    let mut src_data: Vec<Arc<dyn Endpoint>> = Vec::new();
    let mut snk_data: Vec<Arc<dyn Endpoint>> = Vec::new();
    let mut highs = Vec::new();
    for s in 0..k {
        let (src_ep, snk_ep) = channel::pair(cfg.wire(), FaultController::unarmed());
        let (tap, _, max_inflight) = Tap::new(src_ep, s, events.clone());
        src_data.push(Arc::new(tap));
        snk_data.push(Arc::new(snk_ep));
        highs.push(max_inflight);
    }

    let node = SinkSession::new(cfg, env.sink.clone(), Arc::new(snk_ctrl))
        .data_plane(DataPlane::Ready(snk_data))
        .spawn()
        .unwrap();
    let src = SourceSession::new(cfg, env.source.clone(), Arc::new(ctrl_tap))
        .data_plane(DataPlane::Ready(src_data))
        .run(&TransferSpec::fresh(env.files.clone()))
        .unwrap();
    let snk = node.join();
    MultiRun {
        src,
        snk,
        events: events.lock().unwrap_or_else(|e| e.into_inner()).clone(),
        max_inflight: highs.iter().map(|h| h.load(Ordering::SeqCst)).collect(),
        ctrl_sent: ctrl_sent.lock().unwrap_or_else(|e| e.into_inner()).clone(),
    }
}

/// Sorted copy — IO threads race, so cross-run comparison is by multiset.
fn sorted(trace: &[Vec<u8>]) -> Vec<Vec<u8>> {
    let mut t = trace.to_vec();
    t.sort();
    t
}

#[test]
#[allow(deprecated)] // run A deliberately pins the deprecated wrappers
fn default_single_stream_wire_is_byte_identical_to_fused_path() {
    // The acceptance pin: `data_streams = 1` (the default) puts exactly
    // the pre-multi-stream bytes on the wire — the handshake carries no
    // trailing data_streams field, no STREAM_HELLO frame ever appears,
    // and the whole trace through the multi-capable entry points is the
    // same multiset of encoded messages as the legacy fused entry points
    // produce.
    let cfg = Config::for_tests("mstream-fused-pin");
    assert_eq!(cfg.data_streams, 1, "default must be the fused path");
    let wl = workload::big_workload(4, 512 << 10); // 32 objects
    let env = SimEnv::new(cfg.clone(), &wl);

    // Run A: the legacy fused entry points (run_source / spawn_sink) —
    // now thin deprecated wrappers over the session API, pinned here to
    // stay wire-identical to it.
    let events = Arc::new(Mutex::new(Vec::new()));
    let (src_ep, snk_ep) = channel::pair(cfg.wire(), FaultController::unarmed());
    let (tap_a, sent_a, _) = Tap::new(src_ep, CONTROL, events.clone());
    let node = ftlads::coordinator::sink::spawn_sink(&cfg, env.sink.clone(), Arc::new(snk_ep), None)
        .unwrap();
    let src_a = ftlads::coordinator::source::run_source(
        &cfg,
        env.source.clone(),
        Arc::new(tap_a),
        &TransferSpec::fresh(env.files.clone()),
    )
    .unwrap();
    let snk_a = node.join();
    assert!(src_a.fault.is_none(), "{:?}", src_a.fault);
    assert!(snk_a.fault.is_none(), "{:?}", snk_a.fault);
    assert_eq!(src_a.data_streams, 1);
    env.verify_sink_complete().unwrap();
    let sent_a = sent_a.lock().unwrap_or_else(|e| e.into_inner()).clone();

    // The handshake bytes, hand-built to the fused layout: no trailing
    // send_window or data_streams field on CONNECT (both at their
    // omit-at-default value of 1).
    let mut connect = vec![0u8]; // T_CONNECT
    connect.extend_from_slice(&cfg.object_size.to_le_bytes());
    connect.extend_from_slice(&8u32.to_le_bytes()); // 8 RMA slots in tests
    connect.push(0); // resume = false
    connect.extend_from_slice(&1u32.to_le_bytes()); // ack_batch = 1
    assert_eq!(sent_a[0], connect, "CONNECT grew beyond the fused-path bytes");
    assert!(
        sent_a.iter().all(|f| f.first() != Some(&10u8)),
        "STREAM_HELLO on a single-stream session"
    );

    // Run B: the SAME config through the multi-stream entry points must
    // produce the same wire multiset (IO threads race on ordering).
    let env_b = SimEnv::new(cfg.clone(), &wl);
    let run_b = run_multi(&cfg, &env_b);
    assert!(run_b.src.fault.is_none(), "{:?}", run_b.src.fault);
    assert_eq!(run_b.src.data_streams, 1);
    env_b.verify_sink_complete().unwrap();
    assert_eq!(
        sorted(&sent_a),
        sorted(&run_b.ctrl_sent),
        "multi entry points changed the K = 1 wire bytes"
    );
    assert_eq!(src_a.counters.objects_sent, run_b.src.counters.objects_sent);
    assert_eq!(snk_a.counters.ack_messages, run_b.snk.counters.ack_messages);
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    let _ = std::fs::remove_dir_all(&env_b.cfg.ft_dir);
}

#[test]
fn connect_negotiation_takes_min_streams() {
    // k = min(source ask, sink cap), on BOTH sides; 1 disables the data
    // plane entirely (the fused fallback).
    for (src_k, sink_k, expect) in
        [(4u32, 2u32, 2u32), (2, 4, 2), (8, 1, 1), (1, 8, 1), (3, 3, 3)]
    {
        let mut src_cfg = Config::for_tests(&format!("mstream-neg-{src_k}-{sink_k}"));
        src_cfg.data_streams = src_k;
        src_cfg.send_window = 4;
        let mut sink_cfg = src_cfg.clone();
        sink_cfg.data_streams = sink_k;
        let wl = workload::big_workload(3, 512 << 10); // 24 objects
        let env = SimEnv::new(src_cfg.clone(), &wl);

        // Hand-wire with split configs: give each side as many data
        // connections as the SOURCE asks for; negotiation must use (and
        // materialize) only the first `expect`.
        let (src_ctrl, snk_ctrl) = channel::pair(src_cfg.wire(), FaultController::unarmed());
        let mut src_data: Vec<Arc<dyn Endpoint>> = Vec::new();
        let mut snk_data: Vec<Arc<dyn Endpoint>> = Vec::new();
        for _ in 0..src_k.max(sink_k) {
            let (s, d) = channel::pair(src_cfg.wire(), FaultController::unarmed());
            src_data.push(Arc::new(s));
            snk_data.push(Arc::new(d));
        }
        let node = SinkSession::new(&sink_cfg, env.sink.clone(), Arc::new(snk_ctrl))
            .data_plane(DataPlane::Ready(snk_data))
            .spawn()
            .unwrap();
        let src = SourceSession::new(&src_cfg, env.source.clone(), Arc::new(src_ctrl))
            .data_plane(DataPlane::Ready(src_data))
            .run(&TransferSpec::fresh(env.files.clone()))
            .unwrap();
        let snk = node.join();
        assert!(src.fault.is_none(), "{src_k}/{sink_k}: {:?}", src.fault);
        assert!(snk.fault.is_none(), "{src_k}/{sink_k}: {:?}", snk.fault);
        assert_eq!(
            src.data_streams, expect,
            "source must honor min({src_k}, {sink_k})"
        );
        env.verify_sink_complete().unwrap();
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
}

#[test]
fn legacy_field_less_sink_falls_back_to_fused() {
    // A legacy peer's CONNECT_ACK has no data_streams field, which the
    // codec decodes as 1: a source asking for 8 streams must fall back
    // to the fused single connection — no STREAM_HELLO, no data-plane
    // materialization (the empty Ready plane would fail loudly if the
    // source tried), and a complete verified transfer.
    let mut cfg = Config::for_tests("mstream-legacy");
    cfg.data_streams = 8;
    let wl = workload::big_workload(1, 4 * cfg.object_size); // 4 objects
    let env = SimEnv::new(cfg.clone(), &wl);
    let (src_ep, sink_ep) = channel::pair(cfg.wire(), FaultController::unarmed());
    let events = Arc::new(Mutex::new(Vec::new()));
    let (tap, sent, _) = Tap::new(src_ep, CONTROL, events);

    // Scripted legacy sink: a ConnectAck built with data_streams = 1
    // encodes EXACTLY the legacy field-less bytes (the codec omits the
    // trailing field at its default), then the seed's lockstep protocol.
    let legacy = std::thread::spawn(move || {
        loop {
            match sink_ep.recv_timeout(Duration::from_millis(100)) {
                Ok(Message::Connect { ack_batch, send_window, .. }) => {
                    let _ = sink_ep.send(Message::ConnectAck {
                        rma_slots: 8,
                        ack_batch,
                        send_window,
                        data_streams: 1,
                    });
                }
                Ok(Message::NewFile { file_idx, .. }) => {
                    let _ = sink_ep.send(Message::FileId {
                        file_idx,
                        sink_fd: 0,
                        skip: false,
                    });
                }
                Ok(Message::NewBlock { file_idx, block_idx, .. }) => {
                    let _ = sink_ep.send(Message::BlockSync {
                        file_idx,
                        block_idx,
                        ok: true,
                    });
                }
                Ok(Message::FileClose { file_idx }) => {
                    let _ = sink_ep.send(Message::FileCloseAck { file_idx });
                }
                Ok(Message::Bye) => break,
                Ok(_) => {}
                Err(NetError::Timeout) => continue,
                Err(_) => break,
            }
        }
    });

    let report = SourceSession::new(&cfg, env.source.clone(), Arc::new(tap))
        // Empty plane: materializing ANY stream count would error, so
        // the fallback is proven by the transfer completing at all.
        .data_plane(DataPlane::Ready(Vec::new()))
        .run(&TransferSpec::fresh(env.files.clone()))
        .unwrap();
    legacy.join().unwrap();
    assert!(report.fault.is_none(), "{:?}", report.fault);
    assert_eq!(report.data_streams, 1, "legacy peer must negotiate down to fused");
    assert_eq!(report.counters.objects_synced, 4);
    let sent = sent.lock().unwrap_or_else(|e| e.into_inner());
    assert!(
        sent.iter().all(|f| f.first() != Some(&10u8)),
        "STREAM_HELLO sent to a legacy peer"
    );
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn per_stream_inflight_never_exceeds_window_and_all_streams_carry() {
    // Each data stream owns an independent credit window: no stream may
    // ever have more than `send_window` un-acked NEW_BLOCKs on its wire,
    // and with OSTs sharded by the bytes-weighted LPT plan every stream
    // actually carries payload (the plan spreads an 11-OST layout over 4
    // streams).
    let mut cfg = Config::for_tests("mstream-inflight");
    cfg.data_streams = 4;
    cfg.send_window = 2;
    cfg.io_threads = 4;
    let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
    let env = SimEnv::new(cfg.clone(), &wl);
    let run = run_multi(&cfg, &env);
    assert!(run.src.fault.is_none(), "{:?}", run.src.fault);
    assert!(run.snk.fault.is_none(), "{:?}", run.snk.fault);
    assert_eq!(run.src.data_streams, 4);
    assert_eq!(run.src.counters.objects_synced, 48);
    env.verify_sink_complete().unwrap();
    for (s, &high) in run.max_inflight.iter().enumerate() {
        assert!(
            high <= 2,
            "stream {s}: {high} un-acked NEW_BLOCKs in flight (window 2)"
        );
        assert!(high >= 1, "stream {s} carried no blocks — sharding is broken");
    }
    // Every NEW_BLOCK rode a data stream, never the control connection,
    // and its ack came back on the SAME stream.
    let mut sent_on = std::collections::BTreeMap::<usize, u64>::new();
    let mut acked_on = std::collections::BTreeMap::<usize, u64>::new();
    for ev in &run.events {
        match ev {
            Event::NewBlock { stream, .. } => {
                assert_ne!(*stream, CONTROL, "NEW_BLOCK on the control connection");
                *sent_on.entry(*stream).or_default() += 1;
            }
            Event::Ack { stream, n, .. } => {
                assert_ne!(*stream, CONTROL, "BLOCK_SYNC on the control connection");
                *acked_on.entry(*stream).or_default() += *n as u64;
            }
            Event::FileClose { .. } => {}
        }
    }
    assert_eq!(sent_on, acked_on, "per-stream sends and acks must balance");
    assert_eq!(sent_on.values().sum::<u64>(), 48);
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn file_close_barriers_on_every_streams_acks() {
    // FILE_CLOSE rides the control connection, but a file's blocks are
    // spread over every data stream: the source may only close once ALL
    // of them are acknowledged. In the linearized event log, every
    // FILE_CLOSE must be preceded by exactly as many acks for that file
    // as NEW_BLOCKs were sent for it.
    let mut cfg = Config::for_tests("mstream-close-barrier");
    cfg.data_streams = 3;
    cfg.send_window = 4;
    cfg.ack_batch = 4;
    cfg.ack_flush_us = 500;
    cfg.io_threads = 4;
    let wl = workload::big_workload(4, 8 * cfg.object_size); // 32 objects
    let env = SimEnv::new(cfg.clone(), &wl);
    let run = run_multi(&cfg, &env);
    assert!(run.src.fault.is_none(), "{:?}", run.src.fault);
    assert!(run.snk.fault.is_none(), "{:?}", run.snk.fault);
    env.verify_sink_complete().unwrap();

    let mut sent = std::collections::BTreeMap::<u32, u64>::new();
    let mut acked = std::collections::BTreeMap::<u32, u64>::new();
    let mut closes = 0;
    for ev in &run.events {
        match ev {
            Event::NewBlock { file_idx, .. } => *sent.entry(*file_idx).or_default() += 1,
            Event::Ack { file_idx, n, .. } => {
                *acked.entry(*file_idx).or_default() += *n as u64
            }
            Event::FileClose { file_idx } => {
                closes += 1;
                let s = sent.get(file_idx).copied().unwrap_or(0);
                let a = acked.get(file_idx).copied().unwrap_or(0);
                assert!(s > 0, "file {file_idx} closed before any block was sent");
                assert_eq!(
                    a, s,
                    "file {file_idx} closed with {a}/{s} blocks acknowledged — \
                     the close barrier leaked past an un-acked stream"
                );
            }
        }
    }
    assert_eq!(closes, 4, "every file must close exactly once");
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}
