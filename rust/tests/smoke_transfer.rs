use ftlads::config::Config;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::workload;

#[test]
fn basic_transfer_completes() {
    let cfg = Config::for_tests("smoke1");
    let wl = workload::big_workload(4, 512 << 10); // 4 files x 512KiB, 8 objects each
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "fault: {:?}", out.fault);
    assert_eq!(out.source.objects_synced, 32);
    env.verify_sink_complete().unwrap();
}

#[test]
fn fault_then_resume_completes() {
    use ftlads::fault::FaultPlan;
    use ftlads::net::Side;
    let cfg = Config::for_tests("smoke2");
    let wl = workload::big_workload(6, 512 << 10);
    let env = SimEnv::new(cfg, &wl);
    let out = env
        .run(
            &TransferSpec::fresh(env.files.clone())
                .with_fault(FaultPlan::at_fraction(0.4, Side::Source)),
        )
        .unwrap();
    assert!(!out.completed);
    assert!(out.fault.is_some());
    let sent_before = out.source.objects_sent;
    assert!(sent_before > 0 && sent_before < 48);
    // Resume: must transfer only the remainder.
    let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
    assert!(out2.completed, "resume fault: {:?}", out2.fault);
    let skipped = out2.source.objects_skipped_resume;
    assert!(skipped > 0, "resume should skip logged objects");
    env.verify_sink_complete().unwrap();
}

#[test]
fn corruption_is_detected_and_retransmitted() {
    let cfg = Config::for_tests("smoke3");
    let wl = workload::big_workload(2, 256 << 10);
    let env = SimEnv::new(cfg, &wl);
    env.sink.inject_write_corruption(&env.files[0], 0);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "fault: {:?}", out.fault);
    assert_eq!(out.sink.objects_failed_verify, 1);
    assert_eq!(out.source.objects_failed_verify, 1);
    env.verify_sink_complete().unwrap();
}
