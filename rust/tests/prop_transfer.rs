//! Property-based end-to-end tests: random workloads, random FT
//! configurations, random fault points — after fault + resume the sink
//! dataset is always complete and intact, and the resume always reuses
//! durable progress.

use ftlads::config::Config;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{Mechanism, Method};
use ftlads::net::Side;
use ftlads::sched::SchedPolicy;
use ftlads::testutil::{forall, Pcg32};
use ftlads::pfs::Pfs;
use ftlads::workload::{FileSpec, Workload};
use ftlads::{prop_assert, prop_assert_eq};

fn random_workload(rng: &mut Pcg32, object_size: u64) -> Workload {
    let nfiles = rng.range(1, 10) as usize;
    let files = (0..nfiles)
        .map(|i| FileSpec {
            name: format!("w/f{i}"),
            // 1 byte .. 6 objects, deliberately including non-aligned sizes
            size: rng.range(1, 6 * object_size),
        })
        .collect();
    Workload { name: "prop".into(), files }
}

fn random_config(rng: &mut Pcg32, tag: &str) -> Config {
    let mut cfg = Config::for_tests(tag);
    cfg.mechanism = *rng.choose(&[
        Mechanism::File,
        Mechanism::Transaction,
        Mechanism::Universal,
    ]);
    cfg.method = *rng.choose(&Method::ALL);
    cfg.txn_size = rng.range(1, 5) as usize;
    cfg.io_threads = rng.range(1, 6) as usize;
    cfg.file_window = rng.range(1, 10) as usize;
    cfg.ost_count = rng.range(1, 12) as u32;
    cfg.stripe_count = rng.range(1, cfg.ost_count as u64) as u32;
    // Any dequeue policy must preserve the transfer/resume invariants.
    cfg.scheduler = *rng.choose(&SchedPolicy::ALL);
    cfg.sink_scheduler = Some(*rng.choose(&SchedPolicy::ALL));
    // Small RMA pools exercise back-pressure paths.
    cfg.rma_bytes = (rng.range(2, 16) * cfg.object_size) as usize;
    // The batched-ack pipeline must preserve every invariant at any
    // batch size / flush window, including the seed-exact batch of 1.
    cfg.ack_batch = rng.range(1, 17) as u32;
    cfg.ack_flush_us = rng.range(200, 3000);
    // The zero-copy windowed issue path (and its autotuner) must
    // preserve them at any window too, including lockstep.
    cfg.send_window = rng.range(1, 9) as u32;
    cfg.send_window_adaptive = cfg.send_window > 1 && rng.bool(0.5);
    // Sink write coalescing must preserve every invariant at any gather
    // budget — half the runs stay on the seed-exact 0 path, the rest
    // sweep small-to-huge budgets (a budget below 2 objects can never
    // gather and must behave like 0).
    cfg.write_coalesce_bytes =
        *rng.choose(&[0, 0, cfg.object_size, 2 * cfg.object_size, 64 * cfg.object_size]);
    // Source-side preadv gather: same sweep shape as the write coalescer
    // — half the runs stay on the seed-exact 0 path.
    cfg.read_gather_bytes =
        *rng.choose(&[0, 0, cfg.object_size, 2 * cfg.object_size, 64 * cfg.object_size]);
    // The CONNECT-time pool autosizer must be invariant-preserving too.
    cfg.rma_autosize = rng.bool(0.25);
    // Multi-stream data plane: every invariant must hold at any stream
    // count (half the runs stay on the fused single-connection path).
    cfg.data_streams = if rng.bool(0.5) { 1 } else { rng.range(2, 9) as u32 };
    cfg.seed = rng.next_u64();
    cfg
}

#[test]
fn prop_fault_resume_always_completes_and_verifies() {
    forall("fault_resume_e2e", 25, |rng| {
        let cfg = random_config(rng, "prop-e2e");
        let wl = random_workload(rng, cfg.object_size);
        let frac = 0.1 + rng.f64() * 0.8;
        let env = SimEnv::new(cfg, &wl);

        let out = env
            .run(
                &TransferSpec::fresh(env.files.clone())
                    .with_fault(FaultPlan::at_fraction(frac, Side::Source)),
            )
            .map_err(|e| e.to_string())?;

        if out.completed {
            // Tiny datasets can finish before the fault trips; fine.
            env.verify_sink_complete().map_err(|e| e.to_string())?;
        } else {
            let out2 = env
                .run(&TransferSpec::resuming(env.files.clone()))
                .map_err(|e| e.to_string())?;
            prop_assert!(
                out2.completed,
                "resume failed: {:?} (cfg {:?}/{:?})",
                out2.fault,
                env.cfg.mechanism,
                env.cfg.method
            );
            env.verify_sink_complete().map_err(|e| e.to_string())?;
            // No object transferred twice unless it was unsynced at fault:
            // sent(resume) <= total - skipped.
            let total = wl.total_objects(env.cfg.object_size);
            prop_assert!(
                out2.source.objects_skipped_resume
                    + out2.source.objects_sent
                    - out2.source.objects_failed_verify as u64
                    >= total
                        - out2
                            .source
                            .files_skipped_resume
                            .saturating_mul(u64::MAX.min(0)),
                "accounting hole"
            );
        }
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        Ok(())
    });
}

#[test]
fn prop_no_fault_transfer_objects_accounted_exactly() {
    forall("exact_accounting", 25, |rng| {
        let cfg = random_config(rng, "prop-acct");
        let wl = random_workload(rng, cfg.object_size);
        let total = wl.total_objects(cfg.object_size);
        let bytes = wl.total_bytes();
        let env = SimEnv::new(cfg, &wl);
        let out = env
            .run(&TransferSpec::fresh(env.files.clone()))
            .map_err(|e| e.to_string())?;
        prop_assert!(out.completed, "{:?}", out.fault);
        prop_assert_eq!(out.source.objects_sent, total);
        prop_assert_eq!(out.source.objects_synced, total);
        prop_assert_eq!(out.source.bytes_sent, bytes);
        prop_assert_eq!(out.sink.bytes_written, bytes);
        prop_assert_eq!(out.payload_bytes, bytes);
        prop_assert_eq!(out.source.files_completed as usize, wl.file_count());
        env.verify_sink_complete().map_err(|e| e.to_string())?;
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        Ok(())
    });
}

#[test]
fn prop_double_fault_progress_monotone() {
    forall("double_fault", 12, |rng| {
        let cfg = random_config(rng, "prop-dbl");
        // Ensure enough objects that two faults can land.
        let wl = Workload {
            name: "dbl".into(),
            files: (0..6)
                .map(|i| FileSpec {
                    name: format!("d/f{i}"),
                    size: 6 * cfg.object_size,
                })
                .collect(),
        };
        let env = SimEnv::new(cfg, &wl);
        let f1 = 0.2 + rng.f64() * 0.3;
        let out1 = env
            .run(
                &TransferSpec::fresh(env.files.clone())
                    .with_fault(FaultPlan::at_fraction(f1, Side::Source)),
            )
            .map_err(|e| e.to_string())?;
        if out1.completed {
            return Ok(());
        }
        let f2 = 0.5 + rng.f64() * 0.4;
        let out2 = env
            .run(
                &TransferSpec::resuming(env.files.clone())
                    .with_fault(FaultPlan::at_fraction(f2, Side::Source)),
            )
            .map_err(|e| e.to_string())?;
        let out3 = if out2.completed {
            out2
        } else {
            env.run(&TransferSpec::resuming(env.files.clone()))
                .map_err(|e| e.to_string())?
        };
        prop_assert!(out3.completed, "{:?}", out3.fault);
        env.verify_sink_complete().map_err(|e| e.to_string())?;
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        Ok(())
    });
}

#[test]
fn prop_batched_ack_fault_mid_window_never_resends_acked() {
    // Sync logging invariant under batched acks: everything the source
    // acked (and therefore group-committed) before the fault is skipped
    // on resume; only the un-acked tail of each in-flight flush window is
    // retransmitted, and the verified output matches.
    forall("ack_batch_bound", 15, |rng| {
        let mut cfg = random_config(rng, "prop-ackb");
        cfg.ack_batch = *rng.choose(&[2u32, 4, 8, 16]);
        cfg.ack_flush_us = 500;
        let wl = Workload {
            name: "ackb".into(),
            files: (0..6)
                .map(|i| FileSpec {
                    name: format!("ab/f{i}"),
                    size: 6 * cfg.object_size,
                })
                .collect(),
        };
        let total = wl.total_objects(cfg.object_size);
        let frac = 0.2 + rng.f64() * 0.6;
        let env = SimEnv::new(cfg, &wl);
        let out = env
            .run(
                &TransferSpec::fresh(env.files.clone())
                    .with_fault(FaultPlan::at_fraction(frac, Side::Source)),
            )
            .map_err(|e| e.to_string())?;
        if !out.completed {
            // Every object the source group-committed before the fault
            // must be skipped on resume, never retransmitted.
            let logged: u64 = ftlads::ftlog::recover::recover_all(&env.cfg.ft())
                .map_err(|e| e.to_string())?
                .values()
                .map(|s| s.count() as u64)
                .sum();
            let out2 = env
                .run(&TransferSpec::resuming(env.files.clone()))
                .map_err(|e| e.to_string())?;
            prop_assert!(
                out2.completed,
                "resume failed: {:?} ({:?}/{:?} batch {})",
                out2.fault,
                env.cfg.mechanism,
                env.cfg.method,
                env.cfg.ack_batch
            );
            prop_assert!(
                out2.source.objects_sent <= total - logged,
                "logged objects retransmitted: resent {} with {} logged of {}",
                out2.source.objects_sent,
                logged,
                total
            );
        }
        env.verify_sink_complete().map_err(|e| e.to_string())?;
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        Ok(())
    });
}

#[test]
fn prop_torture_outcome_parity_with_calm_reference() {
    // Randomized adversary specs inside the recoverable envelope (dup +
    // delay + partition — no drops, no cuts): the tortured transfer
    // must complete with the SAME logical outcome as a calm run of the
    // same workload/config — every object synced exactly once, every
    // byte written exactly once, sink byte-verified. Duplicates and
    // reordering may only ever cost wire traffic, never correctness.
    use ftlads::config::TortureSpec;
    use ftlads::coordinator::TransferJob;
    forall("torture_parity", 12, |rng| {
        let mut cfg = Config::for_tests("prop-torture");
        cfg.mechanism = *rng.choose(&[
            Mechanism::File,
            Mechanism::Transaction,
            Mechanism::Universal,
        ]);
        cfg.method = *rng.choose(&Method::ALL);
        cfg.send_window = rng.range(1, 6) as u32;
        cfg.ack_batch = rng.range(1, 5) as u32;
        cfg.ack_flush_us = 500;
        cfg.data_streams = if rng.bool(0.5) { 1 } else { rng.range(2, 5) as u32 };

        let mut spec = TortureSpec::quiet(rng.next_u64() | 1);
        spec.dup_data = rng.f64() * 0.5;
        spec.dup_ack = rng.f64() * 0.5;
        spec.delay_data = rng.f64() * 0.5;
        spec.delay_ack = rng.f64() * 0.5;
        spec.reorder_window = rng.range(1, 8) as u32;
        if rng.bool(0.5) {
            spec.partition_every = rng.range(16, 64);
            spec.partition_len = rng.range(4, 32);
        }
        spec.validate().map_err(|e| e.to_string())?;

        let wl = random_workload(rng, cfg.object_size);
        let total = wl.total_objects(cfg.object_size);
        let bytes = wl.total_bytes();

        let env = SimEnv::new(cfg.clone(), &wl);
        let out = TransferJob::builder(
            &env.cfg,
            &TransferSpec::fresh(env.files.clone()),
        )
        .source_pfs(env.source.clone())
        .sink_pfs(env.sink.clone())
        .torture(spec.clone())
        .run()
        .map_err(|e| e.to_string())?;
        prop_assert!(out.completed, "tortured run faulted: {:?} ({spec:?})", out.fault);
        env.verify_sink_complete().map_err(|e| e.to_string())?;

        let calm_env = SimEnv::new(cfg, &wl);
        let calm = calm_env
            .run(&TransferSpec::fresh(calm_env.files.clone()))
            .map_err(|e| e.to_string())?;
        prop_assert!(calm.completed, "{:?}", calm.fault);
        calm_env.verify_sink_complete().map_err(|e| e.to_string())?;

        for (label, tortured, reference) in [
            ("objects_synced", out.source.objects_synced, calm.source.objects_synced),
            ("bytes_written", out.sink.bytes_written, calm.sink.bytes_written),
            ("write_syscalls", out.sink.write_syscalls, calm.sink.write_syscalls),
            (
                "files_completed",
                out.source.files_completed,
                calm.source.files_completed,
            ),
        ] {
            prop_assert!(
                tortured == reference,
                "{label} diverged under torture: {tortured} vs {reference} ({spec:?})"
            );
        }
        prop_assert_eq!(out.source.objects_synced, total);
        prop_assert_eq!(out.sink.bytes_written, bytes);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        let _ = std::fs::remove_dir_all(&calm_env.cfg.ft_dir);
        Ok(())
    });
}

#[test]
fn prop_message_codec_roundtrips_random() {
    use ftlads::net::Message;
    forall("msg_codec", 300, |rng| {
        let msg = match rng.below(11) {
            0 => Message::Connect {
                max_object_size: rng.next_u64(),
                rma_slots: rng.next_u32(),
                resume: rng.bool(0.5),
                ack_batch: rng.next_u32(),
                send_window: if rng.bool(0.5) { 1 } else { rng.next_u32() },
                data_streams: if rng.bool(0.5) { 1 } else { rng.next_u32() },
                job: if rng.bool(0.5) { 0 } else { rng.next_u64() },
            },
            1 => Message::ConnectAck {
                rma_slots: rng.next_u32(),
                ack_batch: rng.next_u32(),
                send_window: if rng.bool(0.5) { 1 } else { rng.next_u32() },
                data_streams: if rng.bool(0.5) { 1 } else { rng.next_u32() },
            },
            10 => Message::StreamHello {
                stream_id: rng.next_u32(),
                job: if rng.bool(0.5) { 0 } else { rng.next_u64() },
            },
            9 => {
                let len = rng.range(0, 64) as usize;
                let blocks = (0..len)
                    .map(|_| (rng.next_u32(), rng.bool(0.5)))
                    .collect();
                Message::BlockSyncBatch { file_idx: rng.next_u32(), blocks }
            }
            2 => {
                let len = rng.range(0, 40) as usize;
                let name: String = (0..len)
                    .map(|_| char::from(b'a' + rng.below(26) as u8))
                    .collect();
                Message::NewFile {
                    file_idx: rng.next_u32(),
                    name,
                    size: rng.next_u64(),
                    start_ost: rng.next_u32(),
                }
            }
            3 => Message::FileId {
                file_idx: rng.next_u32(),
                sink_fd: rng.next_u64(),
                skip: rng.bool(0.5),
            },
            4 => {
                let len = rng.range(0, 2048) as usize;
                let mut data = vec![0u8; len];
                rng.fill_bytes(&mut data);
                // Half the time carry the payload as a refcounted SLICE
                // of a larger buffer — the wire bytes must depend only on
                // the logical view, never the backing representation.
                let payload = if rng.bool(0.5) {
                    ftlads::util::bytes::Bytes::from_vec(data)
                } else {
                    let pad_front = rng.range(1, 64) as usize;
                    let pad_back = rng.range(1, 64) as usize;
                    let mut backing = vec![0xA5u8; pad_front];
                    backing.extend_from_slice(&data);
                    backing.resize(pad_front + len + pad_back, 0x5A);
                    ftlads::util::bytes::Bytes::from_vec(backing)
                        .slice(pad_front..pad_front + len)
                };
                Message::NewBlock {
                    file_idx: rng.next_u32(),
                    block_idx: rng.next_u32(),
                    offset: rng.next_u64(),
                    digest: rng.next_u64(),
                    data: payload,
                }
            }
            5 => Message::BlockSync {
                file_idx: rng.next_u32(),
                block_idx: rng.next_u32(),
                ok: rng.bool(0.5),
            },
            6 => Message::FileClose { file_idx: rng.next_u32() },
            7 => Message::FileCloseAck { file_idx: rng.next_u32() },
            _ => Message::Bye,
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        let back = Message::decode(&buf).map_err(|e| e.to_string())?;
        prop_assert_eq!(&back, &msg);
        // Zero-copy frame decode agrees byte-for-byte with the copying
        // decode on every message.
        let framed =
            Message::decode_frame(&ftlads::util::bytes::Bytes::from_vec(buf.clone()))
                .map_err(|e| e.to_string())?;
        prop_assert_eq!(&framed, &msg);
        // Payload-bearing frames: the wire layout pin. Header is
        // 1 + 4 + 4 + 8 + 8 = 25 bytes, then the u32 payload length,
        // then the payload verbatim — regardless of how the `Bytes` is
        // backed (owned vec or a slice of a larger buffer).
        if let Message::NewBlock { data, .. } = &msg {
            prop_assert_eq!(buf.len(), 29 + data.len());
            prop_assert_eq!(
                u32::from_le_bytes(buf[25..29].try_into().unwrap()) as usize,
                data.len()
            );
            prop_assert!(&buf[29..] == data.as_slice(), "payload bytes moved");
        }
        // Decoder never panics on arbitrary mutations (truncate or flip).
        if !buf.is_empty() {
            let mut mutated = buf.clone();
            let pos = rng.below(mutated.len() as u32) as usize;
            mutated[pos] ^= 1 << rng.below(8);
            let _ = Message::decode(&mutated); // must not panic
            let cut = rng.below(buf.len() as u32) as usize;
            let _ = Message::decode(&buf[..cut]); // must not panic
        }
        Ok(())
    });
}

#[test]
fn prop_congestion_scheduler_prefers_idle_osts() {
    // With one OST heavily loaded, the aggregate wait time charged to the
    // loaded OST must stay bounded: threads route around it.
    forall("congestion_avoidance", 6, |rng| {
        let mut cfg = Config::for_tests("prop-cong");
        cfg.time_scale = 1.0; // need real service times for this property
        cfg.ost_bandwidth = 4e9;
        cfg.ost_latency_us = 40;
        cfg.mechanism = Mechanism::None;
        let wl = Workload {
            name: "cong".into(),
            files: (0..11)
                .map(|i| FileSpec {
                    name: format!("c/f{i}"),
                    size: 4 * cfg.object_size,
                })
                .collect(),
        };
        let loaded = rng.below(11);
        let env = SimEnv::new(cfg, &wl);
        Pfs::ost_model(&*env.source)
            .set_external_load(ftlads::pfs::ost::OstId(loaded), 10.0);
        let out = env
            .run(&TransferSpec::fresh(env.files.clone()))
            .map_err(|e| e.to_string())?;
        prop_assert!(out.completed);
        // The loaded OST still served its own file (layout pins objects),
        // but wait time on OTHER OSTs should be small: they were not
        // queued behind the slow one.
        let osts = Pfs::ost_model(&*env.source);
        let mut other_wait = 0u64;
        for i in 0..11u32 {
            if i != loaded {
                other_wait += osts.stats(ftlads::pfs::ost::OstId(i)).wait_ns;
            }
        }
        let loaded_service = osts.stats(ftlads::pfs::ost::OstId(loaded)).service_ns;
        // Bound with generous headroom: cargo test co-schedules many test
        // binaries, so idle-OST waits pick up scheduler jitter (two
        // threads racing for the same momentarily-idle OST). The property
        // still catches head-of-line blocking, which would serialize
        // EVERY request behind the 10x OST (hundreds of ms, not tens).
        prop_assert!(
            other_wait < loaded_service.max(1) * 4 + 100_000_000,
            "disproportionate waiting on idle OSTs: {other_wait} vs {loaded_service}"
        );
        env.verify_sink_complete().map_err(|e| e.to_string())?;
        Ok(())
    });
}
