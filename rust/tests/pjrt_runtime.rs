//! Cross-layer integration: the AOT artifacts (Pallas kernels lowered by
//! jax) loaded and executed via PJRT from rust, checked bit-for-bit
//! against the native rust implementation (which is itself checked
//! against ref.py by pytest — closing the three-layer loop).
//!
//! Requires `make artifacts`; tests are skipped (not failed) if the
//! artifacts directory is absent so `cargo test` works standalone.

use std::path::PathBuf;
use std::sync::Arc;

use ftlads::config::Config;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::integrity::{self, Digest, DigestEngine, IntegrityMode, NativeEngine, PjrtEngine};
use ftlads::runtime::RuntimeService;
use ftlads::testutil::Pcg32;
use ftlads::workload;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! need_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn pjrt_digest_matches_native() {
    let dir = need_artifacts!();
    let service = RuntimeService::start(&dir).unwrap();
    let handle = service.handle();
    let words = handle.manifest.object_words;
    let engine = PjrtEngine::new(handle).unwrap();

    let mut rng = Pcg32::new(7);
    // Full object, partial object, tiny object, empty-ish object.
    let sizes = [words * 4, words * 4 - 5, 1024, 4];
    let objects: Vec<Vec<u8>> = sizes
        .iter()
        .map(|&n| {
            let mut v = vec![0u8; n];
            rng.fill_bytes(&mut v);
            v
        })
        .collect();
    let refs: Vec<&[u8]> = objects.iter().map(|v| v.as_slice()).collect();

    let pjrt = engine.digest_batch(&refs, words).unwrap();
    let native = NativeEngine.digest_batch(&refs, words).unwrap();
    assert_eq!(pjrt, native, "PJRT kernel digest != native digest");
    // And non-trivial.
    assert_ne!(pjrt[0], Digest { a: 0, b: 0 });
}

#[test]
fn pjrt_digest_batches_larger_than_b() {
    let dir = need_artifacts!();
    let service = RuntimeService::start(&dir).unwrap();
    let handle = service.handle();
    let words = handle.manifest.object_words;
    let b = handle.manifest.digest_batch;
    let engine = PjrtEngine::new(handle).unwrap();

    let mut rng = Pcg32::new(8);
    let objects: Vec<Vec<u8>> = (0..(2 * b + 3))
        .map(|_| {
            let mut v = vec![0u8; 2048];
            rng.fill_bytes(&mut v);
            v
        })
        .collect();
    let refs: Vec<&[u8]> = objects.iter().map(|v| v.as_slice()).collect();
    let pjrt = engine.digest_batch(&refs, words).unwrap();
    let native = NativeEngine.digest_batch(&refs, words).unwrap();
    assert_eq!(pjrt, native);
    assert_eq!(pjrt.len(), 2 * b + 3);
}

#[test]
fn pjrt_recovery_summary_matches_native_popcount() {
    let dir = need_artifacts!();
    let service = RuntimeService::start(&dir).unwrap();
    let handle = service.handle();
    let wb = handle.manifest.bitmap_words;
    let f = handle.manifest.recovery_files;

    let mut rng = Pcg32::new(9);
    // More files than one artifact batch to exercise chunking.
    let n = f + 5;
    let bitmaps: Vec<Vec<u32>> = (0..n)
        .map(|_| (0..wb).map(|_| rng.next_u32()).collect())
        .collect();
    let totals: Vec<u32> = bitmaps
        .iter()
        .map(|bm| {
            // total >= popcount for half, < popcount (clamping) for half.
            let pop = integrity::popcount_words(bm);
            if rng.bool(0.5) {
                pop + rng.below(100)
            } else {
                pop / 2
            }
        })
        .collect();

    let (completed, pending) =
        integrity::pjrt_recovery_summary(&handle, &bitmaps, &totals).unwrap();
    assert_eq!(completed.len(), n);
    for i in 0..n {
        let pop = integrity::popcount_words(&bitmaps[i]);
        let expect_completed = pop.min(totals[i]);
        assert_eq!(completed[i], expect_completed, "row {i}");
        assert_eq!(pending[i], totals[i] - expect_completed, "row {i}");
    }
}

#[test]
fn transfer_with_pjrt_integrity_end_to_end() {
    let dir = need_artifacts!();
    let service = RuntimeService::start(&dir).unwrap();
    let handle = service.handle();

    let mut cfg = Config::for_tests("pjrt-e2e");
    cfg.integrity = IntegrityMode::Pjrt;
    cfg.object_size = handle.manifest.object_bytes as u64;
    cfg.rma_bytes = 16 * cfg.object_size as usize;

    let wl = workload::big_workload(3, 4 * cfg.object_size); // 12 objects
    let env = SimEnv::new(cfg, &wl);
    let out = env
        .run_with_runtime(&TransferSpec::fresh(env.files.clone()), Some(handle))
        .unwrap();
    assert!(out.completed, "fault: {:?}", out.fault);
    assert_eq!(out.source.objects_synced, 12);
    env.verify_sink_complete().unwrap();
}

#[test]
fn pjrt_detects_corrupted_write_on_hot_path() {
    let dir = need_artifacts!();
    let service = RuntimeService::start(&dir).unwrap();
    let handle = service.handle();

    let mut cfg = Config::for_tests("pjrt-corrupt");
    cfg.integrity = IntegrityMode::Pjrt;
    cfg.object_size = handle.manifest.object_bytes as u64;
    cfg.rma_bytes = 16 * cfg.object_size as usize;

    let wl = workload::big_workload(2, 2 * cfg.object_size);
    let env = SimEnv::new(cfg, &wl);
    env.sink
        .inject_write_corruption(&env.files[1], env.cfg.object_size);
    let out = env
        .run_with_runtime(&TransferSpec::fresh(env.files.clone()), Some(handle))
        .unwrap();
    assert!(out.completed, "fault: {:?}", out.fault);
    assert_eq!(out.sink.objects_failed_verify, 1, "kernel must catch the flip");
    env.verify_sink_complete().unwrap();
}

#[test]
fn recovered_counts_via_pjrt_match_sets() {
    let dir = need_artifacts!();
    let service = RuntimeService::start(&dir).unwrap();
    let handle = service.handle();

    use ftlads::ftlog::{recover, CompletedSet};
    let mut sets = std::collections::BTreeMap::new();
    let mut rng = Pcg32::new(11);
    for i in 0..10 {
        let total = 64 + rng.below(512);
        let mut s = CompletedSet::new(total);
        for _ in 0..rng.below(total) {
            s.insert(rng.below(total));
        }
        sets.insert(format!("f{i}"), s);
    }
    let counts = recover::recovered_counts_pjrt(&handle, &sets).unwrap();
    for (name, set) in &sets {
        let (c, p) = counts[name];
        assert_eq!(c, set.count(), "{name}");
        assert_eq!(p, set.total() - set.count(), "{name}");
    }
    let _ = Arc::new(()); // silence unused Arc import if cfg changes
}
